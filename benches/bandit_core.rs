//! Bandit-core microbench: per-policy steady-state `select()` throughput
//! and — via a counting global allocator — *exact* heap allocations per
//! select/update round. The unified `ArmStats` + `Scratch` core promises
//! zero allocations in steady state for every policy; this bench measures
//! it directly (not through a buffer-growth proxy) and fails the shape
//! check if `ucb` or `swucb` ever allocates.
//!
//! Emits `BENCH_bandit.json` (path override: `LASP_BENCH_OUT`) so the
//! selects/sec trajectory is tracked PR-over-PR; `LASP_BENCH_QUICK=1`
//! runs a short smoke variant for CI.

#[path = "common.rs"]
mod common;

use lasp::bandit::{
    EpsilonGreedy, Policy, SlidingWindowUcb, SubsetTuner, ThompsonSampler, UcbTuner,
};
use lasp::util::json::Json;
use lasp::util::Rng;
use std::collections::BTreeMap;
use std::time::Instant;

#[global_allocator]
static GLOBAL: common::CountingAlloc = common::CountingAlloc;

struct PolicyReport {
    name: &'static str,
    selects_per_s: f64,
    allocs_per_select: f64,
    scratch_growths: u64,
}

/// Drive one policy through warmup + a measured steady-state phase on a
/// deterministic synthetic landscape; count allocations across the whole
/// measured select/update loop.
fn measure(name: &'static str, mut policy: Box<dyn Policy>, rounds: usize) -> PolicyReport {
    let k = policy.k();
    let mut env = Rng::new(0xC0FFEE);
    let mut drive = |p: &mut dyn Policy, n: usize| {
        for _ in 0..n {
            let arm = p.select();
            let time = (1.0 + (arm % 13) as f64 * 0.07) * env.relative_noise(0.03);
            p.update(arm, time, 5.0);
        }
    };
    // Warmup: cover the init sweep and let every reusable buffer (scratch,
    // sliding-window deque) reach its high-water mark.
    drive(policy.as_mut(), 2 * k.min(4096) + 64);
    let growths_before = policy.scratch_growths();

    let allocs_before = common::alloc_count();
    let t0 = Instant::now();
    drive(policy.as_mut(), rounds);
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = common::alloc_count() - allocs_before;

    let report = PolicyReport {
        name,
        selects_per_s: rounds as f64 / elapsed.max(1e-12),
        allocs_per_select: allocs as f64 / rounds as f64,
        scratch_growths: policy.scratch_growths() - growths_before,
    };
    println!(
        "bench bandit_core {name:<10} {rounds} rounds: {:>12.0} selects/s, {:.4} allocs/select ({} scratch growths)",
        report.selects_per_s, report.allocs_per_select, report.scratch_growths
    );
    report
}

fn main() {
    let quick = std::env::var("LASP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let rounds = if quick { 2_000 } else { 50_000 };
    let k = 216; // kripke-sized space
    let window = 512;

    println!("## bandit core — steady-state select/update (K={k})");
    let reports = vec![
        measure("ucb", Box::new(UcbTuner::new(k, 0.8, 0.2)), rounds),
        measure("swucb", Box::new(SlidingWindowUcb::new(k, 0.8, 0.2, window)), rounds),
        measure("thompson", Box::new(ThompsonSampler::new(k, 0.8, 0.2, 7)), rounds),
        measure("epsilon", Box::new(EpsilonGreedy::new(k, 0.8, 0.2, 0.1, 7)), rounds),
        measure(
            "subset",
            Box::new(SubsetTuner::new(92_160, 1024, 0.8, 0.2, 7)),
            rounds,
        ),
    ];

    let mut policies = BTreeMap::new();
    for r in &reports {
        let mut o = BTreeMap::new();
        o.insert("selects_per_s".to_string(), Json::Num(r.selects_per_s));
        o.insert("allocs_per_select".to_string(), Json::Num(r.allocs_per_select));
        o.insert("scratch_growths".to_string(), Json::Num(r.scratch_growths as f64));
        policies.insert(r.name.to_string(), Json::Obj(o));
    }
    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("bandit_core".to_string()));
    out.insert(
        "mode".to_string(),
        Json::Str(if quick { "quick" } else { "full" }.to_string()),
    );
    out.insert("rounds".to_string(), Json::Num(rounds as f64));
    out.insert("k".to_string(), Json::Num(k as f64));
    out.insert("policies".to_string(), Json::Obj(policies));
    let path = std::env::var("LASP_BENCH_OUT").unwrap_or_else(|_| "BENCH_bandit.json".to_string());
    std::fs::write(&path, Json::Obj(out).to_string() + "\n").expect("writing bench json");
    println!("\nwrote {path}");

    // The acceptance criterion: zero allocs/select in steady state for ucb
    // and swucb (the paper policy and its non-stationary variant), and no
    // scratch regrowth anywhere.
    let by_name = |n: &str| reports.iter().find(|r| r.name == n).unwrap();
    common::report_shape(
        "bandit_core",
        by_name("ucb").allocs_per_select == 0.0
            && by_name("swucb").allocs_per_select == 0.0
            && reports.iter().all(|r| r.scratch_growths == 0),
    );
}
