//! Bandit-core microbench: per-policy steady-state `select()` throughput
//! and — via a counting global allocator — *exact* heap allocations per
//! select/update round. The unified `ArmStats` + `Scratch` core promises
//! zero allocations in steady state for every policy; this bench measures
//! it directly (not through a buffer-growth proxy) and fails the shape
//! check if `ucb` or `swucb` ever allocates.
//!
//! Emits `BENCH_bandit.json` (path override: `LASP_BENCH_OUT`) so the
//! selects/sec trajectory is tracked PR-over-PR; `LASP_BENCH_QUICK=1`
//! runs a short smoke variant for CI.

#[path = "common.rs"]
mod common;

use lasp::bandit::{
    select_batch, Choice, EpsilonGreedy, Policy, Scratch, SlidingWindowUcb, SubsetTuner,
    ThompsonSampler, UcbTuner,
};
use lasp::util::json::Json;
use lasp::util::Rng;
use std::collections::BTreeMap;
use std::time::Instant;

#[global_allocator]
static GLOBAL: common::CountingAlloc = common::CountingAlloc;

struct PolicyReport {
    name: &'static str,
    selects_per_s: f64,
    allocs_per_select: f64,
    scratch_growths: u64,
}

/// Drive one policy through warmup + a measured steady-state phase on a
/// deterministic synthetic landscape; count allocations across the whole
/// measured select/update loop.
fn measure(name: &'static str, mut policy: Box<dyn Policy>, rounds: usize) -> PolicyReport {
    let k = policy.k();
    let mut env = Rng::new(0xC0FFEE);
    let mut drive = |p: &mut dyn Policy, n: usize| {
        for _ in 0..n {
            let arm = p.select();
            let time = (1.0 + (arm % 13) as f64 * 0.07) * env.relative_noise(0.03);
            p.update(arm, time, 5.0);
        }
    };
    // Warmup: cover the init sweep and let every reusable buffer (scratch,
    // sliding-window deque) reach its high-water mark.
    drive(policy.as_mut(), 2 * k.min(4096) + 64);
    let growths_before = policy.scratch_growths();

    let allocs_before = common::alloc_count();
    let t0 = Instant::now();
    drive(policy.as_mut(), rounds);
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = common::alloc_count() - allocs_before;

    let report = PolicyReport {
        name,
        selects_per_s: rounds as f64 / elapsed.max(1e-12),
        allocs_per_select: allocs as f64 / rounds as f64,
        scratch_growths: policy.scratch_growths() - growths_before,
    };
    println!(
        "bench bandit_core {name:<10} {rounds} rounds: {:>12.0} selects/s, {:.4} allocs/select ({} scratch growths)",
        report.selects_per_s, report.allocs_per_select, report.scratch_growths
    );
    report
}

/// One batched-selection series over a 64-session UCB fleet: `group`
/// sessions advance per [`select_batch`] call (group 1 is the
/// single-select baseline via [`Policy::select_traced`], matching the
/// serve path's one-session-per-request mode). All batched scoring runs
/// through ONE shared scratch, so the series measures exactly what
/// `/v1/suggest/batch` buys: a single warm buffer kept hot in cache
/// instead of 64 per-session buffers.
fn measure_batched(name: &'static str, group: usize, sweeps: usize) -> PolicyReport {
    const FLEET: usize = 64;
    let k = 216;
    let mut fleet: Vec<UcbTuner> = (0..FLEET).map(|_| UcbTuner::new(k, 0.8, 0.2)).collect();
    let mut refs: Vec<&mut dyn Policy> = fleet.iter_mut().map(|p| p as &mut dyn Policy).collect();
    let mut scratch = Scratch::new();
    let mut choices: Vec<Choice> = Vec::with_capacity(group);
    let mut env = Rng::new(0xC0FFEE);

    let mut sweep = |refs: &mut Vec<&mut dyn Policy>,
                     scratch: &mut Scratch,
                     choices: &mut Vec<Choice>,
                     env: &mut Rng| {
        let mut s = 0usize;
        while s < FLEET {
            let e = (s + group).min(FLEET);
            if group == 1 {
                let arm = refs[s].select_traced().arm;
                let time = (1.0 + (arm % 13) as f64 * 0.07) * env.relative_noise(0.03);
                refs[s].update(arm, time, 5.0);
            } else {
                select_batch(&mut refs[s..e], scratch, choices);
                for j in 0..choices.len() {
                    let arm = choices[j].arm;
                    let time = (1.0 + (arm % 13) as f64 * 0.07) * env.relative_noise(0.03);
                    refs[s + j].update(arm, time, 5.0);
                }
            }
            s = e;
        }
    };

    // Warmup: every session finishes its init sweep (k pulls) and every
    // reusable buffer reaches its high-water mark.
    for _ in 0..(2 * k + 16) {
        sweep(&mut refs, &mut scratch, &mut choices, &mut env);
    }
    let growths_before: u64 = refs.iter().map(|p| p.scratch_growths()).sum();

    let allocs_before = common::alloc_count();
    let t0 = Instant::now();
    for _ in 0..sweeps {
        sweep(&mut refs, &mut scratch, &mut choices, &mut env);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = common::alloc_count() - allocs_before;
    let selects = (sweeps * FLEET) as f64;

    let report = PolicyReport {
        name,
        selects_per_s: selects / elapsed.max(1e-12),
        allocs_per_select: allocs as f64 / selects,
        scratch_growths: refs.iter().map(|p| p.scratch_growths()).sum::<u64>() - growths_before,
    };
    println!(
        "bench bandit_core {name:<10} {} selects ({group}/call): {:>12.0} selects/s, {:.4} allocs/select ({} scratch growths)",
        selects as u64, report.selects_per_s, report.allocs_per_select, report.scratch_growths
    );
    report
}

fn main() {
    let quick = std::env::var("LASP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let rounds = if quick { 2_000 } else { 50_000 };
    let k = 216; // kripke-sized space
    let window = 512;

    println!("## bandit core — steady-state select/update (K={k})");
    let reports = vec![
        measure("ucb", Box::new(UcbTuner::new(k, 0.8, 0.2)), rounds),
        measure("swucb", Box::new(SlidingWindowUcb::new(k, 0.8, 0.2, window)), rounds),
        measure("thompson", Box::new(ThompsonSampler::new(k, 0.8, 0.2, 7)), rounds),
        measure("epsilon", Box::new(EpsilonGreedy::new(k, 0.8, 0.2, 0.1, 7)), rounds),
        measure(
            "subset",
            Box::new(SubsetTuner::new(92_160, 1024, 0.8, 0.2, 7)),
            rounds,
        ),
    ];

    // Batched multi-session selection over a 64-session fleet: the same
    // select/update work routed through `select_batch` with 1, 8, and 64
    // sessions per call. The b64 series must beat the single-select
    // baseline (shared warm scratch vs 64 cold per-session buffers) and
    // every batched select must stay allocation-free.
    println!("\n## bandit core — batched multi-session selection (64-session UCB fleet)");
    let sweeps = (rounds / 64).max(50);
    let batched = vec![
        measure_batched("b1", 1, sweeps),
        measure_batched("b8", 8, sweeps),
        measure_batched("b64", 64, sweeps),
    ];

    let mut policies = BTreeMap::new();
    for r in &reports {
        let mut o = BTreeMap::new();
        o.insert("selects_per_s".to_string(), Json::Num(r.selects_per_s));
        o.insert("allocs_per_select".to_string(), Json::Num(r.allocs_per_select));
        o.insert("scratch_growths".to_string(), Json::Num(r.scratch_growths as f64));
        policies.insert(r.name.to_string(), Json::Obj(o));
    }
    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("bandit_core".to_string()));
    out.insert(
        "mode".to_string(),
        Json::Str(if quick { "quick" } else { "full" }.to_string()),
    );
    out.insert("rounds".to_string(), Json::Num(rounds as f64));
    out.insert("k".to_string(), Json::Num(k as f64));
    out.insert("policies".to_string(), Json::Obj(policies));
    let mut batched_out = BTreeMap::new();
    batched_out.insert("fleet_sessions".to_string(), Json::Num(64.0));
    for r in &batched {
        let mut o = BTreeMap::new();
        o.insert("selects_per_s".to_string(), Json::Num(r.selects_per_s));
        o.insert("allocs_per_select".to_string(), Json::Num(r.allocs_per_select));
        o.insert("scratch_growths".to_string(), Json::Num(r.scratch_growths as f64));
        batched_out.insert(r.name.to_string(), Json::Obj(o));
    }
    out.insert("batched".to_string(), Json::Obj(batched_out));
    let path = std::env::var("LASP_BENCH_OUT").unwrap_or_else(|_| "BENCH_bandit.json".to_string());
    std::fs::write(&path, Json::Obj(out).to_string() + "\n").expect("writing bench json");
    println!("\nwrote {path}");

    // The acceptance criterion: zero allocs/select in steady state for ucb
    // and swucb (the paper policy and its non-stationary variant), and no
    // scratch regrowth anywhere.
    let by_name = |n: &str| reports.iter().find(|r| r.name == n).unwrap();
    let batched_by = |n: &str| batched.iter().find(|r| r.name == n).unwrap();
    common::report_shape(
        "bandit_core",
        by_name("ucb").allocs_per_select == 0.0
            && by_name("swucb").allocs_per_select == 0.0
            && reports.iter().all(|r| r.scratch_growths == 0)
            // Batched selection must pay off and stay allocation-free:
            // 64-per-call throughput above the single-select baseline,
            // zero allocs and zero scratch regrowth in every series.
            && batched_by("b64").selects_per_s > batched_by("b1").selects_per_s
            && batched.iter().all(|r| r.allocs_per_select == 0.0 && r.scratch_growths == 0),
    );
}
