//! Chaos-layer overhead bench: the fault-injection layer must be free
//! when it is not firing. Boots the serve stack three ways — chaos
//! disabled, chaos enabled but idle (every probability 0.0, so only the
//! per-point `roll()` short-circuit runs), and chaos actively injecting —
//! and compares suggest-path latency percentiles across the first two.
//!
//! Emits `BENCH_chaos.json` (path override: `LASP_BENCH_OUT`);
//! `LASP_BENCH_QUICK=1` runs a short smoke variant for CI. Shape-fails if
//! the idle layer visibly taxes the hot path.

#[path = "common.rs"]
mod common;

use lasp::chaos::ChaosConfig;
use lasp::serve::{start, HttpClient, ServeConfig, ServerHandle};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

fn boot(chaos: Option<ChaosConfig>) -> ServerHandle {
    start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 2,
        checkpoint_dir: None,
        chaos,
        ..ServeConfig::default()
    })
    .expect("boot serve")
}

fn suggest_body() -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str("bench".to_string()));
    obj.insert("app".to_string(), Json::Str("clomp".to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    obj.insert("alpha".to_string(), Json::Num(1.0));
    obj.insert("beta".to_string(), Json::Num(0.0));
    Json::Obj(obj)
}

/// Drive `n` sequential suggests, returning (p50_us, p99_us).
fn measure(handle: &ServerHandle, n: usize) -> (f64, f64) {
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).expect("connect");
    let body = suggest_body();
    // Warmup: fault the session + connection in.
    for _ in 0..100 {
        let (status, _) = client.post("/v1/suggest", &body).expect("suggest");
        assert_eq!(status, 200);
    }
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        let (status, _) = client.post("/v1/suggest", &body).expect("suggest");
        assert_eq!(status, 200);
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    (samples[n / 2], samples[(n * 99 / 100).min(n - 1)])
}

fn main() {
    let quick = std::env::var("LASP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let n = if quick { 2_000 } else { 20_000 };

    let disabled = boot(None);
    let (dis_p50, dis_p99) = measure(&disabled, n);
    disabled.shutdown().expect("shutdown");
    println!("chaos disabled:     p50 {dis_p50:.1} µs, p99 {dis_p99:.1} µs over {n} suggests");

    // Enabled but idle: the layer is armed, every probability is 0.0, so
    // each fault point costs exactly one short-circuited branch.
    let idle = boot(Some(ChaosConfig::default()));
    let (idle_p50, idle_p99) = measure(&idle, n);
    let idle_injections = {
        let addr = idle.addr().to_string();
        let mut probe = HttpClient::connect(&addr).expect("connect");
        let (status, page) = probe.get("/metrics").expect("metrics");
        assert_eq!(status, 200);
        page.as_str()
            .unwrap_or_default()
            .lines()
            .find_map(|l| {
                l.strip_prefix("lasp_serve_chaos_injections_total")
                    .and_then(|rest| rest.trim().parse::<u64>().ok())
            })
            .unwrap_or(u64::MAX)
    };
    idle.shutdown().expect("shutdown");
    println!("chaos enabled-idle: p50 {idle_p50:.1} µs, p99 {idle_p99:.1} µs over {n} suggests");

    // Actively injecting (delay-free faults only): not gated, printed so
    // regressions in the *firing* path are visible in CI logs too.
    let firing = boot(Some(ChaosConfig { handler_error: 0.2, ..ChaosConfig::default() }));
    let addr = firing.addr().to_string();
    let mut client = HttpClient::connect(&addr).expect("connect");
    let body = suggest_body();
    let t0 = Instant::now();
    let (mut ok, mut injected) = (0u64, 0u64);
    for _ in 0..n {
        match client.post("/v1/suggest", &body).expect("suggest") {
            (200, _) => ok += 1,
            (503, _) => injected += 1,
            (status, resp) => panic!("unexpected status {status}: {resp:?}"),
        }
    }
    let firing_wall = t0.elapsed().as_secs_f64();
    firing.shutdown().expect("shutdown");
    println!(
        "chaos firing (p=0.2): {ok} ok / {injected} injected, {:.0} req/s",
        n as f64 / firing_wall.max(1e-12)
    );

    let p50_ratio = idle_p50 / dis_p50.max(1e-9);
    let p99_ratio = idle_p99 / dis_p99.max(1e-9);
    println!("idle/disabled ratio: p50 {p50_ratio:.2}x, p99 {p99_ratio:.2}x");

    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("chaos".to_string()));
    out.insert("mode".to_string(), Json::Str(if quick { "quick" } else { "full" }.to_string()));
    out.insert("requests".to_string(), Json::Num(n as f64));
    out.insert("disabled_p50_us".to_string(), Json::Num(dis_p50));
    out.insert("disabled_p99_us".to_string(), Json::Num(dis_p99));
    out.insert("idle_p50_us".to_string(), Json::Num(idle_p50));
    out.insert("idle_p99_us".to_string(), Json::Num(idle_p99));
    out.insert("idle_p50_ratio".to_string(), Json::Num(p50_ratio));
    out.insert("idle_p99_ratio".to_string(), Json::Num(p99_ratio));
    out.insert("idle_injections".to_string(), Json::Num(idle_injections as f64));
    out.insert("firing_injected".to_string(), Json::Num(injected as f64));
    let path = std::env::var("LASP_BENCH_OUT").unwrap_or_else(|_| "BENCH_chaos.json".to_string());
    std::fs::write(&path, Json::Obj(out).to_string() + "\n").expect("writing bench json");
    println!("\nwrote {path}");

    // Loose gate — shared-runner latency percentiles are noisy; the claim
    // is "free when off", not "identical to the nanosecond". An idle
    // layer tripling median suggest latency would be a real regression.
    common::report_shape(
        "chaos_overhead",
        p50_ratio < 3.0 && idle_injections == 0 && injected > 0 && ok + injected == n as u64,
    );
}
