//! Minimal self-timing bench harness (criterion is unavailable in this
//! offline build; `[[bench]] harness = false` targets use this instead).
//!
//! Each figure bench (a) regenerates the paper artifact and prints the
//! table/series, (b) checks the qualitative paper-shape predicate, and
//! (c) reports wall-clock timings for the regeneration so `cargo bench`
//! doubles as a coarse performance tracker.

// Shared by every `[[bench]]` target via `#[path]`; not every bench uses
// every helper, and CI denies warnings across all targets.
#![allow(dead_code)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// System allocator wrapper counting every allocation (reallocs included).
/// Shared by the zero-alloc benches (`bandit_core`, `sim_engine`); each
/// bench binary registers it itself:
/// `#[global_allocator] static GLOBAL: common::CountingAlloc = common::CountingAlloc;`
pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Allocation events so far (monotonic; diff around a measured phase).
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Time one closure over `iters` runs; prints mean ± spread like criterion.
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    assert!(iters > 0);
    // Warmup run (excluded).
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples[0];
    let max = samples[samples.len() - 1];
    println!("bench {name:<42} {:>10} (min {} / max {})", human(mean), human(min), human(max));
}

/// Render seconds human-readably.
pub fn human(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Standard bench epilogue: assert + report the paper-shape check.
pub fn report_shape(name: &str, ok: bool) {
    if ok {
        println!("[shape OK] {name} matches the paper's qualitative shape");
    } else {
        println!("[shape MISMATCH] {name}");
        std::process::exit(1);
    }
}
