//! Regenerates paper Fig 10: tuner resource utilization, LASP vs BLISS.
#[path = "common.rs"]
mod common;

fn main() {
    let fig = lasp::experiments::fig10::run();
    fig.report();
    common::bench("fig10 model + host measurement", 3, || {
        let _ = lasp::experiments::fig10::run();
    });
    common::report_shape("fig10", fig.matches_paper_shape());
}
