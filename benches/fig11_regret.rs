//! Regenerates paper Fig 11: cumulative regret (best run), α ∈ {0.8, 0.2}.
#[path = "common.rs"]
mod common;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (iters, tries) = if quick { (800, 2) } else { (1500, 5) };
    let fig = lasp::experiments::fig11::run(iters, tries);
    fig.report();
    common::bench("fig11 one regret-instrumented run", 3, || {
        let _ = lasp::experiments::harness::run_with_regret(
            lasp::apps::AppKind::Kripke,
            lasp::device::PowerMode::Maxn,
            iters,
            0.8,
            0.2,
            1,
        );
    });
    common::report_shape("fig11", fig.matches_paper_shape());
}
