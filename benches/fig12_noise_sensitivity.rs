//! Regenerates paper Fig 12: gains under 5/10/15% synthetic measurement
//! error.
#[path = "common.rs"]
mod common;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (iters, seeds) = if quick { (400, 2) } else { (800, 5) };
    let fig = lasp::experiments::fig12::run(iters, seeds);
    fig.report();
    common::bench("fig12 one noisy tuning run", 3, || {
        let _ = lasp::experiments::harness::run_lasp(
            lasp::apps::AppKind::Kripke,
            lasp::device::PowerMode::Maxn,
            iters,
            0.8,
            0.2,
            7,
            lasp::device::NoiseModel::uniform(0.10),
        );
    });
    common::report_shape("fig12", fig.matches_paper_shape());
}
