//! Regenerates paper Fig 2: LF/HF optimal-configuration overlap.
#[path = "common.rs"]
mod common;

fn main() {
    let fig = lasp::experiments::fig2::run();
    fig.report();
    common::bench("fig2 full regeneration", 3, || {
        let _ = lasp::experiments::fig2::run();
    });
    common::report_shape("fig2", fig.matches_paper_shape());
}
