//! Regenerates paper Fig 3: Kripke execution-time distribution.
#[path = "common.rs"]
mod common;

fn main() {
    let fig = lasp::experiments::fig3::run();
    fig.report();
    common::bench("fig3 oracle sweep + histogram", 5, || {
        let _ = lasp::experiments::fig3::run();
    });
    common::report_shape("fig3", fig.matches_paper_shape());
}
