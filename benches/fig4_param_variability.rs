//! Regenerates paper Fig 4: per-parameter runtime variability (Kripke).
#[path = "common.rs"]
mod common;

fn main() {
    let fig = lasp::experiments::fig4::run();
    fig.report();
    common::bench("fig4 independent parameter sweeps", 5, || {
        let _ = lasp::experiments::fig4::run();
    });
    common::report_shape("fig4", fig.matches_paper_shape());
}
