//! Regenerates paper Fig 6: Lulesh selection-frequency heatmaps
//! (500/1000 iterations × time/power objectives).
#[path = "common.rs"]
mod common;

fn main() {
    let fig = lasp::experiments::fig6::run();
    fig.report();
    common::bench("fig6 four tuning runs (500/1000 it)", 3, || {
        let _ = lasp::experiments::fig6::run();
    });
    common::report_shape("fig6", fig.matches_paper_shape());
}
