//! Regenerates paper Fig 7: exploration convergence for Kripke and Clomp.
#[path = "common.rs"]
mod common;

fn main() {
    let fig = lasp::experiments::fig7::run();
    fig.report();
    common::bench("fig7 four exploration runs", 3, || {
        let _ = lasp::experiments::fig7::run();
    });
    common::report_shape("fig7", fig.matches_paper_shape());
}
