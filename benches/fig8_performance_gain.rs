//! Regenerates paper Fig 8: performance gain vs default under varying α.
#[path = "common.rs"]
mod common;

fn main() {
    let fig = lasp::experiments::fig8::run(1000);
    fig.report();
    common::bench("fig8 16 tuning runs (1000 it)", 2, || {
        let _ = lasp::experiments::fig8::run(1000);
    });
    common::report_shape("fig8", fig.matches_paper_shape());
}
