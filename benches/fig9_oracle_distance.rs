//! Regenerates paper Fig 9: mean distance from Oracle over repeated runs
//! (the paper runs LASP 100 times; pass --quick for 10).
#[path = "common.rs"]
mod common;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (runs, iters) = if quick { (10, 500) } else { (100, 1000) };
    let fig = lasp::experiments::fig9::run(runs, iters);
    fig.report();
    common::bench("fig9 one (app x objective) cell", 2, || {
        let _ = lasp::experiments::fig9::run(2, iters);
    });
    common::report_shape("fig9", fig.matches_paper_shape());
}
