//! Two-node fleet-sync bench: boots a leader and a follower in-process,
//! trains a scenario on the leader, waits for the follower to pull the
//! fleet prior, and measures rounds-to-parity of a warm-started session
//! against a cold-started baseline node — the transfer payoff of the
//! networked fleet plane, tracked PR-over-PR.
//!
//! Emits `BENCH_fleet.json` (path override: `LASP_BENCH_FLEET_OUT`);
//! `LASP_BENCH_QUICK=1` runs a shorter training phase for CI.

#[path = "common.rs"]
mod common;

use lasp::serve::{start, HttpClient, ServeConfig};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

const BEST_ARM: usize = 77;

fn fake_time(arm: usize) -> f64 {
    if arm == BEST_ARM {
        0.3
    } else {
        2.0 + (arm % 13) as f64 * 0.05
    }
}

fn cfg(leader: Option<String>, sync_ms: u64, node_id: &str) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        shards: 2,
        checkpoint_dir: None,
        leader,
        node_id: Some(node_id.to_string()),
        sync_every: Duration::from_millis(sync_ms),
        fleet_retain: 0.5,
        ..Default::default()
    }
}

fn body(client: &str, extra: &[(&str, Json)]) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(client.to_string()));
    obj.insert("app".to_string(), Json::Str("clomp".to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    obj.insert("alpha".to_string(), Json::Num(1.0));
    obj.insert("beta".to_string(), Json::Num(0.0));
    for (k, v) in extra {
        obj.insert((*k).to_string(), v.clone());
    }
    Json::Obj(obj)
}

fn one_round(client: &mut HttpClient, client_id: &str) -> usize {
    let (status, resp) = client.post("/v1/suggest", &body(client_id, &[])).expect("suggest");
    assert_eq!(status, 200, "{resp:?}");
    let arm = resp.get("arm").and_then(Json::as_usize).expect("arm");
    let (status, _) = client
        .post(
            "/v1/report",
            &body(
                client_id,
                &[
                    ("arm", Json::Num(arm as f64)),
                    ("time_s", Json::Num(fake_time(arm))),
                    ("power_w", Json::Num(5.0)),
                ],
            ),
        )
        .expect("report");
    assert_eq!(status, 202);
    arm
}

fn best_arm(client: &mut HttpClient, client_id: &str) -> Option<usize> {
    let q = format!("/v1/best?client_id={client_id}&app=clomp&device=maxn&alpha=1.0&beta=0.0");
    let (status, b) = client.get(&q).expect("best");
    if status != 200 {
        return None;
    }
    b.get("arm").and_then(Json::as_usize)
}

fn rounds_to_parity(addr: &str, client_id: &str, cap: usize) -> usize {
    let mut client = HttpClient::connect(addr).expect("connect");
    for round in 1..=cap {
        one_round(&mut client, client_id);
        if best_arm(&mut client, client_id) == Some(BEST_ARM) {
            return round;
        }
    }
    cap
}

fn main() {
    let quick = std::env::var("LASP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (train_rounds, cap) = if quick { (200, 160) } else { (400, 200) };

    // Leader learns the scenario.
    let leader = start(cfg(None, 60_000, "bench-leader")).expect("boot leader");
    let leader_addr = leader.addr().to_string();
    let mut veteran = HttpClient::connect(&leader_addr).expect("connect leader");
    let t0 = Instant::now();
    for _ in 0..train_rounds {
        one_round(&mut veteran, "veteran");
    }
    let train_s = t0.elapsed().as_secs_f64();

    // Follower syncs; measure time to a usable fleet prior.
    let t0 = Instant::now();
    let follower =
        start(cfg(Some(leader_addr.clone()), 100, "bench-follower")).expect("boot follower");
    let follower_addr = follower.addr().to_string();
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut probe = HttpClient::connect(&follower_addr).expect("connect follower");
    let synced = loop {
        let (status, page) = probe.get("/metrics").expect("metrics");
        assert_eq!(status, 200);
        let text = page.as_str().unwrap_or_default().to_string();
        if text
            .lines()
            .any(|l| l.starts_with("lasp_serve_fleet_prior_keys") && !l.ends_with(" 0"))
        {
            break true;
        }
        if Instant::now() > deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    let sync_latency_s = t0.elapsed().as_secs_f64();

    // Warm (fleet-synced follower) vs cold (isolated node) convergence.
    let warm_rounds = rounds_to_parity(&follower_addr, "newcomer", cap);
    let cold = start(cfg(None, 60_000, "bench-cold")).expect("boot cold");
    let cold_rounds = rounds_to_parity(&cold.addr().to_string(), "newcomer", cap);

    println!("fleet bench: train={train_rounds} rounds in {train_s:.2}s | first sync {sync_latency_s:.2}s");
    println!(
        "rounds-to-parity: warm={warm_rounds} cold={cold_rounds} (speedup {:.1}x)",
        cold_rounds as f64 / warm_rounds.max(1) as f64
    );

    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("fleet_sync".to_string()));
    out.insert("mode".to_string(), Json::Str(if quick { "quick" } else { "full" }.to_string()));
    out.insert("train_rounds".to_string(), Json::Num(train_rounds as f64));
    out.insert("train_s".to_string(), Json::Num(train_s));
    out.insert("sync_latency_s".to_string(), Json::Num(sync_latency_s));
    out.insert("warm_rounds_to_parity".to_string(), Json::Num(warm_rounds as f64));
    out.insert("cold_rounds_to_parity".to_string(), Json::Num(cold_rounds as f64));
    out.insert(
        "speedup".to_string(),
        Json::Num(cold_rounds as f64 / warm_rounds.max(1) as f64),
    );
    let path =
        std::env::var("LASP_BENCH_FLEET_OUT").unwrap_or_else(|_| "BENCH_fleet.json".to_string());
    std::fs::write(&path, Json::Obj(out).to_string() + "\n").expect("writing bench json");
    println!("wrote {path}");

    drop(veteran);
    drop(probe);
    leader.shutdown().expect("leader shutdown");
    follower.shutdown().expect("follower shutdown");
    cold.shutdown().expect("cold shutdown");

    common::report_shape(
        "fleet_sync",
        synced && warm_rounds < cold_rounds && cold_rounds >= 100,
    );
}
