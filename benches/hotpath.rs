//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the per-iteration LASP
//! scoring step for every application space, scalar vs PJRT backends, plus
//! the BLISS GP proposal and the fused episode artifact.

#[path = "common.rs"]
mod common;

use lasp::bandit::{ArmStats, ScalarBackend, ScoreBackend, Scratch};
use lasp::runtime::EngineHandle;
use lasp::util::Rng;

fn populated_state(k: usize, pulls: usize, seed: u64) -> ArmStats {
    let mut state = ArmStats::new(k);
    let mut rng = Rng::new(seed);
    for _ in 0..pulls {
        let arm = rng.below(k);
        state.observe(arm, rng.range(0.5, 3.0), rng.range(3.0, 9.0));
    }
    state
}

fn main() {
    let apps: [(&str, usize); 4] =
        [("lulesh", 128), ("kripke", 216), ("clomp", 125), ("hypre", 92_160)];

    println!("## scalar backend — fused lasp_step (reward norm + UCB + argmax)");
    for (app, k) in apps {
        let state = populated_state(k, 1000, 7);
        let mut backend = ScalarBackend;
        let mut scratch = Scratch::new();
        common::bench(&format!("scalar lasp_step {app} (K={k})"), 50, || {
            let _ = backend.lasp_step(&state, 0.8, 0.2, 0.25, &mut scratch).unwrap();
        });
    }

    match EngineHandle::spawn_default() {
        Ok(engine) => {
            println!("\n## PJRT backend — same step through the AOT artifact");
            for (app, k) in apps {
                let state = populated_state(k, 1000, 7);
                let tau: Vec<f32> = state.tau_sum().iter().map(|&v| v as f32).collect();
                let rho: Vec<f32> = state.rho_sum().iter().map(|&v| v as f32).collect();
                let cnt: Vec<f32> = state.counts().iter().map(|&v| v as f32).collect();
                // Warm the executable cache before timing.
                let _ = engine
                    .lasp_step(app, tau.clone(), rho.clone(), cnt.clone(), 1001.0, 0.8, 0.2, 0.25)
                    .unwrap();
                common::bench(&format!("pjrt lasp_step {app} (K={k})"), 30, || {
                    let _ = engine
                        .lasp_step(
                            app,
                            tau.clone(),
                            rho.clone(),
                            cnt.clone(),
                            1001.0,
                            0.8,
                            0.2,
                            0.25,
                        )
                        .unwrap();
                });
            }

            println!("\n## PJRT fused episode replay (L2 scan artifact)");
            let rewards: Vec<f32> = (0..216).map(|i| (i % 13) as f32 / 13.0).collect();
            let _ = engine
                .ucb_episode("kripke", 500, rewards.clone(), vec![0.0; 216], 1.0, 0.25)
                .unwrap();
            common::bench("pjrt ucb_episode kripke t=500", 10, || {
                let _ = engine
                    .ucb_episode("kripke", 500, rewards.clone(), vec![0.0; 216], 1.0, 0.25)
                    .unwrap();
            });

            println!("\n## PJRT GP proposal (BLISS surrogate)");
            let (n, m, d) = engine.gp_shape().unwrap();
            let x = vec![0.3f32; n * d];
            let y = vec![0.5f32; n];
            let mut mask = vec![0f32; n];
            mask.iter_mut().take(n / 2).for_each(|v| *v = 1.0);
            let xs = vec![0.4f32; m * d];
            let _ = engine
                .gp_propose(x.clone(), y.clone(), mask.clone(), xs.clone(), 0.35, 1e-3, 0.6)
                .unwrap();
            common::bench(&format!("pjrt gp_propose (N={n}, M={m}, D={d})"), 10, || {
                let _ = engine
                    .gp_propose(x.clone(), y.clone(), mask.clone(), xs.clone(), 0.35, 1e-3, 0.6)
                    .unwrap();
            });
        }
        Err(e) => println!("\n(pjrt benches skipped: {e})"),
    }

    println!("\n## rust GP surrogate (BLISS fallback path)");
    let mut gp = lasp::baselines::GpSurrogate::new(0.35, 1e-3);
    let mut rng = Rng::new(3);
    let xs: Vec<Vec<f64>> = (0..64).map(|_| (0..12).map(|_| rng.uniform()).collect()).collect();
    let ys: Vec<f64> = (0..64).map(|_| rng.uniform()).collect();
    common::bench("rust GP fit (N=64, D=12)", 30, || {
        gp.fit(xs.clone(), ys.clone()).unwrap();
    });
    let q: Vec<f64> = (0..12).map(|_| 0.5).collect();
    common::bench("rust GP predict x512", 30, || {
        for _ in 0..512 {
            let _ = gp.predict(&q);
        }
    });

    println!("\n## end-to-end tuning iteration (app model + device + tuner)");
    for (kind, label) in [
        (lasp::apps::AppKind::Kripke, "kripke"),
        (lasp::apps::AppKind::Hypre, "hypre (subset)"),
    ] {
        common::bench(&format!("500-iteration LASP run on {label}"), 3, || {
            let _ = lasp::experiments::harness::run_lasp(
                kind,
                lasp::device::PowerMode::Maxn,
                500,
                0.8,
                0.2,
                5,
                lasp::device::NoiseModel::none(),
            );
        });
    }
}
