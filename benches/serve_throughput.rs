//! Serve-layer throughput bench: boots the tuning service in-process on an
//! ephemeral port and measures (a) single-connection suggest round-trip
//! latency through the real HTTP stack, (b) the steady-state allocation
//! behaviour of the HTTP+JSON layers (must be zero), and (c) closed-loop
//! loadgen throughput with concurrent sessions across all four apps.
//!
//! Emits `BENCH_serve.json` (path override: `LASP_BENCH_OUT`) so the perf
//! trajectory is tracked PR-over-PR; `LASP_BENCH_QUICK=1` runs a short
//! smoke variant for CI.

#[path = "common.rs"]
mod common;

use lasp::serve::{loadgen, LoadgenConfig, ServeConfig};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn suggest_body(client: &str, app: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(client.to_string()));
    obj.insert("app".to_string(), Json::Str(app.to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    Json::Obj(obj)
}

fn main() {
    let quick = std::env::var("LASP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (latency_iters, lg_rounds, lg_sessions, lg_threads) =
        if quick { (50, 800, 32, 4) } else { (200, 4000, 64, 4) };

    let handle = lasp::serve::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 8,
        shards: 8,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .expect("boot serve");
    let addr = handle.addr().to_string();
    let stats = handle.transport_stats();

    println!("## single-connection suggest round-trip (real HTTP stack)");
    let mut client = lasp::serve::HttpClient::connect(&addr).expect("connect");
    for app in ["clomp", "kripke", "lulesh", "hypre"] {
        let body = suggest_body("bench", app).to_string();
        common::bench(&format!("http suggest {app}"), latency_iters, || {
            let status = client.post_slice("/v1/suggest", body.as_bytes()).expect("suggest");
            assert_eq!(status, 200);
        });
    }

    // Steady-state allocation proxy: after the warmup above, a fixed
    // request stream must not grow any HTTP/JSON buffer.
    let alloc_probe_requests = 200u64;
    let body = suggest_body("bench", "clomp").to_string();
    let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
    for _ in 0..alloc_probe_requests {
        let status = client.post_slice("/v1/suggest", body.as_bytes()).expect("suggest");
        assert_eq!(status, 200);
    }
    let steady_allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
    let allocs_per_request = steady_allocs as f64 / alloc_probe_requests as f64;
    println!(
        "\n## steady-state alloc proxy: {steady_allocs} buffer-growth events / {alloc_probe_requests} requests ({allocs_per_request:.4}/req)"
    );

    println!("\n## closed-loop loadgen (concurrent sessions, all apps)");
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        sessions: lg_sessions,
        rounds: lg_rounds,
        threads: lg_threads,
        ..Default::default()
    })
    .expect("loadgen");
    report.print();

    // Same closed loop through the batch endpoints: 16 sessions advance
    // per suggest/report HTTP round-trip pair, so the per-request
    // overhead amortizes and round-trips/s should rise.
    println!("\n## closed-loop loadgen, batched (16 entries/request)");
    let batched_report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        sessions: lg_sessions,
        rounds: lg_rounds,
        threads: lg_threads,
        batch: 16,
        ..Default::default()
    })
    .expect("batched loadgen");
    batched_report.print();

    drop(client);
    handle.shutdown().expect("shutdown");

    // Machine-readable perf baseline, tracked PR-over-PR.
    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("serve_throughput".to_string()));
    out.insert("mode".to_string(), Json::Str(if quick { "quick" } else { "full" }.to_string()));
    out.insert("rounds".to_string(), Json::Num(report.rounds as f64));
    out.insert("sessions".to_string(), Json::Num(report.sessions as f64));
    out.insert("errors".to_string(), Json::Num(report.errors as f64));
    out.insert("elapsed_s".to_string(), Json::Num(report.elapsed_s));
    out.insert("round_trips_per_s".to_string(), Json::Num(report.round_trips_per_s));
    out.insert("req_per_s".to_string(), Json::Num(report.round_trips_per_s * 2.0));
    out.insert("p50_ms".to_string(), Json::Num(report.p50_ms));
    out.insert("p99_ms".to_string(), Json::Num(report.p99_ms));
    out.insert("mean_ms".to_string(), Json::Num(report.mean_ms));
    out.insert("connections".to_string(), Json::Num(report.connections as f64));
    out.insert("reconnects".to_string(), Json::Num(report.reconnects as f64));
    out.insert(
        "requests_per_connection".to_string(),
        Json::Num(report.requests_per_connection()),
    );
    out.insert("steady_alloc_events".to_string(), Json::Num(steady_allocs as f64));
    out.insert("allocs_per_request".to_string(), Json::Num(allocs_per_request));
    let mut batched = BTreeMap::new();
    batched.insert("batch".to_string(), Json::Num(16.0));
    batched.insert("rounds".to_string(), Json::Num(batched_report.rounds as f64));
    batched.insert("errors".to_string(), Json::Num(batched_report.errors as f64));
    batched.insert(
        "round_trips_per_s".to_string(),
        Json::Num(batched_report.round_trips_per_s),
    );
    batched.insert(
        "req_per_s".to_string(),
        // Two HTTP requests (suggest/batch + report/batch) move `batch`
        // rounds, so the raw request rate is round-trips/s * 2 / batch.
        Json::Num(batched_report.round_trips_per_s * 2.0 / 16.0),
    );
    batched.insert("p50_ms".to_string(), Json::Num(batched_report.p50_ms));
    batched.insert("p99_ms".to_string(), Json::Num(batched_report.p99_ms));
    out.insert("batched".to_string(), Json::Obj(batched));
    let path = std::env::var("LASP_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    std::fs::write(&path, Json::Obj(out).to_string() + "\n").expect("writing bench json");
    println!("\nwrote {path}");

    common::report_shape(
        "serve_throughput",
        report.errors == 0
            && report.rounds == lg_rounds
            && report.p99_ms > 0.0
            && steady_allocs == 0
            && batched_report.errors == 0
            && batched_report.rounds == lg_rounds,
    );
}
