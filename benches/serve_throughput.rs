//! Serve-layer throughput bench: boots the tuning service in-process on an
//! ephemeral port and measures (a) single-connection suggest round-trip
//! latency through the real HTTP stack, and (b) closed-loop loadgen
//! throughput with concurrent sessions across all four apps.

#[path = "common.rs"]
mod common;

use lasp::serve::{loadgen, LoadgenConfig, ServeConfig};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::time::Duration;

fn suggest_body(client: &str, app: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(client.to_string()));
    obj.insert("app".to_string(), Json::Str(app.to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    Json::Obj(obj)
}

fn main() {
    let handle = lasp::serve::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 8,
        shards: 8,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .expect("boot serve");
    let addr = handle.addr().to_string();

    println!("## single-connection suggest round-trip (real HTTP stack)");
    let mut client = lasp::serve::HttpClient::connect(&addr).expect("connect");
    for app in ["clomp", "kripke", "lulesh", "hypre"] {
        let body = suggest_body("bench", app);
        common::bench(&format!("http suggest {app}"), 200, || {
            let (status, _) = client.post("/v1/suggest", &body).expect("suggest");
            assert_eq!(status, 200);
        });
    }

    println!("\n## closed-loop loadgen (concurrent sessions, all apps)");
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        sessions: 64,
        rounds: 4000,
        threads: 4,
        ..Default::default()
    })
    .expect("loadgen");
    report.print();

    drop(client);
    handle.shutdown().expect("shutdown");
    common::report_shape(
        "serve_throughput",
        report.errors == 0 && report.rounds == 4000 && report.p99_ms > 0.0,
    );
}
