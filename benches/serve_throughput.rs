//! Serve-layer throughput bench: boots the tuning service in-process on an
//! ephemeral port and measures (a) single-connection suggest round-trip
//! latency through the real HTTP stack, (b) the steady-state allocation
//! behaviour of the HTTP+JSON layers (must be zero), (c) closed-loop
//! loadgen throughput with concurrent sessions across all four apps, and
//! (d) the held-connection series: the same closed loop while 256 / 1k /
//! 10k mostly-idle keep-alive connections ride the reactor's event loops,
//! gated against a legacy blocking-transport baseline at its worker-count
//! ceiling.
//!
//! Emits `BENCH_serve.json` (path override: `LASP_BENCH_OUT`) so the perf
//! trajectory is tracked PR-over-PR; `LASP_BENCH_QUICK=1` runs a short
//! smoke variant for CI.

#[path = "common.rs"]
mod common;

use lasp::serve::{loadgen, HttpClient, LoadgenConfig, ServeConfig};
use lasp::util::json::{Json, JsonSlice};
use std::collections::BTreeMap;
use std::io::Write as _;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

// Process-wide allocation counter backing the contended series'
// zero-steady-state gate: on the routed plane a measured suggest/report
// phase must not allocate anywhere in the process — client, transport,
// or bandit.
#[global_allocator]
static GLOBAL: common::CountingAlloc = common::CountingAlloc;

fn suggest_body(client: &str, app: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(client.to_string()));
    obj.insert("app".to_string(), Json::Str(app.to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    Json::Obj(obj)
}

fn main() {
    let quick = std::env::var("LASP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (latency_iters, lg_rounds, lg_sessions, lg_threads) =
        if quick { (50, 800, 32, 4) } else { (200, 4000, 64, 4) };

    let handle = lasp::serve::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 8,
        shards: 8,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .expect("boot serve");
    let addr = handle.addr().to_string();
    let stats = handle.transport_stats();

    println!("## single-connection suggest round-trip (real HTTP stack)");
    let mut client = lasp::serve::HttpClient::connect(&addr).expect("connect");
    for app in ["clomp", "kripke", "lulesh", "hypre"] {
        let body = suggest_body("bench", app).to_string();
        common::bench(&format!("http suggest {app}"), latency_iters, || {
            let status = client.post_slice("/v1/suggest", body.as_bytes()).expect("suggest");
            assert_eq!(status, 200);
        });
    }

    // Steady-state allocation proxy: after the warmup above, a fixed
    // request stream must not grow any HTTP/JSON buffer.
    let alloc_probe_requests = 200u64;
    let body = suggest_body("bench", "clomp").to_string();
    let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
    for _ in 0..alloc_probe_requests {
        let status = client.post_slice("/v1/suggest", body.as_bytes()).expect("suggest");
        assert_eq!(status, 200);
    }
    let steady_allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
    let allocs_per_request = steady_allocs as f64 / alloc_probe_requests as f64;
    println!(
        "\n## steady-state alloc proxy: {steady_allocs} buffer-growth events / {alloc_probe_requests} requests ({allocs_per_request:.4}/req)"
    );

    println!("\n## closed-loop loadgen (concurrent sessions, all apps)");
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        sessions: lg_sessions,
        rounds: lg_rounds,
        threads: lg_threads,
        ..Default::default()
    })
    .expect("loadgen");
    report.print();

    // Same closed loop through the batch endpoints: 16 sessions advance
    // per suggest/report HTTP round-trip pair, so the per-request
    // overhead amortizes and round-trips/s should rise.
    println!("\n## closed-loop loadgen, batched (16 entries/request)");
    let batched_report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        sessions: lg_sessions,
        rounds: lg_rounds,
        threads: lg_threads,
        batch: 16,
        ..Default::default()
    })
    .expect("batched loadgen");
    batched_report.print();

    // ---- held-connection series (open-loop holders + closed loop) ----
    //
    // 256 / 1k / 10k mostly-idle keep-alive connections (Zipf-activated
    // by the loadgen holder thread) sit on the event loops while the
    // same closed loop runs. Throughput must survive the herd with zero
    // transport errors, zero dropped held connections, and zero
    // steady-state buffer growth.
    #[cfg(unix)]
    let fd_limit = lasp::serve::transport::poller::raise_nofile_limit(65_536).unwrap_or(1024);
    #[cfg(not(unix))]
    let fd_limit = 1024u64;
    // Both socket ends live in this process — two fds per held
    // connection, plus headroom for the server, clients, and runtime.
    // Clamping (and saying so) beats a series that silently sheds dials.
    let max_held = (fd_limit.saturating_sub(1_000) / 2) as usize;

    // Every event loop's response/frame buffers must reach their
    // suggest-path high-water marks before the series measures alloc
    // deltas; connections land on loops round-robin, so twice the loop
    // count covers them all.
    let loops = stats.event_loops.load(Ordering::Relaxed).max(1) as usize;
    for _ in 0..loops * 2 {
        let mut warm = lasp::serve::HttpClient::connect(&addr).expect("warmup connect");
        for _ in 0..4 {
            assert_eq!(warm.post_slice("/v1/suggest", body.as_bytes()).expect("warmup"), 200);
        }
        assert_eq!(warm.get_slice("/healthz").expect("warmup healthz"), 200);
    }

    let held_rounds = if quick { 800 } else { 3000 };
    let mut held_series: Vec<Json> = Vec::new();
    let mut held_ok = true;
    let mut rps_at_10k = 0.0f64;
    for target in [256usize, 1024, 10240] {
        let held = target.min(max_held);
        if held < target {
            println!("\n(fd limit {fd_limit}: clamping {target} held connections to {held})");
        }
        println!("\n## closed loop + {held} held connections (Zipf-activated holder)");
        let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
        let r = loadgen::run(&LoadgenConfig {
            addr: addr.clone(),
            sessions: lg_sessions,
            rounds: held_rounds,
            threads: lg_threads,
            connections: held,
            ..Default::default()
        })
        .expect("held-connection loadgen");
        r.print();
        let held_allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
        println!("held-run buffer-growth events: {held_allocs}");
        // The zero-growth gate applies on unix, where the reactor is the
        // default and the warmup above reached every loop. The non-unix
        // blocking fallback offers no handle on which pool worker serves
        // which connection, so cold-worker growth there is expected.
        held_ok &= r.errors == 0 && r.connect_failures == 0 && (!cfg!(unix) || held_allocs == 0);
        if target == 10240 {
            rps_at_10k = r.round_trips_per_s;
        }
        let mut h = BTreeMap::new();
        h.insert("held_target".to_string(), Json::Num(target as f64));
        h.insert("held_connections".to_string(), Json::Num(r.held_connections as f64));
        h.insert("connect_failures".to_string(), Json::Num(r.connect_failures as f64));
        h.insert("rounds".to_string(), Json::Num(r.rounds as f64));
        h.insert("errors".to_string(), Json::Num(r.errors as f64));
        h.insert("round_trips_per_s".to_string(), Json::Num(r.round_trips_per_s));
        h.insert("req_per_s".to_string(), Json::Num(r.round_trips_per_s * 2.0));
        h.insert("p50_ms".to_string(), Json::Num(r.p50_ms));
        h.insert("p99_ms".to_string(), Json::Num(r.p99_ms));
        h.insert("per_conn_p50_ms".to_string(), Json::Num(r.per_conn_p50_ms));
        h.insert("per_conn_p99_ms".to_string(), Json::Num(r.per_conn_p99_ms));
        h.insert("alloc_events".to_string(), Json::Num(held_allocs as f64));
        held_series.push(Json::Obj(h));
    }

    drop(client);
    handle.shutdown().expect("shutdown");

    // ---- legacy-transport baseline at its worker-count ceiling ----
    //
    // The same closed loop against the blocking pool, no held connections
    // (its concurrency ceiling IS the worker count). The reactor carrying
    // the full held herd must not fall behind this.
    println!("\n## legacy blocking-transport baseline (worker-count ceiling)");
    let legacy = lasp::serve::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 8,
        shards: 8,
        transport: lasp::serve::TransportKind::Blocking,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .expect("boot legacy serve");
    let legacy_report = loadgen::run(&LoadgenConfig {
        addr: legacy.addr().to_string(),
        sessions: lg_sessions,
        rounds: held_rounds,
        threads: lg_threads,
        ..Default::default()
    })
    .expect("legacy loadgen");
    legacy_report.print();
    legacy.shutdown().expect("legacy shutdown");
    // The gate refuses a real regression, not runner jitter: a 10%
    // cushion, with the exact ratio tracked in the JSON PR-over-PR.
    let ceiling_ok = rps_at_10k >= legacy_report.round_trips_per_s * 0.9;
    println!(
        "\nreq/s at 10k held connections: {:.0} (reactor) vs {:.0} (legacy ceiling)",
        rps_at_10k * 2.0,
        legacy_report.round_trips_per_s * 2.0
    );

    // ---- contended multi-loop series (shared-nothing scaling) ----
    //
    // Stable-key closed loops against 1-loop and 4-loop routed servers,
    // uniform and Zipf-skewed key mixes. The uniform series is the
    // scaling gate: going 1→4 event loops must buy >= 1.5x req/s when
    // the host has the cores for it, and the measured phase must not
    // allocate anywhere in the process (counting allocator).
    let contended_rounds = if quick { 1000 } else { 4000 };
    let mut contended_runs: Vec<ContendedRun> = Vec::new();
    for loops in [1usize, 4] {
        for mix in ["uniform", "zipf"] {
            let r = contended_run(loops, mix, contended_rounds);
            println!(
                "\n## contended series: {} loop(s), {} keys: {:.0} req/s ({} errors, {} allocs)",
                loops, mix, r.req_per_s, r.errors, r.alloc_events
            );
            contended_runs.push(r);
        }
    }
    let contended_rps = |loops: usize, mix: &str| {
        contended_runs
            .iter()
            .find(|r| r.event_loops == loops && r.key_mix == mix)
            .map(|r| r.req_per_s)
            .unwrap_or(0.0)
    };
    let contended_scaling = contended_rps(4, "uniform") / contended_rps(1, "uniform").max(1e-9);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // The scaling gate needs the routed plane (unix reactor) and enough
    // cores for four loops to actually run in parallel.
    let scaling_gated = cfg!(unix) && cores >= 4;
    let scaling_ok = !scaling_gated || contended_scaling >= 1.5;
    let contended_ok = contended_runs
        .iter()
        .all(|r| r.errors == 0 && (!cfg!(unix) || r.alloc_events == 0));
    println!(
        "\ncontended scaling 1→4 loops (uniform keys): {contended_scaling:.2}x \
         (gate >=1.5x {})",
        if scaling_gated { "armed" } else { "skipped: needs unix + >=4 cores" }
    );

    // Machine-readable perf baseline, tracked PR-over-PR.
    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("serve_throughput".to_string()));
    out.insert("mode".to_string(), Json::Str(if quick { "quick" } else { "full" }.to_string()));
    out.insert("rounds".to_string(), Json::Num(report.rounds as f64));
    out.insert("sessions".to_string(), Json::Num(report.sessions as f64));
    out.insert("errors".to_string(), Json::Num(report.errors as f64));
    out.insert("elapsed_s".to_string(), Json::Num(report.elapsed_s));
    out.insert("round_trips_per_s".to_string(), Json::Num(report.round_trips_per_s));
    out.insert("req_per_s".to_string(), Json::Num(report.round_trips_per_s * 2.0));
    out.insert("p50_ms".to_string(), Json::Num(report.p50_ms));
    out.insert("p99_ms".to_string(), Json::Num(report.p99_ms));
    out.insert("mean_ms".to_string(), Json::Num(report.mean_ms));
    out.insert("connections".to_string(), Json::Num(report.connections as f64));
    out.insert("reconnects".to_string(), Json::Num(report.reconnects as f64));
    out.insert(
        "requests_per_connection".to_string(),
        Json::Num(report.requests_per_connection()),
    );
    out.insert("steady_alloc_events".to_string(), Json::Num(steady_allocs as f64));
    out.insert("allocs_per_request".to_string(), Json::Num(allocs_per_request));
    let mut batched = BTreeMap::new();
    batched.insert("batch".to_string(), Json::Num(16.0));
    batched.insert("rounds".to_string(), Json::Num(batched_report.rounds as f64));
    batched.insert("errors".to_string(), Json::Num(batched_report.errors as f64));
    batched.insert(
        "round_trips_per_s".to_string(),
        Json::Num(batched_report.round_trips_per_s),
    );
    batched.insert(
        "req_per_s".to_string(),
        // Two HTTP requests (suggest/batch + report/batch) move `batch`
        // rounds, so the raw request rate is round-trips/s * 2 / batch.
        Json::Num(batched_report.round_trips_per_s * 2.0 / 16.0),
    );
    batched.insert("p50_ms".to_string(), Json::Num(batched_report.p50_ms));
    batched.insert("p99_ms".to_string(), Json::Num(batched_report.p99_ms));
    out.insert("batched".to_string(), Json::Obj(batched));
    out.insert("held_series".to_string(), Json::Arr(held_series));
    let contended_series: Vec<Json> = contended_runs
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("event_loops".to_string(), Json::Num(r.event_loops as f64));
            c.insert("key_mix".to_string(), Json::Str(r.key_mix.to_string()));
            c.insert("rounds".to_string(), Json::Num(r.rounds as f64));
            c.insert("req_per_s".to_string(), Json::Num(r.req_per_s));
            c.insert("errors".to_string(), Json::Num(r.errors as f64));
            c.insert("alloc_events".to_string(), Json::Num(r.alloc_events as f64));
            Json::Obj(c)
        })
        .collect();
    out.insert("contended_series".to_string(), Json::Arr(contended_series));
    out.insert("contended_scaling_uniform".to_string(), Json::Num(contended_scaling));
    let mut legacy_json = BTreeMap::new();
    legacy_json.insert("transport".to_string(), Json::Str("blocking".to_string()));
    legacy_json.insert("rounds".to_string(), Json::Num(legacy_report.rounds as f64));
    legacy_json.insert("errors".to_string(), Json::Num(legacy_report.errors as f64));
    legacy_json
        .insert("round_trips_per_s".to_string(), Json::Num(legacy_report.round_trips_per_s));
    legacy_json.insert("req_per_s".to_string(), Json::Num(legacy_report.round_trips_per_s * 2.0));
    legacy_json.insert("p50_ms".to_string(), Json::Num(legacy_report.p50_ms));
    legacy_json.insert("p99_ms".to_string(), Json::Num(legacy_report.p99_ms));
    out.insert("legacy_baseline".to_string(), Json::Obj(legacy_json));
    let path = std::env::var("LASP_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    std::fs::write(&path, Json::Obj(out).to_string() + "\n").expect("writing bench json");
    println!("\nwrote {path}");

    common::report_shape(
        "serve_throughput",
        report.errors == 0
            && report.rounds == lg_rounds
            && report.p99_ms > 0.0
            && steady_allocs == 0
            && batched_report.errors == 0
            && batched_report.rounds == lg_rounds
            && held_ok
            && legacy_report.errors == 0
            && ceiling_ok
            && contended_ok
            && scaling_ok,
    );
}

struct ContendedRun {
    event_loops: usize,
    key_mix: &'static str,
    /// Total suggest/report rounds across all connections.
    rounds: usize,
    req_per_s: f64,
    errors: usize,
    /// Process-wide allocation events during the measured phase.
    alloc_events: u64,
}

/// One suggest→report round with a *stable* key; returns false on any
/// protocol surprise. Allocation-free after warmup: the suggest frame is
/// prebuilt, the report frame is rewritten into a reused buffer, and the
/// response parse is the zero-copy slice parser.
fn contended_round(
    client: &mut HttpClient,
    suggest: &[u8],
    key: &str,
    report: &mut Vec<u8>,
) -> bool {
    if !matches!(client.post_slice("/v1/suggest", suggest), Ok(200)) {
        return false;
    }
    let arm = JsonSlice::parse(client.last_body())
        .ok()
        .and_then(|v| v.get("arm")?.as_usize());
    let Some(arm) = arm else { return false };
    report.clear();
    let _ = write!(
        report,
        "{{\"client_id\":\"{key}\",\"app\":\"clomp\",\"device\":\"maxn\",\
         \"arm\":{arm},\"time_s\":0.5,\"power_w\":5.0}}"
    );
    matches!(client.post_slice("/v1/report", report), Ok(202))
}

/// Closed-loop suggest/report hammer with stable per-connection keys:
/// eight connections, each pinned to one session for the whole run, so
/// the routed plane re-homes a connection at most once and the measured
/// phase is pure hot path. `key_mix` picks the assignment: "uniform"
/// spreads the connections evenly over keys covering all four shards
/// (every loop of a 4-loop server owns live traffic); "zipf" piles six
/// of the eight onto one hot key, the skew ceiling.
fn contended_run(event_loops: usize, key_mix: &'static str, rounds_per_conn: usize) -> ContendedRun {
    const THREADS: usize = 8;
    const WARMUP: usize = 200;
    let handle = lasp::serve::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        event_loops,
        workers: event_loops,
        shards: 4,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .expect("boot contended serve");
    let addr = handle.addr().to_string();

    // Shard-covering keys, discovered through the API itself (the
    // suggest response names the session's shard): key hashing is an
    // implementation detail, and guessing it would leave loops idle.
    let mut shard_keys: [Option<String>; 4] = [None, None, None, None];
    {
        let mut probe = HttpClient::connect(&addr).expect("probe connect");
        let mut found = 0;
        for i in 0..256 {
            if found == 4 {
                break;
            }
            let key = format!("ck-{i}");
            let body = suggest_body(&key, "clomp").to_string();
            assert_eq!(probe.post_slice("/v1/suggest", body.as_bytes()).expect("probe"), 200);
            let shard = JsonSlice::parse(probe.last_body())
                .ok()
                .and_then(|v| v.get("shard")?.as_usize())
                .expect("suggest response carries shard");
            if shard_keys[shard % 4].is_none() {
                shard_keys[shard % 4] = Some(key);
                found += 1;
            }
        }
        assert_eq!(found, 4, "256 candidate keys did not cover 4 shards");
    }
    let shard_keys: Vec<String> = shard_keys.into_iter().flatten().collect();

    let barrier = Arc::new(Barrier::new(THREADS + 1));
    let mut workers = Vec::with_capacity(THREADS);
    for t in 0..THREADS {
        let addr = addr.clone();
        let key = match key_mix {
            "uniform" => shard_keys[t % 4].clone(),
            _ => shard_keys[if t < 6 { 0 } else { t - 5 }].clone(),
        };
        let barrier = Arc::clone(&barrier);
        workers.push(std::thread::spawn(move || -> usize {
            let mut client = HttpClient::connect(&addr).expect("contended connect");
            let suggest = suggest_body(&key, "clomp").to_string();
            let mut report: Vec<u8> = Vec::with_capacity(256);
            let mut errors = 0usize;
            for _ in 0..WARMUP {
                if !contended_round(&mut client, suggest.as_bytes(), &key, &mut report) {
                    errors += 1;
                }
            }
            barrier.wait(); // warmed
            barrier.wait(); // go
            for _ in 0..rounds_per_conn {
                if !contended_round(&mut client, suggest.as_bytes(), &key, &mut report) {
                    errors += 1;
                }
            }
            barrier.wait(); // done
            barrier.wait(); // held until the main thread snapshots
            errors
        }));
    }

    barrier.wait(); // every connection warmed and parked
    let allocs_before = common::alloc_count();
    let t0 = Instant::now();
    barrier.wait(); // go
    barrier.wait(); // done
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let alloc_events = common::alloc_count() - allocs_before;
    barrier.wait(); // release the workers
    let errors: usize = workers.into_iter().map(|w| w.join().expect("contended worker")).sum();
    handle.shutdown().expect("contended shutdown");

    let rounds = THREADS * rounds_per_conn;
    ContendedRun {
        event_loops,
        key_mix,
        rounds,
        // Two HTTP requests per round (suggest + report).
        req_per_s: (rounds * 2) as f64 / elapsed,
        errors,
        alloc_events,
    }
}
