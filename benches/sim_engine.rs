//! Scenario-engine bench: steady-state episode steps/sec and — via the
//! same counting global allocator as `benches/bandit_core.rs` — *exact*
//! heap allocations per episode step, plus parallel sweep throughput
//! (cells/sec, steps/sec) across the pool.
//!
//! The engine's contract is that a steady-state episode step (select →
//! workload → device → observe → record) performs **zero** heap
//! allocations for the UCB policy path; the shape check fails if it ever
//! allocates, or if parallel sweep results stop matching the serial run.
//!
//! Emits `BENCH_sim.json` (path override: `LASP_BENCH_OUT`);
//! `LASP_BENCH_QUICK=1` runs a short smoke variant for CI.

#[path = "common.rs"]
mod common;

use lasp::apps::{self, AppKind};
use lasp::bandit::UcbTuner;
use lasp::device::{JetsonNano, PowerMode};
use lasp::sim::{Episode, EpisodeSpec, PolicyStep, ScenarioGrid, StrategySpec, SweepRunner};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

#[global_allocator]
static GLOBAL: common::CountingAlloc = common::CountingAlloc;

struct EpisodeReport {
    app: &'static str,
    steps_per_s: f64,
    allocs_per_step: f64,
}

/// Steady-state stepping for one (app, UCB) episode: warm up past the
/// init sweep, then measure a long run of manual steps.
fn measure_episode(kind: AppKind, rounds: usize) -> EpisodeReport {
    let app = apps::build(kind);
    let k = app.space().len();
    let mut device = JetsonNano::new(PowerMode::Maxn, 7).with_fidelity(0.15);
    let mut policy = UcbTuner::new(k, 0.8, 0.2);
    let mut step = PolicyStep::new(&mut policy);
    let warmup = k.min(4096) + 64;
    let spec = EpisodeSpec { iterations: warmup + rounds, ..Default::default() };
    let mut episode = Episode::new(app.as_ref(), &mut device, &mut step, &[], &spec);

    for _ in 0..warmup {
        episode.step().expect("warmup step");
    }
    let allocs_before = common::alloc_count();
    let t0 = Instant::now();
    for _ in 0..rounds {
        episode.step().expect("measured step");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = common::alloc_count() - allocs_before;

    let report = EpisodeReport {
        app: kind.name(),
        steps_per_s: rounds as f64 / elapsed.max(1e-12),
        allocs_per_step: allocs as f64 / rounds as f64,
    };
    println!(
        "bench sim_engine episode {:<8} {rounds} steps: {:>12.0} steps/s, {:.4} allocs/step",
        report.app, report.steps_per_s, report.allocs_per_step
    );
    report
}

fn main() {
    let quick = std::env::var("LASP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let rounds = if quick { 5_000 } else { 200_000 };

    println!("## sim engine — steady-state episode stepping (UCB policy)");
    let episodes: Vec<EpisodeReport> = [AppKind::Clomp, AppKind::Kripke, AppKind::Lulesh]
        .into_iter()
        .map(|kind| measure_episode(kind, rounds))
        .collect();

    // Parallel sweep throughput: the fig9-shaped grid (apps × objectives
    // × seeds), serial vs pool, with a determinism cross-check.
    let grid = ScenarioGrid {
        apps: AppKind::all().to_vec(),
        objectives: vec![(0.8, 0.2), (0.2, 0.8)],
        strategies: vec![StrategySpec::Lasp],
        seeds: if quick { vec![1, 2] } else { vec![1, 2, 3, 4, 5] },
        iterations: if quick { 200 } else { 1000 },
        record_trace: true,
        ..Default::default()
    };
    let cells = grid.len();
    let steps_total = (cells * grid.iterations) as f64;

    let t0 = Instant::now();
    let serial = SweepRunner::new(1).sweep(&grid).expect("serial sweep");
    let serial_s = t0.elapsed().as_secs_f64();

    let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let t0 = Instant::now();
    let pooled = SweepRunner::new(threads).sweep(&grid).expect("pooled sweep");
    let pooled_s = t0.elapsed().as_secs_f64();

    let deterministic = serial
        .outcomes
        .iter()
        .zip(&pooled.outcomes)
        .all(|(a, b)| a.trace == b.trace && a.best_index == b.best_index);
    println!(
        "bench sim_engine sweep {cells} cells × {} iters: serial {:>8.0} steps/s | {} threads {:>8.0} steps/s ({:.2}x)",
        grid.iterations,
        steps_total / serial_s.max(1e-12),
        threads,
        steps_total / pooled_s.max(1e-12),
        serial_s / pooled_s.max(1e-12),
    );

    let mut episodes_json = BTreeMap::new();
    for e in &episodes {
        let mut o = BTreeMap::new();
        o.insert("steps_per_s".to_string(), Json::Num(e.steps_per_s));
        o.insert("allocs_per_step".to_string(), Json::Num(e.allocs_per_step));
        episodes_json.insert(e.app.to_string(), Json::Obj(o));
    }
    let mut sweep_json = BTreeMap::new();
    sweep_json.insert("cells".to_string(), Json::Num(cells as f64));
    sweep_json.insert("iterations".to_string(), Json::Num(grid.iterations as f64));
    sweep_json.insert("threads".to_string(), Json::Num(threads as f64));
    sweep_json.insert("serial_steps_per_s".to_string(), Json::Num(steps_total / serial_s.max(1e-12)));
    sweep_json.insert("pooled_steps_per_s".to_string(), Json::Num(steps_total / pooled_s.max(1e-12)));
    sweep_json.insert("speedup".to_string(), Json::Num(serial_s / pooled_s.max(1e-12)));
    sweep_json.insert("deterministic".to_string(), Json::Bool(deterministic));

    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("sim_engine".to_string()));
    out.insert(
        "mode".to_string(),
        Json::Str(if quick { "quick" } else { "full" }.to_string()),
    );
    out.insert("rounds".to_string(), Json::Num(rounds as f64));
    out.insert("episodes".to_string(), Json::Obj(episodes_json));
    out.insert("sweep".to_string(), Json::Obj(sweep_json));
    let path = std::env::var("LASP_BENCH_OUT").unwrap_or_else(|_| "BENCH_sim.json".to_string());
    std::fs::write(&path, Json::Obj(out).to_string() + "\n").expect("writing bench json");
    println!("\nwrote {path}");

    // Shape: zero allocations per steady-state UCB episode step on every
    // app, and pool results identical to the serial run.
    common::report_shape(
        "sim_engine",
        episodes.iter().all(|e| e.allocs_per_step == 0.0) && deterministic,
    );
}
