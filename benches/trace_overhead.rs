//! Flight-recorder overhead bench: measures the cost of one
//! `Recorder::record` on the hot path (single-thread and contended), the
//! cold-path drain, and — under a counting global allocator — proves that
//! steady-state recording performs **zero allocations per event**, the
//! contract that lets the serve hot path trace every suggest for free.
//!
//! Emits `BENCH_trace.json` (path override: `LASP_BENCH_OUT`);
//! `LASP_BENCH_QUICK=1` runs a short smoke variant for CI. Shape-fails if
//! any steady-state record allocates.

#[path = "common.rs"]
mod common;

#[global_allocator]
static GLOBAL: common::CountingAlloc = common::CountingAlloc;

use lasp::obs::{pack_suggest, EventKind, Recorder, TraceEvent};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

fn record_one(rec: &Recorder, i: u64) {
    let (a, b, c) = pack_suggest(
        (i % 128) as u32,
        (i % 125) as u32,
        0.03125,
        i % 7 == 0,
        0,
        i,
    );
    rec.record(EventKind::Suggest, a, b, c);
}

fn main() {
    let quick = std::env::var("LASP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (events, threads) = if quick { (200_000u64, 4usize) } else { (2_000_000u64, 8usize) };

    let rec = Arc::new(Recorder::for_workers(threads));

    // Warmup: claim this thread's lane slot and fault the ring in.
    for i in 0..10_000 {
        record_one(&rec, i);
    }

    // Single-thread hot path, with exact allocation accounting.
    let allocs_before = common::alloc_count();
    let t0 = Instant::now();
    for i in 0..events {
        record_one(&rec, i);
    }
    let wall = t0.elapsed().as_secs_f64();
    let steady_allocs = common::alloc_count() - allocs_before;
    let ns_per_event = wall * 1e9 / events as f64;
    let events_per_s = events as f64 / wall.max(1e-12);
    let allocs_per_event = steady_allocs as f64 / events as f64;
    println!(
        "record (1 thread): {ns_per_event:.1} ns/event, {events_per_s:.0} events/s, \
         {steady_allocs} allocs / {events} events"
    );

    // Contended: every worker hammers its own lane concurrently.
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let rec = rec.clone();
            s.spawn(move || {
                for i in 0..events / threads as u64 {
                    record_one(&rec, i);
                }
            });
        }
    });
    let contended_wall = t0.elapsed().as_secs_f64();
    let contended_total = (events / threads as u64) * threads as u64;
    let contended_events_per_s = contended_total as f64 / contended_wall.max(1e-12);
    println!(
        "record ({threads} threads): {:.1} ns/event aggregate, {contended_events_per_s:.0} events/s",
        contended_wall * 1e9 / contended_total as f64
    );

    // Cold-path drain (the /v1/trace read side — allowed to allocate).
    let mut out_events: Vec<TraceEvent> = Vec::new();
    let recorded = rec.recorded();
    let t0 = Instant::now();
    rec.drain_since(recorded.saturating_sub(4096), &mut out_events);
    let drain_s = t0.elapsed().as_secs_f64();
    println!(
        "drain: {} events in {} (overwritten {})",
        out_events.len(),
        common::human(drain_s),
        rec.overwritten()
    );
    assert!(!out_events.is_empty(), "drain returned nothing");
    assert!(out_events.windows(2).all(|w| w[0].seq < w[1].seq), "drain not seq-sorted");

    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("trace_overhead".to_string()));
    out.insert("mode".to_string(), Json::Str(if quick { "quick" } else { "full" }.to_string()));
    out.insert("events".to_string(), Json::Num(events as f64));
    out.insert("ns_per_event".to_string(), Json::Num(ns_per_event));
    out.insert("events_per_s".to_string(), Json::Num(events_per_s));
    out.insert("contended_threads".to_string(), Json::Num(threads as f64));
    out.insert("contended_events_per_s".to_string(), Json::Num(contended_events_per_s));
    out.insert("steady_alloc_events".to_string(), Json::Num(steady_allocs as f64));
    out.insert("allocs_per_event".to_string(), Json::Num(allocs_per_event));
    out.insert("drain_events".to_string(), Json::Num(out_events.len() as f64));
    out.insert("drain_s".to_string(), Json::Num(drain_s));
    let path = std::env::var("LASP_BENCH_OUT").unwrap_or_else(|_| "BENCH_trace.json".to_string());
    std::fs::write(&path, Json::Obj(out).to_string() + "\n").expect("writing bench json");
    println!("\nwrote {path}");

    common::report_shape(
        "trace_overhead",
        steady_allocs == 0 && rec.recorded() >= events && ns_per_event < 10_000.0,
    );
}
