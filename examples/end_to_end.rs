//! End-to-end driver (EXPERIMENTS.md §E2E): exercises every layer of the
//! stack on a real small workload —
//!
//!   1. loads the AOT artifacts (L1 Pallas kernels + L2 graphs, lowered by
//!      `make artifacts`) into the PJRT CPU runtime;
//!   2. spawns a 3-device edge fleet whose workers score arms *through the
//!      PJRT artifact* (python is not running — the HLO is);
//!   3. tunes all four paper applications at low fidelity with measurement
//!      noise on the lossy link;
//!   4. transfers each tuned configuration to the simulated i7-14700 and
//!      validates at high fidelity (paper Fig 1);
//!   5. reports the paper's headline metrics: Eq. 8 gain over default,
//!      §II-A oracle distance, and the tuner's own footprint.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! Falls back to the scalar backend (with a warning) if artifacts are
//! missing, so the driver always runs.

use lasp::apps::{self, AppKind};
use lasp::coordinator::transfer::validate_on_hpc;
use lasp::coordinator::{Fleet, FleetConfig, TuneJob};
use lasp::device::{NoiseModel, PowerMode};
use lasp::runtime::EngineHandle;
use lasp::telemetry::ResourceTracker;
use std::time::Duration;

fn main() -> lasp::Result<()> {
    println!("=== LASP end-to-end driver ===\n");

    // --- 1. runtime + artifacts ------------------------------------------
    let engine = match EngineHandle::spawn_default() {
        Ok(h) => {
            println!("[runtime] PJRT engine up: platform={}", h.platform()?);
            h.warmup(&[
                "lasp_step_lulesh",
                "lasp_step_kripke",
                "lasp_step_clomp",
                "lasp_step_hypre",
            ])?;
            println!("[runtime] warmed 4 lasp_step artifacts (compiled from HLO text)");
            Some(h)
        }
        Err(e) => {
            println!("[runtime] WARNING: {e}; falling back to scalar backend");
            None
        }
    };

    // --- 2-3. fleet tuning ------------------------------------------------
    let tracker = ResourceTracker::start();
    let mut fleet = Fleet::spawn(
        FleetConfig {
            devices: 3,
            modes: vec![PowerMode::Maxn, PowerMode::Maxn, PowerMode::FiveW],
            seed: 2026,
            fidelity: 0.15,
            loss_prob: 0.03,
            mean_latency_s: 0.005,
            injected_noise: NoiseModel::uniform(0.05),
            progress_every: 125,
        },
        engine.clone(),
    )?;
    println!(
        "[fleet] {} devices up (2×MAXN + 1×5W), 3% loss, 5% measurement noise",
        fleet.size()
    );

    let iterations = 500;
    for app in AppKind::all() {
        let id = fleet.submit(TuneJob { app, iterations, alpha: 0.8, beta: 0.2 })?;
        println!("[fleet] job {id} submitted: tune {app} for {iterations} iterations");
    }
    let mut results = fleet.drain(Duration::from_secs(600))?;
    results.sort_by_key(|r| r.job_id);

    // --- 4-5. HF validation + report --------------------------------------
    println!("\n=== results (LF edge tuning -> HF i7-14700 validation) ===");
    println!(
        "{:<8} {:>6} {:>12} {:>12} {:>10} {:>10} {:>12}",
        "app", "dev", "sim time", "tuner time", "HF gain", "oracle", "pulls(best)"
    );
    let mut all_gains = vec![];
    for r in &results {
        let app = apps::build(r.app);
        let v = validate_on_hpc(app.as_ref(), r.best_index, 2026);
        all_gains.push(v.gain_pct);
        println!(
            "{:<8} {:>6} {:>11.1}s {:>11.3}s {:>9.1}% {:>9.1}% {:>12.0}",
            r.app.to_string(),
            r.device_id,
            r.simulated_device_seconds,
            r.tuner_wall_seconds,
            v.gain_pct,
            v.oracle_distance_pct,
            r.pulls_of_best
        );
        println!("         tuned: {}", app.space().describe(r.best_index));
    }

    let res = tracker.report();
    println!("\n=== headline ===");
    println!(
        "mean HF gain over Table II defaults: {:+.1}%  (paper reports 6-14% at power focus,\nlarger for time focus — shape: every app positive)",
        all_gains.iter().sum::<f64>() / all_gains.len() as f64
    );
    println!(
        "tuner footprint for the whole 4-app campaign: {:.2}s cpu over {:.2}s wall, ΔRSS {:.1} MiB",
        res.cpu_seconds, res.wall_seconds, res.peak_rss_mib
    );
    println!(
        "backend on the hot path: {}",
        if engine.is_some() { "pjrt (AOT artifacts)" } else { "scalar (fallback)" }
    );
    fleet.shutdown();

    // Exit nonzero if the headline shape does not hold.
    if !all_gains.iter().all(|&g| g > -5.0) {
        eprintln!("FAIL: a tuned configuration regressed badly vs default at HF");
        std::process::exit(1);
    }
    Ok(())
}
