//! The paper's central workflow (Fig 1): tune cheaply at LOW fidelity on
//! the edge device, transfer the configuration to the HPC node, execute at
//! HIGH fidelity — and compare against tuning directly on the HPC node.
//!
//! ```bash
//! cargo run --release --example lf_hf_transfer
//! ```

use lasp::apps::{self, AppKind};
use lasp::bandit::{Policy, UcbTuner};
use lasp::coordinator::transfer::validate_on_hpc;
use lasp::device::{Device, HpcNode, JetsonNano, PowerMode};

fn tune_on<D: Device>(app: AppKind, device: &mut D, iterations: usize) -> (usize, f64) {
    let model = apps::build(app);
    let mut tuner = UcbTuner::new(model.space().len(), 0.8, 0.2);
    let mut cost = 0.0;
    for _ in 0..iterations {
        let arm = tuner.select();
        let m = device.run(&model.workload(arm, device.fidelity()));
        cost += m.time_s * m.power_w; // energy spent tuning, joules
        tuner.update(arm, m.time_s, m.power_w);
    }
    (tuner.most_selected(), cost)
}

fn main() {
    println!(
        "{:<8} {:>14} {:>14} {:>11} {:>11} {:>9}",
        "app", "edge tune (J)", "hpc tune (J)", "edge→HF", "hpc→HF", "saving"
    );
    for app in [AppKind::Lulesh, AppKind::Kripke, AppKind::Clomp] {
        // Paper's path: LF tuning on the Jetson (fidelity 0.15)...
        let mut edge = JetsonNano::new(PowerMode::Maxn, 11);
        let (edge_pick, edge_energy) = tune_on(app, &mut edge, 500);
        // ...vs the expensive path: tuning at full fidelity on the node.
        let mut hpc = HpcNode::new(11);
        let (hpc_pick, hpc_energy) = tune_on(app, &mut hpc, 500);

        let model = apps::build(app);
        let edge_v = validate_on_hpc(model.as_ref(), edge_pick, 11);
        let hpc_v = validate_on_hpc(model.as_ref(), hpc_pick, 11);
        println!(
            "{:<8} {:>14.0} {:>14.0} {:>10.1}% {:>10.1}% {:>8.0}x",
            app.to_string(),
            edge_energy,
            hpc_energy,
            edge_v.oracle_distance_pct,
            hpc_v.oracle_distance_pct,
            hpc_energy / edge_energy.max(1e-9),
        );
    }
    println!(
        "\nedge→HF / hpc→HF: distance from the HF oracle of the configuration\n\
         found on each platform; `saving`: tuning-energy ratio (the paper's\n\
         motivation — LF edge runs are orders of magnitude cheaper, yet land\n\
         nearly as close to the oracle)."
    );
}
