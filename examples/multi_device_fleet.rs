//! Fleet scenario: a leader dispatches tuning jobs for all four paper
//! applications across a heterogeneous edge fleet (MAXN and 5W boards) over
//! a lossy CoAP-like link, then validates every result on the HPC node.
//!
//! ```bash
//! cargo run --release --example multi_device_fleet
//! ```

use lasp::apps::{self, AppKind};
use lasp::coordinator::transfer::validate_on_hpc;
use lasp::coordinator::{Fleet, FleetConfig, TuneJob};
use lasp::device::{NoiseModel, PowerMode};
use std::time::Duration;

fn main() -> lasp::Result<()> {
    let mut fleet = Fleet::spawn(
        FleetConfig {
            devices: 4,
            modes: vec![PowerMode::Maxn, PowerMode::FiveW],
            seed: 7,
            fidelity: 0.15,
            loss_prob: 0.05,     // 5% message loss on the edge radio
            mean_latency_s: 0.01,
            injected_noise: NoiseModel::uniform(0.05),
            progress_every: 100,
        },
        None,
    )?;
    println!("fleet up: {} devices (MAXN + 5W, 5% loss)", fleet.size());

    for app in AppKind::all() {
        let id = fleet.submit(TuneJob { app, iterations: 500, alpha: 0.8, beta: 0.2 })?;
        println!("submitted job {id}: {app}");
    }

    let mut results = fleet.drain(Duration::from_secs(300))?;
    results.sort_by_key(|r| r.job_id);
    println!("\n{:<8} {:<8} {:<45} {:>9} {:>8}", "device", "app", "tuned configuration", "HF gain", "oracle");
    for r in &results {
        let app = apps::build(r.app);
        let v = validate_on_hpc(app.as_ref(), r.best_index, 7);
        println!(
            "{:<8} {:<8} {:<45} {:>8.1}% {:>7.1}%",
            r.device_id,
            r.app.to_string(),
            app.space().describe(r.best_index),
            v.gain_pct,
            v.oracle_distance_pct
        );
    }

    // Volatility event: drop the whole fleet to 5 W and re-tune one app —
    // the new tuning session adapts to the new operating point.
    println!("\nswitching fleet to 5W and re-tuning kripke ...");
    fleet.set_power_mode(PowerMode::FiveW);
    fleet.submit(TuneJob { app: AppKind::Kripke, iterations: 300, alpha: 0.8, beta: 0.2 })?;
    let r = fleet.drain(Duration::from_secs(300))?;
    for r in r {
        let app = apps::build(r.app);
        println!(
            "device {} re-tuned {}: {}",
            r.device_id,
            r.app,
            app.space().describe(r.best_index)
        );
    }

    fleet.shutdown();
    Ok(())
}
