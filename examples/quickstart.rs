//! Quickstart: tune one HPC application on one simulated edge device.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This is the 30-second tour: build an app model (Kripke), a Jetson Nano
//! in MAXN mode, run LASP for 500 iterations with the paper's default
//! priorities (α = 0.8, β = 0.2), and print the tuned configuration with
//! its gain over the Table II default.

use lasp::apps::{self, AppKind};
use lasp::device::{Device, JetsonNano, PowerMode};
use lasp::tuning::{oracle_sweep, oracle_distance_pct, SessionConfig, TuningSession};

fn main() -> lasp::Result<()> {
    let app = apps::build(AppKind::Kripke);
    let device = JetsonNano::new(PowerMode::Maxn, 42);
    println!(
        "tuning {} ({} configurations) on {} ...",
        app.name(),
        app.space().len(),
        device.spec().name
    );

    let mut session = TuningSession::new(
        app,
        Box::new(device),
        SessionConfig { iterations: 500, alpha: 0.8, beta: 0.2, record_history: false },
    );
    let outcome = session.run()?;

    println!("tuned configuration (Eq. 4): {}", outcome.best_config);
    println!(
        "pulls of best: {:.0}/500 | simulated device time {:.1}s | tuner overhead {:.4}s",
        outcome.counts[outcome.best_index],
        outcome.simulated_device_seconds,
        outcome.tuner_wall_seconds
    );

    // Score it against the noise-free oracle and the default config.
    let app = apps::build(AppKind::Kripke);
    let sweep = oracle_sweep(app.as_ref(), &PowerMode::Maxn.spec(), 0.15);
    let default = app.default_index();
    let gain = (sweep[default].time_s - sweep[outcome.best_index].time_s)
        / sweep[default].time_s
        * 100.0;
    println!(
        "vs default: {:+.1}% execution time | distance from oracle: {:.1}%",
        gain,
        oracle_distance_pct(&sweep, outcome.best_index)
    );
    Ok(())
}
