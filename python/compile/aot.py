"""AOT-lower the L2 graphs to HLO *text* artifacts for the rust runtime.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Every artifact is lowered with ``return_tuple=True`` — the rust side unwraps
with ``to_tuple()``. A ``manifest.json`` describing every artifact's entry
point, input shapes/dtypes and outputs is written next to the .hlo.txt files
so the rust runtime can validate what it loads.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Arm counts per application (Table II; see DESIGN.md for the Hypre
# discretization that realizes the paper's stated 92,160 size).
APP_SPACES = {
    "lulesh": 128,
    "kripke": 216,
    "clomp": 125,
    "hypre": 92160,
}

# BLISS GP surrogate shapes: up to N observations, M candidates, D features.
GP_N, GP_M, GP_D = 64, 512, 12

# Episode-replay artifacts (small spaces only; the scan inlines the kernel).
EPISODE_SHAPES = [("lulesh", 128, 500), ("lulesh", 128, 1000), ("kripke", 216, 500)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _desc(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def build_plan():
    """(name, jitted fn, example specs, input descr, output descr) tuples."""
    plan = []
    f32 = jnp.float32
    for app, k in APP_SPACES.items():
        plan.append(
            dict(
                name=f"lasp_step_{app}",
                fn=jax.jit(model.lasp_step),
                specs=(_spec((k,)), _spec((k,)), _spec((k,)), _spec(()), _spec(()), _spec(()), _spec(())),
                inputs=[
                    _desc((k,)), _desc((k,)), _desc((k,)),
                    _desc(()), _desc(()), _desc(()), _desc(()),
                ],
                outputs=[_desc((), "s32"), _desc(()), _desc((k,))],
                meta={"kind": "lasp_step", "k": k, "app": app},
            )
        )
        plan.append(
            dict(
                name=f"ucb_scores_{app}",
                fn=jax.jit(model.ucb_scores_graph),
                specs=(_spec((k,)), _spec((k,)), _spec(()), _spec(())),
                inputs=[_desc((k,)), _desc((k,)), _desc(()), _desc(())],
                outputs=[_desc((k,)), _desc((), "s32")],
                meta={"kind": "ucb_scores", "k": k, "app": app},
            )
        )
        plan.append(
            dict(
                name=f"reward_norm_{app}",
                fn=jax.jit(model.reward_norm),
                specs=(_spec((k,)), _spec((k,)), _spec((k,)), _spec(()), _spec(())),
                inputs=[_desc((k,)), _desc((k,)), _desc((k,)), _desc(()), _desc(())],
                outputs=[_desc((k,))],
                meta={"kind": "reward_norm", "k": k, "app": app},
            )
        )
    for app, k, steps in EPISODE_SHAPES:
        plan.append(
            dict(
                name=f"ucb_episode_{app}_t{steps}",
                fn=jax.jit(lambda r, c0, t, ec, s=steps: model.ucb_episode(r, c0, t, ec, s)),
                specs=(_spec((k,)), _spec((k,)), _spec(()), _spec(())),
                inputs=[_desc((k,)), _desc((k,)), _desc(()), _desc(())],
                outputs=[_desc((k,)), _desc((steps,), "s32")],
                meta={"kind": "ucb_episode", "k": k, "app": app, "steps": steps},
            )
        )
    plan.append(
        dict(
            name="gp_propose",
            fn=jax.jit(model.gp_propose),
            specs=(
                _spec((GP_N, GP_D)), _spec((GP_N,)), _spec((GP_N,)),
                _spec((GP_M, GP_D)), _spec(()), _spec(()), _spec(()),
            ),
            inputs=[
                _desc((GP_N, GP_D)), _desc((GP_N,)), _desc((GP_N,)),
                _desc((GP_M, GP_D)), _desc(()), _desc(()), _desc(()),
            ],
            outputs=[_desc((GP_M,)), _desc((GP_M,)), _desc((GP_M,)), _desc((), "s32")],
            meta={"kind": "gp_propose", "n": GP_N, "m": GP_M, "d": GP_D},
        )
    )
    return plan


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="substring filter on artifact names")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "return_tuple": True, "artifacts": []}
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if args.only and os.path.exists(manifest_path):
        # Partial rebuild: keep entries for artifacts we are not touching.
        with open(manifest_path) as f:
            old = json.load(f)
        manifest["artifacts"] = [
            a for a in old.get("artifacts", []) if args.only not in a["name"]
        ]
    for item in build_plan():
        if args.only and args.only not in item["name"]:
            continue
        path = os.path.join(args.out_dir, f"{item['name']}.hlo.txt")
        lowered = item["fn"].lower(*item["specs"])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": item["name"],
                "file": os.path.basename(path),
                "inputs": item["inputs"],
                "outputs": item["outputs"],
                **item["meta"],
            }
        )
        print(f"wrote {path} ({len(text) / 1024:.0f} KiB)")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
