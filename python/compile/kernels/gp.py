"""L1 Pallas kernel for the BLISS baseline's GP surrogate: RBF kernel matrix.

BLISS (Roy et al., PLDI'21) drives tuning with a pool of lightweight surrogate
models; our reimplementation uses a Gaussian-process surrogate whose dominant
cost is building K(X, Y) = exp(-||x - y||^2 / (2 l^2)) for X: (N, D),
Y: (M, D). We tile (N, M) into MXU-friendly blocks and use the
||x||^2 + ||y||^2 - 2 x.y^T decomposition so the inner product is a matmul
that would hit the systolic array on real TPU hardware (interpret=True here —
CPU PJRT cannot run Mosaic calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128
BLOCK_M = 128


def _rbf_kernel(inv2l2_ref, x_ref, y_ref, o_ref):
    """One (BLOCK_N, BLOCK_M) tile of the RBF kernel matrix.

    x_ref: (BLOCK_N, D), y_ref: (BLOCK_M, D) — D rides along whole.
    """
    x = x_ref[...]
    y = y_ref[...]
    xx = jnp.sum(x * x, axis=1, keepdims=True)          # (bn, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T        # (1, bm)
    # The MXU-shaped part: (bn, D) @ (D, bm) in fp32.
    xy = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    sq = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-sq * inv2l2_ref[0])


def _pad_rows(a, block):
    n = a.shape[0]
    pad = (-n) % block
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
    return a


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def rbf_matrix(x, y, lengthscale, block_n=BLOCK_N, block_m=BLOCK_M):
    """K(X, Y) with RBF kernel. x: f32[N, D], y: f32[M, D] -> f32[N, M]."""
    n, d = x.shape
    m = y.shape[0]
    block_n = min(block_n, max(8, n))
    block_m = min(block_m, max(8, m))
    xp = _pad_rows(x.astype(jnp.float32), block_n)
    yp = _pad_rows(y.astype(jnp.float32), block_m)
    grid = (xp.shape[0] // block_n, yp.shape[0] // block_m)
    inv2l2 = jnp.reshape(0.5 / (lengthscale.astype(jnp.float32) ** 2), (1,))
    out = pl.pallas_call(
        _rbf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        interpret=True,
    )(inv2l2, xp, yp)
    return out[:n, :m]
