"""Pure-jnp oracles for every L1 kernel and L2 graph — the correctness signal.

Everything here is the straightforward textbook implementation of the paper's
math; pytest/hypothesis assert the Pallas kernels and the lowered artifacts
match these to tight tolerances.
"""

import jax.numpy as jnp

UNPULLED_SCORE = 1.0e9


def ucb_scores(rewards, counts, t, c=1.0):
    """Paper Eq. 2 with exploration coefficient c: R_x + c·sqrt(2 ln t /
    N_x); +BIG for unpulled arms."""
    bonus = c * jnp.sqrt(
        2.0 * jnp.log(jnp.maximum(t, 1.0)) / jnp.maximum(counts, 1.0)
    )
    return jnp.where(counts > 0.0, rewards + bonus, UNPULLED_SCORE)


def ucb_select(rewards, counts, t, c=1.0):
    s = ucb_scores(rewards, counts, t, c)
    idx = jnp.argmax(s)
    return idx.astype(jnp.int32), s[idx]


def minmax(v, eps=1e-9):
    """MinMax normalization (Alg. 1 line 2) with a degenerate-range guard."""
    lo = jnp.min(v)
    hi = jnp.max(v)
    return (v - lo) / jnp.maximum(hi - lo, eps)


def weighted_reward(mean_tau, mean_rho, alpha, beta, eps=1e-2):
    """Paper Eq. 5 over per-arm mean metrics, re-normalized to [0, 1].

    R'_x = alpha / (tau_hat + eps) + beta / (rho_hat + eps) with tau_hat,
    rho_hat the MinMax-normalized per-arm means; a final MinMax maps the
    unbounded inverse back into [0, 1], matching the paper's stated reward
    range (Sec. III, assumption 3).
    """
    tau_hat = minmax(mean_tau)
    rho_hat = minmax(mean_rho)
    raw = alpha / (tau_hat + eps) + beta / (rho_hat + eps)
    return minmax(raw)


def rbf_matrix(x, y, lengthscale):
    sq = (
        jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
        - 2.0 * x @ y.T
    )
    return jnp.exp(-jnp.maximum(sq, 0.0) / (2.0 * lengthscale**2))


def gp_posterior(x, y, mask, xs, lengthscale, noise):
    """Masked GP regression posterior mean/var at xs.

    mask[i] == 0 rows are padding, decoupled exactly:
    K' = M·K·M + (I − M) + σ²·M with M = diag(mask) (see model.py). Uses a
    dense direct solve — this oracle never gets AOT-lowered.
    """
    k = rbf_matrix(x, x, lengthscale)
    mm = mask[:, None] * mask[None, :]
    k = k * mm + jnp.diag((1.0 - mask) + noise * mask)
    ks = rbf_matrix(x, xs, lengthscale) * mask[:, None]  # (N, M)
    alpha_v = jnp.linalg.solve(k, y * mask)
    mean = ks.T @ alpha_v
    v = jnp.linalg.solve(k, ks)
    var = jnp.maximum(1.0 - jnp.sum(ks * v, axis=0), 1e-12)
    return mean, var


def expected_improvement(mean, var, best, xi=0.01):
    """EI acquisition for a *maximization* problem (rewards)."""
    std = jnp.sqrt(var)
    z = (mean - best - xi) / std
    # Φ and φ of the standard normal (tanh-approximated Φ, AOT-friendly).
    phi = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    cdf = 0.5 * (1.0 + jnp.tanh(0.7978845608028654 * (z + 0.044715 * z**3)))
    return (mean - best - xi) * cdf + std * phi


def ucb_episode(expected_rewards, t0, n0, steps, c=1.0):
    """Deterministic expected-reward replay of UCB1 for `steps` iterations.

    Mirrors Alg. 1 with r(t) = E[R_x] (mean-field replay): used by the fig6
    heatmap fast path and as the oracle for the lowered scan artifact.
    Returns (counts, trace of selected arms).
    """
    counts = jnp.asarray(n0, jnp.float32)
    trace = []
    t = float(t0)
    for _ in range(steps):
        idx, _ = ucb_select(expected_rewards, counts, jnp.float32(t), c)
        counts = counts.at[idx].add(1.0)
        trace.append(idx)
        t += 1.0
    return counts, jnp.stack(trace)
