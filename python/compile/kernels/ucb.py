"""L1 Pallas kernels for the LASP UCB hot path.

The per-iteration cost of LASP is scoring every arm:

    score(x) = R_x + sqrt(2 ln t / N_x)          (paper Eq. 2)

with the convention that an arm never pulled (N_x == 0) scores +BIG so the
initial round-robin "try each arm once" phase of UCB1 falls out of the same
kernel. For the largest space in the paper (Hypre, K = 92,160 arms) this is a
bandwidth-bound elementwise pass followed by an argmax reduction.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the arm axis is tiled
with ``BlockSpec((TILE,))`` so each grid step streams one VMEM-resident tile
of (R, N) pairs, computes scores on the VPU in fp32, and emits a per-tile
(max, argmax) pair; the final cross-tile reduction is a tiny jnp argmax at L2.
``interpret=True`` everywhere — the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Score assigned to never-pulled arms: larger than any reachable UCB value
# (rewards are normalized to [0, 1]; the exploration bonus is <= sqrt(2 ln t)).
UNPULLED_SCORE = 1.0e9

# Arm-axis tile. 1024 f32 lanes * 3 live buffers (R, N, scores) is 12 KiB of
# VMEM per step — far under the ~16 MiB budget; chosen to keep the grid short
# for small spaces while still exercising multi-tile paths for Hypre.
DEFAULT_TILE = 1024


def _score_kernel(tc_ref, r_ref, n_ref, o_ref):
    """scores = R + c·sqrt(2 ln t / N), +BIG where N == 0 (one VMEM tile).

    `c` is the exploration coefficient (paper Eq. 2 has c = 1; with rewards
    re-normalized to [0, 1] the effective paper setting is c ≪ 1 — see
    DESIGN.md §Calibration).
    """
    r = r_ref[...]
    n = n_ref[...]
    t = tc_ref[0]
    c = tc_ref[1]
    # ln t is uniform across the tile; computed once on the scalar.
    bonus = c * jnp.sqrt(2.0 * jnp.log(jnp.maximum(t, 1.0)) / jnp.maximum(n, 1.0))
    o_ref[...] = jnp.where(n > 0.0, r + bonus, UNPULLED_SCORE)


def _select_kernel(tc_ref, r_ref, n_ref, max_ref, arg_ref):
    """Per-tile (max score, argmax lane) pair.

    The cross-tile argmax happens at L2; each grid step writes one (max, arg)
    into its slot, so the kernel output is (num_tiles,) x 2.
    """
    i = pl.program_id(0)
    r = r_ref[...]
    n = n_ref[...]
    t = tc_ref[0]
    c = tc_ref[1]
    tile = r.shape[0]
    bonus = c * jnp.sqrt(2.0 * jnp.log(jnp.maximum(t, 1.0)) / jnp.maximum(n, 1.0))
    scores = jnp.where(n > 0.0, r + bonus, UNPULLED_SCORE)
    lane = jnp.argmax(scores)
    max_ref[0] = scores[lane]
    arg_ref[0] = (i * tile + lane).astype(jnp.int32)


def _pad_to_tile(x, tile, fill):
    k = x.shape[0]
    pad = (-k) % tile
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])
    return x


@functools.partial(jax.jit, static_argnames=("tile",))
def ucb_scores(rewards, counts, t, c=1.0, tile=DEFAULT_TILE):
    """Score all K arms. rewards/counts: f32[K]; t, c: f32 scalars.

    Returns f32[K] scores (Eq. 2 with exploration coefficient c, and the
    unpulled-arm convention).
    """
    k = rewards.shape[0]
    tile = min(tile, max(k, 8))
    r = _pad_to_tile(rewards.astype(jnp.float32), tile, 0.0)
    # Padding arms get count +inf so their bonus is 0 and reward 0: never win.
    n = _pad_to_tile(counts.astype(jnp.float32), tile, jnp.float32(1e30))
    grid = r.shape[0] // tile
    out = pl.pallas_call(
        _score_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # t: broadcast scalar-as-(1,)
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r.shape[0],), jnp.float32),
        interpret=True,
    )(jnp.stack([jnp.asarray(t, jnp.float32), jnp.asarray(c, jnp.float32)]), r, n)
    return out[:k]


@functools.partial(jax.jit, static_argnames=("tile",))
def ucb_select(rewards, counts, t, c=1.0, tile=DEFAULT_TILE):
    """argmax_x UCB(x, t) via per-tile reduction. Returns (best_idx i32, best_score f32)."""
    k = rewards.shape[0]
    tile = min(tile, max(k, 8))
    # Padding lanes: reward -BIG and count +BIG so they can never win the
    # argmax, even when every real arm has a negative reward.
    r = _pad_to_tile(rewards.astype(jnp.float32), tile, jnp.float32(-1e30))
    n = _pad_to_tile(counts.astype(jnp.float32), tile, jnp.float32(1e30))
    grid = r.shape[0] // tile
    maxes, args = pl.pallas_call(
        _select_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.int32),
        ],
        interpret=True,
    )(jnp.stack([jnp.asarray(t, jnp.float32), jnp.asarray(c, jnp.float32)]), r, n)
    best_tile = jnp.argmax(maxes)
    return args[best_tile], maxes[best_tile]
