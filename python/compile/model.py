"""L2 compute graphs for LASP — the functions that get AOT-lowered to HLO.

Each public function here is a pure jax function over fixed-shape arrays,
calling the L1 Pallas kernels (kernels/ucb.py, kernels/gp.py). `aot.py`
lowers one HLO-text artifact per (function, shape) pair; the rust runtime
(`rust/src/runtime/`) loads and executes them on the PJRT CPU client.

Entry points
------------
lasp_step        : the per-iteration hot path — sums/counts -> weighted
                   reward (Eq. 5) -> UCB scores (Eq. 2) -> argmax (Eq. 3).
ucb_scores_graph : scores only (diagnostics, fig6 heatmaps from rust).
reward_norm      : Alg. 1 line 2 + Eq. 5 as a standalone graph.
ucb_episode      : T-step mean-field replay of Alg. 1 as a lax.scan.
gp_propose       : BLISS surrogate — masked GP posterior + EI argmax.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import gp as gpk
from compile.kernels import ucb as ucbk

REWARD_EPS = 1e-2  # guard for the 1/metric inverse in Eq. 5
MINMAX_EPS = 1e-9  # degenerate-range guard for MinMax normalization


def _minmax(v):
    lo = jnp.min(v)
    hi = jnp.max(v)
    return (v - lo) / jnp.maximum(hi - lo, MINMAX_EPS)


def weighted_reward(mean_tau, mean_rho, alpha, beta):
    """Paper Eq. 5 on MinMax-normalized per-arm means, re-normalized to [0,1]."""
    tau_hat = _minmax(mean_tau)
    rho_hat = _minmax(mean_rho)
    raw = alpha / (tau_hat + REWARD_EPS) + beta / (rho_hat + REWARD_EPS)
    return _minmax(raw)


def reward_norm(tau_sum, rho_sum, counts, alpha, beta):
    """Standalone reward graph: running sums + counts -> R[K] in [0, 1].

    Arms never pulled contribute the *mean of pulled arms* to normalization
    (neutral value) so one unpulled arm cannot stretch the MinMax range.
    """
    n = jnp.maximum(counts, 1.0)
    mean_tau = tau_sum / n
    mean_rho = rho_sum / n
    pulled = counts > 0.0
    denom = jnp.maximum(jnp.sum(pulled.astype(jnp.float32)), 1.0)
    fill_tau = jnp.sum(jnp.where(pulled, mean_tau, 0.0)) / denom
    fill_rho = jnp.sum(jnp.where(pulled, mean_rho, 0.0)) / denom
    mean_tau = jnp.where(pulled, mean_tau, fill_tau)
    mean_rho = jnp.where(pulled, mean_rho, fill_rho)
    return (weighted_reward(mean_tau, mean_rho, alpha, beta),)


def lasp_step(tau_sum, rho_sum, counts, t, alpha, beta, c):
    """Fused per-iteration hot path (Alg. 1 lines 4-9).

    Inputs: f32[K] running sums of execution time / power, f32[K] pull
    counts, scalars t, alpha, beta, exploration coefficient c. Returns
    (best_idx i32, best_score f32, rewards f32[K]).
    """
    (rewards,) = reward_norm(tau_sum, rho_sum, counts, alpha, beta)
    idx, score = ucbk.ucb_select(rewards, counts, t, c)
    return idx, score, rewards


def ucb_scores_graph(rewards, counts, t, c):
    """Eq. 2 scores for all arms (Pallas kernel), plus the Eq. 3 argmax."""
    scores = ucbk.ucb_scores(rewards, counts, t, c)
    idx = jnp.argmax(scores).astype(jnp.int32)
    return scores, idx


def ucb_episode(expected_rewards, counts0, t0, c, steps):
    """Mean-field replay of a whole tuning episode as one lax.scan.

    Treats each arm's reward as its (fixed) expectation — the deterministic
    skeleton of Alg. 1, used for fig6/fig7 heatmaps and as an L2 fusion
    showcase. Returns (final counts f32[K], trace i32[steps]).
    """

    def body(carry, _):
        counts, t = carry
        scores = ucbk.ucb_scores(expected_rewards, counts, t, c)
        idx = jnp.argmax(scores).astype(jnp.int32)
        counts = counts.at[idx].add(1.0)
        return (counts, t + 1.0), idx

    (counts, _), trace = jax.lax.scan(
        body, (counts0, t0), None, length=steps
    )
    return counts, trace


def _cg_solve(k_mat, b, iters):
    """Batched conjugate gradient: solve `k_mat @ x = b` for SPD k_mat.

    b: (N, M) right-hand sides. Pure HLO ops only — the obvious
    `jax.scipy.linalg.cho_solve` lowers to a LAPACK typed-FFI custom call
    that xla_extension 0.5.1 (behind the rust `xla` crate) cannot compile,
    so the AOT path needs an iterative solve.
    """

    def body(carry, _):
        x, r, p, rs = carry
        kp = k_mat @ p
        alpha = rs / jnp.maximum(jnp.sum(p * kp, axis=0), 1e-30)
        x = x + p * alpha
        r = r - kp * alpha
        rs_new = jnp.sum(r * r, axis=0)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + p * beta
        return (x, r, p, rs_new), None

    x0 = jnp.zeros_like(b)
    r0 = b
    (x, _, _, _), _ = jax.lax.scan(
        body, (x0, r0, r0, jnp.sum(r0 * r0, axis=0)), None, length=iters
    )
    return x


def gp_propose(x, y, mask, xs, lengthscale, noise, best):
    """BLISS surrogate step: masked GP posterior at candidates + EI argmax.

    x: f32[N, D] observed configs (padded), y: f32[N] observed rewards,
    mask: f32[N] (1 = real row), xs: f32[M, D] candidate configs.
    Returns (mean f32[M], var f32[M], ei f32[M], best_idx i32).

    Masking decouples padded rows exactly: K' = M·K·M + (I − M) + σ²·M with
    M = diag(mask), so padded coordinates reduce to the identity equation
    and contribute nothing to the posterior.
    """
    n = x.shape[0]
    k = gpk.rbf_matrix(x, x, lengthscale)
    mm = mask[:, None] * mask[None, :]
    k = k * mm + jnp.diag((1.0 - mask) + noise * mask)
    ks = gpk.rbf_matrix(x, xs, lengthscale) * mask[:, None]  # (N, M)
    rhs = jnp.concatenate([(y * mask)[:, None], ks], axis=1)
    sol = _cg_solve(k, rhs, iters=2 * n)
    alpha_v = sol[:, 0]
    v = sol[:, 1:]
    mean = ks.T @ alpha_v
    var = jnp.maximum(1.0 - jnp.sum(ks * v, axis=0), 1e-12)
    std = jnp.sqrt(var)
    xi = 0.01
    z = (mean - best - xi) / std
    phi = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    cdf = 0.5 * (1.0 + jnp.tanh(0.7978845608028654 * (z + 0.044715 * z**3)))
    ei = (mean - best - xi) * cdf + std * phi
    best_idx = jnp.argmax(ei).astype(jnp.int32)
    return mean, var, ei, best_idx


# ---------------------------------------------------------------------------
# jit wrappers with static episode length (for lowering + python-side tests)
# ---------------------------------------------------------------------------

lasp_step_jit = jax.jit(lasp_step)
ucb_scores_jit = jax.jit(ucb_scores_graph)
reward_norm_jit = jax.jit(reward_norm)
gp_propose_jit = jax.jit(gp_propose)
ucb_episode_jit = jax.jit(functools.partial(ucb_episode), static_argnames="steps")
