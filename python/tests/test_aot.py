"""AOT plumbing: the lowering plan is well-formed and HLO text round-trips."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestPlan:
    def test_plan_covers_all_apps(self):
        plan = aot.build_plan()
        names = {p["name"] for p in plan}
        for app in ("lulesh", "kripke", "clomp", "hypre"):
            assert f"lasp_step_{app}" in names
            assert f"ucb_scores_{app}" in names
            assert f"reward_norm_{app}" in names
        assert "gp_propose" in names

    def test_arm_counts_match_table2(self):
        # Table II sizes: kripke 216, lulesh 128, clomp 125, hypre 92160.
        assert aot.APP_SPACES == {
            "lulesh": 128,
            "kripke": 216,
            "clomp": 125,
            "hypre": 92160,
        }

    def test_plan_shapes_consistent(self):
        for item in aot.build_plan():
            assert len(item["specs"]) == len(item["inputs"])
            for spec, desc in zip(item["specs"], item["inputs"]):
                assert list(spec.shape) == desc["shape"]


class TestHloText:
    def test_small_artifact_lowering_smoke(self):
        lowered = jax.jit(model.ucb_scores_graph).lower(
            jax.ShapeDtypeStruct((125,), jnp.float32),
            jax.ShapeDtypeStruct((125,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32[125]" in text

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
        reason="artifacts not built (run `make artifacts`)",
    )
    def test_manifest_matches_files(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["format"] == "hlo-text"
        assert manifest["return_tuple"] is True
        for art in manifest["artifacts"]:
            path = os.path.join(ART_DIR, art["file"])
            assert os.path.exists(path), art["file"]
            with open(path) as fh:
                head = fh.read(4096)
            assert "HloModule" in head
