"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes, dtypes-adjacent ranges, and degenerate cases; each
property is the kernel == oracle contract the rust runtime relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gp, ref, ucb

jax.config.update("jax_platform_name", "cpu")


def _rewards_counts(seed, k, max_count=20.0):
    kr, kc = jax.random.split(jax.random.PRNGKey(seed))
    r = jax.random.uniform(kr, (k,), dtype=jnp.float32)
    n = jnp.floor(jax.random.uniform(kc, (k,), dtype=jnp.float32) * max_count)
    return r, n


# ---------------------------------------------------------------------------
# ucb_scores kernel
# ---------------------------------------------------------------------------


class TestUcbScores:
    @pytest.mark.parametrize("k", [1, 7, 8, 120, 125, 128, 216, 1023, 1024, 1025, 4096])
    def test_matches_ref_across_sizes(self, k):
        r, n = _rewards_counts(k, k)
        t = jnp.float32(17.0)
        np.testing.assert_allclose(
            ucb.ucb_scores(r, n, t), ref.ucb_scores(r, n, t), rtol=1e-6
        )

    def test_hypre_size(self):
        r, n = _rewards_counts(0, 92160)
        t = jnp.float32(501.0)
        np.testing.assert_allclose(
            ucb.ucb_scores(r, n, t), ref.ucb_scores(r, n, t), rtol=1e-6
        )

    def test_unpulled_arm_scores_big(self):
        r = jnp.array([0.5, 0.9, 0.1], jnp.float32)
        n = jnp.array([3.0, 0.0, 1.0], jnp.float32)
        s = ucb.ucb_scores(r, n, jnp.float32(5.0))
        assert float(s[1]) == ucb.UNPULLED_SCORE
        assert float(s[0]) < ucb.UNPULLED_SCORE

    def test_t_equals_one_gives_zero_bonus(self):
        # ln 1 = 0: score must equal the raw reward for pulled arms.
        r = jnp.array([0.25, 0.75], jnp.float32)
        n = jnp.array([1.0, 2.0], jnp.float32)
        s = ucb.ucb_scores(r, n, jnp.float32(1.0))
        np.testing.assert_allclose(s, r, atol=1e-7)

    def test_t_below_one_clamped(self):
        # t = 0 would be log(0); kernel clamps to t >= 1.
        r = jnp.array([0.3], jnp.float32)
        n = jnp.array([2.0], jnp.float32)
        s = ucb.ucb_scores(r, n, jnp.float32(0.0))
        np.testing.assert_allclose(s, r, atol=1e-7)

    def test_bonus_decreases_with_count(self):
        r = jnp.zeros((4,), jnp.float32)
        n = jnp.array([1.0, 2.0, 4.0, 8.0], jnp.float32)
        s = np.asarray(ucb.ucb_scores(r, n, jnp.float32(100.0)))
        assert (np.diff(s) < 0).all()

    @settings(max_examples=30, deadline=None)
    @given(
        k=st.integers(1, 300),
        t=st.floats(1.0, 1e6),
        seed=st.integers(0, 2**31 - 1),
        tile=st.sampled_from([8, 32, 128, 1024]),
    )
    def test_property_matches_ref(self, k, t, seed, tile):
        r, n = _rewards_counts(seed, k)
        tt = jnp.float32(t)
        np.testing.assert_allclose(
            ucb.ucb_scores(r, n, tt, tile=tile),
            ref.ucb_scores(r, n, tt),
            rtol=1e-5,
        )


# ---------------------------------------------------------------------------
# ucb_select kernel (per-tile max/argmax reduction)
# ---------------------------------------------------------------------------


class TestUcbSelect:
    @pytest.mark.parametrize("k", [1, 5, 128, 216, 1024, 5000])
    def test_matches_ref(self, k):
        r, n = _rewards_counts(k + 1, k)
        t = jnp.float32(42.0)
        ik, sk = ucb.ucb_select(r, n, t)
        ir, sr = ref.ucb_select(r, n, t)
        assert int(ik) == int(ir)
        np.testing.assert_allclose(float(sk), float(sr), rtol=1e-6)

    def test_prefers_unpulled_arm(self):
        r = jnp.array([0.99, 0.01, 0.5], jnp.float32)
        n = jnp.array([10.0, 0.0, 10.0], jnp.float32)
        idx, _ = ucb.ucb_select(r, n, jnp.float32(100.0))
        assert int(idx) == 1

    def test_padding_never_wins(self):
        # k = 9 with tile 8 pads 7 lanes; none may be selected.
        r = jnp.full((9,), -5.0, jnp.float32)
        n = jnp.ones((9,), jnp.float32)
        idx, _ = ucb.ucb_select(r, n, jnp.float32(2.0), tile=8)
        assert 0 <= int(idx) < 9

    @settings(max_examples=30, deadline=None)
    @given(
        k=st.integers(2, 400),
        t=st.floats(1.0, 1e5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_select_is_argmax(self, k, t, seed):
        r, n = _rewards_counts(seed, k)
        tt = jnp.float32(t)
        idx, score = ucb.ucb_select(r, n, tt)
        scores = ref.ucb_scores(r, n, tt)
        np.testing.assert_allclose(float(score), float(jnp.max(scores)), rtol=1e-5)
        np.testing.assert_allclose(
            float(scores[int(idx)]), float(jnp.max(scores)), rtol=1e-5
        )


# ---------------------------------------------------------------------------
# RBF kernel matrix (BLISS GP surrogate)
# ---------------------------------------------------------------------------


class TestRbfMatrix:
    @pytest.mark.parametrize(
        "n,m,d", [(1, 1, 1), (8, 8, 4), (40, 70, 12), (128, 128, 12), (130, 200, 3)]
    )
    def test_matches_ref(self, n, m, d):
        kx, ky = jax.random.split(jax.random.PRNGKey(n * 1000 + m))
        x = jax.random.normal(kx, (n, d), jnp.float32)
        y = jax.random.normal(ky, (m, d), jnp.float32)
        got = gp.rbf_matrix(x, y, jnp.float32(1.3))
        want = ref.rbf_matrix(x, y, jnp.float32(1.3))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_diagonal_is_one(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 5), jnp.float32)
        k = gp.rbf_matrix(x, x, jnp.float32(0.7))
        np.testing.assert_allclose(jnp.diag(k), jnp.ones(16), atol=1e-5)

    def test_symmetry(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (33, 6), jnp.float32)
        k = np.asarray(gp.rbf_matrix(x, x, jnp.float32(2.0)))
        np.testing.assert_allclose(k, k.T, atol=1e-5)

    def test_values_in_unit_interval(self):
        x = 10.0 * jax.random.normal(jax.random.PRNGKey(2), (20, 4), jnp.float32)
        k = np.asarray(gp.rbf_matrix(x, x, jnp.float32(0.5)))
        assert (k >= 0.0).all() and (k <= 1.0 + 1e-6).all()

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 60),
        m=st.integers(1, 60),
        d=st.integers(1, 16),
        ls=st.floats(0.1, 10.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_matches_ref(self, n, m, d, ls, seed):
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (n, d), jnp.float32)
        y = jax.random.normal(ky, (m, d), jnp.float32)
        np.testing.assert_allclose(
            gp.rbf_matrix(x, y, jnp.float32(ls)),
            ref.rbf_matrix(x, y, jnp.float32(ls)),
            rtol=1e-4,
            atol=1e-5,
        )
