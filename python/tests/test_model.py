"""L2 correctness: the lowered compute graphs implement the paper's math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _sums(seed, k):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    counts = jnp.floor(jax.random.uniform(k3, (k,)) * 6)
    tau_sum = jax.random.uniform(k1, (k,), minval=0.5, maxval=5.0) * counts
    rho_sum = jax.random.uniform(k2, (k,), minval=1.0, maxval=10.0) * counts
    return tau_sum, rho_sum, counts


class TestRewardNorm:
    def test_rewards_in_unit_interval(self):
        tau_sum, rho_sum, counts = _sums(0, 64)
        (r,) = model.reward_norm_jit(
            tau_sum, rho_sum, counts, jnp.float32(0.8), jnp.float32(0.2)
        )
        r = np.asarray(r)
        assert (r >= 0.0).all() and (r <= 1.0 + 1e-6).all()

    def test_fastest_arm_gets_best_reward_time_focus(self):
        # alpha = 1: reward is monotone decreasing in mean execution time.
        counts = jnp.ones((8,), jnp.float32)
        tau_sum = jnp.arange(1, 9, dtype=jnp.float32)
        rho_sum = jnp.ones((8,), jnp.float32)
        (r,) = model.reward_norm_jit(
            tau_sum, rho_sum, counts, jnp.float32(1.0), jnp.float32(0.0)
        )
        r = np.asarray(r)
        assert r.argmax() == 0
        assert (np.diff(r) <= 1e-6).all()

    def test_power_focus_flips_ranking(self):
        counts = jnp.ones((4,), jnp.float32)
        tau_sum = jnp.array([1.0, 2.0, 3.0, 4.0], jnp.float32)
        rho_sum = jnp.array([4.0, 3.0, 2.0, 1.0], jnp.float32)
        (rt,) = model.reward_norm_jit(
            tau_sum, rho_sum, counts, jnp.float32(1.0), jnp.float32(0.0)
        )
        (rp,) = model.reward_norm_jit(
            tau_sum, rho_sum, counts, jnp.float32(0.0), jnp.float32(1.0)
        )
        assert np.asarray(rt).argmax() == 0
        assert np.asarray(rp).argmax() == 3

    def test_unpulled_arms_neutral(self):
        # An unpulled arm must not stretch the MinMax range.
        counts = jnp.array([2.0, 2.0, 0.0], jnp.float32)
        tau_sum = jnp.array([2.0, 6.0, 0.0], jnp.float32)
        rho_sum = jnp.array([4.0, 4.0, 0.0], jnp.float32)
        (r,) = model.reward_norm_jit(
            tau_sum, rho_sum, counts, jnp.float32(1.0), jnp.float32(0.0)
        )
        r = np.asarray(r)
        assert r[0] == pytest.approx(1.0, abs=1e-5)  # fastest pulled arm
        assert r[2] == pytest.approx(r[1:3].mean(), abs=0.5)  # mid-range-ish
        assert 0.0 <= r[2] <= 1.0

    def test_matches_ref_weighted_reward(self):
        counts = jnp.full((32,), 3.0, jnp.float32)
        tau_sum, rho_sum, _ = _sums(7, 32)
        (got,) = model.reward_norm_jit(
            tau_sum, rho_sum, counts, jnp.float32(0.6), jnp.float32(0.4)
        )
        want = ref.weighted_reward(
            tau_sum / 3.0, rho_sum / 3.0, jnp.float32(0.6), jnp.float32(0.4)
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


class TestLaspStep:
    def test_selects_unpulled_first(self):
        k = 16
        tau_sum = jnp.zeros((k,), jnp.float32).at[: k - 1].set(1.0)
        rho_sum = tau_sum
        counts = jnp.zeros((k,), jnp.float32).at[: k - 1].set(1.0)
        idx, score, _ = model.lasp_step_jit(
            tau_sum, rho_sum, counts, jnp.float32(16.0), jnp.float32(0.8),
            jnp.float32(0.2), jnp.float32(1.0),
        )
        assert int(idx) == k - 1

    def test_converges_to_best_arm_when_exploited(self):
        # After heavy sampling, argmax should be the arm with the best reward.
        k = 8
        counts = jnp.full((k,), 1000.0, jnp.float32)
        tau = jnp.array([5.0, 4.0, 3.0, 2.0, 1.0, 6.0, 7.0, 8.0], jnp.float32)
        idx, _, rewards = model.lasp_step_jit(
            tau * counts, jnp.ones((k,)) * counts, counts,
            jnp.float32(8000.0), jnp.float32(1.0), jnp.float32(0.0), jnp.float32(1.0),
        )
        assert int(idx) == 4
        assert np.asarray(rewards).argmax() == 4

    @settings(max_examples=15, deadline=None)
    @given(k=st.integers(2, 200), seed=st.integers(0, 2**31 - 1))
    def test_property_idx_in_range_and_rewards_bounded(self, k, seed):
        tau_sum, rho_sum, counts = _sums(seed, k)
        idx, score, rewards = model.lasp_step_jit(
            tau_sum, rho_sum, counts,
            jnp.float32(counts.sum() + 1.0), jnp.float32(0.5), jnp.float32(0.5),
            jnp.float32(1.0),
        )
        assert 0 <= int(idx) < k
        r = np.asarray(rewards)
        assert (r >= -1e-6).all() and (r <= 1.0 + 1e-6).all()


class TestUcbEpisode:
    def test_matches_ref_replay(self):
        k = 12
        r = jax.random.uniform(jax.random.PRNGKey(0), (k,))
        c0 = jnp.zeros((k,), jnp.float32)
        counts, trace = model.ucb_episode_jit(r, c0, jnp.float32(1.0), jnp.float32(1.0), steps=60)
        counts_ref, trace_ref = ref.ucb_episode(r, 1.0, c0, 60)
        np.testing.assert_array_equal(np.asarray(trace), np.asarray(trace_ref))
        np.testing.assert_allclose(counts, counts_ref)

    def test_plays_each_arm_then_concentrates(self):
        k = 6
        r = jnp.array([0.1, 0.2, 0.95, 0.3, 0.4, 0.5], jnp.float32)
        counts, trace = model.ucb_episode_jit(
            r, jnp.zeros((k,)), jnp.float32(1.0), jnp.float32(1.0), steps=300
        )
        counts = np.asarray(counts)
        assert (counts >= 1).all()  # every arm tried
        assert counts.argmax() == 2  # best arm dominates
        assert counts[2] > 0.5 * 300

    def test_total_count_equals_steps(self):
        k = 9
        r = jax.random.uniform(jax.random.PRNGKey(3), (k,))
        counts, _ = model.ucb_episode_jit(
            r, jnp.zeros((k,)), jnp.float32(1.0), jnp.float32(1.0), steps=120
        )
        assert float(counts.sum()) == 120.0


class TestGpPropose:
    def test_posterior_matches_ref(self):
        N, M, D = 24, 40, 6
        x = jax.random.normal(jax.random.PRNGKey(1), (N, D))
        y = jax.random.uniform(jax.random.PRNGKey(2), (N,))
        mask = jnp.where(jnp.arange(N) < 15, 1.0, 0.0)
        xs = jax.random.normal(jax.random.PRNGKey(3), (M, D))
        mean, var, ei, bi = model.gp_propose_jit(
            x, y, mask, xs, jnp.float32(1.0), jnp.float32(1e-3), jnp.float32(0.5)
        )
        mr, vr = ref.gp_posterior(x, y, mask, xs, jnp.float32(1.0), jnp.float32(1e-3))
        np.testing.assert_allclose(mean, mr, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(var, vr, rtol=1e-3, atol=1e-4)
        assert 0 <= int(bi) < M

    def test_interpolates_training_points(self):
        # Posterior mean at an observed point ~ its observed value.
        N, D = 10, 3
        x = jax.random.normal(jax.random.PRNGKey(4), (N, D))
        y = jax.random.uniform(jax.random.PRNGKey(5), (N,))
        mask = jnp.ones((N,))
        mean, var, _, _ = model.gp_propose_jit(
            x, y, mask, x, jnp.float32(1.0), jnp.float32(1e-4), jnp.float32(0.0)
        )
        np.testing.assert_allclose(mean, y, atol=1e-2)
        assert (np.asarray(var) < 1e-2).all()

    def test_variance_high_far_from_data(self):
        N, D = 8, 2
        x = jax.random.normal(jax.random.PRNGKey(6), (N, D)) * 0.1
        y = jnp.ones((N,)) * 0.5
        mask = jnp.ones((N,))
        far = jnp.full((4, D), 50.0, jnp.float32)
        _, var, _, _ = model.gp_propose_jit(
            x, y, mask, far, jnp.float32(1.0), jnp.float32(1e-4), jnp.float32(0.5)
        )
        np.testing.assert_allclose(var, 1.0, atol=1e-3)

    def test_ei_nonnegative(self):
        N, M, D = 16, 30, 4
        x = jax.random.normal(jax.random.PRNGKey(7), (N, D))
        y = jax.random.uniform(jax.random.PRNGKey(8), (N,))
        mask = jnp.ones((N,))
        xs = jax.random.normal(jax.random.PRNGKey(9), (M, D))
        _, _, ei, _ = model.gp_propose_jit(
            x, y, mask, xs, jnp.float32(1.5), jnp.float32(1e-3), jnp.float32(float(y.max()))
        )
        assert (np.asarray(ei) >= -1e-4).all()
