//! CLOMP performance model (Table II: partsPerThread ∈ {10,20,50,70,90},
//! zonesPerPart ∈ {100,300,500,700,900}, zoneSize ∈ {32,128,512,1024,2048}
//! bytes; defaults 10/100/512; 125 configs).
//!
//! CLOMP (Bronevetsky et al.) measures OpenMP threading overhead on an
//! inner-loop workload under strong scaling: total work is ~fixed, so the
//! knobs trade *scheduling overhead* against *cache behaviour*:
//!
//! * `partsPerThread` — more parts = finer dynamic-scheduling granularity:
//!   better load balance (imbalance ~ 1/parts) but linear per-part dispatch
//!   overhead.
//! * `zonesPerPart` × `zoneSize` — the per-part working set. Below L1 the
//!   per-zone loop overhead dominates (tiny zones); above L2 the part
//!   streams from memory. Sweet spot in the middle, and it *shifts* with
//!   partsPerThread because parts share L2 capacity (interaction).

use super::{fidelity_scale, micro_jitter, AppKind, AppModel, Workload};
use crate::space::{ParamDef, ParamSpace};

/// See module docs.
pub struct Clomp {
    space: ParamSpace,
}

const APP_TAG: u64 = 0x434C_4F4D_50; // "CLOMP"

impl Clomp {
    pub fn new() -> Self {
        let space = ParamSpace::new(
            "clomp",
            vec![
                ParamDef::ints("partsPerThread", &[10, 20, 50, 70, 90], 10)
                    .describe("# of independent pieces of work per thread"),
                ParamDef::ints("zonesPerPart", &[100, 300, 500, 700, 900], 100)
                    .describe("number of zones"),
                ParamDef::ints("zoneSize", &[32, 128, 512, 1024, 2048], 512)
                    .describe("bytes in zone"),
            ],
        );
        Clomp { space }
    }
}

impl Default for Clomp {
    fn default() -> Self {
        Self::new()
    }
}

impl AppModel for Clomp {
    fn kind(&self) -> AppKind {
        AppKind::Clomp
    }

    fn space(&self) -> &ParamSpace {
        &self.space
    }

    fn workload(&self, index: usize, fidelity: f64) -> Workload {
        // Allocation-free per-dimension decode: workload() sits on the
        // episode hot path.
        let parts = self.space.value_at(index, 0).as_int() as f64;
        let zones = self.space.value_at(index, 1).as_int() as f64;
        let zsize = self.space.value_at(index, 2).as_int() as f64;

        // Strong scaling: fixed total byte-work, fidelity-scaled.
        let total_bytes = 4.0e8 * fidelity_scale(fidelity, 0.05);
        // The chosen decomposition processes total_bytes in units of
        // parts × zones × zsize; the *number of passes* over the
        // decomposition is what varies.
        let bytes_per_pass = parts * zones * zsize;
        let passes = total_bytes / bytes_per_pass;

        // Per-zone loop overhead: fixed cost per zone visit; small zones are
        // overhead-dominated (CLOMP's headline effect).
        let per_zone_cost = 90.0; // "cycles" per zone dispatch
        let zone_overhead = passes * parts * zones * per_zone_cost;
        // Per-part OpenMP dispatch cost.
        let part_overhead = passes * parts * 2_500.0;
        // Streaming cost of the actual bytes.
        let byte_cost = total_bytes * 0.9;

        // Cache: per-part working set vs shared L2 slice.
        let ws = zones * zsize;
        let l2_slice = 512.0 * 1024.0 / 4.0; // per-thread slice of L2
        let cache_penalty = if ws > l2_slice {
            1.0 + 0.35 * (ws / l2_slice).ln()
        } else if ws < 8.0 * 1024.0 {
            1.05 // tiny working sets thrash the loop, minor penalty
        } else {
            1.0
        };
        // Load imbalance improves with more parts (dynamic scheduling).
        let imbalance = 1.0 + 0.18 / (parts / 10.0);

        let jitter = 1.0 + 0.02 * micro_jitter(APP_TAG, index);
        let cycles = (byte_cost * cache_penalty + zone_overhead + part_overhead)
            * imbalance
            * jitter;
        let compute = cycles / 1e9; // reference core-seconds

        Workload {
            compute,
            mem_intensity: (0.35 + 0.45 * (ws / (ws + l2_slice))).min(1.0),
            parallel_frac: (0.88 + 0.04 * (parts / 90.0)).min(0.96),
            overhead: 0.006 + 0.00002 * parts,
        }
        .sanitized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn all_times(q: f64) -> Vec<f64> {
        let app = Clomp::new();
        app.space()
            .indices()
            .map(|i| {
                let w = app.workload(i, q);
                w.compute + w.overhead
            })
            .collect()
    }

    #[test]
    fn space_matches_table2() {
        let app = Clomp::new();
        assert_eq!(app.space().len(), 125);
        let d = app.space().decode(app.default_index());
        assert_eq!(d.values[0].as_int(), 10);
        assert_eq!(d.values[1].as_int(), 100);
        assert_eq!(d.values[2].as_int(), 512);
    }

    #[test]
    fn tiny_zones_overhead_dominated() {
        // zoneSize=32 must be slower than zoneSize=512 at defaults.
        let app = Clomp::new();
        let small = app.space().encode_positions(&[0, 0, 0]); // 32 B zones
        let mid = app.space().encode_positions(&[0, 0, 2]); // 512 B zones
        assert!(app.workload(small, 1.0).compute > app.workload(mid, 1.0).compute);
    }

    #[test]
    fn default_is_suboptimal() {
        let t = all_times(1.0);
        let app = Clomp::new();
        let oracle = stats::argmin(&t);
        assert_ne!(oracle, app.default_index());
        let gain = (t[app.default_index()] - t[oracle]) / t[app.default_index()];
        // Fig 8 reports ~10% class gains for Clomp; our surface must allow
        // a tuning gain of at least a few percent and at most ~60%.
        assert!(gain > 0.03 && gain < 0.6, "gain {gain}");
    }

    #[test]
    fn interaction_sweet_spot_shifts() {
        // Optimal zoneSize depends on partsPerThread.
        let app = Clomp::new();
        let best_zsize = |ppos: usize| {
            (0..5)
                .min_by(|&a, &b| {
                    let ia = app.space().encode_positions(&[ppos, 2, a]);
                    let ib = app.space().encode_positions(&[ppos, 2, b]);
                    app.workload(ia, 1.0)
                        .compute
                        .total_cmp(&app.workload(ib, 1.0).compute)
                })
                .unwrap()
        };
        // Not asserting a specific shift direction, only that the surface
        // is not separable in the two parameters everywhere.
        let shifts: Vec<usize> = (0..5).map(best_zsize).collect();
        assert!(shifts.iter().any(|&z| z != shifts[0]) || {
            // Fall back: check interaction through zonesPerPart instead.
            let by_zones: Vec<usize> = (0..5)
                .map(|zpos| {
                    (0..5)
                        .min_by(|&a, &b| {
                            let ia = app.space().encode_positions(&[2, zpos, a]);
                            let ib = app.space().encode_positions(&[2, zpos, b]);
                            app.workload(ia, 1.0)
                                .compute
                                .total_cmp(&app.workload(ib, 1.0).compute)
                        })
                        .unwrap()
                })
                .collect();
            by_zones.iter().any(|&z| z != by_zones[0])
        });
    }

    #[test]
    fn lf_hf_top20_overlap() {
        let lf = all_times(0.15);
        let hf = all_times(1.0);
        let a: std::collections::HashSet<_> = stats::bottom_k(&lf, 20).into_iter().collect();
        let b: std::collections::HashSet<_> = stats::bottom_k(&hf, 20).into_iter().collect();
        assert!(a.intersection(&b).count() >= 8);
    }
}
