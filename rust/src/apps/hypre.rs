//! Hypre (BoomerAMG) performance model — the paper's large space
//! (Table II: 10 solver parameters + processor grid, stated size **92,160**).
//!
//! Table II lists continuous/wide ranges (e.g. strong_threshold ∈ [0,1],
//! trunc_factor ∈ 1-10) that cannot multiply to exactly 92,160 without a
//! discretization the paper does not spell out. We pick the discretization
//! below, which (a) contains every Table II default, (b) spans the printed
//! ranges, and (c) multiplies to exactly 92,160:
//!
//! | param              | domain              | default |
//! |--------------------|---------------------|---------|
//! | Px                 | {2, 4}              | 2       |
//! | Py                 | {2, 4}              | 2       |
//! | strong_threshold   | {0.1,0.25,0.5,0.9}  | 0.25    |
//! | trunc_factor       | {1,2,4,6,8}         | 2       |
//! | P_max_elmts        | {1, 4}              | 1       |
//! | coarsen_type       | {1,2,3}             | 1       |
//! | relax_type         | {1,2}               | 1       |
//! | smooth_type        | {0,1}               | 0       |
//! | smooth_num_levels  | {1,2,3,4}           | 3       |
//! | interp_type        | {1,2,3}             | 1       |
//! | agg_num_levels     | {1,2,5,10}          | 2       |
//!
//! 2·2·4·5·2·3·2·2·4·3·4 = 92,160.
//!
//! Model: AMG total time = setup + iterations × per-iteration cost, the
//! classic AMG trade surface — parameters move *iterations to converge*
//! (coarsening/interpolation quality) against *operator complexity*
//! (denser operators converge in fewer, costlier sweeps). Fidelity scales
//! the grid as m³ (paper §II-C: m from 10 to 100, cost O(m³)).

use super::{fidelity_scale, micro_jitter, AppKind, AppModel, Workload};
use crate::space::{ParamDef, ParamSpace};

/// See module docs.
pub struct Hypre {
    space: ParamSpace,
}

const APP_TAG: u64 = 0x4859_5052_45; // "HYPRE"

impl Hypre {
    pub fn new() -> Self {
        let space = ParamSpace::new(
            "hypre",
            vec![
                ParamDef::ints("Px", &[2, 4], 2).describe("processor grid x"),
                ParamDef::ints("Py", &[2, 4], 2).describe("processor grid y"),
                ParamDef::floats("strong_threshold", &[0.1, 0.25, 0.5, 0.9], 0.25)
                    .describe("AMG strength threshold"),
                ParamDef::ints("trunc_factor", &[1, 2, 4, 6, 8], 2)
                    .describe("truncation factor for interpolation"),
                ParamDef::ints("P_max_elmts", &[1, 4], 1)
                    .describe("max elements per row (AMG)"),
                ParamDef::ints("coarsen_type", &[1, 2, 3], 1)
                    .describe("algorithm for parallel coarsening"),
                ParamDef::ints("relax_type", &[1, 2], 1)
                    .describe("which smoother to be used"),
                ParamDef::ints("smooth_type", &[0, 1], 0)
                    .describe("number of smoothing levels (type)"),
                ParamDef::ints("smooth_num_levels", &[1, 2, 3, 4], 3)
                    .describe("smoother level count"),
                ParamDef::ints("interp_type", &[1, 2, 3], 1)
                    .describe("parallel interpolation operator selection"),
                ParamDef::ints("agg_num_levels", &[1, 2, 5, 10], 2)
                    .describe("levels of aggressive coarsening applied"),
            ],
        );
        Hypre { space }
    }
}

impl Default for Hypre {
    fn default() -> Self {
        Self::new()
    }
}

impl AppModel for Hypre {
    fn kind(&self) -> AppKind {
        AppKind::Hypre
    }

    fn space(&self) -> &ParamSpace {
        &self.space
    }

    fn workload(&self, index: usize, fidelity: f64) -> Workload {
        // Allocation-free per-dimension decode (episode hot path; at 92k
        // arms this is the sweep engine's hottest workload builder).
        let v = |dim: usize| self.space.value_at(index, dim);
        let px = v(0).as_int() as f64;
        let py = v(1).as_int() as f64;
        let strong = v(2).as_float();
        let trunc = v(3).as_int() as f64;
        let pmax = v(4).as_int() as f64;
        let coarsen = v(5).as_int();
        let relax = v(6).as_int();
        let smooth_type = v(7).as_int();
        let smooth_lvls = v(8).as_int() as f64;
        let interp = v(9).as_int();
        let agg = v(10).as_int() as f64;

        // ---- iterations to converge -------------------------------------
        // strong_threshold: classic convex valley around 0.25-0.5 for 3-D
        // Laplacians.
        let strong_f = 1.0 + 2.2 * (strong - 0.35).powi(2) / 0.35;
        // Aggressive coarsening: each aggressive level weakens interpolation
        // (more iters) but shrinks the hierarchy (cheaper iters).
        let agg_iters = 1.0 + 0.05 * agg;
        // Interp/coarsen compatibility matrix: some pairs are known-good.
        let pair = match (coarsen, interp) {
            (1, 1) => 1.00, // Falgout + classical
            (1, 2) => 0.95,
            (1, 3) => 1.10,
            (2, 1) => 1.12, // PMIS prefers distance-two interp
            (2, 2) => 0.92,
            (2, 3) => 1.05,
            (3, 1) => 1.20, // HMIS + classical: weak
            (3, 2) => 1.00,
            (3, 3) => 0.97,
            _ => 1.1,
        };
        // Truncation/Pmax sparsify interpolation: fewer coefficients = more
        // iterations, less work per iteration.
        let sparsity = 1.0 / (1.0 + 0.35 * (trunc / 8.0) + 0.25 * ((pmax - 1.0) / 3.0));
        let iter_sparsity = 1.0 + 0.30 * (1.0 - sparsity);
        // Better smoothers converge faster.
        let smoother_iters = match (relax, smooth_type) {
            (1, 0) => 1.00, // hybrid GS
            (2, 0) => 0.93, // L1-GS
            (1, 1) => 0.88, // + Schwarz pre-smoothing
            (2, 1) => 0.85,
            _ => 1.0,
        };
        let smooth_gain = 1.0 / (1.0 + 0.05 * (smooth_lvls - 1.0));
        let iters = 10.0
            * strong_f
            * agg_iters
            * pair
            * iter_sparsity
            * smoother_iters
            * smooth_gain;

        // ---- per-iteration cost -----------------------------------------
        // Grid work: m³ scaled by fidelity (m: 10 → 100 per the paper).
        let grid_work = fidelity_scale(fidelity, 0.001); // ~m³ ratio 10³/100³
        // Operator complexity: denser interpolation = more nnz per sweep.
        let op_complexity = 1.0 + 0.8 * sparsity - 0.04 * agg;
        // Smoothing cost per level count / type.
        let smooth_cost = 1.0
            + 0.08 * (smooth_lvls - 1.0)
            + if smooth_type == 1 { 0.22 } else { 0.0 }
            + if relax == 2 { 0.06 } else { 0.0 };
        // Processor grid: the model problem is a 4-rank job; (2,2) balances,
        // elongated/oversubscribed grids pay communication.
        let ranks = px * py;
        let aspect = (px / py).max(py / px);
        let comm = 1.0 + 0.06 * (aspect - 1.0) + 0.05 * ((ranks / 4.0) - 1.0).abs();

        let per_iter = 2.8e-1 * grid_work * op_complexity * smooth_cost * comm;
        // AMG setup: coarsening pass, pricier for PMIS/HMIS + aggressive.
        let setup = 1.5e-0
            * grid_work
            * (1.0 + 0.10 * (coarsen as f64 - 1.0) + 0.02 * agg)
            * op_complexity;

        let jitter = 1.0 + 0.025 * micro_jitter(APP_TAG, index);
        let compute = (setup + iters * per_iter) * jitter;

        Workload {
            compute,
            mem_intensity: (0.55 + 0.15 * (op_complexity - 1.0)).min(1.0),
            parallel_frac: (0.90 - 0.02 * (aspect - 1.0)).clamp(0.5, 0.97),
            overhead: 0.012 + 0.002 * ranks,
        }
        .sanitized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn space_matches_table2_size() {
        let app = Hypre::new();
        assert_eq!(app.space().len(), 92_160);
        assert_eq!(app.space().dims(), 11);
    }

    #[test]
    fn defaults_match_table2() {
        let app = Hypre::new();
        let d = app.space().decode(app.default_index());
        assert_eq!(d.values[0].as_int(), 2); // Px
        assert_eq!(d.values[2].as_float(), 0.25); // strong_threshold
        assert_eq!(d.values[8].as_int(), 3); // smooth_num_levels
        assert_eq!(d.values[10].as_int(), 2); // agg_num_levels
    }

    #[test]
    fn strong_threshold_valley() {
        // 0.25 or 0.5 should beat both extremes with everything else default.
        let app = Hypre::new();
        let t = |pos: usize| {
            let mut p = app.space().default_positions();
            p[2] = pos;
            let i = app.space().encode_positions(&p);
            app.workload(i, 1.0).compute
        };
        assert!(t(1).min(t(2)) < t(0));
        assert!(t(1).min(t(2)) < t(3));
    }

    #[test]
    fn exhaustive_sweep_is_fast_and_sane() {
        let app = Hypre::new();
        let start = std::time::Instant::now();
        let mut best = f64::INFINITY;
        let mut worst: f64 = 0.0;
        for i in app.space().indices() {
            let c = app.workload(i, 1.0).compute;
            best = best.min(c);
            worst = worst.max(c);
        }
        assert!(best > 0.0 && worst / best > 1.5, "range {}", worst / best);
        // The oracle sweep must stay cheap — it backs Fig 2/9 benches.
        assert!(start.elapsed().as_secs_f64() < 5.0);
    }

    #[test]
    fn default_leaves_headroom() {
        // Fig 8 reports ~9% (power-focus) gains for Hypre; the time surface
        // must give the tuner something to find.
        let app = Hypre::new();
        let times: Vec<f64> = app
            .space()
            .indices()
            .map(|i| app.workload(i, 1.0).compute)
            .collect();
        let oracle = stats::argmin(&times);
        let gain =
            (times[app.default_index()] - times[oracle]) / times[app.default_index()];
        assert!(gain > 0.05, "gain {gain}");
        assert!(gain < 0.7, "gain {gain}");
    }

    #[test]
    fn lf_hf_top20_overlap_sampled() {
        // Full-space LF/HF double sweep is fine too (fast model).
        let app = Hypre::new();
        let lf: Vec<f64> = app.space().indices().map(|i| {
            let w = app.workload(i, 0.15);
            w.compute + w.overhead
        }).collect();
        let hf: Vec<f64> = app.space().indices().map(|i| {
            let w = app.workload(i, 1.0);
            w.compute + w.overhead
        }).collect();
        let a: std::collections::HashSet<_> = stats::bottom_k(&lf, 20).into_iter().collect();
        let b: std::collections::HashSet<_> = stats::bottom_k(&hf, 20).into_iter().collect();
        // Large space: overhead reranking is stronger here; Fig 2(b) shows
        // smaller-but-significant overlap for the big apps.
        assert!(a.intersection(&b).count() >= 5);
    }
}
