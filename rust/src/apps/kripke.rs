//! Kripke performance model (Table II: Layout ∈ 6 nestings, Gset ∈
//! {1,2,3,8,16,32}, Dset ∈ {8,16,32,48,64,96}; defaults DGZ/1/8; 216 configs).
//!
//! Kripke is an Sn transport sweep; its performance story (Kunen et al.,
//! LLNL-TR-2015) is dominated by how the (Direction, Group, Zone) loop
//! nesting — the `Layout` — matches the blocking induced by the number of
//! group sets and direction sets:
//!
//! * `Gset`/`Dset` split the 32 energy groups / 96 directions into sets; the
//!   inner kernel operates on one (groups-per-set × dirs-per-set × zones)
//!   block. Small blocks → loop/sweep scheduling overhead; large blocks →
//!   the block spills L2 and the innermost stride pattern starts to matter.
//! * Each `Layout` nests the three loops differently. A layout is fast when
//!   its innermost axis is the *longest* axis of the block (long unit-stride
//!   runs) and slow when the innermost axis is short (strided access
//!   dominates). That makes the best layout a function of Gset × Dset — the
//!   Fig 4 observation that layout is the highest-impact parameter, and the
//!   interaction Fig 3(a) shows.

use super::{fidelity_scale, micro_jitter, AppKind, AppModel, Workload};
use crate::space::{ParamDef, ParamSpace};

/// See module docs.
pub struct Kripke {
    space: ParamSpace,
}

const APP_TAG: u64 = 0x4B52_4950_4B45; // "KRIPKE"
const TOTAL_GROUPS: f64 = 32.0;
const TOTAL_DIRS: f64 = 96.0;
/// Zones per sweep subdomain at full fidelity (64³ in the paper's HF runs).
const TOTAL_ZONES: f64 = 64.0 * 64.0 * 64.0;

const LAYOUTS: [&str; 6] = ["DGZ", "DZG", "GDZ", "GZD", "ZDG", "ZGD"];

impl Kripke {
    pub fn new() -> Self {
        let space = ParamSpace::new(
            "kripke",
            vec![
                ParamDef::tags("layout", &LAYOUTS, "DGZ")
                    .describe("data layout and kernel implementation details"),
                ParamDef::ints("gset", &[1, 2, 3, 8, 16, 32], 1)
                    .describe("number of energy group sets"),
                ParamDef::ints("dset", &[8, 16, 32, 48, 64, 96], 8)
                    .describe("number of direction sets"),
            ],
        );
        Kripke { space }
    }

    /// Stride efficiency of `layout` for a (g × d × z) block: innermost axis
    /// length relative to the longest block axis, squashed into a penalty.
    fn layout_penalty(layout: &str, g: f64, d: f64, z: f64) -> f64 {
        // The trailing letter of the nesting is the innermost (unit-stride)
        // axis; the leading letter the outermost.
        let axis_len = |c: u8| match c {
            b'D' => d,
            b'G' => g,
            b'Z' => z,
            _ => unreachable!(),
        };
        let inner = axis_len(layout.as_bytes()[2]);
        let middle = axis_len(layout.as_bytes()[1]);
        let longest = g.max(d).max(z);
        // Short unit-stride runs cost dearly; a long middle axis helps a bit
        // (hardware prefetch across lines).
        let inner_ratio = (inner / longest).clamp(1e-3, 1.0);
        let penalty = 1.0 + 0.55 * (1.0 - inner_ratio).powf(1.5)
            + 0.08 * (1.0 - (middle / longest).clamp(0.0, 1.0));
        penalty
    }
}

impl Default for Kripke {
    fn default() -> Self {
        Self::new()
    }
}

impl AppModel for Kripke {
    fn kind(&self) -> AppKind {
        AppKind::Kripke
    }

    fn space(&self) -> &ParamSpace {
        &self.space
    }

    fn workload(&self, index: usize, fidelity: f64) -> Workload {
        // Allocation-free per-dimension decode (episode hot path): the
        // layout tag is borrowed, never cloned.
        let layout = self.space.value_at(index, 0).as_tag();
        let gsets = self.space.value_at(index, 1).as_int() as f64;
        let dsets = self.space.value_at(index, 2).as_int() as f64;

        // Block dims: groups-per-set × dirs-per-set × zones-per-tile.
        let g = TOTAL_GROUPS / gsets;
        let d = TOTAL_DIRS / dsets;
        // Fidelity scales the zone count (paper: zone size 32³ vs 64³).
        let zones = TOTAL_ZONES * fidelity_scale(fidelity, 0.08);
        let z_tile = 512.0; // zones per cache tile, layout-independent

        // Granularity: number of (gset × dset) sweep tasks; more tasks →
        // more sweep-scheduling overhead but better pipelining up to a point.
        let tasks = gsets * dsets;
        let sched = 1.0 + 0.012 * tasks + 0.35 / tasks;

        // Cache behaviour: block working set (g*d*z_tile values).
        let block = g * d * z_tile;
        let l2 = 64.0 * 1024.0; // values that fit "L2" in the model
        let spill = if block > l2 { 1.0 + 0.25 * ((block / l2).ln()) } else { 1.0 };

        let stride = Self::layout_penalty(layout, g, d, z_tile);
        let jitter = 1.0 + 0.02 * micro_jitter(APP_TAG, index);

        // Total angular work is gsets·dsets·(g·d)·zones = G·D·zones: fixed;
        // the knobs only move efficiency.
        let work_units = TOTAL_GROUPS * TOTAL_DIRS * zones / 1e8;
        let compute = 0.9 * work_units * stride * sched * spill * jitter;

        Workload {
            compute,
            // DRAM traffic dominates the power story for the sweep: spilled
            // blocks stream from memory every pass, strided layouts waste
            // bandwidth on partial lines. The Table II default (gset=1,
            // dset=8) has the *largest* block and therefore the heaviest
            // traffic — the power-focused tuner has real headroom (paper
            // Fig 8 reports ~6% for Kripke).
            mem_intensity: (0.35 + 0.28 * (1.0 - 1.0 / stride) + 1.0 * (spill - 1.0))
                .min(0.95),
            // Every configuration has ≥ 8 sweep tasks on 4 cores: core-side
            // parallelism is saturated and flat across the space.
            parallel_frac: 0.90,
            overhead: 0.008 + 0.0015 * tasks,
        }
        .sanitized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn all_times(q: f64) -> Vec<f64> {
        let app = Kripke::new();
        app.space()
            .indices()
            .map(|i| {
                let w = app.workload(i, q);
                w.compute + w.overhead
            })
            .collect()
    }

    #[test]
    fn space_matches_table2() {
        let app = Kripke::new();
        assert_eq!(app.space().len(), 216);
        let d = app.space().decode(app.default_index());
        assert_eq!(d.values[0].as_tag(), "DGZ");
        assert_eq!(d.values[1].as_int(), 1);
        assert_eq!(d.values[2].as_int(), 8);
    }

    #[test]
    fn layout_is_high_impact() {
        // Fig 4: varying layout alone (others default) moves runtime a lot.
        let app = Kripke::new();
        let mut ts = vec![];
        for l in 0..6 {
            let idx = app.space().encode_positions(&[l, 0, 0]);
            ts.push(app.workload(idx, 1.0).compute);
        }
        let spread = ts.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            / ts.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 1.15, "layout spread only {spread}");
    }

    #[test]
    fn best_layout_depends_on_sets() {
        let app = Kripke::new();
        let best_layout = |gpos: usize, dpos: usize| {
            (0..6)
                .min_by(|&a, &b| {
                    let ia = app.space().encode_positions(&[a, gpos, dpos]);
                    let ib = app.space().encode_positions(&[b, gpos, dpos]);
                    app.workload(ia, 1.0)
                        .compute
                        .total_cmp(&app.workload(ib, 1.0).compute)
                })
                .unwrap()
        };
        // Many group sets (small g) vs many direction sets (small d) should
        // favour different nestings.
        assert_ne!(best_layout(5, 0), best_layout(0, 5));
    }

    #[test]
    fn long_tail_distribution() {
        // Fig 3(b): most configurations deviate significantly from best.
        let t = all_times(1.0);
        let best = t.iter().cloned().fold(f64::INFINITY, f64::min);
        let within_10pct = t.iter().filter(|&&x| x <= best * 1.10).count();
        assert!(within_10pct <= t.len() / 6, "{within_10pct} within 10%");
    }

    #[test]
    fn lf_hf_top20_overlap() {
        let lf = all_times(0.15);
        let hf = all_times(1.0);
        let a: std::collections::HashSet<_> = stats::bottom_k(&lf, 20).into_iter().collect();
        let b: std::collections::HashSet<_> = stats::bottom_k(&hf, 20).into_iter().collect();
        let common = a.intersection(&b).count();
        assert!(common >= 8, "overlap {common}");
    }

    #[test]
    fn more_fidelity_more_work() {
        let app = Kripke::new();
        assert!(app.workload(0, 1.0).compute > 3.0 * app.workload(0, 0.1).compute);
    }
}
