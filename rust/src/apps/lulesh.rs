//! LULESH performance model (Table II: `r` regions 1-15¹, `s` mesh elements
//! 1-8; defaults r=11, s=8; space size 128).
//!
//! ¹ Table II states the Lulesh space size as **128**, but the printed
//! ranges (r: 1-15, s: 1-8) multiply to 120. We follow the stated size and
//! use r ∈ 1..=16 so that 16 × 8 = 128; the default r=11 is unaffected.
//!
//! Model structure (see DESIGN.md §Simulator design):
//! * Work grows with the mesh edge `s` (the shock-hydro kernel is O(s³) per
//!   domain), but *efficiency* is non-monotonic: small `s` under-fills SIMD
//!   lanes, large `s` spills the per-domain working set out of L2 — so
//!   time-per-element has an interior optimum.
//! * The region count `r` controls material-loop granularity: few regions
//!   create load imbalance across threads; many regions add per-region loop
//!   and allocation overhead. Convex with an interior sweet spot, and the
//!   sweet spot *shifts with s* (bigger meshes amortize region overhead
//!   better) — the parameter interaction Fig 3(a) relies on.

use super::{fidelity_scale, micro_jitter, AppKind, AppModel, Workload};
use crate::space::{ParamDef, ParamSpace};

/// See module docs.
pub struct Lulesh {
    space: ParamSpace,
}

const APP_TAG: u64 = 0x4C55_4C45_5348; // "LULESH"

impl Lulesh {
    pub fn new() -> Self {
        let space = ParamSpace::new(
            "lulesh",
            vec![
                ParamDef::int_range("r", 1, 16, 11)
                    .describe("number of regions to run for each domain"),
                ParamDef::int_range("s", 1, 8, 8)
                    .describe("number of elements of cube mesh (edge, x10)"),
            ],
        );
        Lulesh { space }
    }
}

impl Default for Lulesh {
    fn default() -> Self {
        Self::new()
    }
}

impl AppModel for Lulesh {
    fn kind(&self) -> AppKind {
        AppKind::Lulesh
    }

    fn space(&self) -> &ParamSpace {
        &self.space
    }

    fn workload(&self, index: usize, fidelity: f64) -> Workload {
        // Allocation-free per-dimension decode (episode hot path).
        let r = self.space.value_at(index, 0).as_int() as f64; // regions: 1..=16
        let s = self.space.value_at(index, 1).as_int() as f64; // per-domain mesh edge: 1..=8

        // Fixed total problem (the paper's HF run is mesh 80 ≈ 512k
        // elements); `s` decides how it is decomposed into (10s)³-element
        // domains, `q` scales the problem (LF run = mesh 50-ish and below).
        let elements = 512_000.0 * fidelity_scale(fidelity, 0.08);

        // --- vectorization efficiency over s (interior optimum ~5):
        // under-filled SIMD lanes below, register/spill pressure above.
        let simd_eff = 0.55 + 0.45 * (1.0 - ((s - 5.0) / 4.0).powi(2)).max(0.0);
        // --- per-domain working set vs L2: big domains spill.
        let domain_elems = (10.0 * s).powi(3).min(elements);
        let ws_kb = domain_elems * 0.15;
        let spill = if ws_kb > 2048.0 { 1.0 + 0.22 * (ws_kb / 2048.0).ln() } else { 1.0 };
        // --- domain-loop cost: tiny domains mean many domain traversals.
        let ndomains = (elements / domain_elems).max(1.0);
        let domain_loop_s = 0.002 * ndomains;
        // --- region granularity: imbalance ~ 1/r, overhead ~ r; the sweet
        // spot shifts right with bigger domains (more work to amortize).
        let sweet = 6.0 + 0.75 * s;
        let granularity = 1.0 + 0.035 * ((r - sweet) / sweet).powi(2) * sweet
            + 0.30 / r; // residual imbalance for tiny r
        // --- rugged residual: ±2%.
        let jitter = 1.0 + 0.02 * micro_jitter(APP_TAG, index);

        let compute = 2.0e-6 * elements / simd_eff * granularity * spill * jitter
            + domain_loop_s;

        // Per-region serial setup: does not scale with fidelity.
        let overhead = 0.004 * r + 0.010;

        Workload {
            compute,
            // Spilled working sets stream from DRAM.
            mem_intensity: (0.38 + 0.10 * (spill - 1.0) + 0.02 * (r / 16.0)).min(1.0),
            parallel_frac: 0.93 - 0.02 * (1.0 / s),
            overhead,
        }
        .sanitized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn times(q: f64) -> Vec<f64> {
        let app = Lulesh::new();
        app.space()
            .indices()
            .map(|i| {
                let w = app.workload(i, q);
                w.compute + w.overhead
            })
            .collect()
    }

    #[test]
    fn space_matches_table2() {
        let app = Lulesh::new();
        assert_eq!(app.space().len(), 128);
        assert_eq!(app.space().dims(), 2);
        let d = app.space().decode(app.default_index());
        assert_eq!(d.values[0].as_int(), 11);
        assert_eq!(d.values[1].as_int(), 8);
    }

    #[test]
    fn unique_oracle_and_long_tail() {
        let t = times(1.0);
        let best = t.iter().cloned().fold(f64::INFINITY, f64::min);
        let near: usize = t.iter().filter(|&&x| x < best * 1.05).count();
        // A handful of configs near the oracle; the bulk far away.
        assert!(near < t.len() / 8, "near-oracle configs: {near}");
        let median = stats::quantile(&t, 0.5);
        assert!(median > best * 1.3, "median {median} best {best}");
    }

    #[test]
    fn default_not_oracle() {
        let app = Lulesh::new();
        let t = times(1.0);
        let oracle = stats::argmin(&t);
        assert_ne!(oracle, app.default_index());
        // ...but default is not pathological either (within 4x of oracle).
        assert!(t[app.default_index()] < 4.0 * t[oracle]);
    }

    #[test]
    fn parameter_interaction_present() {
        // The best r must depend on s (interaction; Fig 3a).
        let app = Lulesh::new();
        let best_r_for = |s_pos: usize| -> usize {
            (0..16)
                .min_by(|&a, &b| {
                    let ia = app.space().encode_positions(&[a, s_pos]);
                    let ib = app.space().encode_positions(&[b, s_pos]);
                    let ta = app.workload(ia, 1.0).compute;
                    let tb = app.workload(ib, 1.0).compute;
                    ta.total_cmp(&tb)
                })
                .unwrap()
        };
        assert_ne!(best_r_for(0), best_r_for(7));
    }

    #[test]
    fn lf_hf_rank_overlap_substantial_not_total() {
        // Fig 2's premise: top-20 at LF overlaps top-20 at HF.
        let lf = times(0.15);
        let hf = times(1.0);
        let top_lf: std::collections::HashSet<_> =
            stats::bottom_k(&lf, 20).into_iter().collect();
        let top_hf: std::collections::HashSet<_> =
            stats::bottom_k(&hf, 20).into_iter().collect();
        let common = top_lf.intersection(&top_hf).count();
        assert!(common >= 8, "overlap too small: {common}");
    }
}
