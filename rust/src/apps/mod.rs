//! Simulated HPC applications (paper Table II).
//!
//! The paper runs four LLNL proxy/benchmark apps on real hardware; we do not
//! have that testbed, so each app is an **analytic performance model** over
//! exactly the Table II parameter space (see DESIGN.md §Simulator design for
//! the substitution argument). Each model maps
//! `(configuration index, fidelity q)` to an abstract [`Workload`]; the
//! [`crate::device`] layer turns a workload into measured execution time and
//! power for a concrete device, adding run-to-run noise.
//!
//! The models are deterministic and cheap (an exhaustive oracle sweep over
//! Hypre's 92,160 arms is a few ms), and are constructed to exhibit the
//! properties the paper's experiments rely on:
//!
//! 1. a unique oracle with most configurations far from it (Fig 3b);
//! 2. strong parameter interactions (Fig 3a, Fig 4);
//! 3. fidelity-dependent *mild* rank perturbation: compute terms scale with
//!    `q`, per-configuration overhead terms do not, so the LF and HF
//!    rankings overlap heavily but not exactly (Fig 2);
//! 4. power varies much less than time (paper §V-D's observation that the
//!    edge device saturates power under HPC load).

mod clomp;
mod hypre;
mod kripke;
mod lulesh;

pub use clomp::Clomp;
pub use hypre::Hypre;
pub use kripke::Kripke;
pub use lulesh::Lulesh;

use crate::space::ParamSpace;

/// The four applications evaluated in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AppKind {
    Lulesh,
    Kripke,
    Clomp,
    Hypre,
}

impl AppKind {
    /// All apps, in the paper's order.
    pub fn all() -> [AppKind; 4] {
        [AppKind::Lulesh, AppKind::Kripke, AppKind::Clomp, AppKind::Hypre]
    }

    pub fn name(&self) -> &'static str {
        match self {
            AppKind::Lulesh => "lulesh",
            AppKind::Kripke => "kripke",
            AppKind::Clomp => "clomp",
            AppKind::Hypre => "hypre",
        }
    }
}

impl std::str::FromStr for AppKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "lulesh" => Ok(AppKind::Lulesh),
            "kripke" => Ok(AppKind::Kripke),
            "clomp" => Ok(AppKind::Clomp),
            "hypre" => Ok(AppKind::Hypre),
            other => Err(anyhow::anyhow!("unknown application '{other}'")),
        }
    }
}

impl std::fmt::Display for AppKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Abstract work produced by running one configuration at one fidelity.
/// The device model turns this into (time, power).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Workload {
    /// Compute work in *reference core-seconds*: seconds on one reference
    /// core (1 GHz, IPC 1) with no memory stalls.
    pub compute: f64,
    /// Memory-boundedness in `[0, 1]`: 0 = pure compute, 1 = pure streaming.
    pub mem_intensity: f64,
    /// Amdahl parallel fraction in `[0, 1]`.
    pub parallel_frac: f64,
    /// Serial per-run overhead (scheduling/setup) in reference core-seconds;
    /// does *not* scale with fidelity.
    pub overhead: f64,
}

impl Workload {
    /// Clamp all fields into their documented domains.
    pub fn sanitized(mut self) -> Self {
        self.compute = self.compute.max(1e-9);
        self.mem_intensity = self.mem_intensity.clamp(0.0, 1.0);
        self.parallel_frac = self.parallel_frac.clamp(0.0, 1.0);
        self.overhead = self.overhead.max(0.0);
        self
    }
}

/// A simulated HPC application: a Table II parameter space plus the analytic
/// performance model over it.
pub trait AppModel: Send + Sync {
    /// Application kind tag.
    fn kind(&self) -> AppKind;

    /// The Table II parameter space.
    fn space(&self) -> &ParamSpace;

    /// Evaluate the model: configuration `index` at fidelity `q ∈ [0, 1]`
    /// (paper §II-C: `q_min` = cheapest edge run, `q_max` = 1 = the HPC
    /// production problem size).
    fn workload(&self, index: usize, fidelity: f64) -> Workload;

    /// Application name.
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Dense index of the all-defaults configuration.
    fn default_index(&self) -> usize {
        self.space().default_index()
    }
}

/// Construct the simulator for `kind`.
pub fn build(kind: AppKind) -> Box<dyn AppModel> {
    match kind {
        AppKind::Lulesh => Box::new(Lulesh::new()),
        AppKind::Kripke => Box::new(Kripke::new()),
        AppKind::Clomp => Box::new(Clomp::new()),
        AppKind::Hypre => Box::new(Hypre::new()),
    }
}

/// Deterministic per-configuration micro-structure in `[-1, 1]`.
///
/// Real runtime surfaces are rugged: configurations that are neighbours in
/// parameter space still differ by small idiosyncratic amounts (alignment,
/// allocator behaviour, instruction scheduling). A hash of the index gives
/// every configuration a fixed, reproducible residual.
pub(crate) fn micro_jitter(app_tag: u64, index: usize) -> f64 {
    let mut z = (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ app_tag;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
}

/// Fidelity scale for compute work: linear interpolation between the LF
/// floor and 1.0 (paper §II-C assumes evaluation cost linear in `q`).
pub(crate) fn fidelity_scale(q: f64, lf_floor: f64) -> f64 {
    let q = q.clamp(0.0, 1.0);
    lf_floor + (1.0 - lf_floor) * q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_space_sizes() {
        // Table II: kripke 216, lulesh 128, clomp 125, hypre 92160.
        assert_eq!(build(AppKind::Kripke).space().len(), 216);
        assert_eq!(build(AppKind::Lulesh).space().len(), 128);
        assert_eq!(build(AppKind::Clomp).space().len(), 125);
        assert_eq!(build(AppKind::Hypre).space().len(), 92_160);
    }

    #[test]
    fn workloads_sane_everywhere_small_apps() {
        for kind in [AppKind::Lulesh, AppKind::Kripke, AppKind::Clomp] {
            let app = build(kind);
            for i in app.space().indices() {
                for q in [0.0, 0.3, 1.0] {
                    let w = app.workload(i, q);
                    assert!(w.compute > 0.0, "{kind} #{i} q={q}");
                    assert!((0.0..=1.0).contains(&w.mem_intensity));
                    assert!((0.0..=1.0).contains(&w.parallel_frac));
                    assert!(w.overhead >= 0.0);
                }
            }
        }
    }

    #[test]
    fn workloads_sane_sampled_hypre() {
        let app = build(AppKind::Hypre);
        for i in (0..app.space().len()).step_by(97) {
            let w = app.workload(i, 0.5);
            assert!(w.compute > 0.0 && w.compute.is_finite());
            assert!((0.0..=1.0).contains(&w.mem_intensity));
        }
    }

    #[test]
    fn fidelity_increases_compute() {
        for kind in AppKind::all() {
            let app = build(kind);
            let idx = app.default_index();
            let lo = app.workload(idx, 0.1).compute;
            let hi = app.workload(idx, 1.0).compute;
            assert!(hi > lo * 1.5, "{kind}: {lo} !<< {hi}");
        }
    }

    #[test]
    fn overhead_fidelity_invariant() {
        // Overhead must not scale with q — that's what perturbs LF ranking.
        for kind in AppKind::all() {
            let app = build(kind);
            let idx = app.default_index();
            let lo = app.workload(idx, 0.1).overhead;
            let hi = app.workload(idx, 1.0).overhead;
            assert!((lo - hi).abs() < 1e-12, "{kind}");
        }
    }

    #[test]
    fn deterministic() {
        let app = build(AppKind::Kripke);
        assert_eq!(app.workload(17, 0.4), app.workload(17, 0.4));
    }

    #[test]
    fn micro_jitter_bounded_and_stable() {
        for i in 0..1000 {
            let j = micro_jitter(7, i);
            assert!((-1.0..=1.0).contains(&j));
            assert_eq!(j, micro_jitter(7, i));
        }
    }

    #[test]
    fn fidelity_scale_monotone() {
        assert!(fidelity_scale(0.0, 0.05) < fidelity_scale(0.5, 0.05));
        assert!(fidelity_scale(0.5, 0.05) < fidelity_scale(1.0, 0.05));
        assert!((fidelity_scale(1.0, 0.05) - 1.0).abs() < 1e-12);
    }
}
