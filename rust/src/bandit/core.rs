//! The shared bandit core: one struct-of-arrays [`ArmStats`] engine under
//! every policy, plus the reusable [`Scratch`] buffers that keep
//! `Policy::select` allocation-free in steady state.
//!
//! Before this module existed each of the five policies kept its own
//! `RewardState` plus ad-hoc counters, re-implemented warm-start logic per
//! variant, and re-summed the counts slice on every `total_pulls()` call.
//! [`ArmStats`] centralizes the sufficient statistics (paper Alg. 1
//! lines 1-2) in one cache-friendly struct-of-arrays layout:
//!
//! * `counts` / `tau_sum` / `rho_sum` — the per-arm statistics, each a
//!   dense contiguous `Vec<f64>` so score kernels stream them linearly;
//! * `mean_tau` / `mean_rho` — cached per-arm means, updated O(1) on every
//!   [`ArmStats::observe`], so the per-select kernels never divide;
//! * `total` — a cached pull total, making [`ArmStats::total_pulls`] O(1)
//!   (it sits on the suggest hot path via UCB's `log t` term).
//!
//! Invariant: `mean_*[i] == *_sum[i] / counts[i]` whenever `counts[i] > 0`
//! and `0.0` otherwise; `total == Σ counts`. Every mutator re-establishes
//! it, which is why the fields are private.

/// Struct-of-arrays per-arm sufficient statistics: Στ, Σρ, N, cached
/// means, and an O(1) pull total.
#[derive(Debug, Clone, PartialEq)]
pub struct ArmStats {
    counts: Vec<f64>,
    tau_sum: Vec<f64>,
    rho_sum: Vec<f64>,
    mean_tau: Vec<f64>,
    mean_rho: Vec<f64>,
    total: f64,
    /// Iteration counter `t` (1-based, advanced per observation).
    t: f64,
}

impl ArmStats {
    pub fn new(k: usize) -> ArmStats {
        ArmStats {
            counts: vec![0.0; k],
            tau_sum: vec![0.0; k],
            rho_sum: vec![0.0; k],
            mean_tau: vec![0.0; k],
            mean_rho: vec![0.0; k],
            total: 0.0,
            t: 1.0,
        }
    }

    /// Rebuild from raw vectors (checkpoint restore). The caller validates
    /// shapes and finiteness; means and the total are recomputed here.
    pub fn from_parts(tau_sum: Vec<f64>, rho_sum: Vec<f64>, counts: Vec<f64>, t: f64) -> ArmStats {
        assert_eq!(tau_sum.len(), counts.len());
        assert_eq!(rho_sum.len(), counts.len());
        let k = counts.len();
        let mut s = ArmStats {
            counts,
            tau_sum,
            rho_sum,
            mean_tau: vec![0.0; k],
            mean_rho: vec![0.0; k],
            total: 0.0,
            t: t.max(1.0),
        };
        for i in 0..k {
            s.total += s.counts[i];
            if s.counts[i] > 0.0 {
                s.mean_tau[i] = s.tau_sum[i] / s.counts[i];
                s.mean_rho[i] = s.rho_sum[i] / s.counts[i];
            }
        }
        s
    }

    /// Number of arms.
    pub fn k(&self) -> usize {
        self.counts.len()
    }

    /// Record one measurement for `arm`.
    pub fn observe(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.tau_sum[arm] += time_s;
        self.rho_sum[arm] += power_w;
        self.counts[arm] += 1.0;
        self.mean_tau[arm] = self.tau_sum[arm] / self.counts[arm];
        self.mean_rho[arm] = self.rho_sum[arm] / self.counts[arm];
        self.total += 1.0;
        self.t += 1.0;
    }

    /// Remove one previously observed measurement (sliding-window
    /// eviction). The iteration counter `t` is *not* rewound — time only
    /// moves forward. Accumulated fp dust at zero is squashed so an arm
    /// whose window emptied reads as genuinely unpulled.
    pub fn unobserve(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.tau_sum[arm] -= time_s;
        self.rho_sum[arm] -= power_w;
        self.counts[arm] -= 1.0;
        self.total -= 1.0;
        if self.counts[arm] < 1e-9 {
            self.total -= self.counts[arm];
            self.counts[arm] = 0.0;
            self.tau_sum[arm] = 0.0;
            self.rho_sum[arm] = 0.0;
            self.mean_tau[arm] = 0.0;
            self.mean_rho[arm] = 0.0;
        } else {
            self.mean_tau[arm] = self.tau_sum[arm] / self.counts[arm];
            self.mean_rho[arm] = self.rho_sum[arm] / self.counts[arm];
        }
    }

    /// Replace one arm's statistics wholesale (prior installation,
    /// projection). Re-derives `t` as `total + 1`, the convention for
    /// rebuilt states.
    pub fn set_arm(&mut self, arm: usize, count: f64, tau_sum: f64, rho_sum: f64) {
        self.total += count - self.counts[arm];
        self.counts[arm] = count;
        self.tau_sum[arm] = tau_sum;
        self.rho_sum[arm] = rho_sum;
        if count > 0.0 {
            self.mean_tau[arm] = tau_sum / count;
            self.mean_rho[arm] = rho_sum / count;
        } else {
            self.mean_tau[arm] = 0.0;
            self.mean_rho[arm] = 0.0;
        }
        self.t = self.total + 1.0;
    }

    /// Accumulate onto one arm's statistics (sparse-snapshot densify,
    /// cross-node merging). Same `t` convention as [`ArmStats::set_arm`].
    pub fn add_arm(&mut self, arm: usize, count: f64, tau_sum: f64, rho_sum: f64) {
        self.set_arm(
            arm,
            self.counts[arm] + count,
            self.tau_sum[arm] + tau_sum,
            self.rho_sum[arm] + rho_sum,
        );
    }

    /// Pull counts `N_x`.
    pub fn counts(&self) -> &[f64] {
        &self.counts
    }

    /// Per-arm Στ.
    pub fn tau_sum(&self) -> &[f64] {
        &self.tau_sum
    }

    /// Per-arm Σρ.
    pub fn rho_sum(&self) -> &[f64] {
        &self.rho_sum
    }

    /// Cached per-arm mean execution times (0.0 for unpulled arms).
    pub fn mean_tau(&self) -> &[f64] {
        &self.mean_tau
    }

    /// Cached per-arm mean powers (0.0 for unpulled arms).
    pub fn mean_rho(&self) -> &[f64] {
        &self.mean_rho
    }

    /// Iteration counter `t`.
    pub fn t(&self) -> f64 {
        self.t
    }

    /// Total pulls — O(1) via the cached counter.
    pub fn total_pulls(&self) -> f64 {
        self.total
    }

    /// Mean observed (time, power) for `arm`, if it has been pulled.
    pub fn means_of(&self, arm: usize) -> Option<(f64, f64)> {
        if arm >= self.k() || self.counts[arm] <= 0.0 {
            return None;
        }
        Some((self.mean_tau[arm], self.mean_rho[arm]))
    }

    /// Per-arm mean times/powers with unpulled arms filled neutrally (the
    /// mean over pulled arms), mirroring `model.py::reward_norm`.
    /// Reference/diagnostic path — allocates; the hot kernels in
    /// [`super::reward`] fuse this computation instead.
    pub fn filled_means(&self) -> (Vec<f64>, Vec<f64>) {
        let k = self.k();
        let mut mean_tau = vec![0.0; k];
        let mut mean_rho = vec![0.0; k];
        let mut fill_tau = 0.0;
        let mut fill_rho = 0.0;
        let mut pulled = 0.0f64;
        for i in 0..k {
            if self.counts[i] > 0.0 {
                mean_tau[i] = self.mean_tau[i];
                mean_rho[i] = self.mean_rho[i];
                fill_tau += mean_tau[i];
                fill_rho += mean_rho[i];
                pulled += 1.0;
            }
        }
        let denom = pulled.max(1.0);
        let (fill_tau, fill_rho) = (fill_tau / denom, fill_rho / denom);
        for i in 0..k {
            if self.counts[i] == 0.0 {
                mean_tau[i] = fill_tau;
                mean_rho[i] = fill_rho;
            }
        }
        (mean_tau, mean_rho)
    }

    /// Discount for warm-starting: keep per-arm means but shrink effective
    /// counts by `retain ∈ (0, 1]`, so prior knowledge biases early
    /// selection without suppressing re-verification of a shifted
    /// environment. Unpulled arms stay unpulled; pulled arms keep at
    /// least one effective pull.
    pub fn discounted(&self, retain: f64) -> ArmStats {
        assert!(retain > 0.0 && retain <= 1.0);
        let k = self.k();
        let mut out = ArmStats::new(k);
        for i in 0..k {
            if self.counts[i] > 0.0 {
                let kept = (self.counts[i] * retain).max(1.0);
                out.set_arm(i, kept, self.mean_tau[i] * kept, self.mean_rho[i] * kept);
            }
        }
        out
    }
}

/// Reusable per-policy score buffers. Each policy instance owns one, so a
/// session's `select()` allocates only until both buffers reach `k`
/// elements; after that warm-up the whole scoring pass is allocation-free
/// (asserted end-to-end by `rust/tests/serve_hotpath.rs` and per-policy by
/// `benches/bandit_core.rs`). A scratch can also be *shared* across many
/// sessions with different `k` — `resize` keeps capacity at the high-water
/// mark, so a warm shared scratch never reallocates as the batch path
/// ([`crate::bandit::select_batch`]) walks mixed-size sessions.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Eq. 5 rewards from the most recent scoring pass.
    pub rewards: Vec<f64>,
    /// Per-arm scores (UCB bonuses or Thompson samples).
    pub scores: Vec<f64>,
    /// Growth events of this instance (see [`Scratch::growths`]).
    growths: u64,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Size both buffers to `k` arms, counting a growth event when either
    /// has to reallocate. For single-buffer kernels use
    /// [`Scratch::ensure_rewards`] instead — no point carrying a dead
    /// `scores` vector in sessions that never run a two-stage kernel.
    pub fn ensure(&mut self, k: usize) {
        if k > self.rewards.capacity() || k > self.scores.capacity() {
            self.growths += 1;
        }
        self.rewards.resize(k, 0.0);
        self.scores.resize(k, 0.0);
    }

    /// Size only the rewards buffer (kernels that never write scores,
    /// like ε-greedy's greedy pass).
    pub fn ensure_rewards(&mut self, k: usize) {
        if k > self.rewards.capacity() {
            self.growths += 1;
        }
        self.rewards.resize(k, 0.0);
    }

    /// How many times this instance had to reallocate. Flat after warm-up
    /// — the per-session zero-allocation contract, aggregated across live
    /// sessions by `serve::ShardedStore::scratch_growth_total`.
    pub fn growths(&self) -> u64 {
        self.growths
    }

    /// Split mutable borrows of the two buffers (two-stage kernels read
    /// rewards while writing scores).
    pub fn rewards_scores_mut(&mut self) -> (&mut [f64], &mut [f64]) {
        (&mut self.rewards, &mut self.scores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_maintains_cached_invariants() {
        let mut s = ArmStats::new(3);
        s.observe(1, 2.0, 5.0);
        s.observe(1, 4.0, 7.0);
        assert_eq!(s.tau_sum()[1], 6.0);
        assert_eq!(s.rho_sum()[1], 12.0);
        assert_eq!(s.counts()[1], 2.0);
        assert_eq!(s.mean_tau()[1], 3.0);
        assert_eq!(s.mean_rho()[1], 6.0);
        assert_eq!(s.total_pulls(), 2.0);
        assert_eq!(s.t(), 3.0);
        assert_eq!(s.means_of(1), Some((3.0, 6.0)));
        assert_eq!(s.means_of(0), None);
        assert_eq!(s.means_of(99), None);
    }

    #[test]
    fn unobserve_reverses_and_squashes_dust() {
        let mut s = ArmStats::new(2);
        s.observe(0, 1.5, 4.0);
        s.observe(0, 2.5, 6.0);
        s.unobserve(0, 1.5, 4.0);
        assert_eq!(s.counts()[0], 1.0);
        assert_eq!(s.mean_tau()[0], 2.5);
        assert_eq!(s.total_pulls(), 1.0);
        s.unobserve(0, 2.5, 6.0);
        assert_eq!(s.counts()[0], 0.0);
        assert_eq!(s.tau_sum()[0], 0.0);
        assert_eq!(s.mean_tau()[0], 0.0);
        assert_eq!(s.total_pulls(), 0.0);
        // t never rewinds.
        assert_eq!(s.t(), 3.0);
    }

    #[test]
    fn set_and_add_arm_rebuild_totals() {
        let mut s = ArmStats::new(4);
        s.set_arm(2, 5.0, 10.0, 20.0);
        assert_eq!(s.total_pulls(), 5.0);
        assert_eq!(s.mean_tau()[2], 2.0);
        assert_eq!(s.t(), 6.0);
        s.add_arm(2, 5.0, 10.0, 20.0);
        assert_eq!(s.counts()[2], 10.0);
        assert_eq!(s.mean_tau()[2], 2.0);
        s.set_arm(2, 0.0, 0.0, 0.0);
        assert_eq!(s.total_pulls(), 0.0);
        assert_eq!(s.mean_tau()[2], 0.0);
    }

    #[test]
    fn from_parts_recomputes_caches() {
        let s = ArmStats::from_parts(vec![4.0, 0.0], vec![8.0, 0.0], vec![2.0, 0.0], 3.0);
        assert_eq!(s.mean_tau()[0], 2.0);
        assert_eq!(s.mean_rho()[0], 4.0);
        assert_eq!(s.total_pulls(), 2.0);
        assert_eq!(s.t(), 3.0);
        // t clamps to at least 1.
        let s = ArmStats::from_parts(vec![0.0], vec![0.0], vec![0.0], -5.0);
        assert_eq!(s.t(), 1.0);
    }

    #[test]
    fn filled_means_neutral_for_unpulled() {
        let mut s = ArmStats::new(3);
        s.observe(0, 2.0, 4.0);
        s.observe(1, 4.0, 8.0);
        let (mt, mr) = s.filled_means();
        assert_eq!(mt, vec![2.0, 4.0, 3.0]); // arm 2 filled with mean(2,4)
        assert_eq!(mr, vec![4.0, 8.0, 6.0]);
    }

    #[test]
    fn discount_preserves_means_shrinks_counts() {
        let mut s = ArmStats::new(4);
        for _ in 0..10 {
            s.observe(0, 2.0, 6.0);
            s.observe(2, 4.0, 8.0);
        }
        let d = s.discounted(0.3);
        assert_eq!(d.counts()[0], 3.0);
        assert_eq!(d.mean_tau()[0], 2.0);
        assert_eq!(d.mean_rho()[2], 8.0);
        assert_eq!(d.counts()[1], 0.0);
        assert_eq!(d.t(), d.total_pulls() + 1.0);
        // Floor: a single-pull arm keeps one effective pull.
        let mut s = ArmStats::new(1);
        s.observe(0, 1.0, 1.0);
        assert_eq!(s.discounted(0.1).counts()[0], 1.0);
    }

    #[test]
    fn scratch_grows_once_then_stays_flat() {
        let mut sc = Scratch::new();
        sc.ensure(64);
        assert_eq!(sc.rewards.len(), 64);
        assert_eq!(sc.scores.len(), 64);
        assert_eq!(sc.growths(), 1);
        for _ in 0..100 {
            sc.ensure(64);
        }
        assert_eq!(sc.growths(), 1, "steady-state ensure reallocated");
        sc.ensure(128);
        assert_eq!(sc.growths(), 2);

        // The rewards-only variant leaves scores untouched.
        let mut sc = Scratch::new();
        sc.ensure_rewards(32);
        assert_eq!(sc.rewards.len(), 32);
        assert!(sc.scores.is_empty());
        assert_eq!(sc.growths(), 1);
        sc.ensure_rewards(32);
        assert_eq!(sc.growths(), 1);
    }
}
