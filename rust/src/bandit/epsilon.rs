//! ε-greedy ablation policy.
//!
//! The simplest explore/exploit baseline: with probability ε pick a random
//! arm, otherwise exploit the best current weighted reward. Used by the
//! ablation benches to quantify what UCB's confidence bonus buys LASP.
//! A thin strategy layer over the shared [`ArmStats`] core — which also
//! makes it checkpointable and fleet-syncable like every other policy.

use super::core::{ArmStats, Scratch};
use super::reward::weighted_rewards_into;
use super::{top2, Choice, Policy};
use crate::util::Rng;

/// ε-greedy over the paper's Eq. 5 reward.
pub struct EpsilonGreedy {
    stats: ArmStats,
    alpha: f64,
    beta: f64,
    epsilon: f64,
    rng: Rng,
    scratch: Scratch,
}

impl EpsilonGreedy {
    pub fn new(k: usize, alpha: f64, beta: f64, epsilon: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&epsilon));
        EpsilonGreedy {
            stats: ArmStats::new(k),
            alpha,
            beta,
            epsilon,
            rng: Rng::new(seed),
            scratch: Scratch::new(),
        }
    }
}

/// The traced ε-greedy pass over explicit parts, so the same body can run
/// through the policy's own scratch (`select_traced`) or a shared batch
/// scratch (`select_traced_in`). RNG draw order is part of the contract:
/// one `uniform()` per steady-state call, one `below()` on the ε branch.
fn traced_step(
    stats: &ArmStats,
    alpha: f64,
    beta: f64,
    epsilon: f64,
    rng: &mut Rng,
    scratch: &mut Scratch,
) -> Choice {
    // Unpulled arms first (same initialization as UCB1).
    if let Some(arm) = stats.counts().iter().position(|&c| c == 0.0) {
        return Choice { arm, gap: 0.0, explore: true };
    }
    if rng.uniform() < epsilon {
        return Choice { arm: rng.below(stats.k()), gap: 0.0, explore: true };
    }
    scratch.ensure_rewards(stats.k());
    weighted_rewards_into(stats, alpha, beta, &mut scratch.rewards);
    let (arm, gap) = top2(&scratch.rewards);
    Choice { arm, gap, explore: false }
}

impl Policy for EpsilonGreedy {
    fn k(&self) -> usize {
        self.stats.k()
    }

    fn select(&mut self) -> usize {
        self.select_traced().arm
    }

    fn select_traced(&mut self) -> Choice {
        let EpsilonGreedy { stats, alpha, beta, epsilon, rng, scratch } = self;
        traced_step(stats, *alpha, *beta, *epsilon, rng, scratch)
    }

    fn select_traced_in(&mut self, scratch: &mut Scratch) -> Choice {
        traced_step(&self.stats, self.alpha, self.beta, self.epsilon, &mut self.rng, scratch)
    }

    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.stats.observe(arm, time_s, power_w);
    }

    fn counts(&self) -> &[f64] {
        self.stats.counts()
    }

    fn name(&self) -> &'static str {
        "epsilon-greedy"
    }

    fn stats(&self) -> &ArmStats {
        &self.stats
    }

    fn warm_start(&mut self, prior: ArmStats) {
        assert_eq!(prior.k(), self.stats.k(), "warm-start arm count mismatch");
        self.stats = prior;
    }

    fn scratch_growths(&self) -> u64 {
        self.scratch.growths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_sweep_covers_all_arms() {
        let k = 6;
        let mut p = EpsilonGreedy::new(k, 1.0, 0.0, 0.2, 3);
        for expected in 0..k {
            let arm = p.select();
            assert_eq!(arm, expected);
            p.update(arm, 1.0, 1.0);
        }
    }

    #[test]
    fn zero_epsilon_pure_greedy() {
        let mut p = EpsilonGreedy::new(3, 1.0, 0.0, 0.0, 1);
        let times = [3.0, 1.0, 2.0];
        for _ in 0..100 {
            let arm = p.select();
            p.update(arm, times[arm], 1.0);
        }
        assert_eq!(p.most_selected(), 1);
        // After the sweep, greedy never leaves the best arm.
        assert_eq!(p.counts()[1], 98.0);
    }

    #[test]
    fn high_epsilon_keeps_exploring() {
        let mut p = EpsilonGreedy::new(4, 1.0, 0.0, 0.9, 5);
        let times = [2.0, 1.0, 2.0, 2.0];
        for _ in 0..800 {
            let arm = p.select();
            p.update(arm, times[arm], 1.0);
        }
        // Every arm keeps getting substantial pulls under heavy exploration.
        for &c in p.counts() {
            assert!(c > 80.0, "counts {:?}", p.counts());
        }
    }

    #[test]
    fn warm_start_skips_init_sweep_and_exploits() {
        // The satellite fix: ε-greedy now shares the core, so a restored
        // prior (every arm pulled, arm 1 clearly best) must go straight to
        // exploitation under ε = 0.
        let mut prior = ArmStats::new(3);
        for _ in 0..20 {
            prior.observe(0, 3.0, 1.0);
            prior.observe(1, 0.5, 1.0);
            prior.observe(2, 2.0, 1.0);
        }
        let mut p = EpsilonGreedy::new(3, 1.0, 0.0, 0.0, 9);
        p.warm_start(prior);
        assert_eq!(p.select(), 1);
        assert_eq!(p.stats().total_pulls(), 60.0);
        assert_eq!(p.total_pulls(), 60.0);
    }

    #[test]
    #[should_panic]
    fn warm_start_arm_mismatch_panics() {
        let mut p = EpsilonGreedy::new(4, 1.0, 0.0, 0.1, 1);
        p.warm_start(ArmStats::new(3));
    }
}
