//! ε-greedy ablation policy.
//!
//! The simplest explore/exploit baseline: with probability ε pick a random
//! arm, otherwise exploit the best current weighted reward. Used by the
//! ablation benches to quantify what UCB's confidence bonus buys LASP.

use super::reward::{weighted_rewards, RewardState};
use super::Policy;
use crate::util::{stats, Rng};

/// ε-greedy over the paper's Eq. 5 reward.
pub struct EpsilonGreedy {
    state: RewardState,
    alpha: f64,
    beta: f64,
    epsilon: f64,
    rng: Rng,
}

impl EpsilonGreedy {
    pub fn new(k: usize, alpha: f64, beta: f64, epsilon: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&epsilon));
        EpsilonGreedy {
            state: RewardState::new(k),
            alpha,
            beta,
            epsilon,
            rng: Rng::new(seed),
        }
    }
}

impl Policy for EpsilonGreedy {
    fn k(&self) -> usize {
        self.state.k()
    }

    fn select(&mut self) -> usize {
        // Unpulled arms first (same initialization as UCB1).
        if let Some(arm) = self.state.counts.iter().position(|&c| c == 0.0) {
            return arm;
        }
        if self.rng.uniform() < self.epsilon {
            return self.rng.below(self.k());
        }
        let (mt, mr) = self.state.filled_means();
        let rewards = weighted_rewards(&mt, &mr, self.alpha, self.beta);
        stats::argmax(&rewards)
    }

    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.state.observe(arm, time_s, power_w);
    }

    fn counts(&self) -> &[f64] {
        &self.state.counts
    }

    fn name(&self) -> &'static str {
        "epsilon-greedy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_sweep_covers_all_arms() {
        let k = 6;
        let mut p = EpsilonGreedy::new(k, 1.0, 0.0, 0.2, 3);
        for expected in 0..k {
            let arm = p.select();
            assert_eq!(arm, expected);
            p.update(arm, 1.0, 1.0);
        }
    }

    #[test]
    fn zero_epsilon_pure_greedy() {
        let mut p = EpsilonGreedy::new(3, 1.0, 0.0, 0.0, 1);
        let times = [3.0, 1.0, 2.0];
        for _ in 0..100 {
            let arm = p.select();
            p.update(arm, times[arm], 1.0);
        }
        assert_eq!(p.most_selected(), 1);
        // After the sweep, greedy never leaves the best arm.
        assert_eq!(p.counts()[1], 98.0);
    }

    #[test]
    fn high_epsilon_keeps_exploring() {
        let mut p = EpsilonGreedy::new(4, 1.0, 0.0, 0.9, 5);
        let times = [2.0, 1.0, 2.0, 2.0];
        for _ in 0..800 {
            let arm = p.select();
            p.update(arm, times[arm], 1.0);
        }
        // Every arm keeps getting substantial pulls under heavy exploration.
        for &c in p.counts() {
            assert!(c > 80.0, "counts {:?}", p.counts());
        }
    }
}
