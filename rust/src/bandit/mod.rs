//! The LASP bandit engine (paper §III-IV).
//!
//! Every configuration in the application's [`crate::space::ParamSpace`] is
//! an arm. Pulling an arm runs the application once (on the device
//! simulator) and observes execution time τ and power ρ; the policy's
//! bookkeeping turns those into the paper's weighted reward (Eq. 5) and the
//! next selection (Eq. 2-3). The tuned configuration is the most-selected
//! arm (Eq. 4).
//!
//! [`UcbTuner`] is LASP itself. [`EpsilonGreedy`], [`ThompsonSampler`] and
//! [`SlidingWindowUcb`] are ablation policies used by the extension benches
//! (the paper motivates MAB adaptivity; these quantify it).
//!
//! The UCB score computation is delegated to a [`ScoreBackend`]: either the
//! pure-rust [`ScalarBackend`] or the AOT PJRT artifact
//! ([`crate::runtime::Engine`]), which are differentially tested against
//! each other.

pub mod epsilon;
pub mod persist;
pub mod regret;
pub mod reward;
pub mod subset;
pub mod swucb;
pub mod thompson;
pub mod ucb;

pub use epsilon::EpsilonGreedy;
pub use regret::RegretTracker;
pub use reward::{RewardState, ScalarBackend, ScoreBackend, StepOutput, DEFAULT_EXPLORATION};
pub use subset::SubsetTuner;
pub use swucb::SlidingWindowUcb;
pub use thompson::ThompsonSampler;
pub use ucb::UcbTuner;

/// A sequential arm-selection policy over `k` arms.
///
/// The contract mirrors the paper's loop (Alg. 1): call [`Policy::select`],
/// run the configuration, feed the measurement back via [`Policy::update`].
pub trait Policy: Send {
    /// Number of arms.
    fn k(&self) -> usize;

    /// Choose the arm to pull at the current iteration.
    fn select(&mut self) -> usize;

    /// Observe the measurement for `arm` (execution time seconds, watts).
    fn update(&mut self, arm: usize, time_s: f64, power_w: f64);

    /// Pull counts `N_x`.
    fn counts(&self) -> &[f64];

    /// Eq. 4: the most frequently selected arm — the tuner's answer.
    fn most_selected(&self) -> usize {
        crate::util::stats::argmax(self.counts())
    }

    /// Total pulls so far.
    fn total_pulls(&self) -> f64 {
        self.counts().iter().sum()
    }

    /// Policy name for reports.
    fn name(&self) -> &'static str;

    /// The underlying reward sufficient statistics, if this policy keeps
    /// them (UCB-family policies do) — enables checkpointing.
    fn reward_state(&self) -> Option<&RewardState> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// All policies must try every arm and converge toward good arms on a
    /// stationary synthetic bandit where arm quality improves with index.
    fn exercise(mut p: Box<dyn Policy>, k: usize) {
        let mut rng = crate::util::Rng::new(42);
        for _ in 0..40 * k {
            let arm = p.select();
            assert!(arm < k);
            // Higher-index arms are faster (better): time 1.0 -> 0.3.
            let t = 1.0 - 0.7 * (arm as f64 / (k - 1) as f64);
            let noise = rng.relative_noise(0.05);
            p.update(arm, t * noise, 5.0);
        }
        assert_eq!(p.total_pulls(), (40 * k) as f64);
        // The answer should land in the best quartile of arms.
        let best = p.most_selected();
        assert!(best >= (3 * k) / 4, "{} picked arm {best} of {k}", p.name());
    }

    #[test]
    fn all_policies_converge() {
        let k = 16;
        exercise(Box::new(UcbTuner::new(k, 1.0, 0.0)), k);
        exercise(Box::new(EpsilonGreedy::new(k, 1.0, 0.0, 0.1, 7)), k);
        exercise(Box::new(ThompsonSampler::new(k, 1.0, 0.0, 11)), k);
        exercise(Box::new(SlidingWindowUcb::new(k, 1.0, 0.0, 400)), k);
    }
}
