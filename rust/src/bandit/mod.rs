//! The LASP bandit engine (paper §III-IV).
//!
//! Every configuration in the application's [`crate::space::ParamSpace`] is
//! an arm. Pulling an arm runs the application once (on the device
//! simulator) and observes execution time τ and power ρ; the policy's
//! bookkeeping turns those into the paper's weighted reward (Eq. 5) and the
//! next selection (Eq. 2-3). The tuned configuration is the most-selected
//! arm (Eq. 4).
//!
//! Since the unified-core refactor every policy is a thin *strategy layer*
//! over one shared [`core::ArmStats`] engine: the core owns the per-arm
//! sufficient statistics (struct-of-arrays, cached means, O(1) pull
//! total), the policies own only their selection rule plus whatever extra
//! state that rule needs (an rng, a sliding window, a candidate map). All
//! steady-state scoring runs through each policy's reusable
//! [`core::Scratch`], so [`Policy::select`] allocates nothing once warm.
//!
//! [`UcbTuner`] is LASP itself. [`EpsilonGreedy`], [`ThompsonSampler`] and
//! [`SlidingWindowUcb`] are ablation policies used by the extension benches
//! (the paper motivates MAB adaptivity; these quantify it).
//!
//! The UCB score computation is delegated to a [`ScoreBackend`]: either the
//! pure-rust [`ScalarBackend`] or the AOT PJRT artifact
//! ([`crate::runtime::Engine`]), which are differentially tested against
//! each other.

pub mod core;
pub mod epsilon;
pub mod persist;
pub mod regret;
pub mod reward;
pub mod subset;
pub mod swucb;
pub mod thompson;
pub mod ucb;

pub use self::core::{ArmStats, Scratch};
pub use epsilon::EpsilonGreedy;
pub use regret::RegretTracker;
pub use reward::{ScalarBackend, ScoreBackend, Step, DEFAULT_EXPLORATION};
pub use subset::SubsetTuner;
pub use swucb::SlidingWindowUcb;
pub use thompson::ThompsonSampler;
pub use ucb::UcbTuner;

/// A selection decision plus the observability facts the flight recorder
/// logs per suggest: how close the runner-up was and whether the pick was
/// driven by the exploration term rather than the reward estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Choice {
    /// The chosen arm — always identical to what [`Policy::select`]
    /// would have returned at the same state (same RNG draws included).
    pub arm: usize,
    /// Top-2 score gap: winning score minus runner-up score, `0.0` when
    /// there is no runner-up or the decision bypassed scoring (initial
    /// sweep, ε-random branch).
    pub gap: f64,
    /// `true` when the pick was exploratory: an unpulled arm, an
    /// ε-random draw, or a choice that differs from the greedy
    /// reward-argmax.
    pub explore: bool,
}

/// Running top-2 over a score slice: `(argmax, best − second)`. Ties
/// resolve to the first maximum, matching [`crate::util::stats::argmax`].
pub(crate) fn top2(xs: &[f64]) -> (usize, f64) {
    let mut best_i = 0usize;
    let mut best = f64::NEG_INFINITY;
    let mut second = f64::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best {
            second = best;
            best = x;
            best_i = i;
        } else if x > second {
            second = x;
        }
    }
    (best_i, if xs.len() > 1 { best - second } else { 0.0 })
}

/// A sequential arm-selection policy over `k` arms.
///
/// The contract mirrors the paper's loop (Alg. 1): call [`Policy::select`],
/// run the configuration, feed the measurement back via [`Policy::update`].
/// Every policy is backed by one [`ArmStats`] core, exposed through
/// [`Policy::stats`] — that is what checkpointing, fleet sync and
/// warm-starting read and write, identically for every variant.
pub trait Policy: Send {
    /// Number of arms (full space — subset policies report the full space
    /// here and keep their candidate-space core behind [`Policy::stats`]).
    fn k(&self) -> usize;

    /// Choose the arm to pull at the current iteration. Allocation-free
    /// in steady state: scoring runs through the policy's [`Scratch`].
    fn select(&mut self) -> usize;

    /// [`Policy::select`] plus the decision telemetry the serve-path
    /// flight recorder logs. The contract is strict: for any policy
    /// state, `select_traced().arm` and `select()` return the same arm
    /// and consume the same RNG draws, and the traced pass stays
    /// allocation-free once the scratch is warm. Policies with real
    /// scoring passes override this; the default reports no telemetry.
    fn select_traced(&mut self) -> Choice {
        Choice { arm: self.select(), gap: 0.0, explore: false }
    }

    /// [`Policy::select_traced`] scoring through a caller-provided
    /// scratch instead of the policy's own — the primitive under
    /// [`select_batch`], which drives many sessions through one shared
    /// scratch so a batched suggest keeps a single warm buffer instead of
    /// touching every session's. The contract is the same as
    /// [`Policy::select_traced`] plus buffer independence: the returned
    /// [`Choice`] and the RNG draws consumed are bit-identical no matter
    /// which scratch the scores land in (scores are pure functions of the
    /// policy state). The policy's own scratch is neither read nor grown.
    fn select_traced_in(&mut self, scratch: &mut Scratch) -> Choice {
        let _ = scratch;
        self.select_traced()
    }

    /// Observe the measurement for `arm` (execution time seconds, watts).
    fn update(&mut self, arm: usize, time_s: f64, power_w: f64);

    /// Pull counts `N_x` (full-space view).
    fn counts(&self) -> &[f64];

    /// Eq. 4: the most frequently selected arm — the tuner's answer.
    fn most_selected(&self) -> usize {
        crate::util::stats::argmax(self.counts())
    }

    /// Total pulls so far — O(1) via the core's cached counter (policies
    /// whose full-space view diverges from their core, like the windowed
    /// SW-UCB, override this with their own cached total).
    fn total_pulls(&self) -> f64 {
        self.stats().total_pulls()
    }

    /// Policy name for reports.
    fn name(&self) -> &'static str;

    /// The shared arm-statistics core — the policy's sufficient
    /// statistics for checkpointing and fleet transfer. Subset policies
    /// expose their candidate-space core (positions are subset indices);
    /// windowed policies expose the windowed view.
    fn stats(&self) -> &ArmStats;

    /// Warm-start from a prior in the policy's own arm space (already
    /// discounted by the caller — see `serve::store::Tuner::warm_start`
    /// for the one shared dimension-check → project → discount pipeline).
    /// Each strategy absorbs the same prior its own way: UCB-family and
    /// Thompson install it as their core, SW-UCB replays it into the
    /// window, subset additionally projects counts to the full space.
    fn warm_start(&mut self, prior: ArmStats);

    /// Growth events of the policy's [`Scratch`] — flat after warm-up is
    /// the per-policy zero-allocation contract, asserted end-to-end by
    /// `rust/tests/serve_hotpath.rs`.
    fn scratch_growths(&self) -> u64;
}

/// Multi-session batched selection: one [`Choice`] per session, in entry
/// order, every scoring pass running through the single shared `scratch`.
/// This is the bandit-side core of `POST /v1/suggest/batch`: a batch of N
/// sessions costs one warm scratch (kept hot in cache across sessions)
/// instead of N per-session buffers, and the choices are bit-identical to
/// calling [`Policy::select_traced`] on each session in the same order
/// (pinned for every policy by `rust/tests/batch_equivalence.rs`).
///
/// `choices` is cleared and refilled — reuse it across batches (alongside
/// the scratch) to keep the steady state allocation-free.
pub fn select_batch(
    sessions: &mut [&mut dyn Policy],
    scratch: &mut Scratch,
    choices: &mut Vec<Choice>,
) {
    choices.clear();
    choices.reserve(sessions.len());
    for session in sessions.iter_mut() {
        choices.push(session.select_traced_in(scratch));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// All policies must try every arm and converge toward good arms on a
    /// stationary synthetic bandit where arm quality improves with index.
    fn exercise(mut p: Box<dyn Policy>, k: usize) {
        let mut rng = crate::util::Rng::new(42);
        for _ in 0..40 * k {
            let arm = p.select();
            assert!(arm < k);
            // Higher-index arms are faster (better): time 1.0 -> 0.3.
            let t = 1.0 - 0.7 * (arm as f64 / (k - 1) as f64);
            let noise = rng.relative_noise(0.05);
            p.update(arm, t * noise, 5.0);
        }
        assert_eq!(p.total_pulls(), (40 * k) as f64);
        // The answer should land in the best quartile of arms.
        let best = p.most_selected();
        assert!(best >= (3 * k) / 4, "{} picked arm {best} of {k}", p.name());
    }

    #[test]
    fn all_policies_converge() {
        let k = 16;
        exercise(Box::new(UcbTuner::new(k, 1.0, 0.0)), k);
        exercise(Box::new(EpsilonGreedy::new(k, 1.0, 0.0, 0.1, 7)), k);
        exercise(Box::new(ThompsonSampler::new(k, 1.0, 0.0, 11)), k);
        exercise(Box::new(SlidingWindowUcb::new(k, 1.0, 0.0, 400)), k);
    }

    #[test]
    fn select_traced_matches_select_including_rng_draws() {
        // Two identically seeded instances of every policy, one driven
        // through select(), the other through select_traced(): the arm
        // sequences must match exactly (same RNG draw order).
        let drive = |traced: bool| -> Vec<usize> {
            let mut policies: Vec<Box<dyn Policy>> = vec![
                Box::new(UcbTuner::new(8, 1.0, 0.0)),
                Box::new(EpsilonGreedy::new(8, 1.0, 0.0, 0.3, 7)),
                Box::new(ThompsonSampler::new(8, 1.0, 0.0, 11)),
                Box::new(SlidingWindowUcb::new(8, 1.0, 0.0, 32)),
                Box::new(SubsetTuner::new(100, 8, 1.0, 0.0, 3)),
            ];
            let mut out = vec![];
            for p in policies.iter_mut() {
                for i in 0..60usize {
                    let arm = if traced { p.select_traced().arm } else { p.select() };
                    out.push(arm);
                    p.update(arm, 1.0 + ((arm + i) % 5) as f64 * 0.2, 5.0);
                }
            }
            out
        };
        assert_eq!(drive(false), drive(true));
    }

    #[test]
    fn select_batch_matches_per_session_traced_selects() {
        // One shared scratch across a mixed-k fleet vs each session's own
        // scratch: identical choices, identical RNG draws, in entry order.
        let fleet = || -> Vec<Box<dyn Policy>> {
            vec![
                Box::new(UcbTuner::new(8, 1.0, 0.0)),
                Box::new(EpsilonGreedy::new(5, 1.0, 0.0, 0.3, 7)),
                Box::new(ThompsonSampler::new(12, 1.0, 0.0, 11)),
                Box::new(SlidingWindowUcb::new(8, 1.0, 0.0, 32)),
                Box::new(SubsetTuner::new(100, 8, 1.0, 0.0, 3)),
            ]
        };
        let (mut singles, mut batched) = (fleet(), fleet());
        let mut scratch = Scratch::new();
        let mut choices = Vec::new();
        for round in 0..60usize {
            let expected: Vec<Choice> =
                singles.iter_mut().map(|p| p.select_traced()).collect();
            let mut refs: Vec<&mut dyn Policy> =
                batched.iter_mut().map(|p| p.as_mut()).collect();
            select_batch(&mut refs, &mut scratch, &mut choices);
            assert_eq!(choices, expected, "round {round}");
            for (p, c) in singles.iter_mut().zip(&expected) {
                p.update(c.arm, 1.0 + ((c.arm + round) % 5) as f64 * 0.2, 5.0);
            }
            for (p, c) in batched.iter_mut().zip(&choices) {
                p.update(c.arm, 1.0 + ((c.arm + round) % 5) as f64 * 0.2, 5.0);
            }
        }
    }

    #[test]
    fn traced_choices_expose_gap_and_explore() {
        let mut p = UcbTuner::new(4, 1.0, 0.0);
        // Init sweep: unpulled arms are exploratory picks.
        for _ in 0..4 {
            let c = p.select_traced();
            assert!(c.explore);
            p.update(c.arm, 1.0 + c.arm as f64, 5.0);
        }
        // Steady state: the top-2 gap is finite and non-negative, and a
        // long-exploited arm eventually reads as exploit.
        let mut saw_exploit = false;
        for _ in 0..60 {
            let c = p.select_traced();
            assert!(c.gap.is_finite() && c.gap >= 0.0);
            saw_exploit |= !c.explore;
            p.update(c.arm, 1.0 + c.arm as f64, 5.0);
        }
        assert!(saw_exploit, "60 steady-state picks never exploited");
    }

    #[test]
    fn every_policy_exposes_its_core() {
        // The unified-core contract: stats() is total (no Option), and a
        // policy's pulls are visible through it after updates.
        let policies: Vec<Box<dyn Policy>> = vec![
            Box::new(UcbTuner::new(4, 1.0, 0.0)),
            Box::new(EpsilonGreedy::new(4, 1.0, 0.0, 0.1, 3)),
            Box::new(ThompsonSampler::new(4, 1.0, 0.0, 3)),
            Box::new(SlidingWindowUcb::new(4, 1.0, 0.0, 16)),
            Box::new(SubsetTuner::new(100, 4, 1.0, 0.0, 3)),
        ];
        for mut p in policies {
            let arm = p.select();
            p.update(arm, 1.0, 5.0);
            assert_eq!(p.stats().total_pulls(), 1.0, "{}", p.name());
            assert_eq!(p.total_pulls(), 1.0, "{}", p.name());
        }
    }
}
