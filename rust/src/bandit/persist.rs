//! Tuner-state checkpointing and warm starts.
//!
//! The paper's motivation section stresses that "the optimal configuration
//! evolves with changes in input type, input size, or incremental
//! algorithmic improvements" and that re-tuning from scratch is what makes
//! cumulative autotuning cost explode. A bandit's sufficient statistics
//! are tiny (3 f64 per arm), so LASP can checkpoint them after a campaign
//! and *warm-start* the next one: prior knowledge is kept but discounted,
//! letting the tuner re-verify quickly instead of re-exploring blindly.
//!
//! Since the unified-core refactor the serialized state is the shared
//! [`ArmStats`] engine itself (cached means and totals are derived, so
//! only the three sum vectors and `t` travel), which is why every policy
//! — not just the UCB family — checkpoints identically.

use super::core::ArmStats;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Current checkpoint format version.
const VERSION: f64 = 1.0;

/// Serialize an arm-statistics core (plus identifying metadata) to JSON.
pub fn to_json(state: &ArmStats, app: &str, alpha: f64, beta: f64) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("version".into(), Json::Num(VERSION));
    obj.insert("app".into(), Json::Str(app.into()));
    obj.insert("alpha".into(), Json::Num(alpha));
    obj.insert("beta".into(), Json::Num(beta));
    obj.insert("t".into(), Json::Num(state.t()));
    let vec_of = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
    obj.insert("tau_sum".into(), vec_of(state.tau_sum()));
    obj.insert("rho_sum".into(), vec_of(state.rho_sum()));
    obj.insert("counts".into(), vec_of(state.counts()));
    Json::Obj(obj).to_string()
}

/// Parsed checkpoint.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub app: String,
    pub alpha: f64,
    pub beta: f64,
    pub state: ArmStats,
}

/// Parse a checkpoint from JSON text.
pub fn from_json(text: &str) -> Result<Checkpoint> {
    let root = Json::parse(text).map_err(|e| anyhow!("checkpoint parse: {e}"))?;
    if root.get("version").and_then(Json::as_f64) != Some(VERSION) {
        return Err(anyhow!("unsupported checkpoint version"));
    }
    let read_vec = |key: &str| -> Result<Vec<f64>> {
        root.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint missing {key}"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric {key}")))
            .collect()
    };
    let tau_sum = read_vec("tau_sum")?;
    let rho_sum = read_vec("rho_sum")?;
    let counts = read_vec("counts")?;
    if tau_sum.len() != counts.len() || rho_sum.len() != counts.len() {
        return Err(anyhow!("checkpoint vector lengths disagree"));
    }
    if counts.iter().any(|&c| c < 0.0 || !c.is_finite()) {
        return Err(anyhow!("checkpoint counts invalid"));
    }
    if tau_sum.iter().chain(rho_sum.iter()).any(|x| !x.is_finite()) {
        return Err(anyhow!("checkpoint sums invalid"));
    }
    let t = root.get("t").and_then(Json::as_f64).unwrap_or(1.0).max(1.0);
    let state = ArmStats::from_parts(tau_sum, rho_sum, counts, t);
    Ok(Checkpoint {
        app: root
            .get("app")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string(),
        alpha: root.get("alpha").and_then(Json::as_f64).unwrap_or(0.8),
        beta: root.get("beta").and_then(Json::as_f64).unwrap_or(0.2),
        state,
    })
}

/// Write `text` to `path` atomically (unique temp file + fsync + rename),
/// so a reader — or a crash — never observes a torn checkpoint. The temp
/// name is unique per call, so concurrent writers of the same path (e.g.
/// the serve layer's periodic checkpointer racing a manual
/// `POST /v1/checkpoint`) each install a complete file; last rename wins.
pub fn write_atomic(path: &Path, text: &str) -> Result<()> {
    use std::io::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp.{}.{seq}", std::process::id()));
    let result = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(text.as_bytes())
            .with_context(|| format!("writing {}", tmp.display()))?;
        // Flush data blocks before the rename so a crash cannot install a
        // name pointing at unwritten content.
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Write a checkpoint file (atomically).
pub fn save(path: &Path, state: &ArmStats, app: &str, alpha: f64, beta: f64) -> Result<()> {
    write_atomic(path, &to_json(state, app, alpha, beta))
}

/// Read a checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    from_json(&text)
}

/// Discount a prior state for warm-starting (see [`ArmStats::discounted`]:
/// per-arm means are kept, effective counts shrink by `retain ∈ (0, 1]`).
pub fn discounted(prior: &ArmStats, retain: f64) -> ArmStats {
    prior.discounted(retain)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::{Policy, UcbTuner};
    use crate::util::Rng;

    fn populated(k: usize, pulls: usize) -> ArmStats {
        let mut s = ArmStats::new(k);
        let mut rng = Rng::new(3);
        for _ in 0..pulls {
            s.observe(rng.below(k), rng.range(0.2, 4.0), rng.range(2.0, 9.0));
        }
        s
    }

    #[test]
    fn json_roundtrip_exact() {
        let s = populated(40, 500);
        let text = to_json(&s, "kripke", 0.8, 0.2);
        let cp = from_json(&text).unwrap();
        assert_eq!(cp.app, "kripke");
        assert_eq!(cp.state.tau_sum(), s.tau_sum());
        assert_eq!(cp.state.rho_sum(), s.rho_sum());
        assert_eq!(cp.state.counts(), s.counts());
        assert_eq!(cp.state.t(), s.t());
        // Derived caches are rebuilt, so the whole core round-trips.
        assert_eq!(cp.state.total_pulls(), s.total_pulls());
        assert_eq!(cp.state.mean_tau(), s.mean_tau());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lasp-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.json");
        let s = populated(16, 100);
        save(&path, &s, "clomp", 1.0, 0.0).unwrap();
        let cp = load(&path).unwrap();
        assert_eq!(cp.app, "clomp");
        assert_eq!(cp.state.counts(), s.counts());
    }

    #[test]
    fn rejects_corrupt_checkpoints() {
        assert!(from_json("{}").is_err());
        assert!(from_json("not json").is_err());
        // Mismatched lengths.
        let bad = r#"{"version":1,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1,2],"rho_sum":[1],"counts":[1,1]}"#;
        assert!(from_json(bad).is_err());
        // Negative counts.
        let bad = r#"{"version":1,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1],"rho_sum":[1],"counts":[-2]}"#;
        assert!(from_json(bad).is_err());
        // Non-finite counts.
        let bad = r#"{"version":1,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1],"rho_sum":[1],"counts":[1e999]}"#;
        assert!(from_json(bad).is_err());
        // Non-finite sums (would poison means and fail re-serialization).
        let bad = r#"{"version":1,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1e999],"rho_sum":[1],"counts":[1]}"#;
        assert!(from_json(bad).is_err());
        let bad = r#"{"version":1,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1],"rho_sum":[-1e999],"counts":[1]}"#;
        assert!(from_json(bad).is_err());
        // Non-numeric vector entries.
        let bad = r#"{"version":1,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":["a"],"rho_sum":[1],"counts":[1]}"#;
        assert!(from_json(bad).is_err());
        // Wrong / missing version.
        let bad = r#"{"version":99,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1],"rho_sum":[1],"counts":[1]}"#;
        assert!(from_json(bad).is_err());
        let bad = r#"{"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1],"rho_sum":[1],"counts":[1]}"#;
        assert!(from_json(bad).is_err());
    }

    #[test]
    fn metadata_defaults_fill_in() {
        // Optional metadata falls back instead of failing: `t` clamps to
        // at least 1, app/alpha/beta take the paper defaults.
        let min = r#"{"version":1,"tau_sum":[2],"rho_sum":[4],"counts":[2]}"#;
        let cp = from_json(min).unwrap();
        assert_eq!(cp.app, "unknown");
        assert_eq!(cp.alpha, 0.8);
        assert_eq!(cp.beta, 0.2);
        assert_eq!(cp.state.t(), 1.0);
        let clamped = r#"{"version":1,"t":-5,"tau_sum":[2],"rho_sum":[4],"counts":[2]}"#;
        assert_eq!(from_json(clamped).unwrap().state.t(), 1.0);
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("lasp-atomic-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        let s1 = populated(8, 50);
        let s2 = populated(8, 90);
        save(&path, &s1, "kripke", 0.8, 0.2).unwrap();
        save(&path, &s2, "kripke", 0.8, 0.2).unwrap();
        let cp = load(&path).unwrap();
        assert_eq!(cp.state.counts(), s2.counts(), "second write must win");
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .count();
        assert_eq!(leftovers, 0, "temp files left behind");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn discount_full_retention_is_lossless() {
        // retain = 1.0 keeps pulled arms' counts and sums exactly
        // (counts from observe() are whole numbers >= 1).
        let s = populated(12, 200);
        let d = discounted(&s, 1.0);
        for i in 0..12 {
            if s.counts()[i] > 0.0 {
                assert!((d.counts()[i] - s.counts()[i]).abs() < 1e-12);
                assert!((d.tau_sum()[i] - s.tau_sum()[i]).abs() < 1e-9);
                assert!((d.rho_sum()[i] - s.rho_sum()[i]).abs() < 1e-9);
            } else {
                assert_eq!(d.counts()[i], 0.0);
            }
        }
    }

    #[test]
    fn discount_never_revives_unpulled_arms() {
        let mut s = ArmStats::new(6);
        s.observe(2, 1.0, 2.0);
        s.observe(4, 3.0, 2.0);
        let d = discounted(&s, 0.3);
        for i in [0usize, 1, 3, 5] {
            assert_eq!(d.counts()[i], 0.0);
            assert_eq!(d.tau_sum()[i], 0.0);
        }
        // t is rebuilt from the retained counts.
        assert!((d.t() - (d.total_pulls() + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn discount_preserves_means_shrinks_counts() {
        let s = populated(10, 300);
        let d = discounted(&s, 0.1);
        for i in 0..10 {
            if s.counts()[i] > 0.0 {
                let m1 = s.mean_tau()[i];
                let m2 = d.mean_tau()[i];
                assert!((m1 - m2).abs() < 1e-12);
                assert!(d.counts()[i] <= s.counts()[i]);
                assert!(d.counts()[i] >= 1.0);
            }
        }
    }

    #[test]
    fn warm_start_converges_faster_after_input_change() {
        // Scenario from the paper's motivation: the input size changes
        // (fidelity 0.15 -> 0.5 shifts the surface mildly). A warm-started
        // tuner should reach a near-oracle arm with fewer fresh pulls than
        // a cold-started one.
        use crate::apps::{self, AppKind};
        use crate::device::{Device, JetsonNano, PowerMode};
        let app = apps::build(AppKind::Clomp);
        let k = app.space().len();

        // Phase 1: tune at q=0.15 and checkpoint.
        let mut device = JetsonNano::new(PowerMode::Maxn, 8).with_fidelity(0.15);
        let mut cold = UcbTuner::new(k, 1.0, 0.0);
        for _ in 0..800 {
            let arm = cold.select();
            let m = device.run(&app.workload(arm, device.fidelity()));
            cold.update(arm, m.time_s, m.power_w);
        }
        let prior = cold.stats().clone();

        // Phase 2 (new input size q=0.5): cold vs warm with a small budget.
        let sweep: Vec<f64> = app
            .space()
            .indices()
            .map(|i| {
                crate::device::run_with_cap(&PowerMode::Maxn.spec(), &app.workload(i, 0.5)).time_s
            })
            .collect();
        let best_time = sweep.iter().cloned().fold(f64::INFINITY, f64::min);

        // Budget smaller than k: a cold start cannot even finish the UCB
        // init sweep, a warm start exploits prior knowledge immediately.
        let run_phase2 = |state: Option<ArmStats>| -> f64 {
            let mut tuner = UcbTuner::new(k, 1.0, 0.0);
            if let Some(s) = state {
                tuner = tuner.with_state(s);
            }
            let mut device = JetsonNano::new(PowerMode::Maxn, 9).with_fidelity(0.5);
            for _ in 0..60 {
                let arm = tuner.select();
                let m = device.run(&app.workload(arm, device.fidelity()));
                tuner.update(arm, m.time_s, m.power_w);
            }
            sweep[tuner.most_selected()] / best_time
        };

        let cold_ratio = run_phase2(None);
        let warm_ratio = run_phase2(Some(discounted(&prior, 0.2)));
        assert!(
            warm_ratio <= cold_ratio + 1e-9,
            "warm {warm_ratio} worse than cold {cold_ratio}"
        );
        assert!(warm_ratio < 1.10, "warm start should land near-oracle: {warm_ratio}");
    }
}
