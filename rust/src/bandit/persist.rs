//! Tuner-state checkpointing and warm starts.
//!
//! The paper's motivation section stresses that "the optimal configuration
//! evolves with changes in input type, input size, or incremental
//! algorithmic improvements" and that re-tuning from scratch is what makes
//! cumulative autotuning cost explode. A bandit's sufficient statistics
//! are tiny (3 f64 per arm), so LASP can checkpoint them after a campaign
//! and *warm-start* the next one: prior knowledge is kept but discounted,
//! letting the tuner re-verify quickly instead of re-exploring blindly.

use super::reward::RewardState;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Current checkpoint format version.
const VERSION: f64 = 1.0;

/// Serialize a reward state (plus identifying metadata) to JSON text.
pub fn to_json(state: &RewardState, app: &str, alpha: f64, beta: f64) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("version".into(), Json::Num(VERSION));
    obj.insert("app".into(), Json::Str(app.into()));
    obj.insert("alpha".into(), Json::Num(alpha));
    obj.insert("beta".into(), Json::Num(beta));
    obj.insert("t".into(), Json::Num(state.t));
    let vec_of = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
    obj.insert("tau_sum".into(), vec_of(&state.tau_sum));
    obj.insert("rho_sum".into(), vec_of(&state.rho_sum));
    obj.insert("counts".into(), vec_of(&state.counts));
    Json::Obj(obj).to_string()
}

/// Parsed checkpoint.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub app: String,
    pub alpha: f64,
    pub beta: f64,
    pub state: RewardState,
}

/// Parse a checkpoint from JSON text.
pub fn from_json(text: &str) -> Result<Checkpoint> {
    let root = Json::parse(text).map_err(|e| anyhow!("checkpoint parse: {e}"))?;
    if root.get("version").and_then(Json::as_f64) != Some(VERSION) {
        return Err(anyhow!("unsupported checkpoint version"));
    }
    let read_vec = |key: &str| -> Result<Vec<f64>> {
        root.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint missing {key}"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric {key}")))
            .collect()
    };
    let tau_sum = read_vec("tau_sum")?;
    let rho_sum = read_vec("rho_sum")?;
    let counts = read_vec("counts")?;
    if tau_sum.len() != counts.len() || rho_sum.len() != counts.len() {
        return Err(anyhow!("checkpoint vector lengths disagree"));
    }
    if counts.iter().any(|&c| c < 0.0 || !c.is_finite()) {
        return Err(anyhow!("checkpoint counts invalid"));
    }
    let mut state = RewardState::new(counts.len());
    state.tau_sum = tau_sum;
    state.rho_sum = rho_sum;
    state.counts = counts;
    state.t = root.get("t").and_then(Json::as_f64).unwrap_or(1.0).max(1.0);
    Ok(Checkpoint {
        app: root
            .get("app")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string(),
        alpha: root.get("alpha").and_then(Json::as_f64).unwrap_or(0.8),
        beta: root.get("beta").and_then(Json::as_f64).unwrap_or(0.2),
        state,
    })
}

/// Write a checkpoint file.
pub fn save(path: &Path, state: &RewardState, app: &str, alpha: f64, beta: f64) -> Result<()> {
    std::fs::write(path, to_json(state, app, alpha, beta))
        .with_context(|| format!("writing {}", path.display()))
}

/// Read a checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    from_json(&text)
}

/// Discount a prior state for warm-starting: keep per-arm means but shrink
/// effective counts by `retain ∈ (0, 1]`, so prior knowledge biases early
/// selection without suppressing re-verification of a shifted environment.
pub fn discounted(prior: &RewardState, retain: f64) -> RewardState {
    assert!(retain > 0.0 && retain <= 1.0);
    let k = prior.k();
    let mut out = RewardState::new(k);
    for i in 0..k {
        if prior.counts[i] > 0.0 {
            let kept = (prior.counts[i] * retain).max(1.0);
            let mean_tau = prior.tau_sum[i] / prior.counts[i];
            let mean_rho = prior.rho_sum[i] / prior.counts[i];
            out.counts[i] = kept;
            out.tau_sum[i] = mean_tau * kept;
            out.rho_sum[i] = mean_rho * kept;
        }
    }
    out.t = out.counts.iter().sum::<f64>() + 1.0;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::{Policy, UcbTuner};
    use crate::util::Rng;

    fn populated(k: usize, pulls: usize) -> RewardState {
        let mut s = RewardState::new(k);
        let mut rng = Rng::new(3);
        for _ in 0..pulls {
            s.observe(rng.below(k), rng.range(0.2, 4.0), rng.range(2.0, 9.0));
        }
        s
    }

    #[test]
    fn json_roundtrip_exact() {
        let s = populated(40, 500);
        let text = to_json(&s, "kripke", 0.8, 0.2);
        let cp = from_json(&text).unwrap();
        assert_eq!(cp.app, "kripke");
        assert_eq!(cp.state.tau_sum, s.tau_sum);
        assert_eq!(cp.state.rho_sum, s.rho_sum);
        assert_eq!(cp.state.counts, s.counts);
        assert_eq!(cp.state.t, s.t);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lasp-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.json");
        let s = populated(16, 100);
        save(&path, &s, "clomp", 1.0, 0.0).unwrap();
        let cp = load(&path).unwrap();
        assert_eq!(cp.app, "clomp");
        assert_eq!(cp.state.counts, s.counts);
    }

    #[test]
    fn rejects_corrupt_checkpoints() {
        assert!(from_json("{}").is_err());
        assert!(from_json("not json").is_err());
        // Mismatched lengths.
        let bad = r#"{"version":1,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1,2],"rho_sum":[1],"counts":[1,1]}"#;
        assert!(from_json(bad).is_err());
        // Negative counts.
        let bad = r#"{"version":1,"app":"x","alpha":1,"beta":0,"t":3,
            "tau_sum":[1],"rho_sum":[1],"counts":[-2]}"#;
        assert!(from_json(bad).is_err());
    }

    #[test]
    fn discount_preserves_means_shrinks_counts() {
        let s = populated(10, 300);
        let d = discounted(&s, 0.1);
        for i in 0..10 {
            if s.counts[i] > 0.0 {
                let m1 = s.tau_sum[i] / s.counts[i];
                let m2 = d.tau_sum[i] / d.counts[i];
                assert!((m1 - m2).abs() < 1e-12);
                assert!(d.counts[i] <= s.counts[i]);
                assert!(d.counts[i] >= 1.0);
            }
        }
    }

    #[test]
    fn warm_start_converges_faster_after_input_change() {
        // Scenario from the paper's motivation: the input size changes
        // (fidelity 0.15 -> 0.5 shifts the surface mildly). A warm-started
        // tuner should reach a near-oracle arm with fewer fresh pulls than
        // a cold-started one.
        use crate::apps::{self, AppKind};
        use crate::device::{Device, JetsonNano, PowerMode};
        let app = apps::build(AppKind::Clomp);
        let k = app.space().len();

        // Phase 1: tune at q=0.15 and checkpoint.
        let mut device = JetsonNano::new(PowerMode::Maxn, 8).with_fidelity(0.15);
        let mut cold = UcbTuner::new(k, 1.0, 0.0);
        for _ in 0..800 {
            let arm = cold.select();
            let m = device.run(&app.workload(arm, device.fidelity()));
            cold.update(arm, m.time_s, m.power_w);
        }
        let prior = cold.state().clone();

        // Phase 2 (new input size q=0.5): cold vs warm with a small budget.
        let sweep: Vec<f64> = app
            .space()
            .indices()
            .map(|i| {
                crate::device::run_with_cap(&PowerMode::Maxn.spec(), &app.workload(i, 0.5)).time_s
            })
            .collect();
        let best_time = sweep.iter().cloned().fold(f64::INFINITY, f64::min);

        // Budget smaller than k: a cold start cannot even finish the UCB
        // init sweep, a warm start exploits prior knowledge immediately.
        let run_phase2 = |state: Option<RewardState>| -> f64 {
            let mut tuner = UcbTuner::new(k, 1.0, 0.0);
            if let Some(s) = state {
                tuner = tuner.with_state(s);
            }
            let mut device = JetsonNano::new(PowerMode::Maxn, 9).with_fidelity(0.5);
            for _ in 0..60 {
                let arm = tuner.select();
                let m = device.run(&app.workload(arm, device.fidelity()));
                tuner.update(arm, m.time_s, m.power_w);
            }
            sweep[tuner.most_selected()] / best_time
        };

        let cold_ratio = run_phase2(None);
        let warm_ratio = run_phase2(Some(discounted(&prior, 0.2)));
        assert!(
            warm_ratio <= cold_ratio + 1e-9,
            "warm {warm_ratio} worse than cold {cold_ratio}"
        );
        assert!(warm_ratio < 1.10, "warm start should land near-oracle: {warm_ratio}");
    }
}
