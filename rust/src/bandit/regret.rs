//! Regret accounting (paper Eq. 1 and the Eq. 7 UCB1 bound).

/// Tracks cumulative expected regret `R_T = T·μ* − Σ μ_{j(t)}` against a
/// known per-arm expected-reward vector (available in simulation: the
/// noise-free oracle sweep).
#[derive(Debug, Clone)]
pub struct RegretTracker {
    /// Expected reward per arm under the experiment's (α, β).
    mu: Vec<f64>,
    mu_star: f64,
    cumulative: f64,
    /// Cumulative regret after each round (the Fig 11 series).
    trajectory: Vec<f64>,
}

impl RegretTracker {
    /// `mu[i]` = expected reward of arm `i`; `μ*` is its max.
    pub fn new(mu: Vec<f64>) -> Self {
        assert!(!mu.is_empty());
        let mu_star = mu.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        RegretTracker { mu, mu_star, cumulative: 0.0, trajectory: vec![] }
    }

    /// Record the arm played this round.
    pub fn record(&mut self, arm: usize) {
        self.cumulative += self.mu_star - self.mu[arm];
        self.trajectory.push(self.cumulative);
    }

    /// Total expected regret so far (Eq. 1).
    pub fn cumulative(&self) -> f64 {
        self.cumulative
    }

    /// Cumulative-regret series, one entry per round (Fig 11).
    pub fn trajectory(&self) -> &[f64] {
        &self.trajectory
    }

    /// Rounds recorded.
    pub fn rounds(&self) -> usize {
        self.trajectory.len()
    }

    /// Average regret per play `R_n / n` — tends to 0 for UCB (Eq. 7).
    pub fn average(&self) -> f64 {
        if self.trajectory.is_empty() {
            0.0
        } else {
            self.cumulative / self.trajectory.len() as f64
        }
    }

    /// The Eq. 7 logarithmic UCB1 regret bound at `n` plays:
    /// `8 ln n Σ_{i: μ_i<μ*} 1/Δ_i + (1 + π²/3) Σ Δ_i`.
    pub fn ucb1_bound(&self, n: usize) -> f64 {
        let ln_n = (n.max(1) as f64).ln();
        let mut inv_gap_sum = 0.0;
        let mut gap_sum = 0.0;
        for &m in &self.mu {
            let gap = self.mu_star - m;
            if gap > 1e-12 {
                inv_gap_sum += 1.0 / gap;
                gap_sum += gap;
            }
        }
        8.0 * ln_n * inv_gap_sum + (1.0 + std::f64::consts::PI.powi(2) / 3.0) * gap_sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_regret_for_optimal_play() {
        let mut r = RegretTracker::new(vec![0.2, 0.9, 0.5]);
        for _ in 0..10 {
            r.record(1);
        }
        assert_eq!(r.cumulative(), 0.0);
        assert_eq!(r.average(), 0.0);
    }

    #[test]
    fn accumulates_gap_for_suboptimal_play() {
        let mut r = RegretTracker::new(vec![0.2, 0.9]);
        r.record(0);
        r.record(0);
        assert!((r.cumulative() - 1.4).abs() < 1e-12);
        assert_eq!(r.trajectory(), &[0.7, 1.4]);
    }

    #[test]
    fn bound_grows_logarithmically() {
        let r = RegretTracker::new(vec![0.1, 0.5, 0.9]);
        let b100 = r.ucb1_bound(100);
        let b10000 = r.ucb1_bound(10_000);
        // log growth: doubling the exponent doubles (not squares) the bound.
        assert!(b10000 < 2.5 * b100, "{b100} -> {b10000}");
        assert!(b10000 > b100);
    }

    #[test]
    fn ucb_respects_eq7_bound_on_synthetic_bandit() {
        // Run actual UCB1 on a 5-arm Bernoulli-ish bandit and check Eq. 7.
        use crate::bandit::{Policy, UcbTuner};
        let mu = vec![0.3, 0.5, 0.7, 0.2, 0.9];
        let mut tracker = RegretTracker::new(mu.clone());
        let mut tuner = UcbTuner::new(5, 1.0, 0.0);
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..2000 {
            let arm = tuner.select();
            tracker.record(arm);
            // Map reward mean to a time measurement: faster = better.
            let time = (1.0 - mu[arm]) * rng.relative_noise(0.05);
            tuner.update(arm, time, 1.0);
        }
        assert!(tracker.cumulative() <= tracker.ucb1_bound(2000));
        // And regret rate is clearly sub-linear: average regret well below
        // the uniform-random value.
        let uniform_avg = (0.9 - (0.3 + 0.5 + 0.7 + 0.2 + 0.9) / 5.0) * 0.99;
        assert!(tracker.average() < uniform_avg);
    }
}
