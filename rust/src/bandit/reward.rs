//! Reward bookkeeping (paper Alg. 1 lines 1-2, Eq. 5) and the score
//! backend abstraction shared by the pure-rust and PJRT implementations.

use anyhow::Result;

/// Reward assigned to never-pulled arms by the UCB kernel (must match
/// `python/compile/kernels/ucb.py::UNPULLED_SCORE`).
pub const UNPULLED_SCORE: f64 = 1.0e9;
/// Guard for the `1/metric` inverse in Eq. 5 (must match `model.py`).
pub const REWARD_EPS: f64 = 1e-2;
/// Degenerate-range guard for MinMax (must match `model.py`).
pub const MINMAX_EPS: f64 = 1e-9;
/// Default exploration coefficient for LASP.
///
/// The paper's Eq. 2 uses c = 1 over rewards it *states* lie in [0, 1], but
/// its Eq. 5 reward (α/τ̂ + β/ρ̂) is unbounded — up to (α+β)/ε = 100 — which
/// makes the sqrt bonus negligible in their setting. We keep rewards
/// genuinely normalized and scale the bonus instead; c = 0.25 reproduces the
/// paper's observed convergence speeds (DESIGN.md §Calibration).
pub const DEFAULT_EXPLORATION: f64 = 0.25;

/// Running per-arm sufficient statistics: Στ, Σρ, N.
#[derive(Debug, Clone)]
pub struct RewardState {
    pub tau_sum: Vec<f64>,
    pub rho_sum: Vec<f64>,
    pub counts: Vec<f64>,
    /// Iteration counter `t` (1-based, incremented per update).
    pub t: f64,
}

impl RewardState {
    pub fn new(k: usize) -> Self {
        RewardState {
            tau_sum: vec![0.0; k],
            rho_sum: vec![0.0; k],
            counts: vec![0.0; k],
            t: 1.0,
        }
    }

    pub fn k(&self) -> usize {
        self.counts.len()
    }

    /// Record one measurement for `arm`.
    pub fn observe(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.tau_sum[arm] += time_s;
        self.rho_sum[arm] += power_w;
        self.counts[arm] += 1.0;
        self.t += 1.0;
    }

    /// Per-arm mean execution times with unpulled arms filled neutrally
    /// (the mean over pulled arms), mirroring `model.py::reward_norm`.
    pub fn filled_means(&self) -> (Vec<f64>, Vec<f64>) {
        let k = self.k();
        let mut mean_tau = vec![0.0; k];
        let mut mean_rho = vec![0.0; k];
        let mut fill_tau = 0.0;
        let mut fill_rho = 0.0;
        let mut pulled = 0.0f64;
        for i in 0..k {
            if self.counts[i] > 0.0 {
                mean_tau[i] = self.tau_sum[i] / self.counts[i];
                mean_rho[i] = self.rho_sum[i] / self.counts[i];
                fill_tau += mean_tau[i];
                fill_rho += mean_rho[i];
                pulled += 1.0;
            }
        }
        let denom = pulled.max(1.0);
        let (fill_tau, fill_rho) = (fill_tau / denom, fill_rho / denom);
        for i in 0..k {
            if self.counts[i] == 0.0 {
                mean_tau[i] = fill_tau;
                mean_rho[i] = fill_rho;
            }
        }
        (mean_tau, mean_rho)
    }
}

/// Output of one fused scoring step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Eq. 3: arm with the highest UCB score.
    pub best: usize,
    /// Its UCB score.
    pub score: f64,
    /// Eq. 5 rewards for all arms (normalized to `[0, 1]`).
    pub rewards: Vec<f64>,
}

/// The per-iteration scoring hot path: reward normalization (Eq. 5) +
/// UCB scores (Eq. 2) + argmax (Eq. 3). Implemented by [`ScalarBackend`]
/// (pure rust) and [`crate::runtime::Engine`] (AOT PJRT artifact).
pub trait ScoreBackend: Send {
    fn lasp_step(
        &mut self,
        state: &RewardState,
        alpha: f64,
        beta: f64,
        exploration: f64,
    ) -> Result<StepOutput>;

    /// Backend name for reports.
    fn backend_name(&self) -> &'static str;
}

/// Pure-rust reference backend, semantically identical to the lowered
/// `lasp_step` artifact (differential-tested in `rust/tests/`).
#[derive(Debug, Default, Clone)]
pub struct ScalarBackend;

/// Eq. 5 weighted reward over filled per-arm means, re-normalized to [0,1].
pub fn weighted_rewards(
    mean_tau: &[f64],
    mean_rho: &[f64],
    alpha: f64,
    beta: f64,
) -> Vec<f64> {
    let tau_hat = minmax_eps(mean_tau);
    let rho_hat = minmax_eps(mean_rho);
    let raw: Vec<f64> = tau_hat
        .iter()
        .zip(&rho_hat)
        .map(|(t, r)| alpha / (t + REWARD_EPS) + beta / (r + REWARD_EPS))
        .collect();
    minmax_eps(&raw)
}

fn minmax_eps(xs: &[f64]) -> Vec<f64> {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let range = (hi - lo).max(MINMAX_EPS);
    xs.iter().map(|x| (x - lo) / range).collect()
}

/// Eq. 2 scores for all arms (with exploration coefficient `c`).
pub fn ucb_scores(rewards: &[f64], counts: &[f64], t: f64, c: f64) -> Vec<f64> {
    let log_t = t.max(1.0).ln();
    rewards
        .iter()
        .zip(counts)
        .map(|(r, n)| {
            if *n > 0.0 {
                r + c * (2.0 * log_t / n.max(1.0)).sqrt()
            } else {
                UNPULLED_SCORE
            }
        })
        .collect()
}

impl ScoreBackend for ScalarBackend {
    /// Fused single-buffer implementation of the reference pipeline
    /// `filled_means → weighted_rewards → ucb_scores → argmax`
    /// (§Perf: 3 passes and one allocation instead of 9 passes and 7 —
    /// see EXPERIMENTS.md §Perf for before/after; equivalence is asserted
    /// by `fused_step_matches_reference_pipeline` below and the PJRT
    /// differential tests).
    fn lasp_step(
        &mut self,
        state: &RewardState,
        alpha: f64,
        beta: f64,
        exploration: f64,
    ) -> Result<StepOutput> {
        let k = state.k();
        let counts = &state.counts;

        // Pass 1: per-arm means (pulled only) + fill value + mean extrema.
        let mut fill_tau = 0.0;
        let mut fill_rho = 0.0;
        let mut pulled = 0.0f64;
        let mut tau_lo = f64::INFINITY;
        let mut tau_hi = f64::NEG_INFINITY;
        let mut rho_lo = f64::INFINITY;
        let mut rho_hi = f64::NEG_INFINITY;
        for i in 0..k {
            if counts[i] > 0.0 {
                let mt = state.tau_sum[i] / counts[i];
                let mr = state.rho_sum[i] / counts[i];
                fill_tau += mt;
                fill_rho += mr;
                pulled += 1.0;
                tau_lo = tau_lo.min(mt);
                tau_hi = tau_hi.max(mt);
                rho_lo = rho_lo.min(mr);
                rho_hi = rho_hi.max(mr);
            }
        }
        let denom = pulled.max(1.0);
        let fill_tau = fill_tau / denom;
        let fill_rho = fill_rho / denom;
        if pulled == 0.0 {
            // Degenerate: nothing observed; fill value defines the range.
            tau_lo = fill_tau;
            tau_hi = fill_tau;
            rho_lo = fill_rho;
            rho_hi = fill_rho;
        } else {
            // Unpulled arms carry the fill mean: it is inside [lo, hi]
            // already when pulled > 0, so extrema are unchanged.
        }
        let tau_range = (tau_hi - tau_lo).max(MINMAX_EPS);
        let rho_range = (rho_hi - rho_lo).max(MINMAX_EPS);

        // Pass 2: raw Eq. 5 rewards into the output buffer + raw extrema.
        let mut rewards = vec![0.0f64; k];
        let mut raw_lo = f64::INFINITY;
        let mut raw_hi = f64::NEG_INFINITY;
        for i in 0..k {
            let (mt, mr) = if counts[i] > 0.0 {
                (state.tau_sum[i] / counts[i], state.rho_sum[i] / counts[i])
            } else {
                (fill_tau, fill_rho)
            };
            let tau_hat = (mt - tau_lo) / tau_range;
            let rho_hat = (mr - rho_lo) / rho_range;
            let raw = alpha / (tau_hat + REWARD_EPS) + beta / (rho_hat + REWARD_EPS);
            rewards[i] = raw;
            raw_lo = raw_lo.min(raw);
            raw_hi = raw_hi.max(raw);
        }
        let raw_range = (raw_hi - raw_lo).max(MINMAX_EPS);

        // Pass 3: normalize rewards in place + UCB score + running argmax.
        let log_t = state.t.max(1.0).ln();
        let bonus_base = 2.0 * log_t;
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for i in 0..k {
            let r = (rewards[i] - raw_lo) / raw_range;
            rewards[i] = r;
            let score = if counts[i] > 0.0 {
                r + exploration * (bonus_base / counts[i]).sqrt()
            } else {
                UNPULLED_SCORE
            };
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        Ok(StepOutput { best, score: best_score, rewards })
    }

    fn backend_name(&self) -> &'static str {
        "scalar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn observe_accumulates() {
        let mut s = RewardState::new(3);
        s.observe(1, 2.0, 5.0);
        s.observe(1, 4.0, 7.0);
        assert_eq!(s.tau_sum[1], 6.0);
        assert_eq!(s.rho_sum[1], 12.0);
        assert_eq!(s.counts[1], 2.0);
        assert_eq!(s.t, 3.0);
    }

    #[test]
    fn filled_means_neutral_for_unpulled() {
        let mut s = RewardState::new(3);
        s.observe(0, 2.0, 4.0);
        s.observe(1, 4.0, 8.0);
        let (mt, mr) = s.filled_means();
        assert_eq!(mt, vec![2.0, 4.0, 3.0]); // arm 2 filled with mean(2,4)
        assert_eq!(mr, vec![4.0, 8.0, 6.0]);
    }

    #[test]
    fn rewards_bounded_and_ordered() {
        // alpha=1: reward strictly decreasing in mean time.
        let r = weighted_rewards(&[1.0, 2.0, 3.0], &[5.0, 5.0, 5.0], 1.0, 0.0);
        assert!(r[0] > r[1] && r[1] > r[2]);
        assert!((r[0] - 1.0).abs() < 1e-9 && r[2].abs() < 1e-9);
        for x in r {
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn unpulled_scores_big() {
        let s = ucb_scores(&[0.5, 0.5], &[0.0, 3.0], 10.0, 1.0);
        assert_eq!(s[0], UNPULLED_SCORE);
        assert!(s[1] < UNPULLED_SCORE);
    }

    #[test]
    fn scalar_backend_selects_unpulled_first() {
        let mut s = RewardState::new(4);
        s.observe(0, 1.0, 1.0);
        s.observe(1, 1.0, 1.0);
        let out = ScalarBackend.lasp_step(&s, 0.8, 0.2, 1.0).unwrap();
        assert!(out.best == 2 || out.best == 3);
        assert_eq!(out.score, UNPULLED_SCORE);
    }

    #[test]
    fn scalar_backend_exploits_best_arm() {
        let mut s = RewardState::new(3);
        for _ in 0..500 {
            s.observe(0, 5.0, 5.0);
            s.observe(1, 1.0, 5.0); // fastest
            s.observe(2, 3.0, 5.0);
        }
        let out = ScalarBackend.lasp_step(&s, 1.0, 0.0, 1.0).unwrap();
        assert_eq!(out.best, 1);
        assert_eq!(stats::argmax(&out.rewards), 1);
    }

    #[test]
    fn fused_step_matches_reference_pipeline() {
        // The optimized lasp_step must equal the composed reference
        // functions bit-for-bit-ish across many random states.
        let mut rng = crate::util::Rng::new(5);
        for trial in 0..200 {
            let k = 2 + rng.below(300);
            let mut s = RewardState::new(k);
            for _ in 0..rng.below(1000) {
                s.observe(rng.below(k), rng.range(0.05, 9.0), rng.range(0.5, 12.0));
            }
            let (alpha, beta, c) = (rng.uniform(), rng.uniform(), rng.range(0.01, 1.5));
            let fused = ScalarBackend.lasp_step(&s, alpha, beta, c).unwrap();
            let (mt, mr) = s.filled_means();
            let rewards = weighted_rewards(&mt, &mr, alpha, beta);
            let scores = ucb_scores(&rewards, &s.counts, s.t, c);
            let best = stats::argmax(&scores);
            assert_eq!(fused.best, best, "trial {trial}");
            assert!((fused.score - scores[best]).abs() < 1e-12, "trial {trial}");
            for (a, b) in fused.rewards.iter().zip(&rewards) {
                assert!((a - b).abs() < 1e-12, "trial {trial}");
            }
        }
    }

    #[test]
    fn alpha_beta_tradeoff() {
        let mut s = RewardState::new(2);
        for _ in 0..100 {
            s.observe(0, 1.0, 10.0); // fast, hungry
            s.observe(1, 2.0, 5.0); // slow, frugal
        }
        let time_focus = ScalarBackend.lasp_step(&s, 1.0, 0.0, 1.0).unwrap();
        let power_focus = ScalarBackend.lasp_step(&s, 0.0, 1.0, 1.0).unwrap();
        assert_eq!(stats::argmax(&time_focus.rewards), 0);
        assert_eq!(stats::argmax(&power_focus.rewards), 1);
    }
}
