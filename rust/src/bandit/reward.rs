//! Score kernels over the shared [`ArmStats`] core (paper Eq. 2, Eq. 5)
//! and the score-backend abstraction shared by the pure-rust and PJRT
//! implementations.
//!
//! Two flavours of every kernel exist:
//!
//! * `*_into` — the hot-path form: reads the core's cached per-arm means,
//!   writes into a caller-provided buffer (in practice a policy's
//!   [`Scratch`]), allocates nothing;
//! * the allocating form (`weighted_rewards`, `ucb_scores`) — the
//!   reference pipeline over plain slices, used by the offline experiment
//!   drivers and as the equivalence oracle for the fused kernels.

use super::core::{ArmStats, Scratch};
use anyhow::Result;

/// Reward assigned to never-pulled arms by the UCB kernel (must match
/// `python/compile/kernels/ucb.py::UNPULLED_SCORE`).
pub const UNPULLED_SCORE: f64 = 1.0e9;
/// Guard for the `1/metric` inverse in Eq. 5 (must match `model.py`).
pub const REWARD_EPS: f64 = 1e-2;
/// Degenerate-range guard for MinMax (must match `model.py`).
pub const MINMAX_EPS: f64 = 1e-9;
/// Default exploration coefficient for LASP.
///
/// The paper's Eq. 2 uses c = 1 over rewards it *states* lie in [0, 1], but
/// its Eq. 5 reward (α/τ̂ + β/ρ̂) is unbounded — up to (α+β)/ε = 100 — which
/// makes the sqrt bonus negligible in their setting. We keep rewards
/// genuinely normalized and scale the bonus instead; c = 0.25 reproduces the
/// paper's observed convergence speeds (DESIGN.md §Calibration).
pub const DEFAULT_EXPLORATION: f64 = 0.25;

/// Raw-reward extrema produced by the shared pass over [`ArmStats`].
struct RawExtrema {
    lo: f64,
    range: f64,
}

/// Passes 1-2 of the fused pipeline: per-arm fill means + mean extrema,
/// then raw Eq. 5 rewards into `out`. Shared by [`weighted_rewards_into`]
/// and [`ScalarBackend::lasp_step`] so both produce bit-identical rewards.
fn raw_rewards_into(stats: &ArmStats, alpha: f64, beta: f64, out: &mut [f64]) -> RawExtrema {
    let k = stats.k();
    debug_assert_eq!(out.len(), k);
    let counts = stats.counts();
    let mean_tau = stats.mean_tau();
    let mean_rho = stats.mean_rho();

    // Pass 1: fill value + mean extrema over pulled arms (cached means —
    // the core keeps `mean_* = *_sum / counts` current on every observe).
    //
    // Branch-free: unpulled arms contribute `+0.0` to the fill sums (their
    // cached means are exactly 0.0, and every partial sum is non-negative,
    // so the added zeros cannot flip a sign bit) and `±inf` to the extrema
    // (the identity elements of min/max). The fill sums keep their frozen
    // left-to-right order — reassociating them would drift the fill means
    // and break the bit-stability contract pinned by the frozen scalar
    // references in `batch_equivalence.rs` and the policy goldens. The
    // pulled counter sums whole 1.0s, exact in any order.
    let mut fill_tau = 0.0;
    let mut fill_rho = 0.0;
    let mut pulled = 0.0f64;
    let mut tau_lo = f64::INFINITY;
    let mut tau_hi = f64::NEG_INFINITY;
    let mut rho_lo = f64::INFINITY;
    let mut rho_hi = f64::NEG_INFINITY;
    for i in 0..k {
        let on = counts[i] > 0.0;
        let mt = mean_tau[i];
        let mr = mean_rho[i];
        fill_tau += if on { mt } else { 0.0 };
        fill_rho += if on { mr } else { 0.0 };
        pulled += if on { 1.0 } else { 0.0 };
        tau_lo = tau_lo.min(if on { mt } else { f64::INFINITY });
        tau_hi = tau_hi.max(if on { mt } else { f64::NEG_INFINITY });
        rho_lo = rho_lo.min(if on { mr } else { f64::INFINITY });
        rho_hi = rho_hi.max(if on { mr } else { f64::NEG_INFINITY });
    }
    let denom = pulled.max(1.0);
    let fill_tau = fill_tau / denom;
    let fill_rho = fill_rho / denom;
    if pulled == 0.0 {
        // Degenerate: nothing observed; fill value defines the range.
        tau_lo = fill_tau;
        tau_hi = fill_tau;
        rho_lo = fill_rho;
        rho_hi = fill_rho;
    }
    // Unpulled arms carry the fill mean, which lies inside [lo, hi]
    // whenever pulled > 0, so the extrema above are already final.
    let tau_range = (tau_hi - tau_lo).max(MINMAX_EPS);
    let rho_range = (rho_hi - rho_lo).max(MINMAX_EPS);

    // Pass 2: raw Eq. 5 rewards into the output buffer + raw extrema.
    // Branch-free per element (the unpulled fallback is a select, not a
    // branch, so every lane runs the same arithmetic) with `chunks_exact`
    // bodies and split min/max accumulators — min/max are associative and
    // commutative over the non-NaN rewards, so lane-splitting them cannot
    // change a bit, unlike the ordered fill sums above.
    const LANES: usize = 4;
    let mut lo_l = [f64::INFINITY; LANES];
    let mut hi_l = [f64::NEG_INFINITY; LANES];
    let head = k - k % LANES;
    let mut i = 0;
    while i < head {
        for l in 0..LANES {
            let j = i + l;
            let on = counts[j] > 0.0;
            let mt = if on { mean_tau[j] } else { fill_tau };
            let mr = if on { mean_rho[j] } else { fill_rho };
            let tau_hat = (mt - tau_lo) / tau_range;
            let rho_hat = (mr - rho_lo) / rho_range;
            let raw = alpha / (tau_hat + REWARD_EPS) + beta / (rho_hat + REWARD_EPS);
            out[j] = raw;
            lo_l[l] = lo_l[l].min(raw);
            hi_l[l] = hi_l[l].max(raw);
        }
        i += LANES;
    }
    for j in head..k {
        let on = counts[j] > 0.0;
        let mt = if on { mean_tau[j] } else { fill_tau };
        let mr = if on { mean_rho[j] } else { fill_rho };
        let tau_hat = (mt - tau_lo) / tau_range;
        let rho_hat = (mr - rho_lo) / rho_range;
        let raw = alpha / (tau_hat + REWARD_EPS) + beta / (rho_hat + REWARD_EPS);
        out[j] = raw;
        lo_l[0] = lo_l[0].min(raw);
        hi_l[0] = hi_l[0].max(raw);
    }
    let raw_lo = lo_l.iter().fold(f64::INFINITY, |a, &b| a.min(b));
    let raw_hi = hi_l.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
    RawExtrema { lo: raw_lo, range: (raw_hi - raw_lo).max(MINMAX_EPS) }
}

/// Eq. 5 weighted rewards over the core's (fill-completed) means,
/// re-normalized to [0, 1], written into `out` (`out.len() == stats.k()`).
/// Allocation-free; equivalent to
/// `weighted_rewards(&stats.filled_means()...)` bit for bit.
pub fn weighted_rewards_into(stats: &ArmStats, alpha: f64, beta: f64, out: &mut [f64]) {
    let raw = raw_rewards_into(stats, alpha, beta, out);
    for r in out.iter_mut() {
        *r = (*r - raw.lo) / raw.range;
    }
}

/// Eq. 5 weighted reward over explicit per-arm means, re-normalized to
/// [0, 1]. Reference/offline form (allocates); the experiment drivers use
/// it to build regret oracles from sweeps.
pub fn weighted_rewards(mean_tau: &[f64], mean_rho: &[f64], alpha: f64, beta: f64) -> Vec<f64> {
    let tau_hat = minmax_eps(mean_tau);
    let rho_hat = minmax_eps(mean_rho);
    let raw: Vec<f64> = tau_hat
        .iter()
        .zip(&rho_hat)
        .map(|(t, r)| alpha / (t + REWARD_EPS) + beta / (r + REWARD_EPS))
        .collect();
    minmax_eps(&raw)
}

fn minmax_eps(xs: &[f64]) -> Vec<f64> {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let range = (hi - lo).max(MINMAX_EPS);
    xs.iter().map(|x| (x - lo) / range).collect()
}

/// Eq. 2 scores for all arms into `out` (`out.len() == rewards.len()`),
/// with exploration coefficient `c`. Allocation-free.
pub fn ucb_scores_into(rewards: &[f64], counts: &[f64], t: f64, c: f64, out: &mut [f64]) {
    debug_assert_eq!(rewards.len(), counts.len());
    debug_assert_eq!(rewards.len(), out.len());
    let k = rewards.len();
    let (rewards, counts, out) = (&rewards[..k], &counts[..k], &mut out[..k]);
    let log_t = t.max(1.0).ln();
    let bonus_base = 2.0 * log_t;
    // Branch-free: the bonus is computed for every lane (`max(1.0)` keeps
    // the division safe and is the identity for real counts, which are
    // never fractional below 1) and the unpulled sentinel is a select.
    for i in 0..k {
        let bonus = c * (bonus_base / counts[i].max(1.0)).sqrt();
        out[i] = if counts[i] > 0.0 { rewards[i] + bonus } else { UNPULLED_SCORE };
    }
}

/// Eq. 2 scores for all arms (reference/offline form — allocates).
pub fn ucb_scores(rewards: &[f64], counts: &[f64], t: f64, c: f64) -> Vec<f64> {
    let mut out = vec![0.0; rewards.len()];
    ucb_scores_into(rewards, counts, t, c, &mut out);
    out
}

/// Result of one fused scoring step. The Eq. 5 rewards land in the
/// caller's [`Scratch::rewards`] instead of a fresh allocation.
#[derive(Debug, Clone, Copy)]
pub struct Step {
    /// Eq. 3: arm with the highest UCB score.
    pub best: usize,
    /// Its UCB score.
    pub score: f64,
}

/// The per-iteration scoring hot path: reward normalization (Eq. 5) +
/// UCB scores (Eq. 2) + argmax (Eq. 3). Implemented by [`ScalarBackend`]
/// (pure rust) and the AOT PJRT artifact
/// ([`crate::runtime::PjrtScoreBackend`]). Implementations must leave the
/// normalized rewards in `scratch.rewards` and are expected to be
/// allocation-free once the scratch reaches `stats.k()` elements.
pub trait ScoreBackend: Send {
    fn lasp_step(
        &mut self,
        stats: &ArmStats,
        alpha: f64,
        beta: f64,
        exploration: f64,
        scratch: &mut Scratch,
    ) -> Result<Step>;

    /// Backend name for reports.
    fn backend_name(&self) -> &'static str;
}

/// Pure-rust reference backend, semantically identical to the lowered
/// `lasp_step` artifact (differential-tested in `rust/tests/`).
#[derive(Debug, Default, Clone)]
pub struct ScalarBackend;

impl ScoreBackend for ScalarBackend {
    /// Fused zero-allocation implementation of the reference pipeline
    /// `filled_means → weighted_rewards → ucb_scores → argmax`
    /// (3 passes, no allocations, rewards left in `scratch.rewards`;
    /// equivalence is asserted by `fused_step_matches_reference_pipeline`
    /// below and the PJRT differential tests).
    fn lasp_step(
        &mut self,
        stats: &ArmStats,
        alpha: f64,
        beta: f64,
        exploration: f64,
        scratch: &mut Scratch,
    ) -> Result<Step> {
        let k = stats.k();
        scratch.ensure(k);
        let (rewards, scores) = scratch.rewards_scores_mut();
        let (rewards, scores) = (&mut rewards[..k], &mut scores[..k]);
        let raw = raw_rewards_into(stats, alpha, beta, rewards);

        // Pass 3a: normalize rewards in place + UCB score, branch-free.
        // The bonus runs on every lane — for unpulled arms it degenerates
        // to inf/NaN, which the select discards before it can matter — so
        // the loop carries no per-iteration branch and vectorizes.
        let counts = &stats.counts()[..k];
        let log_t = stats.t().max(1.0).ln();
        let bonus_base = 2.0 * log_t;
        for i in 0..k {
            let r = (rewards[i] - raw.lo) / raw.range;
            rewards[i] = r;
            let bonus = exploration * (bonus_base / counts[i]).sqrt();
            scores[i] = if counts[i] > 0.0 { r + bonus } else { UNPULLED_SCORE };
        }
        // Pass 3b: first-max argmax scan (kept scalar: the comparison is a
        // loop-carried dependency; ties resolve to the lowest index).
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for (i, &score) in scores.iter().enumerate() {
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        Ok(Step { best, score: best_score })
    }

    fn backend_name(&self) -> &'static str {
        "scalar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn step(s: &ArmStats, alpha: f64, beta: f64, c: f64) -> (Step, Vec<f64>) {
        let mut scratch = Scratch::new();
        let out = ScalarBackend.lasp_step(s, alpha, beta, c, &mut scratch).unwrap();
        (out, scratch.rewards)
    }

    #[test]
    fn rewards_bounded_and_ordered() {
        // alpha=1: reward strictly decreasing in mean time.
        let r = weighted_rewards(&[1.0, 2.0, 3.0], &[5.0, 5.0, 5.0], 1.0, 0.0);
        assert!(r[0] > r[1] && r[1] > r[2]);
        assert!((r[0] - 1.0).abs() < 1e-9 && r[2].abs() < 1e-9);
        for x in r {
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn unpulled_scores_big() {
        let s = ucb_scores(&[0.5, 0.5], &[0.0, 3.0], 10.0, 1.0);
        assert_eq!(s[0], UNPULLED_SCORE);
        assert!(s[1] < UNPULLED_SCORE);
    }

    #[test]
    fn into_kernels_match_reference_forms() {
        let mut rng = crate::util::Rng::new(41);
        for _ in 0..100 {
            let k = 2 + rng.below(120);
            let mut s = ArmStats::new(k);
            for _ in 0..rng.below(400) {
                s.observe(rng.below(k), rng.range(0.05, 9.0), rng.range(0.5, 12.0));
            }
            let (alpha, beta) = (rng.uniform(), rng.uniform());
            let (mt, mr) = s.filled_means();
            let reference = weighted_rewards(&mt, &mr, alpha, beta);
            let mut fused = vec![0.0; k];
            weighted_rewards_into(&s, alpha, beta, &mut fused);
            for (a, b) in fused.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-12, "weighted_rewards_into drifted: {a} vs {b}");
            }
            let t = s.t();
            let mut scores = vec![0.0; k];
            ucb_scores_into(&fused, s.counts(), t, 0.25, &mut scores);
            for (a, b) in scores.iter().zip(&ucb_scores(&reference, s.counts(), t, 0.25)) {
                assert!((a - b).abs() < 1e-12, "ucb_scores_into drifted: {a} vs {b}");
            }
        }
    }

    #[test]
    fn scalar_backend_selects_unpulled_first() {
        let mut s = ArmStats::new(4);
        s.observe(0, 1.0, 1.0);
        s.observe(1, 1.0, 1.0);
        let (out, _) = step(&s, 0.8, 0.2, 1.0);
        assert!(out.best == 2 || out.best == 3);
        assert_eq!(out.score, UNPULLED_SCORE);
    }

    #[test]
    fn scalar_backend_exploits_best_arm() {
        let mut s = ArmStats::new(3);
        for _ in 0..500 {
            s.observe(0, 5.0, 5.0);
            s.observe(1, 1.0, 5.0); // fastest
            s.observe(2, 3.0, 5.0);
        }
        let (out, rewards) = step(&s, 1.0, 0.0, 1.0);
        assert_eq!(out.best, 1);
        assert_eq!(stats::argmax(&rewards), 1);
    }

    #[test]
    fn fused_step_matches_reference_pipeline() {
        // The optimized lasp_step must equal the composed reference
        // functions bit-for-bit-ish across many random states.
        let mut rng = crate::util::Rng::new(5);
        let mut scratch = Scratch::new();
        for trial in 0..200 {
            let k = 2 + rng.below(300);
            let mut s = ArmStats::new(k);
            for _ in 0..rng.below(1000) {
                s.observe(rng.below(k), rng.range(0.05, 9.0), rng.range(0.5, 12.0));
            }
            let (alpha, beta, c) = (rng.uniform(), rng.uniform(), rng.range(0.01, 1.5));
            let fused = ScalarBackend.lasp_step(&s, alpha, beta, c, &mut scratch).unwrap();
            let (mt, mr) = s.filled_means();
            let rewards = weighted_rewards(&mt, &mr, alpha, beta);
            let scores = ucb_scores(&rewards, s.counts(), s.t(), c);
            let best = stats::argmax(&scores);
            assert_eq!(fused.best, best, "trial {trial}");
            assert!((fused.score - scores[best]).abs() < 1e-12, "trial {trial}");
            for (a, b) in scratch.rewards[..k].iter().zip(&rewards) {
                assert!((a - b).abs() < 1e-12, "trial {trial}");
            }
        }
    }

    #[test]
    fn alpha_beta_tradeoff() {
        let mut s = ArmStats::new(2);
        for _ in 0..100 {
            s.observe(0, 1.0, 10.0); // fast, hungry
            s.observe(1, 2.0, 5.0); // slow, frugal
        }
        let (_, time_rewards) = step(&s, 1.0, 0.0, 1.0);
        let (_, power_rewards) = step(&s, 0.0, 1.0, 1.0);
        assert_eq!(stats::argmax(&time_rewards), 0);
        assert_eq!(stats::argmax(&power_rewards), 1);
    }
}
