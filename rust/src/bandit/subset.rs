//! Candidate-subset tuning for very large spaces (paper §IV-B).
//!
//! The paper names scalability as LASP's main limitation: "as the number of
//! arms increases, the UCB algorithm requires exploring a large number of
//! options before it can intelligently determine the optimal
//! configurations". With K ≫ T (Hypre: 92,160 arms vs ~10³ iterations) the
//! UCB init sweep alone exceeds the budget. [`SubsetTuner`] realizes the
//! paper's "swiftly discarding low-performing configurations" idea in its
//! simplest robust form: draw a seeded uniform candidate subset sized to
//! the budget and run full LASP over it. Pull counts are reported in the
//! full space so Eq. 4 output and downstream metrics are unchanged.

use super::core::ArmStats;
use super::ucb::UcbTuner;
use super::{Choice, Policy};
use crate::util::Rng;
use std::collections::HashMap;

/// LASP over a uniform candidate subset of a large space.
pub struct SubsetTuner {
    inner: UcbTuner,
    /// subset position -> full-space index.
    candidates: Vec<usize>,
    /// full-space index -> subset position.
    positions: HashMap<usize, usize>,
    /// Full-space pull counts (Eq. 4 view).
    full_counts: Vec<f64>,
}

impl SubsetTuner {
    /// Draw `m` candidates from `0..k` with `seed`, tune over them.
    pub fn new(k: usize, m: usize, alpha: f64, beta: f64, seed: u64) -> Self {
        assert!(m >= 2 && m <= k);
        let mut rng = Rng::new(seed);
        let candidates = rng.sample_indices(k, m);
        Self::with_candidates(k, candidates, alpha, beta)
    }

    /// Tune over an explicit candidate list (e.g. pre-screened configs).
    pub fn with_candidates(k: usize, candidates: Vec<usize>, alpha: f64, beta: f64) -> Self {
        assert!(!candidates.is_empty());
        let positions: HashMap<usize, usize> =
            candidates.iter().enumerate().map(|(p, &i)| (i, p)).collect();
        assert_eq!(positions.len(), candidates.len(), "duplicate candidates");
        assert!(candidates.iter().all(|&c| c < k));
        SubsetTuner {
            inner: UcbTuner::new(candidates.len(), alpha, beta),
            candidates,
            positions,
            full_counts: vec![0.0; k],
        }
    }

    /// Builder: exploration coefficient of the inner UCB.
    pub fn with_exploration(mut self, c: f64) -> Self {
        self.inner.set_exploration(c);
        self
    }

    /// The candidate list (full-space indices).
    pub fn candidates(&self) -> &[usize] {
        &self.candidates
    }

    /// Whether a full-space arm is in the candidate subset.
    pub fn contains_arm(&self, arm: usize) -> bool {
        self.positions.contains_key(&arm)
    }

    /// Subset position of a full-space arm, if it is a candidate.
    pub fn position_of(&self, arm: usize) -> Option<usize> {
        self.positions.get(&arm).copied()
    }

    /// Builder form of [`Policy::warm_start`] (subset-space prior).
    pub fn with_prior_state(mut self, stats: ArmStats) -> Self {
        self.warm_start(stats);
        self
    }

    /// Project a *full-space* prior (e.g. a fleet prior aggregated across
    /// nodes whose sessions drew different candidate subsets) onto this
    /// tuner's candidates, producing a subset-space [`ArmStats`] that
    /// [`Policy::warm_start`] accepts.
    pub fn project_full_prior(&self, full: &ArmStats) -> ArmStats {
        assert_eq!(full.k(), self.full_counts.len(), "full-space prior size mismatch");
        let mut sub = ArmStats::new(self.candidates.len());
        for (pos, &arm) in self.candidates.iter().enumerate() {
            if full.counts()[arm] > 0.0 {
                sub.set_arm(pos, full.counts()[arm], full.tau_sum()[arm], full.rho_sum()[arm]);
            }
        }
        sub
    }

    /// Recommended subset size for a `k`-arm space under `iterations`
    /// budget: at most a third of the budget goes to the init sweep.
    pub fn recommended_size(k: usize, iterations: usize) -> usize {
        (iterations / 3).clamp(16, 1024).min(k)
    }
}

impl Policy for SubsetTuner {
    fn k(&self) -> usize {
        self.full_counts.len()
    }

    fn select(&mut self) -> usize {
        self.candidates[self.inner.select()]
    }

    fn select_traced(&mut self) -> Choice {
        let c = self.inner.select_traced();
        Choice { arm: self.candidates[c.arm], ..c }
    }

    fn select_traced_in(&mut self, scratch: &mut super::core::Scratch) -> Choice {
        let c = self.inner.select_traced_in(scratch);
        Choice { arm: self.candidates[c.arm], ..c }
    }

    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        let pos = *self
            .positions
            .get(&arm)
            .unwrap_or_else(|| panic!("arm {arm} not in candidate subset"));
        self.inner.update(pos, time_s, power_w);
        self.full_counts[arm] += 1.0;
    }

    fn counts(&self) -> &[f64] {
        &self.full_counts
    }

    fn name(&self) -> &'static str {
        "lasp-ucb1-subset"
    }

    fn stats(&self) -> &ArmStats {
        // Subset-local core (positions are subset indices).
        self.inner.stats()
    }

    /// Warm-start the inner tuner from a *subset-space* prior (e.g. a
    /// [`super::persist`] checkpoint of this tuner's core). The caller
    /// must rebuild the tuner with the same candidate list — in practice
    /// the same draw seed — so positions line up. The prior counts are
    /// also projected into the full-space Eq. 4 view so `most_selected`
    /// survives a restart.
    fn warm_start(&mut self, prior: ArmStats) {
        assert_eq!(
            prior.k(),
            self.candidates.len(),
            "subset warm-start size mismatch"
        );
        for (pos, &full) in self.candidates.iter().enumerate() {
            self.full_counts[full] = prior.counts()[pos];
        }
        self.inner.warm_start(prior);
    }

    fn scratch_growths(&self) -> u64 {
        self.inner.scratch_growths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_selection_into_candidate_set() {
        let mut t = SubsetTuner::new(10_000, 32, 1.0, 0.0, 7);
        let cands: std::collections::HashSet<usize> =
            t.candidates().iter().copied().collect();
        for _ in 0..100 {
            let arm = t.select();
            assert!(cands.contains(&arm));
            t.update(arm, 1.0, 1.0);
        }
        assert_eq!(t.total_pulls(), 100.0);
    }

    #[test]
    fn concentrates_within_subset() {
        let mut t = SubsetTuner::new(5_000, 24, 1.0, 0.0, 3);
        // The lowest candidate index is the fastest arm.
        let best = *t.candidates().iter().min().unwrap();
        for _ in 0..600 {
            let arm = t.select();
            let time = if arm == best { 0.3 } else { 2.0 };
            t.update(arm, time, 5.0);
        }
        assert_eq!(t.most_selected(), best);
    }

    #[test]
    fn full_counts_live_in_full_space() {
        let mut t = SubsetTuner::new(1000, 16, 0.5, 0.5, 1);
        for _ in 0..50 {
            let arm = t.select();
            t.update(arm, 1.0, 1.0);
        }
        assert_eq!(t.counts().len(), 1000);
        assert_eq!(t.counts().iter().sum::<f64>(), 50.0);
    }

    #[test]
    #[should_panic]
    fn update_outside_subset_panics() {
        let mut t = SubsetTuner::with_candidates(100, vec![1, 2, 3], 1.0, 0.0);
        t.update(99, 1.0, 1.0);
    }

    #[test]
    fn same_seed_same_candidates_and_warm_start() {
        // The serve checkpoint path: tune, checkpoint the subset-space
        // state, rebuild with the same seed, restore. Candidates and the
        // Eq. 4 answer must line up.
        let mut t = SubsetTuner::new(10_000, 64, 1.0, 0.0, 123);
        for _ in 0..300 {
            let arm = t.select();
            let time = if arm == t.candidates()[5] { 0.3 } else { 2.0 };
            t.update(arm, time, 5.0);
        }
        let best = t.most_selected();
        let state = t.stats().clone();

        let rebuilt = SubsetTuner::new(10_000, 64, 1.0, 0.0, 123).with_prior_state(state);
        assert_eq!(rebuilt.candidates(), t.candidates());
        assert_eq!(rebuilt.most_selected(), best);
        assert_eq!(rebuilt.total_pulls(), 300.0);
        assert!(rebuilt.contains_arm(best));
        assert_eq!(
            rebuilt.position_of(best),
            t.candidates().iter().position(|&c| c == best)
        );
    }

    #[test]
    fn full_space_prior_projects_onto_candidates() {
        let t = SubsetTuner::new(1_000, 16, 1.0, 0.0, 5);
        let mut full = ArmStats::new(1_000);
        for arm in 0..1_000 {
            full.observe(arm, 1.0 + (arm % 7) as f64, 5.0);
        }
        let sub = t.project_full_prior(&full);
        assert_eq!(sub.k(), 16);
        for (pos, &arm) in t.candidates().iter().enumerate() {
            assert_eq!(sub.counts()[pos], 1.0);
            assert_eq!(sub.mean_tau()[pos], 1.0 + (arm % 7) as f64);
        }
        let warmed = t.with_prior_state(sub);
        assert_eq!(warmed.total_pulls(), 16.0);
    }

    #[test]
    #[should_panic]
    fn warm_start_size_mismatch_panics() {
        let state = ArmStats::new(32);
        let _ = SubsetTuner::new(1000, 16, 1.0, 0.0, 1).with_prior_state(state);
    }

    #[test]
    fn recommended_size_bounds() {
        assert_eq!(SubsetTuner::recommended_size(92_160, 1000), 333);
        assert_eq!(SubsetTuner::recommended_size(92_160, 10_000), 1024);
        assert_eq!(SubsetTuner::recommended_size(128, 1000), 128);
        assert_eq!(SubsetTuner::recommended_size(92_160, 10), 16);
    }
}
