//! Sliding-window UCB for non-stationary edge environments.
//!
//! The paper stresses that edge conditions drift (thermal throttling, power
//! mode switches, co-located load). Plain UCB1 averages over all history;
//! SW-UCB computes rewards over only the last `window` observations, so a
//! reward distribution shift is forgotten after one window. This is the
//! "future work: adaptive algorithms" direction made concrete, exercised by
//! the mode-switch ablation bench.
//!
//! A thin strategy layer over the shared [`ArmStats`] core: the core holds
//! the *windowed* sufficient statistics (kept incrementally via
//! `observe`/`unobserve`), while lifetime pull counts — the Eq. 4 view —
//! live beside it with their own O(1) cached total.

use super::core::{ArmStats, Scratch};
use super::reward::{ucb_scores_into, weighted_rewards_into, DEFAULT_EXPLORATION};
use super::{top2, Choice, Policy};
use crate::util::stats;
use std::collections::VecDeque;

/// UCB1 over a sliding window of the most recent observations.
pub struct SlidingWindowUcb {
    alpha: f64,
    beta: f64,
    window: usize,
    /// (arm, time, power) of the most recent `window` pulls.
    history: VecDeque<(usize, f64, f64)>,
    /// Windowed sufficient statistics, kept incrementally.
    stats: ArmStats,
    /// Lifetime pull counts (Eq. 4 output still uses all history).
    lifetime_counts: Vec<f64>,
    /// Cached lifetime total (O(1) `total_pulls`).
    lifetime_total: f64,
    scratch: Scratch,
}

impl SlidingWindowUcb {
    pub fn new(k: usize, alpha: f64, beta: f64, window: usize) -> Self {
        assert!(window >= k, "window must cover at least one pull per arm");
        SlidingWindowUcb {
            alpha,
            beta,
            window,
            history: VecDeque::with_capacity(window + 1),
            stats: ArmStats::new(k),
            lifetime_counts: vec![0.0; k],
            lifetime_total: 0.0,
            scratch: Scratch::new(),
        }
    }

    /// The configured window length.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Builder form of [`Policy::warm_start`].
    pub fn with_prior(mut self, prior: ArmStats) -> Self {
        self.warm_start(prior);
        self
    }
}

/// The traced windowed-UCB pass over explicit parts, so the same body can
/// run through the policy's own scratch (`select_traced`) or a shared
/// batch scratch (`select_traced_in`).
fn traced_step(
    windowed: &ArmStats,
    alpha: f64,
    beta: f64,
    history_len: usize,
    scratch: &mut Scratch,
) -> Choice {
    // Arms absent from the current window are "unpulled": retried.
    if let Some(arm) = windowed.counts().iter().position(|&c| c == 0.0) {
        return Choice { arm, gap: 0.0, explore: true };
    }
    scratch.ensure(windowed.k());
    weighted_rewards_into(windowed, alpha, beta, &mut scratch.rewards);
    // Windowed t: bonus uses the window size, not lifetime.
    let t_eff = (history_len as f64).max(1.0);
    let (rewards, scores) = scratch.rewards_scores_mut();
    ucb_scores_into(rewards, windowed.counts(), t_eff, DEFAULT_EXPLORATION, scores);
    let (arm, gap) = top2(scores);
    Choice { arm, gap, explore: arm != stats::argmax(rewards) }
}

impl Policy for SlidingWindowUcb {
    fn k(&self) -> usize {
        self.stats.k()
    }

    fn select(&mut self) -> usize {
        self.select_traced().arm
    }

    fn select_traced(&mut self) -> Choice {
        traced_step(&self.stats, self.alpha, self.beta, self.history.len(), &mut self.scratch)
    }

    fn select_traced_in(&mut self, scratch: &mut Scratch) -> Choice {
        traced_step(&self.stats, self.alpha, self.beta, self.history.len(), scratch)
    }

    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.history.push_back((arm, time_s, power_w));
        self.stats.observe(arm, time_s, power_w);
        self.lifetime_counts[arm] += 1.0;
        self.lifetime_total += 1.0;
        if self.history.len() > self.window {
            let (old_arm, old_t, old_p) = self.history.pop_front().unwrap();
            // `unobserve` guards accumulated fp error at zero.
            self.stats.unobserve(old_arm, old_t, old_p);
        }
    }

    fn counts(&self) -> &[f64] {
        &self.lifetime_counts
    }

    fn total_pulls(&self) -> f64 {
        self.lifetime_total
    }

    fn name(&self) -> &'static str {
        "sw-ucb"
    }

    fn stats(&self) -> &ArmStats {
        // The *windowed* sufficient statistics: a checkpoint restores the
        // recent view of the environment, which is exactly what SW-UCB
        // considers current.
        &self.stats
    }

    /// Warm-start by replaying each arm's prior mean into the window as
    /// synthetic observations. Going through the history deque (rather
    /// than poking the sums directly) preserves the eviction invariant:
    /// every unit of windowed state has a history entry that will
    /// eventually age out, so prior knowledge is forgotten exactly like
    /// real observations. When the prior holds more pulls than the window,
    /// every arm's replay count is scaled down *proportionally* (with a
    /// floor of one entry per pulled arm), so no arm loses its prior just
    /// because of its index.
    fn warm_start(&mut self, prior: ArmStats) {
        assert_eq!(prior.k(), self.stats.k(), "warm-start arm count mismatch");
        let total = prior.total_pulls();
        if total <= 0.0 {
            return;
        }
        let scale = (self.window as f64 / total).min(1.0);
        for arm in 0..prior.k() {
            let Some((mean_tau, mean_rho)) = prior.means_of(arm) else {
                continue;
            };
            let n = ((prior.counts()[arm] * scale).round() as usize).max(1);
            for _ in 0..n {
                if self.history.len() >= self.window {
                    break;
                }
                self.history.push_back((arm, mean_tau, mean_rho));
                self.stats.observe(arm, mean_tau, mean_rho);
                self.lifetime_counts[arm] += 1.0;
                self.lifetime_total += 1.0;
            }
        }
    }

    fn scratch_growths(&self) -> u64 {
        self.scratch.growths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adapts_to_distribution_shift() {
        // Arm 0 is best for 600 pulls, then arm 2 becomes best. SW-UCB must
        // switch; measure pulls of arm 2 in the last 200 rounds.
        let mut p = SlidingWindowUcb::new(3, 1.0, 0.0, 150);
        let mut recent_arm2 = 0;
        for t in 0..1200 {
            let arm = p.select();
            let time = if t < 600 {
                [1.0, 2.0, 2.0][arm]
            } else {
                [2.0, 2.0, 1.0][arm]
            };
            p.update(arm, time, 1.0);
            if t >= 1000 && arm == 2 {
                recent_arm2 += 1;
            }
        }
        assert!(recent_arm2 > 120, "only {recent_arm2} recent pulls of new best");
    }

    #[test]
    fn plain_ucb_adapts_slower_than_swucb() {
        // Same shift; count post-shift pulls of the new best arm.
        let run = |mut p: Box<dyn Policy>| {
            let mut post_shift_best = 0;
            for t in 0..1200 {
                let arm = p.select();
                let time = if t < 600 {
                    [1.0, 2.0, 2.0][arm]
                } else {
                    [2.0, 2.0, 1.0][arm]
                };
                p.update(arm, time, 1.0);
                if t >= 600 && arm == 2 {
                    post_shift_best += 1;
                }
            }
            post_shift_best
        };
        let sw = run(Box::new(SlidingWindowUcb::new(3, 1.0, 0.0, 150)));
        let plain = run(Box::new(crate::bandit::UcbTuner::new(3, 1.0, 0.0)));
        assert!(sw > plain, "sw {sw} <= plain {plain}");
    }

    #[test]
    fn window_eviction_keeps_counts_consistent() {
        let mut p = SlidingWindowUcb::new(4, 0.5, 0.5, 16);
        for i in 0..200 {
            let arm = i % 4;
            p.update(arm, 1.0 + arm as f64, 2.0);
        }
        let window_total: f64 = p.stats().counts().iter().sum();
        assert_eq!(window_total, 16.0);
        assert_eq!(p.stats().total_pulls(), 16.0);
        let lifetime_total: f64 = p.counts().iter().sum();
        assert_eq!(lifetime_total, 200.0);
        assert_eq!(p.total_pulls(), 200.0);
    }

    #[test]
    #[should_panic]
    fn window_smaller_than_arms_rejected() {
        SlidingWindowUcb::new(10, 1.0, 0.0, 5);
    }

    #[test]
    fn warm_start_replays_prior_into_window() {
        let mut prior = ArmStats::new(3);
        for _ in 0..20 {
            prior.observe(0, 2.0, 4.0);
            prior.observe(1, 0.5, 4.0);
            prior.observe(2, 3.0, 4.0);
        }
        let p = SlidingWindowUcb::new(3, 1.0, 0.0, 100).with_prior(prior);
        // Replayed means match the prior exactly.
        assert_eq!(p.stats().counts(), &[20.0, 20.0, 20.0]);
        assert!((p.stats().mean_tau()[1] - 0.5).abs() < 1e-12);
        assert_eq!(p.history.len(), 60);
        // And the replayed entries age out like real observations.
        let mut p = p;
        for _ in 0..100 {
            let arm = p.select();
            p.update(arm, 1.0, 1.0);
        }
        let window_total: f64 = p.stats().counts().iter().sum();
        assert_eq!(window_total, 100.0);
    }

    #[test]
    fn warm_start_capped_at_window_proportionally() {
        // 1500 prior pulls into a 64-slot window: every arm keeps a share
        // proportional to its prior counts — no arm is dropped just
        // because of its index.
        let mut prior = ArmStats::new(3);
        for _ in 0..500 {
            prior.observe(0, 1.0, 1.0);
            prior.observe(1, 2.0, 1.0);
            prior.observe(2, 3.0, 1.0);
        }
        let p = SlidingWindowUcb::new(3, 1.0, 0.0, 64).with_prior(prior);
        assert!(p.history.len() <= 64);
        for arm in 0..3 {
            assert!(p.stats().counts()[arm] > 0.0, "arm {arm} lost its prior");
            let mean = p.stats().mean_tau()[arm];
            assert!((mean - (arm as f64 + 1.0)).abs() < 1e-9);
        }
        // Shares are roughly equal for equal prior counts.
        assert!((p.stats().counts()[0] - p.stats().counts()[2]).abs() <= 1.0);
    }
}
