//! Gaussian Thompson-sampling ablation policy.
//!
//! Posterior-sampling alternative to UCB's optimism: each arm's reward mean
//! gets a Normal posterior (known-variance model); every round samples each
//! posterior and plays the argmax. Included to quantify the paper's choice
//! of UCB against the other classic stochastic-bandit family. A thin
//! strategy layer over the shared [`ArmStats`] core; sampling runs through
//! the reusable [`Scratch`], so `select()` is allocation-free once warm.

use super::core::{ArmStats, Scratch};
use super::reward::weighted_rewards_into;
use super::{top2, Choice, Policy};
use crate::util::{stats, Rng};

/// Thompson sampling over the paper's Eq. 5 reward.
pub struct ThompsonSampler {
    stats: ArmStats,
    alpha: f64,
    beta: f64,
    rng: Rng,
    /// Assumed observation std-dev of the normalized reward.
    obs_std: f64,
    scratch: Scratch,
}

impl ThompsonSampler {
    pub fn new(k: usize, alpha: f64, beta: f64, seed: u64) -> Self {
        ThompsonSampler {
            stats: ArmStats::new(k),
            alpha,
            beta,
            rng: Rng::new(seed),
            obs_std: 0.25,
            scratch: Scratch::new(),
        }
    }

    /// Builder: warm-start from a prior state (see [`super::persist`]).
    /// The prior's arm count must match `k`; pulled arms start with
    /// narrowed posteriors proportional to their retained counts.
    pub fn with_state(mut self, stats: ArmStats) -> Self {
        self.warm_start(stats);
        self
    }
}

/// The traced sampling pass over explicit parts, so the same body can run
/// through the sampler's own scratch (`select_traced`) or a shared batch
/// scratch (`select_traced_in`). RNG draw order is part of the contract:
/// exactly one `normal()` per arm, in arm order, on the steady-state path.
fn traced_step(
    stats_: &ArmStats,
    alpha: f64,
    beta: f64,
    obs_std: f64,
    rng: &mut Rng,
    scratch: &mut Scratch,
) -> Choice {
    if let Some(arm) = stats_.counts().iter().position(|&c| c == 0.0) {
        return Choice { arm, gap: 0.0, explore: true };
    }
    let k = stats_.k();
    scratch.ensure(k);
    weighted_rewards_into(stats_, alpha, beta, &mut scratch.rewards);
    // Sample posterior mean ~ N(reward_i, obs_std² / N_i) per arm.
    let (rewards, scores) = scratch.rewards_scores_mut();
    for (i, (r, n)) in rewards.iter().zip(stats_.counts()).enumerate() {
        scores[i] = r + rng.normal() * obs_std / n.max(1.0).sqrt();
    }
    let (arm, gap) = top2(scores);
    Choice { arm, gap, explore: arm != stats::argmax(rewards) }
}

impl Policy for ThompsonSampler {
    fn k(&self) -> usize {
        self.stats.k()
    }

    fn select(&mut self) -> usize {
        self.select_traced().arm
    }

    fn select_traced(&mut self) -> Choice {
        let ThompsonSampler { stats: st, alpha, beta, rng, obs_std, scratch } = self;
        traced_step(st, *alpha, *beta, *obs_std, rng, scratch)
    }

    fn select_traced_in(&mut self, scratch: &mut Scratch) -> Choice {
        traced_step(&self.stats, self.alpha, self.beta, self.obs_std, &mut self.rng, scratch)
    }

    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.stats.observe(arm, time_s, power_w);
    }

    fn counts(&self) -> &[f64] {
        self.stats.counts()
    }

    fn name(&self) -> &'static str {
        "thompson"
    }

    fn stats(&self) -> &ArmStats {
        &self.stats
    }

    fn warm_start(&mut self, prior: ArmStats) {
        assert_eq!(prior.k(), self.stats.k(), "warm-start arm count mismatch");
        self.stats = prior;
    }

    fn scratch_growths(&self) -> u64 {
        self.scratch.growths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_clear_winner() {
        let mut p = ThompsonSampler::new(4, 1.0, 0.0, 17);
        let times = [3.0, 0.5, 2.5, 3.5];
        for _ in 0..600 {
            let arm = p.select();
            p.update(arm, times[arm], 1.0);
        }
        assert_eq!(p.most_selected(), 1);
        assert!(p.counts()[1] > 400.0);
    }

    #[test]
    fn posterior_narrows_with_pulls() {
        // With many pulls everywhere, selection becomes near-deterministic.
        let mut p = ThompsonSampler::new(3, 1.0, 0.0, 23);
        let times = [2.0, 1.0, 1.5];
        for _ in 0..900 {
            let arm = p.select();
            p.update(arm, times[arm], 1.0);
        }
        let last_hundred: f64 = p.counts()[1];
        assert!(last_hundred > 600.0, "counts {:?}", p.counts());
    }

    #[test]
    fn warm_start_biases_toward_prior_best() {
        // A restored posterior should exploit immediately: every arm
        // carries prior counts (no init sweep), and the prior best
        // dominates selection.
        let mut prior = ArmStats::new(4);
        for _ in 0..50 {
            prior.observe(0, 2.0, 1.0);
            prior.observe(1, 2.0, 1.0);
            prior.observe(2, 0.5, 1.0);
            prior.observe(3, 2.0, 1.0);
        }
        let mut p = ThompsonSampler::new(4, 1.0, 0.0, 5).with_state(prior);
        let picks_of_best = (0..100).filter(|_| p.select() == 2).count();
        assert!(picks_of_best > 60, "only {picks_of_best}/100 prior-best picks");
        assert_eq!(p.stats().counts()[2], 50.0);
    }

    #[test]
    #[should_panic]
    fn warm_start_arm_mismatch_panics() {
        let prior = ArmStats::new(3);
        let _ = ThompsonSampler::new(4, 1.0, 0.0, 5).with_state(prior);
    }
}
