//! Gaussian Thompson-sampling ablation policy.
//!
//! Posterior-sampling alternative to UCB's optimism: each arm's reward mean
//! gets a Normal posterior (known-variance model); every round samples each
//! posterior and plays the argmax. Included to quantify the paper's choice
//! of UCB against the other classic stochastic-bandit family.

use super::reward::{weighted_rewards, RewardState};
use super::Policy;
use crate::util::{stats, Rng};

/// Thompson sampling over the paper's Eq. 5 reward.
pub struct ThompsonSampler {
    state: RewardState,
    alpha: f64,
    beta: f64,
    rng: Rng,
    /// Assumed observation std-dev of the normalized reward.
    obs_std: f64,
}

impl ThompsonSampler {
    pub fn new(k: usize, alpha: f64, beta: f64, seed: u64) -> Self {
        ThompsonSampler {
            state: RewardState::new(k),
            alpha,
            beta,
            rng: Rng::new(seed),
            obs_std: 0.25,
        }
    }

    /// Builder: warm-start from a prior reward state (see
    /// [`super::persist`]). The state's arm count must match `k`; pulled
    /// arms start with narrowed posteriors proportional to their retained
    /// counts.
    pub fn with_state(mut self, state: RewardState) -> Self {
        assert_eq!(state.k(), self.state.k(), "warm-start arm count mismatch");
        self.state = state;
        self
    }
}

impl Policy for ThompsonSampler {
    fn k(&self) -> usize {
        self.state.k()
    }

    fn select(&mut self) -> usize {
        if let Some(arm) = self.state.counts.iter().position(|&c| c == 0.0) {
            return arm;
        }
        let (mt, mr) = self.state.filled_means();
        let rewards = weighted_rewards(&mt, &mr, self.alpha, self.beta);
        // Sample posterior mean ~ N(reward_i, obs_std² / N_i) per arm.
        let samples: Vec<f64> = rewards
            .iter()
            .zip(&self.state.counts)
            .map(|(r, n)| r + self.rng.normal() * self.obs_std / n.max(1.0).sqrt())
            .collect();
        stats::argmax(&samples)
    }

    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.state.observe(arm, time_s, power_w);
    }

    fn counts(&self) -> &[f64] {
        &self.state.counts
    }

    fn name(&self) -> &'static str {
        "thompson"
    }

    fn reward_state(&self) -> Option<&RewardState> {
        Some(&self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_clear_winner() {
        let mut p = ThompsonSampler::new(4, 1.0, 0.0, 17);
        let times = [3.0, 0.5, 2.5, 3.5];
        for _ in 0..600 {
            let arm = p.select();
            p.update(arm, times[arm], 1.0);
        }
        assert_eq!(p.most_selected(), 1);
        assert!(p.counts()[1] > 400.0);
    }

    #[test]
    fn posterior_narrows_with_pulls() {
        // With many pulls everywhere, selection becomes near-deterministic.
        let mut p = ThompsonSampler::new(3, 1.0, 0.0, 23);
        let times = [2.0, 1.0, 1.5];
        for _ in 0..900 {
            let arm = p.select();
            p.update(arm, times[arm], 1.0);
        }
        let last_hundred: f64 = p.counts()[1];
        assert!(last_hundred > 600.0, "counts {:?}", p.counts());
    }

    #[test]
    fn warm_start_biases_toward_prior_best() {
        // A restored posterior should exploit immediately: every arm
        // carries prior counts (no init sweep), and the prior best
        // dominates selection.
        let mut prior = RewardState::new(4);
        for _ in 0..50 {
            prior.observe(0, 2.0, 1.0);
            prior.observe(1, 2.0, 1.0);
            prior.observe(2, 0.5, 1.0);
            prior.observe(3, 2.0, 1.0);
        }
        let mut p = ThompsonSampler::new(4, 1.0, 0.0, 5).with_state(prior);
        let picks_of_best = (0..100).filter(|_| p.select() == 2).count();
        assert!(picks_of_best > 60, "only {picks_of_best}/100 prior-best picks");
        assert_eq!(p.reward_state().unwrap().counts[2], 50.0);
    }

    #[test]
    #[should_panic]
    fn warm_start_arm_mismatch_panics() {
        let prior = RewardState::new(3);
        let _ = ThompsonSampler::new(4, 1.0, 0.0, 5).with_state(prior);
    }
}
