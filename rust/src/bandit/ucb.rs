//! LASP's UCB1 policy (paper Alg. 1).

use super::reward::{RewardState, ScalarBackend, ScoreBackend, DEFAULT_EXPLORATION};
use super::Policy;

/// The LASP tuner: UCB1 over the weighted time/power reward.
///
/// `alpha` and `beta` are the paper's user-priority weights for execution
/// time and power consumption respectively (§III). The score computation is
/// pluggable: [`ScalarBackend`] by default, or the AOT PJRT artifact via
/// [`UcbTuner::with_backend`].
pub struct UcbTuner {
    state: RewardState,
    alpha: f64,
    beta: f64,
    exploration: f64,
    backend: Box<dyn ScoreBackend>,
    /// Rewards from the most recent scoring pass (diagnostics).
    last_rewards: Vec<f64>,
}

impl UcbTuner {
    /// UCB1 with the pure-rust scalar backend.
    pub fn new(k: usize, alpha: f64, beta: f64) -> Self {
        Self::with_backend(k, alpha, beta, Box::new(ScalarBackend))
    }

    /// UCB1 with an explicit scoring backend (e.g. the PJRT engine).
    pub fn with_backend(
        k: usize,
        alpha: f64,
        beta: f64,
        backend: Box<dyn ScoreBackend>,
    ) -> Self {
        assert!(k > 0);
        assert!((0.0..=1.0).contains(&alpha) && (0.0..=1.0).contains(&beta));
        UcbTuner {
            state: RewardState::new(k),
            alpha,
            beta,
            exploration: DEFAULT_EXPLORATION,
            backend,
            last_rewards: vec![],
        }
    }

    /// Builder: warm-start from a prior reward state (see
    /// [`super::persist`]). The state's arm count must match `k`.
    pub fn with_state(mut self, state: RewardState) -> Self {
        assert_eq!(state.k(), self.state.k(), "warm-start arm count mismatch");
        self.state = state;
        self
    }

    /// Builder: override the exploration coefficient (1.0 = textbook UCB1).
    pub fn with_exploration(mut self, c: f64) -> Self {
        assert!(c >= 0.0);
        self.exploration = c;
        self
    }

    /// The exploration coefficient c.
    pub fn exploration(&self) -> f64 {
        self.exploration
    }

    /// The time-priority weight α.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// The power-priority weight β.
    pub fn beta(&self) -> f64 {
        self.beta
    }

    /// Current iteration counter `t`.
    pub fn t(&self) -> f64 {
        self.state.t
    }

    /// Rewards from the most recent scoring pass (empty before first call).
    pub fn last_rewards(&self) -> &[f64] {
        &self.last_rewards
    }

    /// Scoring backend name ("scalar" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.backend_name()
    }

    /// Borrow the raw reward state (telemetry / checkpointing).
    pub fn state(&self) -> &RewardState {
        &self.state
    }
}

impl Policy for UcbTuner {
    fn k(&self) -> usize {
        self.state.k()
    }

    fn select(&mut self) -> usize {
        let out = self
            .backend
            .lasp_step(&self.state, self.alpha, self.beta, self.exploration)
            .expect("score backend failed");
        self.last_rewards = out.rewards;
        out.best
    }

    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        // No select/update pairing is enforced: the online tuning service
        // (`serve`) applies reports asynchronously through batched
        // ingestion, so updates may arrive out of order relative to the
        // most recent `select`. UCB's sufficient statistics are
        // order-free, so any valid arm is accepted.
        self.state.observe(arm, time_s, power_w);
    }

    fn counts(&self) -> &[f64] {
        &self.state.counts
    }

    fn name(&self) -> &'static str {
        "lasp-ucb1"
    }

    fn reward_state(&self) -> Option<&RewardState> {
        Some(&self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tries_every_arm_first() {
        let k = 8;
        let mut tuner = UcbTuner::new(k, 1.0, 0.0);
        let mut seen = vec![false; k];
        for _ in 0..k {
            let arm = tuner.select();
            assert!(!seen[arm], "arm {arm} repeated before full sweep");
            seen[arm] = true;
            tuner.update(arm, 1.0, 1.0);
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn concentrates_on_fastest_arm() {
        let mut tuner = UcbTuner::new(5, 1.0, 0.0);
        let times = [2.0, 1.8, 0.6, 1.5, 2.2];
        for _ in 0..600 {
            let arm = tuner.select();
            tuner.update(arm, times[arm], 5.0);
        }
        assert_eq!(tuner.most_selected(), 2);
        assert!(tuner.counts()[2] > 300.0);
    }

    #[test]
    fn beta_focus_prefers_frugal_arm() {
        let mut tuner = UcbTuner::new(3, 0.0, 1.0);
        let power = [8.0, 3.0, 6.0];
        for _ in 0..400 {
            let arm = tuner.select();
            tuner.update(arm, 1.0, power[arm]);
        }
        assert_eq!(tuner.most_selected(), 1);
    }

    #[test]
    fn t_advances_per_update() {
        let mut tuner = UcbTuner::new(2, 0.5, 0.5);
        assert_eq!(tuner.t(), 1.0);
        let a = tuner.select();
        tuner.update(a, 1.0, 1.0);
        assert_eq!(tuner.t(), 2.0);
    }

    #[test]
    #[should_panic]
    fn invalid_alpha_rejected() {
        UcbTuner::new(2, 1.5, 0.0);
    }
}
