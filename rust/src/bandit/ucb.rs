//! LASP's UCB1 policy (paper Alg. 1).

use super::core::{ArmStats, Scratch};
use super::reward::{ScalarBackend, ScoreBackend, DEFAULT_EXPLORATION, UNPULLED_SCORE};
use super::{Choice, Policy};

/// The LASP tuner: UCB1 over the weighted time/power reward.
///
/// A thin strategy layer over the shared [`ArmStats`] core: the core keeps
/// the statistics, the pluggable [`ScoreBackend`] turns them into Eq. 2
/// scores through the tuner's reusable [`Scratch`] — [`Policy::select`]
/// allocates nothing in steady state.
///
/// `alpha` and `beta` are the paper's user-priority weights for execution
/// time and power consumption respectively (§III). The score computation is
/// pluggable: [`ScalarBackend`] by default, or the AOT PJRT artifact via
/// [`UcbTuner::with_backend`].
pub struct UcbTuner {
    stats: ArmStats,
    alpha: f64,
    beta: f64,
    exploration: f64,
    backend: Box<dyn ScoreBackend>,
    scratch: Scratch,
}

impl UcbTuner {
    /// UCB1 with the pure-rust scalar backend.
    pub fn new(k: usize, alpha: f64, beta: f64) -> Self {
        Self::with_backend(k, alpha, beta, Box::new(ScalarBackend))
    }

    /// UCB1 with an explicit scoring backend (e.g. the PJRT engine).
    pub fn with_backend(
        k: usize,
        alpha: f64,
        beta: f64,
        backend: Box<dyn ScoreBackend>,
    ) -> Self {
        assert!(k > 0);
        assert!((0.0..=1.0).contains(&alpha) && (0.0..=1.0).contains(&beta));
        UcbTuner {
            stats: ArmStats::new(k),
            alpha,
            beta,
            exploration: DEFAULT_EXPLORATION,
            backend,
            scratch: Scratch::new(),
        }
    }

    /// Builder: warm-start from a prior state (see [`super::persist`]).
    /// The prior's arm count must match `k`.
    pub fn with_state(mut self, stats: ArmStats) -> Self {
        self.warm_start(stats);
        self
    }

    /// Builder: override the exploration coefficient (1.0 = textbook UCB1).
    pub fn with_exploration(mut self, c: f64) -> Self {
        self.set_exploration(c);
        self
    }

    /// Override the exploration coefficient in place.
    pub fn set_exploration(&mut self, c: f64) {
        assert!(c >= 0.0);
        self.exploration = c;
    }

    /// The exploration coefficient c.
    pub fn exploration(&self) -> f64 {
        self.exploration
    }

    /// The time-priority weight α.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// The power-priority weight β.
    pub fn beta(&self) -> f64 {
        self.beta
    }

    /// Current iteration counter `t`.
    pub fn t(&self) -> f64 {
        self.stats.t()
    }

    /// Rewards from the most recent scoring pass (empty before first call).
    pub fn last_rewards(&self) -> &[f64] {
        &self.scratch.rewards
    }

    /// Scoring backend name ("scalar" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.backend_name()
    }
}

/// The traced selection pass over explicit parts, so the same body can
/// score through the tuner's own scratch (`select_traced`) or a shared
/// batch scratch (`select_traced_in`). The arm is the backend's verbatim
/// (bit-identical to `select`, scalar or PJRT). Both backends leave the
/// normalized Eq. 5 rewards in `scratch.rewards` — the `ScoreBackend`
/// contract — so the telemetry pass recomputes the per-arm scores from
/// them with running top-2 locals: reads only, no scratch growth.
fn traced_step(
    stats: &ArmStats,
    alpha: f64,
    beta: f64,
    exploration: f64,
    backend: &mut dyn ScoreBackend,
    scratch: &mut Scratch,
) -> Choice {
    let step =
        backend.lasp_step(stats, alpha, beta, exploration, scratch).expect("score backend failed");
    let k = stats.k();
    let counts = stats.counts();
    let bonus_base = 2.0 * stats.t().max(1.0).ln();
    let mut best = f64::NEG_INFINITY;
    let mut second = f64::NEG_INFINITY;
    let mut greedy = 0usize;
    let mut greedy_r = f64::NEG_INFINITY;
    for i in 0..k {
        let r = scratch.rewards[i];
        let score = if counts[i] > 0.0 {
            r + exploration * (bonus_base / counts[i]).sqrt()
        } else {
            UNPULLED_SCORE
        };
        if score > best {
            second = best;
            best = score;
        } else if score > second {
            second = score;
        }
        if r > greedy_r {
            greedy_r = r;
            greedy = i;
        }
    }
    Choice {
        arm: step.best,
        gap: if k > 1 { best - second } else { 0.0 },
        explore: counts[step.best] == 0.0 || step.best != greedy,
    }
}

impl Policy for UcbTuner {
    fn k(&self) -> usize {
        self.stats.k()
    }

    fn select(&mut self) -> usize {
        self.backend
            .lasp_step(&self.stats, self.alpha, self.beta, self.exploration, &mut self.scratch)
            .expect("score backend failed")
            .best
    }

    fn select_traced(&mut self) -> Choice {
        traced_step(
            &self.stats,
            self.alpha,
            self.beta,
            self.exploration,
            self.backend.as_mut(),
            &mut self.scratch,
        )
    }

    fn select_traced_in(&mut self, scratch: &mut Scratch) -> Choice {
        traced_step(&self.stats, self.alpha, self.beta, self.exploration, self.backend.as_mut(), scratch)
    }

    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        // No select/update pairing is enforced: the online tuning service
        // (`serve`) applies reports asynchronously through batched
        // ingestion, so updates may arrive out of order relative to the
        // most recent `select`. UCB's sufficient statistics are
        // order-free, so any valid arm is accepted.
        self.stats.observe(arm, time_s, power_w);
    }

    fn counts(&self) -> &[f64] {
        self.stats.counts()
    }

    fn name(&self) -> &'static str {
        "lasp-ucb1"
    }

    fn stats(&self) -> &ArmStats {
        &self.stats
    }

    fn warm_start(&mut self, prior: ArmStats) {
        assert_eq!(prior.k(), self.stats.k(), "warm-start arm count mismatch");
        self.stats = prior;
    }

    fn scratch_growths(&self) -> u64 {
        self.scratch.growths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tries_every_arm_first() {
        let k = 8;
        let mut tuner = UcbTuner::new(k, 1.0, 0.0);
        let mut seen = vec![false; k];
        for _ in 0..k {
            let arm = tuner.select();
            assert!(!seen[arm], "arm {arm} repeated before full sweep");
            seen[arm] = true;
            tuner.update(arm, 1.0, 1.0);
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn concentrates_on_fastest_arm() {
        let mut tuner = UcbTuner::new(5, 1.0, 0.0);
        let times = [2.0, 1.8, 0.6, 1.5, 2.2];
        for _ in 0..600 {
            let arm = tuner.select();
            tuner.update(arm, times[arm], 5.0);
        }
        assert_eq!(tuner.most_selected(), 2);
        assert!(tuner.counts()[2] > 300.0);
    }

    #[test]
    fn beta_focus_prefers_frugal_arm() {
        let mut tuner = UcbTuner::new(3, 0.0, 1.0);
        let power = [8.0, 3.0, 6.0];
        for _ in 0..400 {
            let arm = tuner.select();
            tuner.update(arm, 1.0, power[arm]);
        }
        assert_eq!(tuner.most_selected(), 1);
    }

    #[test]
    fn t_advances_per_update() {
        let mut tuner = UcbTuner::new(2, 0.5, 0.5);
        assert_eq!(tuner.t(), 1.0);
        let a = tuner.select();
        tuner.update(a, 1.0, 1.0);
        assert_eq!(tuner.t(), 2.0);
    }

    #[test]
    fn select_reuses_scratch_after_warmup() {
        let mut tuner = UcbTuner::new(32, 1.0, 0.0);
        let arm = tuner.select(); // scratch reaches its high-water mark
        tuner.update(arm, 1.0, 1.0);
        let before = tuner.scratch_growths();
        assert_eq!(before, 1);
        for _ in 0..200 {
            let arm = tuner.select();
            tuner.update(arm, 1.0 + (arm % 3) as f64, 5.0);
        }
        assert_eq!(
            tuner.scratch_growths(),
            before,
            "steady-state select grew the scratch"
        );
        assert_eq!(tuner.last_rewards().len(), 32);
    }

    #[test]
    #[should_panic]
    fn invalid_alpha_rejected() {
        UcbTuner::new(2, 1.5, 0.0);
    }

    #[test]
    #[should_panic]
    fn warm_start_arm_mismatch_panics() {
        let _ = UcbTuner::new(4, 1.0, 0.0).with_state(ArmStats::new(3));
    }
}
