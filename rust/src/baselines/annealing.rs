//! Simulated annealing over the dense configuration index — the classic
//! rule-based heuristic family the paper contrasts with (Kirkpatrick [10]):
//! fast, but liable to park in local optima on rugged surfaces.

use super::{Decision, Measurement, Objective, SearchStep, Searcher};
use crate::util::Rng;
use anyhow::Result;

/// Metropolis annealer with geometric cooling and index-neighbourhood moves.
pub struct SimulatedAnnealing {
    rng: Rng,
    objective: Objective,
    /// Initial temperature (in normalized-cost units).
    pub t0: f64,
    /// Geometric cooling factor per step.
    pub cooling: f64,
    /// Neighbourhood radius as a fraction of `k`.
    pub radius_frac: f64,
}

impl SimulatedAnnealing {
    pub fn new(seed: u64, alpha: f64, beta: f64) -> Self {
        SimulatedAnnealing {
            rng: Rng::new(seed),
            objective: Objective::new(alpha, beta),
            t0: 0.4,
            cooling: 0.985,
            radius_frac: 0.08,
        }
    }

    fn neighbour(&mut self, current: usize, k: usize) -> usize {
        let radius = ((k as f64 * self.radius_frac) as i64).max(1);
        let delta = self.rng.below((2 * radius + 1) as usize) as i64 - radius;
        (current as i64 + delta).rem_euclid(k as i64) as usize
    }
}

/// One incremental annealing run: `next` proposes (the initial random
/// point, then index-neighbourhood moves), `observe` applies Metropolis
/// acceptance and cools the temperature.
pub struct AnnealingRun<'a> {
    search: &'a mut SimulatedAnnealing,
    k: usize,
    /// Incumbent position and its normalized cost (None before the first
    /// observation).
    current: Option<(usize, f64)>,
    best: Option<(usize, f64)>,
    temp: f64,
}

impl SearchStep for AnnealingRun<'_> {
    fn next(&mut self) -> Result<Option<Decision>> {
        let index = match self.current {
            None => self.search.rng.below(self.k),
            Some((current, _)) => self.search.neighbour(current, self.k),
        };
        Ok(Some(Decision::at_native(index)))
    }

    fn observe(&mut self, index: usize, _fidelity: f64, m: Measurement) {
        self.search.objective.observe(&m);
        let cost = self.search.objective.cost(&m);
        match self.current {
            None => {
                self.current = Some((index, cost));
                self.best = Some((index, cost));
            }
            Some((_, current_cost)) => {
                // Metropolis acceptance on the normalized objective. The
                // `||` short-circuit keeps the RNG draw order identical to
                // the pre-refactor loop: no uniform is consumed on
                // strictly-improving moves.
                let accept = cost < current_cost
                    || self.search.rng.uniform()
                        < ((current_cost - cost) / self.temp.max(1e-6)).exp();
                if accept {
                    self.current = Some((index, cost));
                }
                let improved = match self.best {
                    None => true,
                    Some((_, b)) => cost < b,
                };
                if improved {
                    self.best = Some((index, cost));
                }
                self.temp *= self.search.cooling;
            }
        }
    }

    fn recommend(&self) -> usize {
        self.best.map_or(0, |(i, _)| i)
    }

    fn best_objective(&self) -> f64 {
        self.best.map_or(f64::INFINITY, |(_, c)| c)
    }

    fn name(&self) -> &'static str {
        "simulated-annealing"
    }
}

impl Searcher for SimulatedAnnealing {
    fn begin<'a>(&'a mut self, k: usize, _budget: usize, _q: f64) -> Box<dyn SearchStep + 'a> {
        let temp = self.t0;
        Box::new(AnnealingRun { search: self, k, current: None, best: None, temp })
    }

    fn name(&self) -> &'static str {
        "simulated-annealing"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::valley_eval;
    use crate::baselines::FnEval;

    #[test]
    fn cools_toward_exploitation() {
        // Late-phase moves should cluster near the incumbent: measure mean
        // |Δindex| of accepted positions early vs late.
        let k = 200;
        let mut s = SimulatedAnnealing::new(3, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(k, 4), fidelity: 0.2 };
        let out = s.run(k, 400, &mut eval).unwrap();
        let idx: Vec<f64> = out.trace.iter().map(|s| s.index as f64).collect();
        let spread = |xs: &[f64]| crate::util::stats::std_dev(xs);
        assert!(spread(&idx[300..]) < spread(&idx[..100]) * 1.2);
    }

    #[test]
    fn budget_respected() {
        let mut s = SimulatedAnnealing::new(1, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(50, 5), fidelity: 0.2 };
        assert_eq!(s.run(50, 33, &mut eval).unwrap().evaluations(), 33);
    }

    #[test]
    fn neighbour_wraps_and_stays_in_range() {
        let mut s = SimulatedAnnealing::new(2, 1.0, 0.0);
        for _ in 0..1000 {
            let n = s.neighbour(0, 100);
            assert!(n < 100);
        }
    }
}
