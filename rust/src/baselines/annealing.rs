//! Simulated annealing over the dense configuration index — the classic
//! rule-based heuristic family the paper contrasts with (Kirkpatrick [10]):
//! fast, but liable to park in local optima on rugged surfaces.

use super::{EvalFn, Objective, Sample, SearchOutcome, Searcher};
use crate::util::Rng;
use anyhow::Result;

/// Metropolis annealer with geometric cooling and index-neighbourhood moves.
pub struct SimulatedAnnealing {
    rng: Rng,
    objective: Objective,
    /// Initial temperature (in normalized-cost units).
    pub t0: f64,
    /// Geometric cooling factor per step.
    pub cooling: f64,
    /// Neighbourhood radius as a fraction of `k`.
    pub radius_frac: f64,
}

impl SimulatedAnnealing {
    pub fn new(seed: u64, alpha: f64, beta: f64) -> Self {
        SimulatedAnnealing {
            rng: Rng::new(seed),
            objective: Objective::new(alpha, beta),
            t0: 0.4,
            cooling: 0.985,
            radius_frac: 0.08,
        }
    }

    fn neighbour(&mut self, current: usize, k: usize) -> usize {
        let radius = ((k as f64 * self.radius_frac) as i64).max(1);
        let delta = self.rng.below((2 * radius + 1) as usize) as i64 - radius;
        (current as i64 + delta).rem_euclid(k as i64) as usize
    }
}

impl Searcher for SimulatedAnnealing {
    fn run(&mut self, k: usize, budget: usize, eval: &mut dyn EvalFn) -> Result<SearchOutcome> {
        let q = eval.native_fidelity();
        let mut trace = Vec::with_capacity(budget);
        let mut current = self.rng.below(k);
        let m0 = eval.eval(current, q);
        self.objective.observe(&m0);
        trace.push(Sample { index: current, measurement: m0, fidelity: q });
        let mut current_cost = self.objective.cost(&m0);
        let (mut best_index, mut best_cost) = (current, current_cost);
        let mut temp = self.t0;

        while trace.len() < budget {
            let cand = self.neighbour(current, k);
            let m = eval.eval(cand, q);
            self.objective.observe(&m);
            trace.push(Sample { index: cand, measurement: m, fidelity: q });
            let cost = self.objective.cost(&m);
            // Metropolis acceptance on the normalized objective.
            let accept = cost < current_cost
                || self.rng.uniform() < ((current_cost - cost) / temp.max(1e-6)).exp();
            if accept {
                current = cand;
                current_cost = cost;
            }
            if cost < best_cost {
                best_cost = cost;
                best_index = cand;
            }
            temp *= self.cooling;
        }
        Ok(SearchOutcome { best_index, best_objective: best_cost, trace })
    }

    fn name(&self) -> &'static str {
        "simulated-annealing"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::valley_eval;
    use crate::baselines::FnEval;

    #[test]
    fn cools_toward_exploitation() {
        // Late-phase moves should cluster near the incumbent: measure mean
        // |Δindex| of accepted positions early vs late.
        let k = 200;
        let mut s = SimulatedAnnealing::new(3, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(k, 4), fidelity: 0.2 };
        let out = s.run(k, 400, &mut eval).unwrap();
        let idx: Vec<f64> = out.trace.iter().map(|s| s.index as f64).collect();
        let spread = |xs: &[f64]| crate::util::stats::std_dev(xs);
        assert!(spread(&idx[300..]) < spread(&idx[..100]) * 1.2);
    }

    #[test]
    fn budget_respected() {
        let mut s = SimulatedAnnealing::new(1, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(50, 5), fidelity: 0.2 };
        assert_eq!(s.run(50, 33, &mut eval).unwrap().evaluations(), 33);
    }

    #[test]
    fn neighbour_wraps_and_stays_in_range() {
        let mut s = SimulatedAnnealing::new(2, 1.0, 0.0);
        for _ in 0..1000 {
            let n = s.neighbour(0, 100);
            assert!(n < 100);
        }
    }
}
