//! BLISS-style Bayesian-optimization baseline (Roy et al., PLDI'21 [16]).
//!
//! BLISS drives tuning with lightweight surrogate models; our
//! reimplementation uses a Gaussian-process surrogate with an
//! expected-improvement acquisition over a random candidate pool. The GP
//! math runs either in pure rust ([`GpSurrogate`], dense Cholesky) or on
//! the AOT `gp_propose` artifact via the PJRT engine — both paths are
//! differentially tested.
//!
//! This baseline exists for two paper artifacts: Fig 10 (resource footprint
//! of BLISS vs LASP) and the §V-D discussion (BLISS converges in fewer
//! evaluations but costs far more per iteration).

use super::{Decision, Measurement, Objective, SearchStep, Searcher};
use crate::runtime::EngineHandle;
use crate::util::{stats, Rng};
use anyhow::{anyhow, Result};

/// Pure-rust GP regression surrogate (RBF kernel, dense Cholesky).
pub struct GpSurrogate {
    pub lengthscale: f64,
    pub noise: f64,
    x: Vec<Vec<f64>>,
    y: Vec<f64>,
    /// Cholesky factor of K + σ²I (lower triangular, row-major).
    chol: Vec<f64>,
}

impl GpSurrogate {
    pub fn new(lengthscale: f64, noise: f64) -> Self {
        GpSurrogate { lengthscale, noise, x: vec![], y: vec![], chol: vec![] }
    }

    fn kernel(&self, a: &[f64], b: &[f64]) -> f64 {
        let sq: f64 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
        (-sq / (2.0 * self.lengthscale * self.lengthscale)).exp()
    }

    /// Fit on observations (replaces any previous fit).
    pub fn fit(&mut self, x: Vec<Vec<f64>>, y: Vec<f64>) -> Result<()> {
        assert_eq!(x.len(), y.len());
        let n = x.len();
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = self.kernel(&x[i], &x[j]);
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
            k[i * n + i] += self.noise;
        }
        self.chol = cholesky(&k, n)?;
        self.x = x;
        self.y = y;
        Ok(())
    }

    /// Posterior (mean, variance) at a query point.
    pub fn predict(&self, q: &[f64]) -> (f64, f64) {
        let n = self.x.len();
        if n == 0 {
            return (0.0, 1.0);
        }
        let ks: Vec<f64> = self.x.iter().map(|xi| self.kernel(xi, q)).collect();
        // alpha = K⁻¹ y via two triangular solves.
        let alpha = chol_solve(&self.chol, n, &self.y);
        let mean = ks.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        // v = L⁻¹ ks; var = k(q,q) − ‖v‖².
        let v = forward_sub(&self.chol, n, &ks);
        let var: f64 = 1.0 - v.iter().map(|x| x * x).sum::<f64>();
        (mean, var.max(1e-12))
    }

    /// Expected improvement (maximization) at `q` given incumbent `best`.
    pub fn expected_improvement(&self, q: &[f64], best: f64) -> f64 {
        let (mean, var) = self.predict(q);
        let std = var.sqrt();
        let xi = 0.01;
        let z = (mean - best - xi) / std;
        let phi = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
        let cdf = 0.5 * (1.0 + erf_approx(z / std::f64::consts::SQRT_2));
        (mean - best - xi) * cdf + std * phi
    }
}

/// Dense Cholesky (lower factor), row-major.
fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(anyhow!("matrix not positive definite at {i}"));
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Solve L z = b.
fn forward_sub(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for j in 0..i {
            sum -= l[i * n + j] * z[j];
        }
        z[i] = sum / l[i * n + i];
    }
    z
}

/// Solve (L Lᵀ) x = b.
fn chol_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let z = forward_sub(l, n, b);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = z[i];
        for j in i + 1..n {
            sum -= l[j * n + i] * x[j];
        }
        x[i] = sum / l[i * n + i];
    }
    x
}

/// Abramowitz-Stegun erf approximation (|err| < 1.5e-7).
fn erf_approx(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// The BLISS-style BO searcher.
pub struct BlissBo {
    rng: Rng,
    objective: Objective,
    /// Feature embedding for configurations; defaults to scaled index.
    features: Option<Box<dyn Fn(usize) -> Vec<f64> + Send>>,
    /// Random initial design size.
    pub init_samples: usize,
    /// Candidate pool per BO iteration.
    pub candidates: usize,
    /// Observation cap (matches the AOT artifact's N).
    pub max_obs: usize,
    pub lengthscale: f64,
    pub noise: f64,
    /// Optional PJRT engine: use the `gp_propose` artifact.
    engine: Option<EngineHandle>,
}

impl BlissBo {
    pub fn new(seed: u64, alpha: f64, beta: f64) -> Self {
        BlissBo {
            rng: Rng::new(seed),
            objective: Objective::new(alpha, beta),
            features: None,
            init_samples: 8,
            candidates: 256,
            max_obs: 64,
            lengthscale: 0.35,
            noise: 1e-3,
            engine: None,
        }
    }

    /// Use a real feature embedding (e.g. `ParamSpace::features`).
    pub fn with_features(mut self, f: impl Fn(usize) -> Vec<f64> + Send + 'static) -> Self {
        self.features = Some(Box::new(f));
        self
    }

    /// Route GP math through the AOT `gp_propose` artifact.
    pub fn with_engine(mut self, engine: EngineHandle) -> Self {
        self.engine = Some(engine);
        self
    }

    fn feat(&self, index: usize, k: usize) -> Vec<f64> {
        match &self.features {
            Some(f) => f(index),
            None => vec![index as f64 / k.max(1) as f64],
        }
    }

    /// Propose the next index from candidates given observations.
    fn propose(
        &mut self,
        k: usize,
        obs_x: &[Vec<f64>],
        obs_y: &[f64],
        cands: &[usize],
    ) -> Result<usize> {
        let best = obs_y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if let Some(engine) = &self.engine {
            let (n_max, m_max, d_max) = engine.gp_shape()?;
            let n = obs_x.len().min(n_max);
            let d = obs_x[0].len().min(d_max);
            let mut x = vec![0f32; n_max * d_max];
            let mut y = vec![0f32; n_max];
            let mut mask = vec![0f32; n_max];
            // Most recent n observations.
            let start = obs_x.len() - n;
            for (row, i) in (start..obs_x.len()).enumerate() {
                for (c, &v) in obs_x[i].iter().take(d).enumerate() {
                    x[row * d_max + c] = v as f32;
                }
                y[row] = obs_y[i] as f32;
                mask[row] = 1.0;
            }
            let mut xs = vec![0f32; m_max * d_max];
            for (row, &ci) in cands.iter().take(m_max).enumerate() {
                let f = self.feat(ci, k);
                for (c, &v) in f.iter().take(d).enumerate() {
                    xs[row * d_max + c] = v as f32;
                }
            }
            // Unused candidate rows duplicate candidate 0 (harmless ties).
            for row in cands.len().min(m_max)..m_max {
                for c in 0..d_max {
                    xs[row * d_max + c] = xs[c];
                }
            }
            let (_, _, _, idx) = engine.gp_propose(
                x,
                y,
                mask,
                xs,
                self.lengthscale as f32,
                self.noise as f32,
                best as f32,
            )?;
            return Ok(cands[idx.min(cands.len() - 1)]);
        }
        let mut gp = GpSurrogate::new(self.lengthscale, self.noise);
        gp.fit(obs_x.to_vec(), obs_y.to_vec())?;
        let ei: Vec<f64> = cands
            .iter()
            .map(|&c| gp.expected_improvement(&self.feat(c, k), best))
            .collect();
        Ok(cands[stats::argmax(&ei)])
    }
}

/// One incremental BLISS run: a random initial design, then one GP
/// fit-and-propose per step over the most recent `max_obs` observations.
pub struct BlissRun<'a> {
    search: &'a mut BlissBo,
    k: usize,
    init: usize,
    samples: Vec<(usize, Measurement)>,
}

impl BlissRun<'_> {
    /// Score the whole run with the final objective extrema (stable
    /// objective), exactly as the pre-refactor batch loop did.
    fn best(&self) -> (usize, f64) {
        let (mut best_index, mut best_cost) =
            (self.samples.first().map_or(0, |s| s.0), f64::INFINITY);
        for (index, m) in &self.samples {
            let c = self.search.objective.cost(m);
            if c < best_cost {
                best_cost = c;
                best_index = *index;
            }
        }
        (best_index, best_cost)
    }
}

impl SearchStep for BlissRun<'_> {
    fn next(&mut self) -> Result<Option<Decision>> {
        if self.samples.len() < self.init {
            return Ok(Some(Decision::at_native(self.search.rng.below(self.k))));
        }
        // Rebuild y from the stable, latest objective extrema: reward =
        // 1 − cost (BO maximizes).
        let window = self.samples.len().saturating_sub(self.search.max_obs);
        let obs = &self.samples[window..];
        let obs_x: Vec<Vec<f64>> =
            obs.iter().map(|(i, _)| self.search.feat(*i, self.k)).collect();
        let obs_y: Vec<f64> = obs
            .iter()
            .map(|(_, m)| 1.0 - self.search.objective.cost(m))
            .collect();
        let n_cand = self.search.candidates.min(self.k);
        let cands = self.search.rng.sample_indices(self.k, n_cand);
        let index = self.search.propose(self.k, &obs_x, &obs_y, &cands)?;
        Ok(Some(Decision::at_native(index)))
    }

    fn observe(&mut self, index: usize, _fidelity: f64, m: Measurement) {
        self.search.objective.observe(&m);
        self.samples.push((index, m));
    }

    fn recommend(&self) -> usize {
        self.best().0
    }

    fn best_objective(&self) -> f64 {
        self.best().1
    }

    fn name(&self) -> &'static str {
        "bliss-bo"
    }
}

impl Searcher for BlissBo {
    fn begin<'a>(&'a mut self, k: usize, budget: usize, _q: f64) -> Box<dyn SearchStep + 'a> {
        let init = self.init_samples.min(budget);
        Box::new(BlissRun { search: self, k, init, samples: Vec::with_capacity(budget) })
    }

    fn name(&self) -> &'static str {
        "bliss-bo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::valley_eval;
    use crate::baselines::FnEval;

    #[test]
    fn cholesky_roundtrip() {
        // A = L Lᵀ for a known SPD matrix.
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2).unwrap();
        assert!((l[0] - 2.0).abs() < 1e-12);
        assert!((l[2] - 1.0).abs() < 1e-12);
        assert!((l[3] - (3.0f64 - 1.0).sqrt()).abs() < 1e-12);
        // Solve A x = b and check.
        let x = chol_solve(&l, 2, &[8.0, 7.0]);
        assert!((4.0 * x[0] + 2.0 * x[1] - 8.0).abs() < 1e-9);
        assert!((2.0 * x[0] + 3.0 * x[1] - 7.0).abs() < 1e-9);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0];
        assert!(cholesky(&a, 2).is_err());
    }

    #[test]
    fn gp_interpolates() {
        let mut gp = GpSurrogate::new(0.5, 1e-6);
        let x: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 / 8.0]).collect();
        let y: Vec<f64> = x.iter().map(|p| (p[0] * 6.0).sin()).collect();
        gp.fit(x.clone(), y.clone()).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let (m, v) = gp.predict(xi);
            assert!((m - yi).abs() < 1e-3, "{m} vs {yi}");
            assert!(v < 1e-3);
        }
        // Far from data: prior variance.
        let (_, v) = gp.predict(&[10.0]);
        assert!(v > 0.9);
    }

    #[test]
    fn erf_matches_known_values() {
        assert!(erf_approx(0.0).abs() < 1e-7);
        assert!((erf_approx(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf_approx(-1.0) + 0.8427007).abs() < 1e-5);
    }

    #[test]
    fn bo_beats_random_at_small_budget() {
        // BO with 40 evals should land nearer the valley optimum than
        // random with 40 evals (averaged over seeds).
        let k = 200;
        let err = |best: usize| (best as f64 / k as f64 - 1.0 / 3.0).abs();
        let mut bo_err = 0.0;
        let mut rnd_err = 0.0;
        for seed in 0..5 {
            let mut eval = FnEval { f: valley_eval(k, 100 + seed), fidelity: 0.2 };
            let out = BlissBo::new(seed, 1.0, 0.0).run(k, 40, &mut eval).unwrap();
            bo_err += err(out.best_index);
            let mut eval = FnEval { f: valley_eval(k, 100 + seed), fidelity: 0.2 };
            let out = crate::baselines::RandomSearch::new(seed, 1.0, 0.0)
                .run(k, 40, &mut eval)
                .unwrap();
            rnd_err += err(out.best_index);
        }
        assert!(bo_err <= rnd_err + 0.05, "bo {bo_err} vs random {rnd_err}");
    }

    #[test]
    fn pjrt_engine_path_matches_scalar_path() {
        let Some(dir) = crate::runtime::find_artifacts_dir() else { return };
        let engine = EngineHandle::spawn(dir).unwrap();
        let k = 120;
        let run = |bo: BlissBo| {
            let mut bo = bo;
            let mut eval = FnEval { f: valley_eval(k, 55), fidelity: 0.2 };
            bo.run(k, 30, &mut eval).unwrap().best_index as f64 / k as f64
        };
        let scalar = run(BlissBo::new(9, 1.0, 0.0));
        let pjrt = run(BlissBo::new(9, 1.0, 0.0).with_engine(engine));
        // Same seed, same candidates; proposals may differ slightly in f32
        // vs f64, but both must land near the valley.
        assert!((scalar - 1.0 / 3.0).abs() < 0.12, "scalar {scalar}");
        assert!((pjrt - 1.0 / 3.0).abs() < 0.12, "pjrt {pjrt}");
    }
}
