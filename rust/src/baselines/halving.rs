//! Hyperband-style successive halving (Li et al. [29]) over the fidelity
//! knob: evaluate many configurations cheaply at low fidelity, keep the
//! best fraction, re-evaluate the survivors at higher fidelity, repeat.
//! The natural multi-fidelity competitor to LASP's single-fidelity bandit.

use super::{EvalFn, Objective, Sample, SearchOutcome, Searcher};
use crate::util::Rng;
use anyhow::Result;

/// Successive halving with geometric fidelity ramp.
pub struct SuccessiveHalving {
    rng: Rng,
    objective: Objective,
    /// Survivor fraction per rung (1/eta).
    pub eta: usize,
    /// Fidelity of the first rung (fraction of native q..1 range).
    pub q_min: f64,
}

impl SuccessiveHalving {
    pub fn new(seed: u64, alpha: f64, beta: f64) -> Self {
        SuccessiveHalving {
            rng: Rng::new(seed),
            objective: Objective::new(alpha, beta),
            eta: 3,
            q_min: 0.05,
        }
    }
}

impl Searcher for SuccessiveHalving {
    fn run(&mut self, k: usize, budget: usize, eval: &mut dyn EvalFn) -> Result<SearchOutcome> {
        let mut trace: Vec<Sample> = vec![];
        // Rung count from budget: each rung keeps 1/eta of the cohort; the
        // initial cohort is sized so the whole ladder fits the budget.
        let rungs = 3usize;
        // cohort + cohort/eta + cohort/eta² <= budget
        let denom: f64 = (0..rungs).map(|r| 1.0 / (self.eta as f64).powi(r as i32)).sum();
        let cohort_size = ((budget as f64 / denom) as usize).clamp(1, k);

        let mut cohort = self.rng.sample_indices(k, cohort_size);
        let q_hi = 1.0f64.min(eval.native_fidelity().max(self.q_min) * 4.0);
        // Costs are only comparable within one rung (execution time scales
        // with fidelity), so the recommendation is the *last* rung's winner.
        let mut last_winner: Option<(usize, f64)> = None;

        for rung in 0..rungs {
            // Geometric fidelity ramp: q_min -> q_hi across rungs.
            let frac = rung as f64 / (rungs - 1).max(1) as f64;
            let q = self.q_min * (q_hi / self.q_min).powf(frac);
            // Per-rung objective: measurements at this fidelity only.
            let mut rung_obj = Objective::new(self.objective.alpha, self.objective.beta);
            let mut rung_ms: Vec<(usize, crate::device::Measurement)> = vec![];
            for &index in &cohort {
                if trace.len() >= budget {
                    break;
                }
                let m = eval.eval(index, q);
                rung_obj.observe(&m);
                self.objective.observe(&m);
                trace.push(Sample { index, measurement: m, fidelity: q });
                rung_ms.push((index, m));
            }
            let mut scored: Vec<(usize, f64)> = rung_ms
                .into_iter()
                .map(|(i, m)| (i, rung_obj.cost(&m)))
                .collect();
            scored.sort_by(|a, b| a.1.total_cmp(&b.1));
            if let Some(&(i, c)) = scored.first() {
                last_winner = Some((i, c));
            }
            let keep = (scored.len() / self.eta).max(1);
            cohort = scored.into_iter().take(keep).map(|(i, _)| i).collect();
            if trace.len() >= budget || cohort.len() <= 1 {
                break;
            }
        }

        let (best_index, best_objective) =
            last_winner.unwrap_or((cohort.first().copied().unwrap_or(0), f64::INFINITY));
        Ok(SearchOutcome { best_index, best_objective, trace })
    }

    fn name(&self) -> &'static str {
        "successive-halving"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::valley_eval;
    use crate::baselines::FnEval;

    #[test]
    fn fidelity_ramps_upward() {
        let mut s = SuccessiveHalving::new(1, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(100, 2), fidelity: 0.2 };
        let out = s.run(100, 200, &mut eval).unwrap();
        let first = out.trace.first().unwrap().fidelity;
        let last = out.trace.last().unwrap().fidelity;
        assert!(last > first, "fidelity did not ramp: {first} -> {last}");
    }

    #[test]
    fn survivors_shrink() {
        let mut s = SuccessiveHalving::new(2, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(100, 3), fidelity: 0.2 };
        let out = s.run(100, 150, &mut eval).unwrap();
        // Count distinct configs per fidelity level; must be decreasing.
        let mut by_q: std::collections::BTreeMap<u64, std::collections::HashSet<usize>> =
            Default::default();
        for s in &out.trace {
            by_q.entry((s.fidelity * 1e6) as u64).or_default().insert(s.index);
        }
        let sizes: Vec<usize> = by_q.values().map(|v| v.len()).collect();
        assert!(sizes.windows(2).all(|w| w[1] <= w[0]), "{sizes:?}");
    }

    #[test]
    fn budget_respected() {
        let mut s = SuccessiveHalving::new(3, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(80, 4), fidelity: 0.2 };
        assert!(s.run(80, 90, &mut eval).unwrap().evaluations() <= 90);
    }
}
