//! Hyperband-style successive halving (Li et al. [29]) over the fidelity
//! knob: evaluate many configurations cheaply at low fidelity, keep the
//! best fraction, re-evaluate the survivors at higher fidelity, repeat.
//! The natural multi-fidelity competitor to LASP's single-fidelity bandit.

use super::{Decision, Measurement, Objective, SearchStep, Searcher};
use crate::util::Rng;
use anyhow::Result;

const RUNGS: usize = 3;

/// Successive halving with geometric fidelity ramp.
pub struct SuccessiveHalving {
    rng: Rng,
    objective: Objective,
    /// Survivor fraction per rung (1/eta).
    pub eta: usize,
    /// Fidelity of the first rung (fraction of native q..1 range).
    pub q_min: f64,
}

impl SuccessiveHalving {
    pub fn new(seed: u64, alpha: f64, beta: f64) -> Self {
        SuccessiveHalving {
            rng: Rng::new(seed),
            objective: Objective::new(alpha, beta),
            eta: 3,
            q_min: 0.05,
        }
    }
}

/// One incremental halving run: a rung ladder driven step by step. Costs
/// are only comparable within one rung (execution time scales with
/// fidelity), so each rung keeps its own [`Objective`] and the
/// recommendation is the *latest* rung's winner.
pub struct HalvingRun<'a> {
    search: &'a mut SuccessiveHalving,
    rung: usize,
    cohort: Vec<usize>,
    /// Next position within the current rung's cohort.
    pos: usize,
    /// Current rung fidelity.
    q: f64,
    q_hi: f64,
    rung_obj: Objective,
    rung_ms: Vec<(usize, Measurement)>,
    last_winner: Option<(usize, f64)>,
    done: bool,
}

impl HalvingRun<'_> {
    /// Score the (possibly partial) current rung with its own objective.
    fn rung_winner(&self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for (index, m) in &self.rung_ms {
            let c = self.rung_obj.cost(m);
            let better = match best {
                None => true,
                Some((_, b)) => c < b,
            };
            if better {
                best = Some((*index, c));
            }
        }
        best
    }

    /// Close the current rung: record its winner, keep the best `1/eta`
    /// of the cohort, and ramp the fidelity for the next rung.
    fn finish_rung(&mut self) {
        let mut scored: Vec<(usize, f64)> = self
            .rung_ms
            .iter()
            .map(|(i, m)| (*i, self.rung_obj.cost(m)))
            .collect();
        scored.sort_by(|a, b| a.1.total_cmp(&b.1));
        if let Some(&(i, c)) = scored.first() {
            self.last_winner = Some((i, c));
        }
        let keep = (scored.len() / self.search.eta).max(1);
        self.cohort = scored.into_iter().take(keep).map(|(i, _)| i).collect();
        self.rung += 1;
        if self.rung >= RUNGS || self.cohort.len() <= 1 {
            self.done = true;
            return;
        }
        // Geometric fidelity ramp: q_min -> q_hi across rungs.
        let frac = self.rung as f64 / (RUNGS - 1).max(1) as f64;
        self.q = self.search.q_min * (self.q_hi / self.search.q_min).powf(frac);
        self.rung_obj = Objective::new(self.search.objective.alpha, self.search.objective.beta);
        self.rung_ms.clear();
        self.pos = 0;
    }
}

impl SearchStep for HalvingRun<'_> {
    fn next(&mut self) -> Result<Option<Decision>> {
        if !self.done && self.pos >= self.cohort.len() {
            self.finish_rung();
        }
        if self.done {
            return Ok(None);
        }
        let index = self.cohort[self.pos];
        self.pos += 1;
        Ok(Some(Decision { index, fidelity: Some(self.q) }))
    }

    fn observe(&mut self, index: usize, _fidelity: f64, m: Measurement) {
        self.search.objective.observe(&m);
        self.rung_obj.observe(&m);
        self.rung_ms.push((index, m));
    }

    fn recommend(&self) -> usize {
        // A rung in flight (budget exhausted mid-rung, or a completed rung
        // not yet closed by a further `next`) recommends its own winner —
        // matching the pre-refactor batch loop, which always scored the
        // final (possibly partial) rung.
        if let Some((i, _)) = self.rung_winner() {
            return i;
        }
        match self.last_winner {
            Some((i, _)) => i,
            None => self.cohort.first().copied().unwrap_or(0),
        }
    }

    fn best_objective(&self) -> f64 {
        if let Some((_, c)) = self.rung_winner() {
            return c;
        }
        self.last_winner.map_or(f64::INFINITY, |(_, c)| c)
    }

    fn name(&self) -> &'static str {
        "successive-halving"
    }
}

impl Searcher for SuccessiveHalving {
    fn begin<'a>(&'a mut self, k: usize, budget: usize, q: f64) -> Box<dyn SearchStep + 'a> {
        // The initial cohort is sized so the whole ladder fits the budget:
        // cohort + cohort/eta + cohort/eta² <= budget.
        let denom: f64 = (0..RUNGS).map(|r| 1.0 / (self.eta as f64).powi(r as i32)).sum();
        let cohort_size = ((budget as f64 / denom) as usize).clamp(1, k);
        let cohort = self.rng.sample_indices(k, cohort_size);
        let q_hi = 1.0f64.min(q.max(self.q_min) * 4.0);
        let q0 = self.q_min;
        let rung_obj = Objective::new(self.objective.alpha, self.objective.beta);
        Box::new(HalvingRun {
            search: self,
            rung: 0,
            cohort,
            pos: 0,
            q: q0,
            q_hi,
            rung_obj,
            rung_ms: vec![],
            last_winner: None,
            done: false,
        })
    }

    fn name(&self) -> &'static str {
        "successive-halving"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::valley_eval;
    use crate::baselines::FnEval;

    #[test]
    fn fidelity_ramps_upward() {
        let mut s = SuccessiveHalving::new(1, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(100, 2), fidelity: 0.2 };
        let out = s.run(100, 200, &mut eval).unwrap();
        let first = out.trace.first().unwrap().fidelity;
        let last = out.trace.last().unwrap().fidelity;
        assert!(last > first, "fidelity did not ramp: {first} -> {last}");
    }

    #[test]
    fn survivors_shrink() {
        let mut s = SuccessiveHalving::new(2, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(100, 3), fidelity: 0.2 };
        let out = s.run(100, 150, &mut eval).unwrap();
        // Count distinct configs per fidelity level; must be decreasing.
        let mut by_q: std::collections::BTreeMap<u64, std::collections::HashSet<usize>> =
            Default::default();
        for s in &out.trace {
            by_q.entry((s.fidelity * 1e6) as u64).or_default().insert(s.index);
        }
        let sizes: Vec<usize> = by_q.values().map(|v| v.len()).collect();
        assert!(sizes.windows(2).all(|w| w[1] <= w[0]), "{sizes:?}");
    }

    #[test]
    fn budget_respected() {
        let mut s = SuccessiveHalving::new(3, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(80, 4), fidelity: 0.2 };
        assert!(s.run(80, 90, &mut eval).unwrap().evaluations() <= 90);
    }

    #[test]
    fn ladder_finishes_before_large_budget() {
        // With a huge budget the ladder converges to <=1 survivor and the
        // stepper reports exhaustion (`next` -> None) instead of looping.
        let mut s = SuccessiveHalving::new(5, 1.0, 0.0);
        let mut f = valley_eval(40, 6);
        let mut step = s.begin(40, 10_000, 0.2);
        let mut evals = 0;
        while let Some(d) = step.next().unwrap() {
            let q = d.fidelity.unwrap_or(0.2);
            let m = f(d.index, q);
            step.observe(d.index, q, m);
            evals += 1;
            assert!(evals < 10_000, "ladder never exhausted");
        }
        assert!(evals > 0);
        let rec = step.recommend();
        assert!(rec < 40);
    }
}
