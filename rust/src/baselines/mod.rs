//! Baseline configuration-search strategies the paper compares against (or
//! that its citations represent): default-config, exhaustive oracle, random
//! search, simulated annealing (the classic heuristic family [10]), a
//! BLISS-style Bayesian-optimization tuner [16], and Hyperband-style
//! successive halving [29] over the fidelity knob.
//!
//! All strategies implement [`Searcher`] over an abstract evaluation
//! closure so the experiment drivers can run any of them against the same
//! simulated app + device pair.

mod annealing;
mod bliss;
mod halving;
mod random_search;

pub use annealing::SimulatedAnnealing;
pub use bliss::{BlissBo, GpSurrogate};
pub use halving::SuccessiveHalving;
pub use random_search::RandomSearch;

use crate::device::Measurement;
use anyhow::Result;

/// One evaluated sample in a search trace.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub index: usize,
    pub measurement: Measurement,
    /// Fidelity the sample was evaluated at (successive halving varies it).
    pub fidelity: f64,
}

/// Result of a search run.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// The configuration the searcher recommends.
    pub best_index: usize,
    /// Objective value of the recommendation (as seen by the searcher).
    pub best_objective: f64,
    /// Every evaluation performed, in order.
    pub trace: Vec<Sample>,
}

impl SearchOutcome {
    /// Number of evaluations consumed.
    pub fn evaluations(&self) -> usize {
        self.trace.len()
    }
}

/// Evaluation oracle handed to a searcher: runs configuration `index` at
/// fidelity `q` and returns the measurement. Implementations wrap an app
/// model + device simulator (see `experiments::harness`).
pub trait EvalFn {
    fn eval(&mut self, index: usize, fidelity: f64) -> Measurement;
    /// The device's native (low) fidelity.
    fn native_fidelity(&self) -> f64;
}

/// One incremental search decision: evaluate configuration `index`, at an
/// explicit fidelity if the strategy controls it (successive halving), or
/// at the environment's native fidelity when `None`.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    pub index: usize,
    pub fidelity: Option<f64>,
}

impl Decision {
    /// A decision at the environment's native fidelity.
    pub fn at_native(index: usize) -> Decision {
        Decision { index, fidelity: None }
    }
}

/// The incremental stepping interface every search strategy exposes — the
/// same select/observe contract as a bandit [`crate::bandit::Policy`], so
/// the `sim` engine can drive baselines and policies through one episode
/// loop. Obtained from [`Searcher::begin`]; the borrow ties the run to its
/// parent searcher (RNG and objective state live there).
pub trait SearchStep: Send {
    /// The next configuration to evaluate, or `None` when the strategy has
    /// exhausted its schedule before the episode budget (successive
    /// halving's ladder can converge early). Errors abort the episode
    /// (e.g. a GP fit on a non-positive-definite kernel).
    fn next(&mut self) -> Result<Option<Decision>>;

    /// Observe the measurement for `index` evaluated at `fidelity`.
    fn observe(&mut self, index: usize, fidelity: f64, m: Measurement);

    /// The configuration the strategy currently recommends.
    fn recommend(&self) -> usize;

    /// Objective value of the recommendation (as seen by the searcher).
    fn best_objective(&self) -> f64;

    /// Per-arm pull counts, when the strategy tracks them (bandits do;
    /// search heuristics generally do not).
    fn counts(&self) -> Option<&[f64]> {
        None
    }

    /// Strategy name for reports.
    fn name(&self) -> &'static str;
}

/// Adapter so closures `(usize, f64) -> Measurement` can serve as [`EvalFn`]
/// with an explicit native fidelity tag.
pub struct FnEval<F: FnMut(usize, f64) -> Measurement> {
    pub f: F,
    pub fidelity: f64,
}

impl<F: FnMut(usize, f64) -> Measurement> EvalFn for FnEval<F> {
    fn eval(&mut self, index: usize, fidelity: f64) -> Measurement {
        (self.f)(index, fidelity)
    }

    fn native_fidelity(&self) -> f64 {
        self.fidelity
    }
}

/// A sequential configuration searcher.
///
/// Since the unified-engine refactor a searcher is a *factory* for
/// incremental [`SearchStep`] runs; the old per-searcher evaluation loops
/// are gone. [`Searcher::run`] is provided once, here, as the single
/// batch-mode loop over the stepping interface — `sim::Episode` drives the
/// very same steps for scenario-engine runs.
pub trait Searcher: Send {
    /// Start an incremental search over `k` arms with an evaluation budget
    /// of `budget` and the environment's native fidelity `q`.
    fn begin<'a>(&'a mut self, k: usize, budget: usize, q: f64) -> Box<dyn SearchStep + 'a>;

    /// Strategy name for reports.
    fn name(&self) -> &'static str;

    /// Search over `k` arms with at most `budget` evaluations — the one
    /// shared select/evaluate/observe loop.
    fn run(&mut self, k: usize, budget: usize, eval: &mut dyn EvalFn) -> Result<SearchOutcome> {
        let q = eval.native_fidelity();
        let mut step = self.begin(k, budget, q);
        let mut trace = Vec::with_capacity(budget);
        while trace.len() < budget {
            let Some(d) = step.next()? else { break };
            let fidelity = d.fidelity.unwrap_or(q);
            let measurement = eval.eval(d.index, fidelity);
            step.observe(d.index, fidelity, measurement);
            trace.push(Sample { index: d.index, measurement, fidelity });
        }
        Ok(SearchOutcome {
            best_index: step.recommend(),
            best_objective: step.best_objective(),
            trace,
        })
    }
}

/// Scalarizes measurements into the search objective (lower = better),
/// mirroring the paper's α/β weighting over MinMax-normalized metrics;
/// searchers track running extrema since global min/max are unknown online.
#[derive(Debug, Clone)]
pub struct Objective {
    pub alpha: f64,
    pub beta: f64,
    tau_lo: f64,
    tau_hi: f64,
    rho_lo: f64,
    rho_hi: f64,
}

impl Objective {
    pub fn new(alpha: f64, beta: f64) -> Self {
        Objective {
            alpha,
            beta,
            tau_lo: f64::INFINITY,
            tau_hi: f64::NEG_INFINITY,
            rho_lo: f64::INFINITY,
            rho_hi: f64::NEG_INFINITY,
        }
    }

    /// Update extrema with a new measurement.
    pub fn observe(&mut self, m: &Measurement) {
        self.tau_lo = self.tau_lo.min(m.time_s);
        self.tau_hi = self.tau_hi.max(m.time_s);
        self.rho_lo = self.rho_lo.min(m.power_w);
        self.rho_hi = self.rho_hi.max(m.power_w);
    }

    /// Weighted normalized cost in `[0, 1]` (lower = better).
    pub fn cost(&self, m: &Measurement) -> f64 {
        let tau = (m.time_s - self.tau_lo) / (self.tau_hi - self.tau_lo).max(1e-9);
        let rho = (m.power_w - self.rho_lo) / (self.rho_hi - self.rho_lo).max(1e-9);
        (self.alpha * tau + self.beta * rho) / (self.alpha + self.beta).max(1e-9)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Rng;

    /// Synthetic quadratic valley over k arms; minimum at k/3.
    pub fn valley_eval(k: usize, seed: u64) -> impl FnMut(usize, f64) -> Measurement {
        let mut rng = Rng::new(seed);
        move |i, q| {
            let x = i as f64 / k as f64;
            let opt = 1.0 / 3.0;
            let t = (0.5 + 4.0 * (x - opt) * (x - opt)) * q.max(0.05);
            Measurement {
                time_s: t * rng.relative_noise(0.02),
                power_w: 5.0 + x * rng.relative_noise(0.02),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::valley_eval;
    use super::*;

    fn check_searcher(mut s: Box<dyn Searcher>, budget: usize, tol: f64) {
        let k = 120;
        let mut eval = FnEval { f: valley_eval(k, 9), fidelity: 0.2 };
        let out = s.run(k, budget, &mut eval).unwrap();
        assert!(out.evaluations() <= budget, "{} overspent", s.name());
        let got = out.best_index as f64 / k as f64;
        assert!(
            (got - 1.0 / 3.0).abs() < tol,
            "{}: best {} ({} evals)",
            s.name(),
            out.best_index,
            out.evaluations()
        );
    }

    #[test]
    fn all_searchers_find_the_valley() {
        check_searcher(Box::new(RandomSearch::new(3, 1.0, 0.0)), 200, 0.10);
        check_searcher(Box::new(SimulatedAnnealing::new(5, 1.0, 0.0)), 300, 0.10);
        check_searcher(Box::new(BlissBo::new(7, 1.0, 0.0)), 60, 0.10);
        check_searcher(Box::new(SuccessiveHalving::new(11, 1.0, 0.0)), 400, 0.10);
    }

    #[test]
    fn objective_orders_measurements() {
        let mut o = Objective::new(1.0, 0.0);
        let fast = Measurement { time_s: 1.0, power_w: 9.0 };
        let slow = Measurement { time_s: 3.0, power_w: 4.0 };
        o.observe(&fast);
        o.observe(&slow);
        assert!(o.cost(&fast) < o.cost(&slow));
        let mut p = Objective::new(0.0, 1.0);
        p.observe(&fast);
        p.observe(&slow);
        assert!(p.cost(&slow) < p.cost(&fast));
    }
}
