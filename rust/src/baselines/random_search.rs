//! Uniform random search — the canonical sanity baseline.

use super::{EvalFn, Objective, Sample, SearchOutcome, Searcher};
use crate::util::Rng;
use anyhow::Result;

/// Sample configurations uniformly at random; recommend the best seen.
pub struct RandomSearch {
    rng: Rng,
    objective: Objective,
}

impl RandomSearch {
    pub fn new(seed: u64, alpha: f64, beta: f64) -> Self {
        RandomSearch { rng: Rng::new(seed), objective: Objective::new(alpha, beta) }
    }
}

impl Searcher for RandomSearch {
    fn run(&mut self, k: usize, budget: usize, eval: &mut dyn EvalFn) -> Result<SearchOutcome> {
        let q = eval.native_fidelity();
        let mut trace = Vec::with_capacity(budget);
        for _ in 0..budget {
            let index = self.rng.below(k);
            let measurement = eval.eval(index, q);
            self.objective.observe(&measurement);
            trace.push(Sample { index, measurement, fidelity: q });
        }
        // Score the whole trace with the final extrema (stable objective).
        let (mut best_index, mut best_objective) = (trace[0].index, f64::INFINITY);
        for s in &trace {
            let c = self.objective.cost(&s.measurement);
            if c < best_objective {
                best_objective = c;
                best_index = s.index;
            }
        }
        Ok(SearchOutcome { best_index, best_objective, trace })
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::valley_eval;
    use crate::baselines::FnEval;

    #[test]
    fn respects_budget_exactly() {
        let mut s = RandomSearch::new(1, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(50, 2), fidelity: 0.2 };
        let out = s.run(50, 77, &mut eval).unwrap();
        assert_eq!(out.evaluations(), 77);
    }

    #[test]
    fn deterministic_for_seed() {
        let run = |seed| {
            let mut s = RandomSearch::new(seed, 1.0, 0.0);
            let mut eval = FnEval { f: valley_eval(50, 3), fidelity: 0.2 };
            s.run(50, 40, &mut eval).unwrap().best_index
        };
        assert_eq!(run(5), run(5));
    }
}
