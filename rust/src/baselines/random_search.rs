//! Uniform random search — the canonical sanity baseline.

use super::{Decision, Measurement, Objective, SearchStep, Searcher};
use crate::util::Rng;
use anyhow::Result;

/// Sample configurations uniformly at random; recommend the best seen.
pub struct RandomSearch {
    rng: Rng,
    objective: Objective,
}

impl RandomSearch {
    pub fn new(seed: u64, alpha: f64, beta: f64) -> Self {
        RandomSearch { rng: Rng::new(seed), objective: Objective::new(alpha, beta) }
    }
}

/// One incremental random-search run. Samples are kept so the
/// recommendation can be scored against the final objective extrema
/// (stable objective), exactly as the pre-refactor batch loop did.
pub struct RandomSearchRun<'a> {
    search: &'a mut RandomSearch,
    k: usize,
    samples: Vec<(usize, Measurement)>,
}

impl RandomSearchRun<'_> {
    fn best(&self) -> (usize, f64) {
        let (mut best_index, mut best_objective) =
            (self.samples.first().map_or(0, |s| s.0), f64::INFINITY);
        for (index, m) in &self.samples {
            let c = self.search.objective.cost(m);
            if c < best_objective {
                best_objective = c;
                best_index = *index;
            }
        }
        (best_index, best_objective)
    }
}

impl SearchStep for RandomSearchRun<'_> {
    fn next(&mut self) -> Result<Option<Decision>> {
        Ok(Some(Decision::at_native(self.search.rng.below(self.k))))
    }

    fn observe(&mut self, index: usize, _fidelity: f64, m: Measurement) {
        self.search.objective.observe(&m);
        self.samples.push((index, m));
    }

    fn recommend(&self) -> usize {
        self.best().0
    }

    fn best_objective(&self) -> f64 {
        self.best().1
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

impl Searcher for RandomSearch {
    fn begin<'a>(&'a mut self, k: usize, budget: usize, _q: f64) -> Box<dyn SearchStep + 'a> {
        Box::new(RandomSearchRun { search: self, k, samples: Vec::with_capacity(budget) })
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil::valley_eval;
    use crate::baselines::FnEval;

    #[test]
    fn respects_budget_exactly() {
        let mut s = RandomSearch::new(1, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(50, 2), fidelity: 0.2 };
        let out = s.run(50, 77, &mut eval).unwrap();
        assert_eq!(out.evaluations(), 77);
    }

    #[test]
    fn deterministic_for_seed() {
        let run = |seed| {
            let mut s = RandomSearch::new(seed, 1.0, 0.0);
            let mut eval = FnEval { f: valley_eval(50, 3), fidelity: 0.2 };
            s.run(50, 40, &mut eval).unwrap().best_index
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn stepping_interface_matches_batch_run() {
        // The default `Searcher::run` drives `begin()`; a hand-rolled loop
        // over the same steps must land on the same recommendation.
        let mut batch = RandomSearch::new(9, 1.0, 0.0);
        let mut eval = FnEval { f: valley_eval(60, 4), fidelity: 0.2 };
        let expect = batch.run(60, 50, &mut eval).unwrap().best_index;

        let mut s = RandomSearch::new(9, 1.0, 0.0);
        let mut f = valley_eval(60, 4);
        let mut step = s.begin(60, 50, 0.2);
        for _ in 0..50 {
            let d = step.next().unwrap().unwrap();
            let q = d.fidelity.unwrap_or(0.2);
            let m = f(d.index, q);
            step.observe(d.index, q, m);
        }
        assert_eq!(step.recommend(), expect);
    }
}
