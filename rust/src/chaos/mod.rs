//! Deterministic, seed-driven fault injection (the "chaos engine").
//!
//! Two injection surfaces share this module:
//!
//! * **Serve side** — [`ChaosLayer`], constructed from a `[chaos]` config
//!   section (`lasp serve --chaos <file.toml>`), draws faults from one
//!   seeded PRNG at well-defined points of the data plane: connection
//!   accept, request handler, batch flush, fleet push/pull, checkpoint
//!   write. Every injection is counted and logged through the flight
//!   recorder as a [`EventKind::Chaos`] event, so a chaotic run leaves a
//!   complete, replayable record of *what* was broken *when*.
//! * **Sim side** — [`sim::DeliveryChaos`], the episode-level delivery
//!   fault model (churn storms, Zipf-skewed duplication, delayed and
//!   reordered reports, node kill/rejoin) driven by the scenario event
//!   DSL (`churn@i=p`, `dup@i=p`, `zipf@i=s`, `delay@i=w`, `kill@i=j`).
//!
//! Determinism contract: every fault is a pure function of the configured
//! seed and the draw sequence — two runs with the same seed and the same
//! traffic order inject identically. The layer is `Option` everywhere it
//! is consulted: a server without `--chaos` carries `None` and pays zero
//! overhead (the `serve_hotpath` zero-alloc assertions and
//! `benches/chaos.rs` pin this), and an enabled-but-idle layer (all
//! probabilities 0.0) short-circuits before touching its RNG lock.
//!
//! The failure-model semantics the injections exercise — the idempotency
//! window, fleet backoff states, checkpoint retry — are documented in
//! DESIGN.md §Failure model.

pub mod sim;

use crate::config::parse_toml;
use crate::obs::{EventKind, Recorder};
use crate::util::Rng;
use anyhow::{anyhow, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Where a serve-side fault can be injected. Codes are stable: they ride
/// in the `a` word of [`EventKind::Chaos`] trace events and in capture
/// files, so renumbering would corrupt recorded histories.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// A just-accepted connection is closed before any byte is served.
    Accept = 0,
    /// The request handler answers 503 (or stalls) before routing.
    Handler = 1,
    /// A report in a batch flush is delivered twice (duplicate delivery).
    BatchFlush = 2,
    /// A fleet push/pull cycle fails before reaching the leader.
    FleetSync = 3,
    /// A checkpoint file write fails (simulated I/O error).
    CheckpointWrite = 4,
}

/// Number of distinct [`FaultPoint`]s (sizes the per-point counters).
pub const FAULT_POINTS: usize = 5;

impl FaultPoint {
    pub fn code(self) -> u64 {
        self as u64
    }

    pub fn from_code(code: u64) -> Option<FaultPoint> {
        match code {
            0 => Some(FaultPoint::Accept),
            1 => Some(FaultPoint::Handler),
            2 => Some(FaultPoint::BatchFlush),
            3 => Some(FaultPoint::FleetSync),
            4 => Some(FaultPoint::CheckpointWrite),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::Accept => "accept",
            FaultPoint::Handler => "handler",
            FaultPoint::BatchFlush => "batch_flush",
            FaultPoint::FleetSync => "fleet_sync",
            FaultPoint::CheckpointWrite => "checkpoint_write",
        }
    }
}

/// Decoded name for a fault-point code from a trace event (`"unknown"`
/// for codes this build does not know).
pub fn fault_point_name(code: u64) -> &'static str {
    FaultPoint::from_code(code).map_or("unknown", FaultPoint::name)
}

/// What the handler fault point injects.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HandlerFault {
    /// Answer 503 before routing (the request never reaches a handler).
    Error,
    /// Stall the worker for the configured delay before routing.
    Delay(std::time::Duration),
}

/// The `[chaos]` config section: one seed plus a per-point probability.
/// All probabilities default to 0.0 — a config with only a seed is an
/// enabled-but-idle layer, useful for measuring the layer's own cost.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// PRNG seed; identical seeds inject identically over identical
    /// traffic orders.
    pub seed: u64,
    /// P(close a just-accepted connection).
    pub accept_drop: f64,
    /// P(answer 503 before routing a request).
    pub handler_error: f64,
    /// P(stall a request by `handler_delay_ms` before routing).
    pub handler_delay: f64,
    /// Injected handler stall, milliseconds.
    pub handler_delay_ms: u64,
    /// P(redeliver a report during a batch flush — duplicate delivery).
    pub flush_duplicate: f64,
    /// P(fail a fleet sync cycle before it reaches the leader).
    pub fleet_fail: f64,
    /// P(fail one checkpoint file write attempt).
    pub checkpoint_fail: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0xC0FFEE,
            accept_drop: 0.0,
            handler_error: 0.0,
            handler_delay: 0.0,
            handler_delay_ms: 5,
            flush_duplicate: 0.0,
            fleet_fail: 0.0,
            checkpoint_fail: 0.0,
        }
    }
}

impl ChaosConfig {
    /// Parse a `[chaos]` section from a TOML string (the config parser's
    /// TOML subset: scalar keys only).
    pub fn from_toml_str(text: &str) -> Result<ChaosConfig> {
        let doc = parse_toml(text).map_err(|e| anyhow!("chaos config parse: {e}"))?;
        let Some(section) = doc.get("chaos") else {
            return Err(anyhow!("chaos config has no [chaos] section"));
        };
        Self::from_section(section)
    }

    /// Build from an already-parsed `[chaos]` table (the `LaspConfig`
    /// loader hands its section here).
    pub fn from_section(
        section: &std::collections::BTreeMap<String, crate::config::TomlValue>,
    ) -> Result<ChaosConfig> {
        let mut cfg = ChaosConfig::default();
        if let Some(v) = section.get("seed") {
            let s = v.as_int().ok_or_else(|| anyhow!("chaos.seed must be an integer"))?;
            if s < 0 {
                return Err(anyhow!("chaos.seed must be non-negative, got {s}"));
            }
            cfg.seed = s as u64;
        }
        let mut prob = |key: &str, slot: &mut f64| -> Result<()> {
            if let Some(v) = section.get(key) {
                *slot = v
                    .as_float()
                    .ok_or_else(|| anyhow!("chaos.{key} must be a number"))?;
            }
            Ok(())
        };
        prob("accept_drop", &mut cfg.accept_drop)?;
        prob("handler_error", &mut cfg.handler_error)?;
        prob("handler_delay", &mut cfg.handler_delay)?;
        prob("flush_duplicate", &mut cfg.flush_duplicate)?;
        prob("fleet_fail", &mut cfg.fleet_fail)?;
        prob("checkpoint_fail", &mut cfg.checkpoint_fail)?;
        if let Some(v) = section.get("handler_delay_ms") {
            let ms = v
                .as_int()
                .ok_or_else(|| anyhow!("chaos.handler_delay_ms must be an integer"))?;
            if !(0..=10_000).contains(&ms) {
                return Err(anyhow!("chaos.handler_delay_ms must lie in 0..=10000, got {ms}"));
            }
            cfg.handler_delay_ms = ms as u64;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a chaos config file (`lasp serve --chaos <file>`).
    pub fn from_file(path: &std::path::Path) -> Result<ChaosConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml_str(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Every probability must be a valid probability.
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("accept_drop", self.accept_drop),
            ("handler_error", self.handler_error),
            ("handler_delay", self.handler_delay),
            ("flush_duplicate", self.flush_duplicate),
            ("fleet_fail", self.fleet_fail),
            ("checkpoint_fail", self.checkpoint_fail),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(anyhow!("chaos.{name} must lie in [0, 1], got {p}"));
            }
        }
        Ok(())
    }
}

/// The serve-side injection layer: one seeded PRNG behind a mutex (fault
/// points are spread across threads; injections are rare by construction),
/// per-point injection counters, and the flight recorder every injection
/// is logged through.
///
/// Probability-zero points short-circuit *before* the lock, so an
/// enabled-but-idle layer costs one branch per consultation and a fully
/// absent layer (`Option::None` at the call sites) costs nothing.
pub struct ChaosLayer {
    cfg: ChaosConfig,
    rng: Mutex<Rng>,
    injected: [AtomicU64; FAULT_POINTS],
    total: AtomicU64,
    recorder: Arc<Recorder>,
}

impl ChaosLayer {
    pub fn new(cfg: ChaosConfig, recorder: Arc<Recorder>) -> ChaosLayer {
        let rng = Mutex::new(Rng::new(cfg.seed));
        ChaosLayer {
            cfg,
            rng,
            injected: Default::default(),
            total: AtomicU64::new(0),
            recorder,
        }
    }

    pub fn config(&self) -> &ChaosConfig {
        &self.cfg
    }

    /// Draw once against probability `p`. `p == 0` never locks the RNG —
    /// the enabled-but-idle fast path.
    fn roll(&self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            return true;
        }
        let mut rng = match self.rng.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        rng.uniform() < p
    }

    /// Count and trace one injection. `arg` is point-specific context
    /// (shard, delay ms, attempt number) carried in the event's `c` word.
    fn inject(&self, point: FaultPoint, arg: u64) {
        self.injected[point as usize].fetch_add(1, Ordering::Relaxed);
        let nth = self.total.fetch_add(1, Ordering::Relaxed) + 1;
        self.recorder.record(EventKind::Chaos, point.code(), nth, arg);
    }

    /// Should this just-accepted connection be dropped?
    pub fn accept_drop(&self) -> bool {
        let hit = self.roll(self.cfg.accept_drop);
        if hit {
            self.inject(FaultPoint::Accept, 0);
        }
        hit
    }

    /// Should this request be faulted before routing, and how?
    /// Error wins over delay when both are configured and both fire.
    pub fn handler_fault(&self) -> Option<HandlerFault> {
        if self.roll(self.cfg.handler_error) {
            self.inject(FaultPoint::Handler, 0);
            return Some(HandlerFault::Error);
        }
        if self.roll(self.cfg.handler_delay) {
            self.inject(FaultPoint::Handler, self.cfg.handler_delay_ms);
            return Some(HandlerFault::Delay(std::time::Duration::from_millis(
                self.cfg.handler_delay_ms,
            )));
        }
        None
    }

    /// Should this report be redelivered during the flush (duplicate
    /// delivery)? `shard` travels in the trace event.
    pub fn flush_duplicate(&self, shard: usize) -> bool {
        let hit = self.roll(self.cfg.flush_duplicate);
        if hit {
            self.inject(FaultPoint::BatchFlush, shard as u64);
        }
        hit
    }

    /// Should this fleet sync cycle fail before reaching the leader?
    pub fn fleet_fail(&self) -> bool {
        let hit = self.roll(self.cfg.fleet_fail);
        if hit {
            self.inject(FaultPoint::FleetSync, 0);
        }
        hit
    }

    /// Should this checkpoint file write attempt fail? `attempt` (0-based)
    /// travels in the trace event.
    pub fn checkpoint_fail(&self, attempt: u64) -> bool {
        let hit = self.roll(self.cfg.checkpoint_fail);
        if hit {
            self.inject(FaultPoint::CheckpointWrite, attempt);
        }
        hit
    }

    /// Total injections so far (exported as
    /// `lasp_serve_chaos_injections_total`).
    pub fn injections(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Injections at one fault point.
    pub fn injections_at(&self, point: FaultPoint) -> u64 {
        self.injected[point as usize].load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for ChaosLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChaosLayer")
            .field("cfg", &self.cfg)
            .field("injections", &self.injections())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(cfg: ChaosConfig) -> ChaosLayer {
        ChaosLayer::new(cfg, Arc::new(Recorder::new(1, 256)))
    }

    #[test]
    fn parses_a_full_chaos_section() {
        let cfg = ChaosConfig::from_toml_str(
            r#"
            [chaos]
            seed = 99
            accept_drop = 0.1
            handler_error = 0.2
            handler_delay = 0.3
            handler_delay_ms = 7
            flush_duplicate = 0.4
            fleet_fail = 0.5
            checkpoint_fail = 0.6
            "#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.accept_drop, 0.1);
        assert_eq!(cfg.handler_delay_ms, 7);
        assert_eq!(cfg.checkpoint_fail, 0.6);
        // A bare section is the enabled-but-idle default.
        let idle = ChaosConfig::from_toml_str("[chaos]\nseed = 1\n").unwrap();
        assert_eq!(idle.accept_drop, 0.0);
        assert_eq!(idle.handler_delay_ms, 5);
    }

    #[test]
    fn rejects_malformed_chaos_configs() {
        assert!(ChaosConfig::from_toml_str("[serve]\nport = 1\n").is_err());
        assert!(ChaosConfig::from_toml_str("[chaos]\naccept_drop = 1.5\n").is_err());
        assert!(ChaosConfig::from_toml_str("[chaos]\naccept_drop = -0.1\n").is_err());
        assert!(ChaosConfig::from_toml_str("[chaos]\nseed = -3\n").is_err());
        assert!(ChaosConfig::from_toml_str("[chaos]\nhandler_delay_ms = 99999\n").is_err());
        assert!(ChaosConfig::from_toml_str("[chaos]\nfleet_fail = \"often\"\n").is_err());
    }

    #[test]
    fn injections_are_deterministic_per_seed() {
        let draws = |seed: u64| -> Vec<bool> {
            let l = layer(ChaosConfig { seed, accept_drop: 0.5, ..Default::default() });
            (0..64).map(|_| l.accept_drop()).collect()
        };
        assert_eq!(draws(7), draws(7));
        assert_ne!(draws(7), draws(8));
    }

    #[test]
    fn zero_probability_points_never_fire_and_certain_points_always_do() {
        let idle = layer(ChaosConfig { seed: 1, ..Default::default() });
        for _ in 0..100 {
            assert!(!idle.accept_drop());
            assert!(idle.handler_fault().is_none());
            assert!(!idle.flush_duplicate(0));
            assert!(!idle.fleet_fail());
            assert!(!idle.checkpoint_fail(0));
        }
        assert_eq!(idle.injections(), 0);

        let certain = layer(ChaosConfig {
            seed: 1,
            accept_drop: 1.0,
            handler_error: 1.0,
            ..Default::default()
        });
        assert!(certain.accept_drop());
        assert_eq!(certain.handler_fault(), Some(HandlerFault::Error));
        assert_eq!(certain.injections(), 2);
        assert_eq!(certain.injections_at(FaultPoint::Accept), 1);
        assert_eq!(certain.injections_at(FaultPoint::Handler), 1);
    }

    #[test]
    fn injections_are_traced_through_the_recorder() {
        let recorder = Arc::new(Recorder::new(1, 256));
        let l = ChaosLayer::new(
            ChaosConfig { seed: 3, checkpoint_fail: 1.0, ..Default::default() },
            recorder.clone(),
        );
        assert!(l.checkpoint_fail(2));
        let mut events = Vec::new();
        recorder.drain_since(0, &mut events);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind_name(), "chaos");
        assert_eq!(events[0].a, FaultPoint::CheckpointWrite.code());
        assert_eq!(events[0].c, 2);
        assert_eq!(fault_point_name(events[0].a), "checkpoint_write");
        assert_eq!(fault_point_name(999), "unknown");
    }

    #[test]
    fn fault_point_codes_roundtrip() {
        for p in [
            FaultPoint::Accept,
            FaultPoint::Handler,
            FaultPoint::BatchFlush,
            FaultPoint::FleetSync,
            FaultPoint::CheckpointWrite,
        ] {
            assert_eq!(FaultPoint::from_code(p.code()), Some(p));
        }
        assert_eq!(FaultPoint::from_code(5), None);
    }
}
