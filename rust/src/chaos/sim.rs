//! Sim-side delivery chaos: the episode-level fault model behind the
//! `churn@`, `dup@`, `zipf@`, `delay@` and `kill@` scenario events.
//!
//! The serve plane sees faults on real sockets; the sim plane models the
//! same failure class *between* a device run and the strategy's `observe`:
//! a measured report can be lost (session churn — the client vanished
//! mid-evaluation), duplicated (at-least-once delivery retries, optionally
//! with a Zipf-skewed duplicate tail modelling popularity-skewed retry
//! storms), or delayed by a bounded window (which reorders deliveries).
//! A `kill@i=j` outage stops the loop entirely for `[i, j)` and drops
//! everything in flight.
//!
//! Determinism: all draws come from one [`Rng`] seeded from the episode
//! spec, so a chaotic cell is as replayable as a clean one — bit-identical
//! at any sweep thread count (`rust/tests/chaos.rs` pins this).

use crate::device::Measurement;
use crate::util::Rng;

/// A report held in flight by the delay window.
#[derive(Debug, Clone, Copy)]
pub struct PendingReport {
    /// Iteration at which the report arrives.
    pub due: usize,
    pub arm: usize,
    pub fidelity: f64,
    pub m: Measurement,
}

/// Bounded Zipf(s) sampler over ranks `1..=n` (P(r) ∝ r^-s), used for
/// skewed duplicate-count draws: most reports get rank 1 (no extra
/// copies), a heavy-tailed few get rank 2+ (duplicate bursts).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(s: f64, n: usize) -> Zipf {
        assert!(s > 0.0 && n > 0, "Zipf needs s > 0 and n > 0");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 1..=n {
            acc += (r as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw a rank in `1..=n`.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        match self.cdf.iter().position(|&c| u < c) {
            Some(i) => i + 1,
            None => self.cdf.len(),
        }
    }
}

/// Max duplicate ranks the Zipf tail can draw (bounds worst-case copies).
const ZIPF_RANKS: usize = 16;

/// The per-episode delivery fault state. Created lazily by the episode the
/// first time a chaos event arms it — episodes without chaos events never
/// construct one (zero steady-state overhead for clean cells).
#[derive(Debug, Clone)]
pub struct DeliveryChaos {
    rng: Rng,
    /// P(a report is lost — the session churned away mid-evaluation).
    churn: f64,
    /// P(a delivered report is duplicated once).
    dup: f64,
    /// Zipf-skewed duplicate-count draw (rank − 1 extra copies).
    zipf: Option<Zipf>,
    /// Uniform 0..=window extra iterations of delivery delay (0 = off).
    delay_window: usize,
    buffer: Vec<PendingReport>,
}

impl DeliveryChaos {
    pub fn new(seed: u64) -> DeliveryChaos {
        DeliveryChaos {
            rng: Rng::new(seed),
            churn: 0.0,
            dup: 0.0,
            zipf: None,
            delay_window: 0,
            buffer: Vec::new(),
        }
    }

    pub fn set_churn(&mut self, p: f64) {
        self.churn = p;
    }

    pub fn set_dup(&mut self, p: f64) {
        self.dup = p;
    }

    /// `s <= 0` disables the Zipf duplicate tail.
    pub fn set_zipf(&mut self, s: f64) {
        self.zipf = (s > 0.0).then(|| Zipf::new(s, ZIPF_RANKS));
    }

    pub fn set_delay(&mut self, window: usize) {
        self.delay_window = window;
    }

    /// Reports in the delay buffer (undelivered).
    pub fn in_flight(&self) -> usize {
        self.buffer.len()
    }

    /// Drop everything in flight (a killed node loses its outstanding
    /// reports with it).
    pub fn clear_in_flight(&mut self) {
        self.buffer.clear();
    }

    /// Route one freshly measured report at iteration `t`: decide loss and
    /// duplication, then either deliver now or buffer delayed copies.
    pub fn route(
        &mut self,
        t: usize,
        arm: usize,
        fidelity: f64,
        m: Measurement,
        deliver: &mut dyn FnMut(usize, f64, Measurement),
    ) {
        if self.churn > 0.0 && self.rng.uniform() < self.churn {
            return; // lost: the client vanished before reporting
        }
        let mut copies = 1usize;
        if self.dup > 0.0 && self.rng.uniform() < self.dup {
            copies += 1;
        }
        if let Some(z) = &self.zipf {
            copies += z.draw(&mut self.rng) - 1;
        }
        for _ in 0..copies {
            if self.delay_window > 0 {
                let due = t + 1 + self.rng.below(self.delay_window as u64 + 1) as usize;
                self.buffer.push(PendingReport { due, arm, fidelity, m });
            } else {
                deliver(arm, fidelity, m);
            }
        }
    }

    /// Deliver every buffered report due at or before `t`, in arrival
    /// order (two reports with different draws swap — delivery reorder).
    pub fn deliver_due(&mut self, t: usize, deliver: &mut dyn FnMut(usize, f64, Measurement)) {
        let mut i = 0;
        while i < self.buffer.len() {
            if self.buffer[i].due <= t {
                let p = self.buffer.remove(i);
                deliver(p.arm, p.fidelity, p.m);
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(time_s: f64) -> Measurement {
        Measurement { time_s, power_w: 5.0 }
    }

    fn collect(chaos: &mut DeliveryChaos, t: usize, arm: usize) -> Vec<usize> {
        let mut out = Vec::new();
        chaos.route(t, arm, 0.15, m(1.0), &mut |a, _, _| out.push(a));
        out
    }

    #[test]
    fn zipf_is_rank_one_heavy_and_deterministic() {
        let z = Zipf::new(1.2, ZIPF_RANKS);
        let mut rng = Rng::new(11);
        let draws: Vec<usize> = (0..2000).map(|_| z.draw(&mut rng)).collect();
        assert!(draws.iter().all(|&r| (1..=ZIPF_RANKS).contains(&r)));
        let ones = draws.iter().filter(|&&r| r == 1).count();
        // Rank 1 dominates a Zipf(1.2) head.
        assert!(ones > draws.len() / 3, "rank-1 share too small: {ones}/{}", draws.len());
        assert!(draws.iter().any(|&r| r > 1), "tail never fired");
        let mut rng2 = Rng::new(11);
        let again: Vec<usize> = (0..2000).map(|_| z.draw(&mut rng2)).collect();
        assert_eq!(draws, again);
    }

    #[test]
    fn churn_drops_and_dup_duplicates() {
        let mut c = DeliveryChaos::new(5);
        c.set_churn(1.0);
        assert!(collect(&mut c, 0, 3).is_empty());
        let mut c = DeliveryChaos::new(5);
        c.set_dup(1.0);
        assert_eq!(collect(&mut c, 0, 3), vec![3, 3]);
        // Probabilistic churn loses some but not all.
        let mut c = DeliveryChaos::new(5);
        c.set_churn(0.4);
        let delivered: usize = (0..500).map(|t| collect(&mut c, t, 1).len()).sum();
        assert!(delivered > 200 && delivered < 400, "delivered {delivered}/500");
    }

    #[test]
    fn delay_buffers_and_reorders() {
        let mut c = DeliveryChaos::new(9);
        c.set_delay(6);
        for t in 0..20 {
            assert!(collect(&mut c, t, t).is_empty(), "delayed report delivered early");
        }
        assert_eq!(c.in_flight(), 20);
        let mut order = Vec::new();
        for t in 20..40 {
            c.deliver_due(t, &mut |a, _, _| order.push(a));
        }
        assert_eq!(c.in_flight(), 0);
        assert_eq!(order.len(), 20);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(order, sorted, "a 6-wide delay window should reorder");
        // A kill drops everything in flight.
        let mut c = DeliveryChaos::new(9);
        c.set_delay(6);
        let _ = collect(&mut c, 0, 0);
        assert_eq!(c.in_flight(), 1);
        c.clear_in_flight();
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn routing_is_deterministic_per_seed() {
        let run = |seed: u64| -> Vec<usize> {
            let mut c = DeliveryChaos::new(seed);
            c.set_churn(0.2);
            c.set_dup(0.3);
            c.set_zipf(1.5);
            c.set_delay(4);
            let mut out = Vec::new();
            for t in 0..100 {
                c.deliver_due(t, &mut |a, _, _| out.push(a));
                c.route(t, t, 0.15, m(1.0), &mut |a, _, _| out.push(a));
            }
            out
        };
        assert_eq!(run(21), run(21));
        assert_ne!(run(21), run(22));
    }
}
