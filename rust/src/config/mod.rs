//! Configuration system: a TOML-subset parser (offline build — no external
//! crates) and the typed [`LaspConfig`] the CLI and examples consume.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! integer, float and boolean values, `#` comments. That covers every knob
//! this system exposes; nested tables/arrays are intentionally rejected.

mod toml_mini;

pub use toml_mini::{parse_toml, TomlValue};

use crate::apps::AppKind;
use crate::device::{NoiseModel, PowerMode};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Which scoring backend the tuner uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust scalar math.
    Scalar,
    /// AOT PJRT artifacts (requires `make artifacts`).
    Pjrt,
}

impl std::str::FromStr for Backend {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(Backend::Scalar),
            "pjrt" => Ok(Backend::Pjrt),
            other => Err(anyhow!("unknown backend '{other}' (scalar|pjrt)")),
        }
    }
}

/// Full run configuration (CLI flags override file values).
#[derive(Debug, Clone)]
pub struct LaspConfig {
    // [tune]
    pub app: AppKind,
    pub iterations: usize,
    pub alpha: f64,
    pub beta: f64,
    pub seed: u64,
    pub backend: Backend,
    // [device]
    pub mode: PowerMode,
    pub fidelity: f64,
    /// Injected synthetic measurement error percentage (0.0-1.0).
    pub noise_pct: f64,
    // [fleet]
    pub devices: usize,
    pub loss_prob: f64,
    pub latency_s: f64,
}

impl Default for LaspConfig {
    fn default() -> Self {
        LaspConfig {
            app: AppKind::Kripke,
            iterations: 500,
            alpha: 0.8,
            beta: 0.2,
            seed: 42,
            backend: Backend::Scalar,
            mode: PowerMode::Maxn,
            fidelity: 0.15,
            noise_pct: 0.0,
            devices: 2,
            loss_prob: 0.0,
            latency_s: 0.0,
        }
    }
}

impl LaspConfig {
    /// Load from a TOML file, with defaults for anything unspecified.
    pub fn from_file(path: &Path) -> Result<LaspConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<LaspConfig> {
        let doc = parse_toml(text).map_err(|e| anyhow!("config parse: {e}"))?;
        let mut cfg = LaspConfig::default();

        let get = |section: &str, key: &str| -> Option<&TomlValue> {
            doc.get(section).and_then(|s| s.get(key))
        };
        if let Some(v) = get("tune", "app") {
            cfg.app = v.as_str().ok_or_else(|| anyhow!("tune.app must be a string"))?.parse()?;
        }
        if let Some(v) = get("tune", "iterations") {
            cfg.iterations = v.as_int().ok_or_else(|| anyhow!("tune.iterations must be int"))? as usize;
        }
        if let Some(v) = get("tune", "alpha") {
            cfg.alpha = v.as_float().ok_or_else(|| anyhow!("tune.alpha must be number"))?;
        }
        if let Some(v) = get("tune", "beta") {
            cfg.beta = v.as_float().ok_or_else(|| anyhow!("tune.beta must be number"))?;
        }
        if let Some(v) = get("tune", "seed") {
            cfg.seed = v.as_int().ok_or_else(|| anyhow!("tune.seed must be int"))? as u64;
        }
        if let Some(v) = get("tune", "backend") {
            cfg.backend = v.as_str().ok_or_else(|| anyhow!("tune.backend must be string"))?.parse()?;
        }
        if let Some(v) = get("device", "mode") {
            cfg.mode = v.as_str().ok_or_else(|| anyhow!("device.mode must be string"))?.parse()?;
        }
        if let Some(v) = get("device", "fidelity") {
            cfg.fidelity = v.as_float().ok_or_else(|| anyhow!("device.fidelity must be number"))?;
        }
        if let Some(v) = get("device", "noise_pct") {
            cfg.noise_pct = v.as_float().ok_or_else(|| anyhow!("device.noise_pct must be number"))?;
        }
        if let Some(v) = get("fleet", "devices") {
            cfg.devices = v.as_int().ok_or_else(|| anyhow!("fleet.devices must be int"))? as usize;
        }
        if let Some(v) = get("fleet", "loss_prob") {
            cfg.loss_prob = v.as_float().ok_or_else(|| anyhow!("fleet.loss_prob must be number"))?;
        }
        if let Some(v) = get("fleet", "latency_s") {
            cfg.latency_s = v.as_float().ok_or_else(|| anyhow!("fleet.latency_s must be number"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check ranges.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.alpha) || !(0.0..=1.0).contains(&self.beta) {
            return Err(anyhow!("alpha/beta must lie in [0, 1]"));
        }
        if self.alpha + self.beta == 0.0 {
            return Err(anyhow!("alpha + beta must be positive"));
        }
        if !(0.0..=1.0).contains(&self.fidelity) {
            return Err(anyhow!("fidelity must lie in [0, 1]"));
        }
        if !(0.0..1.0).contains(&self.loss_prob) {
            return Err(anyhow!("loss_prob must lie in [0, 1)"));
        }
        if self.iterations == 0 || self.devices == 0 {
            return Err(anyhow!("iterations and devices must be positive"));
        }
        Ok(())
    }

    /// The injected-noise model from `noise_pct`.
    pub fn noise(&self) -> NoiseModel {
        if self.noise_pct > 0.0 {
            NoiseModel::uniform(self.noise_pct)
        } else {
            NoiseModel::none()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        LaspConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let cfg = LaspConfig::from_toml_str(
            r#"
            # LASP experiment
            [tune]
            app = "hypre"
            iterations = 1000
            alpha = 0.2
            beta = 0.8
            seed = 7
            backend = "pjrt"

            [device]
            mode = "5w"
            fidelity = 0.3
            noise_pct = 0.10

            [fleet]
            devices = 4
            loss_prob = 0.05
            latency_s = 0.02
            "#,
        )
        .unwrap();
        assert_eq!(cfg.app, AppKind::Hypre);
        assert_eq!(cfg.iterations, 1000);
        assert_eq!(cfg.backend, Backend::Pjrt);
        assert_eq!(cfg.mode, PowerMode::FiveW);
        assert_eq!(cfg.devices, 4);
        assert!((cfg.noise_pct - 0.10).abs() < 1e-12);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let cfg = LaspConfig::from_toml_str("[tune]\napp = \"clomp\"\n").unwrap();
        assert_eq!(cfg.app, AppKind::Clomp);
        assert_eq!(cfg.iterations, LaspConfig::default().iterations);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(LaspConfig::from_toml_str("[tune]\nalpha = 2.0\n").is_err());
        assert!(LaspConfig::from_toml_str("[tune]\napp = \"nope\"\n").is_err());
        assert!(LaspConfig::from_toml_str("[tune]\niterations = 0\n").is_err());
        assert!(LaspConfig::from_toml_str("[tune]\nalpha = 0.0\nbeta = 0.0\n").is_err());
    }

    #[test]
    fn noise_model_from_pct() {
        let mut cfg = LaspConfig::default();
        assert_eq!(cfg.noise(), NoiseModel::none());
        cfg.noise_pct = 0.15;
        assert_eq!(cfg.noise(), NoiseModel::uniform(0.15));
    }
}
