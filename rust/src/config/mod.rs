//! Configuration system: a TOML-subset parser (offline build — no external
//! crates) and the typed [`LaspConfig`] the CLI and examples consume.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! integer, float and boolean values, `#` comments. That covers every knob
//! this system exposes; nested tables/arrays are intentionally rejected.

mod toml_mini;

pub use toml_mini::{parse_toml, TomlValue};

use crate::apps::AppKind;
use crate::device::{NoiseModel, PowerMode};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Which scoring backend the tuner uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust scalar math.
    Scalar,
    /// AOT PJRT artifacts (requires `make artifacts`).
    Pjrt,
}

impl std::str::FromStr for Backend {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(Backend::Scalar),
            "pjrt" => Ok(Backend::Pjrt),
            other => Err(anyhow!("unknown backend '{other}' (scalar|pjrt)")),
        }
    }
}

/// Full run configuration (CLI flags override file values).
#[derive(Debug, Clone)]
pub struct LaspConfig {
    // [tune]
    pub app: AppKind,
    pub iterations: usize,
    pub alpha: f64,
    pub beta: f64,
    pub seed: u64,
    pub backend: Backend,
    // [device]
    pub mode: PowerMode,
    pub fidelity: f64,
    /// Injected synthetic measurement error percentage (0.0-1.0).
    pub noise_pct: f64,
    // [fleet] — the simulated in-process fleet (`lasp fleet`) ...
    pub devices: usize,
    pub loss_prob: f64,
    pub latency_s: f64,
    // ... and the networked sync plane (`lasp serve --leader`).
    /// Leader address to push/pull fleet state against (None = standalone).
    pub fleet_leader: Option<String>,
    /// Stable node identity on the sync wire (None = derived from addr).
    pub fleet_node_id: Option<String>,
    /// Seconds between fleet push/pull cycles.
    pub fleet_sync_secs: f64,
    /// Retention (0, 1] applied when warm-starting from a fleet prior.
    pub fleet_retain: f64,
    /// Half-life (seconds) for time-decaying fleet evidence.
    pub fleet_half_life_secs: f64,
    // [serve]
    pub serve_port: u16,
    pub serve_workers: usize,
    /// Reactor event loops; 0 = auto (one per core).
    pub serve_event_loops: usize,
    /// Session-store shards; 0 = auto (track the event-loop count so
    /// the routed plane's ownership map tiles evenly).
    pub serve_shards: usize,
    pub serve_queue_cap: usize,
    pub serve_batch: usize,
    pub serve_checkpoint_dir: Option<String>,
    pub serve_checkpoint_secs: f64,
    pub serve_retain: f64,
    // [chaos] — deterministic fault injection for the serve plane
    // (`lasp serve --chaos <file>` loads a standalone file; a `[chaos]`
    // section in the main config works too). None = no chaos code runs.
    pub chaos: Option<crate::chaos::ChaosConfig>,
}

impl Default for LaspConfig {
    fn default() -> Self {
        LaspConfig {
            app: AppKind::Kripke,
            iterations: 500,
            alpha: 0.8,
            beta: 0.2,
            seed: 42,
            backend: Backend::Scalar,
            mode: PowerMode::Maxn,
            fidelity: 0.15,
            noise_pct: 0.0,
            devices: 2,
            loss_prob: 0.0,
            latency_s: 0.0,
            fleet_leader: None,
            fleet_node_id: None,
            fleet_sync_secs: 10.0,
            fleet_retain: 0.3,
            fleet_half_life_secs: 600.0,
            serve_port: 8787,
            serve_workers: 8,
            serve_event_loops: 0,
            serve_shards: 0,
            serve_queue_cap: 4096,
            serve_batch: 128,
            serve_checkpoint_dir: None,
            serve_checkpoint_secs: 30.0,
            serve_retain: 0.5,
            chaos: None,
        }
    }
}

impl LaspConfig {
    /// Load from a TOML file, with defaults for anything unspecified.
    pub fn from_file(path: &Path) -> Result<LaspConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<LaspConfig> {
        let doc = parse_toml(text).map_err(|e| anyhow!("config parse: {e}"))?;
        let mut cfg = LaspConfig::default();

        let get = |section: &str, key: &str| -> Option<&TomlValue> {
            doc.get(section).and_then(|s| s.get(key))
        };
        if let Some(v) = get("tune", "app") {
            cfg.app = v.as_str().ok_or_else(|| anyhow!("tune.app must be a string"))?.parse()?;
        }
        if let Some(v) = get("tune", "iterations") {
            cfg.iterations = v.as_int().ok_or_else(|| anyhow!("tune.iterations must be int"))? as usize;
        }
        if let Some(v) = get("tune", "alpha") {
            cfg.alpha = v.as_float().ok_or_else(|| anyhow!("tune.alpha must be number"))?;
        }
        if let Some(v) = get("tune", "beta") {
            cfg.beta = v.as_float().ok_or_else(|| anyhow!("tune.beta must be number"))?;
        }
        if let Some(v) = get("tune", "seed") {
            cfg.seed = v.as_int().ok_or_else(|| anyhow!("tune.seed must be int"))? as u64;
        }
        if let Some(v) = get("tune", "backend") {
            cfg.backend = v.as_str().ok_or_else(|| anyhow!("tune.backend must be string"))?.parse()?;
        }
        if let Some(v) = get("device", "mode") {
            cfg.mode = v.as_str().ok_or_else(|| anyhow!("device.mode must be string"))?.parse()?;
        }
        if let Some(v) = get("device", "fidelity") {
            cfg.fidelity = v.as_float().ok_or_else(|| anyhow!("device.fidelity must be number"))?;
        }
        if let Some(v) = get("device", "noise_pct") {
            cfg.noise_pct = v.as_float().ok_or_else(|| anyhow!("device.noise_pct must be number"))?;
        }
        if let Some(v) = get("fleet", "devices") {
            cfg.devices = v.as_int().ok_or_else(|| anyhow!("fleet.devices must be int"))? as usize;
        }
        if let Some(v) = get("fleet", "loss_prob") {
            cfg.loss_prob = v.as_float().ok_or_else(|| anyhow!("fleet.loss_prob must be number"))?;
        }
        if let Some(v) = get("fleet", "latency_s") {
            cfg.latency_s = v.as_float().ok_or_else(|| anyhow!("fleet.latency_s must be number"))?;
        }
        if let Some(v) = get("fleet", "leader") {
            cfg.fleet_leader = Some(
                v.as_str()
                    .ok_or_else(|| anyhow!("fleet.leader must be a string"))?
                    .to_string(),
            );
        }
        if let Some(v) = get("fleet", "node_id") {
            cfg.fleet_node_id = Some(
                v.as_str()
                    .ok_or_else(|| anyhow!("fleet.node_id must be a string"))?
                    .to_string(),
            );
        }
        if let Some(v) = get("fleet", "sync_secs") {
            cfg.fleet_sync_secs = v
                .as_float()
                .ok_or_else(|| anyhow!("fleet.sync_secs must be number"))?;
        }
        if let Some(v) = get("fleet", "retain") {
            cfg.fleet_retain =
                v.as_float().ok_or_else(|| anyhow!("fleet.retain must be number"))?;
        }
        if let Some(v) = get("fleet", "half_life_secs") {
            cfg.fleet_half_life_secs = v
                .as_float()
                .ok_or_else(|| anyhow!("fleet.half_life_secs must be number"))?;
        }
        // Checked integer conversion: TOML values are i64, and a plain
        // `as usize` would wrap negatives into huge counts.
        let pos_count = |section: &str, key: &str, v: &TomlValue| -> Result<usize> {
            let i = v.as_int().ok_or_else(|| anyhow!("{section}.{key} must be int"))?;
            if !(1..=1_000_000).contains(&i) {
                return Err(anyhow!("{section}.{key} must lie in 1..=1000000, got {i}"));
            }
            Ok(i as usize)
        };
        if let Some(v) = get("serve", "port") {
            let i = v.as_int().ok_or_else(|| anyhow!("serve.port must be int"))?;
            if !(0..=65_535).contains(&i) {
                return Err(anyhow!("serve.port must lie in 0..=65535, got {i}"));
            }
            cfg.serve_port = i as u16;
        }
        if let Some(v) = get("serve", "workers") {
            cfg.serve_workers = pos_count("serve", "workers", v)?;
        }
        if let Some(v) = get("serve", "event_loops") {
            // Unlike the other counts, 0 is meaningful here: auto-size to
            // one event loop per core.
            let i = v.as_int().ok_or_else(|| anyhow!("serve.event_loops must be int"))?;
            if !(0..=1_000_000).contains(&i) {
                return Err(anyhow!("serve.event_loops must lie in 0..=1000000, got {i}"));
            }
            cfg.serve_event_loops = i as usize;
        }
        if let Some(v) = get("serve", "shards") {
            cfg.serve_shards = pos_count("serve", "shards", v)?;
        }
        if let Some(v) = get("serve", "queue_cap") {
            cfg.serve_queue_cap = pos_count("serve", "queue_cap", v)?;
        }
        if let Some(v) = get("serve", "batch") {
            cfg.serve_batch = pos_count("serve", "batch", v)?;
        }
        if let Some(v) = get("serve", "checkpoint_dir") {
            cfg.serve_checkpoint_dir = Some(
                v.as_str()
                    .ok_or_else(|| anyhow!("serve.checkpoint_dir must be a string"))?
                    .to_string(),
            );
        }
        if let Some(v) = get("serve", "checkpoint_secs") {
            cfg.serve_checkpoint_secs = v
                .as_float()
                .ok_or_else(|| anyhow!("serve.checkpoint_secs must be number"))?;
        }
        if let Some(v) = get("serve", "retain") {
            cfg.serve_retain =
                v.as_float().ok_or_else(|| anyhow!("serve.retain must be number"))?;
        }
        if let Some(section) = doc.get("chaos") {
            cfg.chaos = Some(crate::chaos::ChaosConfig::from_section(section)?);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check ranges.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.alpha) || !(0.0..=1.0).contains(&self.beta) {
            return Err(anyhow!("alpha/beta must lie in [0, 1]"));
        }
        if self.alpha + self.beta == 0.0 {
            return Err(anyhow!("alpha + beta must be positive"));
        }
        if !(0.0..=1.0).contains(&self.fidelity) {
            return Err(anyhow!("fidelity must lie in [0, 1]"));
        }
        if !(0.0..1.0).contains(&self.loss_prob) {
            return Err(anyhow!("loss_prob must lie in [0, 1)"));
        }
        if self.iterations == 0 || self.devices == 0 {
            return Err(anyhow!("iterations and devices must be positive"));
        }
        // Guard before serve_config(): Duration::from_secs_f64 panics on
        // negative/non-finite input.
        if !(self.serve_checkpoint_secs.is_finite() && self.serve_checkpoint_secs > 0.0) {
            return Err(anyhow!("serve.checkpoint_secs must be positive"));
        }
        if !(self.fleet_sync_secs.is_finite() && self.fleet_sync_secs > 0.0) {
            return Err(anyhow!("fleet.sync_secs must be positive"));
        }
        if !(self.fleet_half_life_secs.is_finite() && self.fleet_half_life_secs > 0.0) {
            return Err(anyhow!("fleet.half_life_secs must be positive"));
        }
        // Single source of truth for the remaining serve rules.
        self.serve_config().validate()?;
        Ok(())
    }

    /// The serve-layer configuration view of this config.
    pub fn serve_config(&self) -> crate::serve::ServeConfig {
        crate::serve::ServeConfig {
            addr: format!("127.0.0.1:{}", self.serve_port),
            workers: self.serve_workers,
            event_loops: self.serve_event_loops,
            transport: crate::serve::transport::default_kind(),
            shards: self.serve_shards,
            queue_cap: self.serve_queue_cap,
            max_batch: self.serve_batch,
            checkpoint_dir: self.serve_checkpoint_dir.as_ref().map(std::path::PathBuf::from),
            checkpoint_every: std::time::Duration::from_secs_f64(self.serve_checkpoint_secs),
            warm_retain: self.serve_retain,
            leader: self.fleet_leader.clone(),
            node_id: self.fleet_node_id.clone(),
            sync_every: std::time::Duration::from_secs_f64(self.fleet_sync_secs),
            fleet_retain: self.fleet_retain,
            fleet_half_life: std::time::Duration::from_secs_f64(self.fleet_half_life_secs),
            trace_file: None,
            chaos: self.chaos.clone(),
        }
    }

    /// The injected-noise model from `noise_pct`.
    pub fn noise(&self) -> NoiseModel {
        if self.noise_pct > 0.0 {
            NoiseModel::uniform(self.noise_pct)
        } else {
            NoiseModel::none()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        LaspConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let cfg = LaspConfig::from_toml_str(
            r#"
            # LASP experiment
            [tune]
            app = "hypre"
            iterations = 1000
            alpha = 0.2
            beta = 0.8
            seed = 7
            backend = "pjrt"

            [device]
            mode = "5w"
            fidelity = 0.3
            noise_pct = 0.10

            [fleet]
            devices = 4
            loss_prob = 0.05
            latency_s = 0.02
            "#,
        )
        .unwrap();
        assert_eq!(cfg.app, AppKind::Hypre);
        assert_eq!(cfg.iterations, 1000);
        assert_eq!(cfg.backend, Backend::Pjrt);
        assert_eq!(cfg.mode, PowerMode::FiveW);
        assert_eq!(cfg.devices, 4);
        assert!((cfg.noise_pct - 0.10).abs() < 1e-12);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let cfg = LaspConfig::from_toml_str("[tune]\napp = \"clomp\"\n").unwrap();
        assert_eq!(cfg.app, AppKind::Clomp);
        assert_eq!(cfg.iterations, LaspConfig::default().iterations);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(LaspConfig::from_toml_str("[tune]\nalpha = 2.0\n").is_err());
        assert!(LaspConfig::from_toml_str("[tune]\napp = \"nope\"\n").is_err());
        assert!(LaspConfig::from_toml_str("[tune]\niterations = 0\n").is_err());
        assert!(LaspConfig::from_toml_str("[tune]\nalpha = 0.0\nbeta = 0.0\n").is_err());
    }

    #[test]
    fn parses_serve_section() {
        let cfg = LaspConfig::from_toml_str(
            r#"
            [serve]
            port = 9999
            workers = 4
            shards = 16
            queue_cap = 512
            batch = 64
            checkpoint_dir = "/tmp/lasp-ckpt"
            checkpoint_secs = 5.0
            retain = 0.25
            "#,
        )
        .unwrap();
        assert_eq!(cfg.serve_port, 9999);
        assert_eq!(cfg.serve_workers, 4);
        assert_eq!(cfg.serve_shards, 16);
        assert_eq!(cfg.serve_queue_cap, 512);
        assert_eq!(cfg.serve_batch, 64);
        assert_eq!(cfg.serve_checkpoint_dir.as_deref(), Some("/tmp/lasp-ckpt"));
        assert!((cfg.serve_checkpoint_secs - 5.0).abs() < 1e-12);
        assert!((cfg.serve_retain - 0.25).abs() < 1e-12);
        let sc = cfg.serve_config();
        assert_eq!(sc.addr, "127.0.0.1:9999");
        assert_eq!(sc.shards, 16);
        assert_eq!(sc.checkpoint_every, std::time::Duration::from_secs(5));
    }

    #[test]
    fn parses_fleet_sync_section() {
        let cfg = LaspConfig::from_toml_str(
            r#"
            [fleet]
            devices = 3
            leader = "10.0.0.7:8787"
            node_id = "edge-a"
            sync_secs = 2.5
            retain = 0.4
            half_life_secs = 120.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.devices, 3);
        assert_eq!(cfg.fleet_leader.as_deref(), Some("10.0.0.7:8787"));
        assert_eq!(cfg.fleet_node_id.as_deref(), Some("edge-a"));
        assert!((cfg.fleet_sync_secs - 2.5).abs() < 1e-12);
        assert!((cfg.fleet_retain - 0.4).abs() < 1e-12);
        assert!((cfg.fleet_half_life_secs - 120.0).abs() < 1e-12);
        let sc = cfg.serve_config();
        assert_eq!(sc.leader.as_deref(), Some("10.0.0.7:8787"));
        assert_eq!(sc.node_id.as_deref(), Some("edge-a"));
        assert_eq!(sc.sync_every, std::time::Duration::from_millis(2500));
        assert!((sc.fleet_retain - 0.4).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_fleet_sync_values() {
        assert!(LaspConfig::from_toml_str("[fleet]\nsync_secs = 0\n").is_err());
        assert!(LaspConfig::from_toml_str("[fleet]\nsync_secs = -2\n").is_err());
        assert!(LaspConfig::from_toml_str("[fleet]\nretain = 0.0\n").is_err());
        assert!(LaspConfig::from_toml_str("[fleet]\nretain = 1.5\n").is_err());
        assert!(LaspConfig::from_toml_str("[fleet]\nhalf_life_secs = 0\n").is_err());
        assert!(LaspConfig::from_toml_str("[fleet]\nleader = \"\"\n").is_err());
        assert!(LaspConfig::from_toml_str("[fleet]\nleader = 12\n").is_err());
    }

    #[test]
    fn rejects_bad_serve_values() {
        assert!(LaspConfig::from_toml_str("[serve]\nshards = 0\n").is_err());
        assert!(LaspConfig::from_toml_str("[serve]\nretain = 0.0\n").is_err());
        assert!(LaspConfig::from_toml_str("[serve]\nretain = 1.5\n").is_err());
        assert!(LaspConfig::from_toml_str("[serve]\ncheckpoint_secs = 0\n").is_err());
        // Negative/oversized integers must error, not wrap through `as`.
        assert!(LaspConfig::from_toml_str("[serve]\nworkers = -1\n").is_err());
        assert!(LaspConfig::from_toml_str("[serve]\nport = 65536\n").is_err());
        assert!(LaspConfig::from_toml_str("[serve]\nport = -1\n").is_err());
    }

    #[test]
    fn parses_chaos_section() {
        let cfg = LaspConfig::from_toml_str(
            r#"
            [chaos]
            seed = 99
            handler_error = 0.05
            fleet_fail = 0.5
            "#,
        )
        .unwrap();
        let chaos = cfg.chaos.expect("chaos section parsed");
        assert_eq!(chaos.seed, 99);
        assert!((chaos.handler_error - 0.05).abs() < 1e-12);
        assert!((chaos.fleet_fail - 0.5).abs() < 1e-12);
        assert_eq!(cfg.serve_config().chaos, Some(chaos));
        // No [chaos] section ⇒ the layer stays off entirely.
        assert!(LaspConfig::from_toml_str("[tune]\napp = \"clomp\"\n").unwrap().chaos.is_none());
    }

    #[test]
    fn rejects_bad_chaos_values() {
        assert!(LaspConfig::from_toml_str("[chaos]\nhandler_error = 1.5\n").is_err());
        assert!(LaspConfig::from_toml_str("[chaos]\naccept_drop = -0.1\n").is_err());
        assert!(LaspConfig::from_toml_str("[chaos]\nseed = -1\n").is_err());
    }

    #[test]
    fn noise_model_from_pct() {
        let mut cfg = LaspConfig::default();
        assert_eq!(cfg.noise(), NoiseModel::none());
        cfg.noise_pct = 0.15;
        assert_eq!(cfg.noise(), NoiseModel::uniform(0.15));
    }
}
