//! TOML-subset parser: `[section]`, `key = value`, `#` comments.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`alpha = 1` is fine).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section -> key -> value. Keys before any `[section]`
/// land in the `""` section.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse the TOML subset. Errors carry the 1-based line number.
pub fn parse_toml(text: &str) -> Result<TomlDoc, String> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() || name.contains('[') || name.contains('.') {
                return Err(format!("line {}: unsupported section '{name}'", lineno + 1));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.entry(section.clone())
            .or_default()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue, String> {
    if v.is_empty() {
        return Err("empty value".into());
    }
    if let Some(stripped) = v.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if v.starts_with('[') {
        return Err("arrays are not supported in this subset".into());
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{v}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            "top = 1\n[a]\nx = \"s\"\ny = 2\nz = 2.5\nw = true\n[b]\nq = false\n",
        )
        .unwrap();
        assert_eq!(doc[""]["top"], TomlValue::Int(1));
        assert_eq!(doc["a"]["x"], TomlValue::Str("s".into()));
        assert_eq!(doc["a"]["y"], TomlValue::Int(2));
        assert_eq!(doc["a"]["z"], TomlValue::Float(2.5));
        assert_eq!(doc["a"]["w"], TomlValue::Bool(true));
        assert_eq!(doc["b"]["q"], TomlValue::Bool(false));
    }

    #[test]
    fn comments_stripped_but_not_in_strings() {
        let doc = parse_toml("[s]\na = 1 # trailing\nb = \"x # y\"\n").unwrap();
        assert_eq!(doc["s"]["a"], TomlValue::Int(1));
        assert_eq!(doc["s"]["b"], TomlValue::Str("x # y".into()));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_toml("[s]\nbad line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = parse_toml("[unterminated\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn rejects_unsupported_constructs() {
        assert!(parse_toml("[a.b]\n").is_err());
        assert!(parse_toml("x = [1, 2]\n").is_err());
        assert!(parse_toml("x = \"unterminated\n").is_err());
    }

    #[test]
    fn float_coercion() {
        assert_eq!(TomlValue::Int(3).as_float(), Some(3.0));
        assert_eq!(TomlValue::Str("s".into()).as_float(), None);
    }

    #[test]
    fn later_values_override() {
        let doc = parse_toml("[s]\na = 1\na = 2\n").unwrap();
        assert_eq!(doc["s"]["a"], TomlValue::Int(2));
    }
}
