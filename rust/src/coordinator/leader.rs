//! The fleet leader: device registry, job queue, least-loaded dispatch,
//! result collection, and loss-tolerant bookkeeping.

use super::messages::{LinkSim, Message};
use super::worker::{DeviceWorker, WorkerConfig};
use crate::apps::AppKind;
use crate::device::{NoiseModel, PowerMode};
use crate::runtime::EngineHandle;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Relative speed score of a power mode (freq × cores, normalized to 5W).
fn mode_speed(mode: PowerMode) -> f64 {
    let s = mode.spec();
    (s.freq_ghz * s.cores as f64) / (0.918 * 2.0)
}

/// Job weight: iterations × log-ish space size (arm count drives both the
/// per-iteration scoring cost and the simulated application runtime mix).
fn job_weight(job: &TuneJob) -> f64 {
    let k = crate::apps::build(job.app).space().len() as f64;
    job.iterations as f64 * k.ln()
}

/// Jobs above this weight prefer the fastest idle device
/// (500 iterations × ln(216) ≈ 2.7k; Hypre-sized campaigns ≈ 5.7k).
const HEAVY_JOB_WEIGHT: f64 = 4000.0;

/// A tuning job submitted to the fleet.
#[derive(Debug, Clone)]
pub struct TuneJob {
    pub app: AppKind,
    pub iterations: usize,
    pub alpha: f64,
    pub beta: f64,
}

/// Completed job record.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job_id: u64,
    pub device_id: u32,
    pub app: AppKind,
    pub best_index: usize,
    pub pulls_of_best: f64,
    pub tuner_wall_seconds: f64,
    pub simulated_device_seconds: f64,
}

/// Fleet-wide configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub devices: usize,
    /// Power mode per device (cycled if shorter than `devices`).
    pub modes: Vec<PowerMode>,
    pub seed: u64,
    pub fidelity: f64,
    /// Link quality between leader and devices.
    pub loss_prob: f64,
    pub mean_latency_s: f64,
    pub injected_noise: NoiseModel,
    pub progress_every: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            devices: 2,
            modes: vec![PowerMode::Maxn],
            seed: 42,
            fidelity: 0.15,
            loss_prob: 0.0,
            mean_latency_s: 0.0,
            injected_noise: NoiseModel::none(),
            progress_every: 200,
        }
    }
}

/// The leader: owns the workers and the uplink.
pub struct Fleet {
    workers: HashMap<u32, DeviceWorker>,
    /// Device capability registry (heterogeneous fleets, paper §IV-B):
    /// relative speed score per device, derived from its power mode.
    capability: HashMap<u32, f64>,
    uplink_rx: Receiver<Message>,
    next_job: u64,
    /// In-flight job -> (device, spec).
    in_flight: HashMap<u64, (u32, TuneJob)>,
    /// Devices with no in-flight job.
    idle: Vec<u32>,
    /// Progress beacons per job (diagnostics).
    progress: HashMap<u64, usize>,
    /// Results consumed while waiting inside `submit` (returned by `drain`).
    completed: Vec<JobResult>,
}

impl Fleet {
    /// Spawn the fleet. If `engine` is set, workers score through PJRT.
    pub fn spawn(config: FleetConfig, engine: Option<EngineHandle>) -> Result<Fleet> {
        assert!(config.devices > 0);
        let (up_tx, up_rx): (Sender<Message>, Receiver<Message>) = std::sync::mpsc::channel();
        let mut workers = HashMap::new();
        let mut capability = HashMap::new();
        for d in 0..config.devices {
            let device_id = d as u32;
            let mode = config.modes[d % config.modes.len()];
            let link = LinkSim::new(
                config.seed.wrapping_add(d as u64),
                config.loss_prob,
                config.mean_latency_s,
            );
            let wc = WorkerConfig {
                device_id,
                mode,
                seed: config.seed.wrapping_mul(31).wrapping_add(d as u64),
                fidelity: config.fidelity,
                progress_every: config.progress_every,
                injected_noise: config.injected_noise,
            };
            workers.insert(device_id, DeviceWorker::spawn(wc, up_tx.clone(), link, engine.clone()));
            capability.insert(device_id, mode_speed(mode));
        }
        let mut fleet = Fleet {
            workers,
            capability,
            uplink_rx: up_rx,
            next_job: 1,
            in_flight: HashMap::new(),
            idle: vec![],
            progress: HashMap::new(),
            completed: vec![],
        };
        // Collect registrations (lossy links may eat some; registration is
        // best-effort — every spawned device is usable regardless).
        let deadline = Instant::now() + Duration::from_secs(5);
        while fleet.idle.len() < config.devices && Instant::now() < deadline {
            match fleet.uplink_rx.recv_timeout(Duration::from_millis(50)) {
                Ok(Message::Register { device_id, .. }) => fleet.idle.push(device_id),
                Ok(_) => {}
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("all workers died during registration"))
                }
            }
        }
        // Registration beacons lost to the link: enroll the device anyway.
        for id in fleet.workers.keys() {
            if !fleet.idle.contains(id) {
                fleet.idle.push(*id);
            }
        }
        fleet.idle.sort_unstable();
        Ok(fleet)
    }

    /// Number of devices in the fleet.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job to an idle device, capability-aware: heavier jobs
    /// (larger spaces × more iterations) go to faster devices (paper
    /// §IV-B's heterogeneous-fleet challenge). Blocks only when every
    /// device is busy — backpressure by design.
    pub fn submit(&mut self, job: TuneJob) -> Result<u64> {
        let device_id = match self.pick_device(&job) {
            Some(d) => d,
            None => {
                // Wait for any completion (stashed for `drain`), then retry.
                let done = self.wait_one(Duration::from_secs(600))?;
                let device = done.device_id;
                self.completed.push(done);
                // The freed device is the only idle one.
                let pos = self.idle.iter().position(|&x| x == device);
                if let Some(p) = pos {
                    self.idle.remove(p);
                }
                device
            }
        };
        let job_id = self.next_job;
        self.next_job += 1;
        let msg = Message::TuneJob {
            job_id,
            app: job.app,
            iterations: job.iterations,
            alpha: job.alpha,
            beta: job.beta,
        };
        self.workers[&device_id]
            .mailbox
            .send(msg)
            .map_err(|_| anyhow!("device {device_id} mailbox closed"))?;
        self.in_flight.insert(job_id, (device_id, job));
        Ok(job_id)
    }

    /// Pick the idle device whose capability best matches the job's
    /// weight: heavy jobs take the fastest idle device, light jobs the
    /// slowest (keeping fast devices free). Removes the pick from `idle`.
    fn pick_device(&mut self, job: &TuneJob) -> Option<u32> {
        if self.idle.is_empty() {
            return None;
        }
        let weight = job_weight(job);
        // Order idle devices by capability; heavy -> take max, light -> min.
        let (pos, _) = self
            .idle
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                let ca = self.capability.get(a).copied().unwrap_or(1.0);
                let cb = self.capability.get(b).copied().unwrap_or(1.0);
                if weight >= HEAVY_JOB_WEIGHT {
                    ca.total_cmp(&cb)
                } else {
                    cb.total_cmp(&ca)
                }
            })?;
        Some(self.idle.remove(pos))
    }

    /// Switch every device's power mode (fleet-wide volatility event).
    pub fn set_power_mode(&mut self, mode: PowerMode) {
        for w in self.workers.values() {
            let _ = w.mailbox.send(Message::SetPowerMode { mode });
        }
    }

    /// Wait for the next JobDone, absorbing progress beacons.
    pub fn wait_one(&mut self, timeout: Duration) -> Result<JobResult> {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| anyhow!("timed out waiting for a job"))?;
            match self.uplink_rx.recv_timeout(remaining) {
                Ok(Message::Progress { job_id, .. }) => {
                    *self.progress.entry(job_id).or_default() += 1;
                }
                Ok(Message::JobDone {
                    job_id,
                    device_id,
                    best_index,
                    pulls_of_best,
                    tuner_wall_seconds,
                    simulated_device_seconds,
                }) => {
                    let (dev, job) = self
                        .in_flight
                        .remove(&job_id)
                        .ok_or_else(|| anyhow!("unknown job {job_id}"))?;
                    debug_assert_eq!(dev, device_id);
                    self.idle.push(device_id);
                    return Ok(JobResult {
                        job_id,
                        device_id,
                        app: job.app,
                        best_index,
                        pulls_of_best,
                        tuner_wall_seconds,
                        simulated_device_seconds,
                    });
                }
                Ok(_) => {}
                Err(RecvTimeoutError::Timeout) => {
                    return Err(anyhow!("timed out waiting for a job"))
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("all workers disconnected"))
                }
            }
        }
    }

    /// Wait for all in-flight jobs; includes results consumed by `submit`
    /// backpressure waits.
    pub fn drain(&mut self, timeout: Duration) -> Result<Vec<JobResult>> {
        let mut out = std::mem::take(&mut self.completed);
        while !self.in_flight.is_empty() {
            out.push(self.wait_one(timeout)?);
        }
        Ok(out)
    }

    /// Progress beacons observed for a job.
    pub fn progress_count(&self, job_id: u64) -> usize {
        self.progress.get(&job_id).copied().unwrap_or(0)
    }

    /// Orderly shutdown: signal and join every worker.
    pub fn shutdown(mut self) {
        for (_, w) in self.workers.drain() {
            let _ = w.mailbox.send(Message::Shutdown);
            w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_job(app: AppKind) -> TuneJob {
        TuneJob { app, iterations: 150, alpha: 1.0, beta: 0.0 }
    }

    #[test]
    fn fleet_runs_jobs_across_devices() {
        let mut fleet = Fleet::spawn(
            FleetConfig { devices: 3, ..Default::default() },
            None,
        )
        .unwrap();
        assert_eq!(fleet.size(), 3);
        let mut ids = vec![];
        for app in [AppKind::Clomp, AppKind::Lulesh, AppKind::Kripke] {
            ids.push(fleet.submit(small_job(app)).unwrap());
        }
        let results = fleet.drain(Duration::from_secs(120)).unwrap();
        assert_eq!(results.len(), 3);
        let devices: std::collections::HashSet<u32> =
            results.iter().map(|r| r.device_id).collect();
        assert_eq!(devices.len(), 3, "jobs should spread across devices");
        fleet.shutdown();
    }

    #[test]
    fn backpressure_queues_when_fleet_busy() {
        let mut fleet = Fleet::spawn(
            FleetConfig { devices: 1, ..Default::default() },
            None,
        )
        .unwrap();
        // Two jobs on one device: the second submit blocks until the first
        // completes, then succeeds.
        fleet.submit(small_job(AppKind::Clomp)).unwrap();
        fleet.submit(small_job(AppKind::Clomp)).unwrap();
        let results = fleet.drain(Duration::from_secs(120)).unwrap();
        assert_eq!(results.len(), 2); // incl. the one consumed during submit
        fleet.shutdown();
    }

    #[test]
    fn heavy_jobs_land_on_fast_devices() {
        // 1 MAXN + 1 5W device: the Hypre-sized job must go to the MAXN
        // board, the small Clomp job to the 5W board.
        let mut fleet = Fleet::spawn(
            FleetConfig {
                devices: 2,
                modes: vec![PowerMode::Maxn, PowerMode::FiveW],
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let heavy = fleet
            .submit(TuneJob { app: AppKind::Hypre, iterations: 600, alpha: 1.0, beta: 0.0 })
            .unwrap();
        let light = fleet
            .submit(TuneJob { app: AppKind::Clomp, iterations: 100, alpha: 1.0, beta: 0.0 })
            .unwrap();
        let results = fleet.drain(Duration::from_secs(300)).unwrap();
        let by_id: std::collections::HashMap<u64, u32> =
            results.iter().map(|r| (r.job_id, r.device_id)).collect();
        assert_eq!(by_id[&heavy], 0, "heavy job should take the MAXN device");
        assert_eq!(by_id[&light], 1, "light job should take the 5W device");
        fleet.shutdown();
    }

    #[test]
    fn lossy_links_do_not_lose_results_forever() {
        // JobDone can be dropped by the link; in a real deployment CoAP
        // confirmable retransmission handles it. Our LinkSim drops are
        // per-message; with loss 0.2 and progress beacons as keepalives the
        // expected JobDone arrival over 3 jobs is overwhelming... but to
        // keep the test deterministic we only assert no crash + at least
        // one result arrives across several attempts.
        let mut fleet = Fleet::spawn(
            FleetConfig { devices: 2, loss_prob: 0.2, ..Default::default() },
            None,
        )
        .unwrap();
        let mut got = 0;
        for _ in 0..4 {
            fleet.submit(small_job(AppKind::Clomp)).unwrap();
        }
        // Drain with tolerance: dropped JobDone messages leave jobs
        // in-flight; time them out quickly.
        for _ in 0..4 {
            if let Ok(r) = fleet.wait_one(Duration::from_secs(5)) {
                assert!(r.best_index < 125);
                got += 1;
            }
        }
        assert!(got >= 1, "no results survived a 20% lossy link");
        fleet.shutdown();
    }
}
