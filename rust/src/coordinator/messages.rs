//! Leader↔worker wire protocol with CoAP-flavoured constraints.
//!
//! The paper (§IV-A) positions LASP behind CoAP (Constrained Application
//! Protocol). We model the properties that matter to the coordinator:
//! small payloads (configuration indices and scalar measurements — never
//! full traces), per-message size accounting, and a lossy/laggy link
//! simulator that the leader's retry logic must absorb.

use crate::apps::AppKind;
use crate::device::PowerMode;
use crate::util::Rng;

/// Protocol messages. Payload sizes are kept CoAP-friendly: indices and
/// scalars only.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Leader -> worker: run a tuning job.
    TuneJob {
        job_id: u64,
        app: AppKind,
        iterations: usize,
        alpha: f64,
        beta: f64,
    },
    /// Leader -> worker: switch power mode (environment volatility).
    SetPowerMode { mode: PowerMode },
    /// Leader -> worker: orderly shutdown.
    Shutdown,
    /// Worker -> leader: periodic progress beacon.
    Progress {
        job_id: u64,
        device_id: u32,
        iterations_done: usize,
        current_best: usize,
    },
    /// Worker -> leader: job finished.
    JobDone {
        job_id: u64,
        device_id: u32,
        best_index: usize,
        pulls_of_best: f64,
        tuner_wall_seconds: f64,
        simulated_device_seconds: f64,
    },
    /// Worker -> leader: device registering with the fleet.
    Register { device_id: u32, mode: PowerMode },
}

impl Message {
    /// Approximate encoded size in bytes (CoAP budget accounting). The
    /// constants mirror a compact CBOR-ish encoding of each variant.
    pub fn wire_size(&self) -> usize {
        match self {
            Message::TuneJob { .. } => 4 + 8 + 1 + 4 + 8 + 8,
            Message::SetPowerMode { .. } => 4 + 1,
            Message::Shutdown => 4,
            Message::Progress { .. } => 4 + 8 + 4 + 4 + 4,
            Message::JobDone { .. } => 4 + 8 + 4 + 4 + 8 + 8 + 8,
            Message::Register { .. } => 4 + 4 + 1,
        }
    }

    /// CoAP default MTU-safe payload bound (RFC 7252 suggests ≤ ~1 KiB;
    /// we keep an order of magnitude under it).
    pub const MAX_WIRE_SIZE: usize = 128;
}

/// A message in flight, stamped with simulated arrival delay.
#[derive(Debug, Clone)]
pub struct Envelope {
    pub msg: Message,
    /// Simulated network latency for this hop, seconds.
    pub latency_s: f64,
}

/// Lossy, laggy link model for the edge network.
#[derive(Debug, Clone)]
pub struct LinkSim {
    rng: Rng,
    /// Probability a message is dropped.
    pub loss_prob: f64,
    /// Mean latency, seconds.
    pub mean_latency_s: f64,
    dropped: u64,
    delivered: u64,
    bytes: u64,
}

impl LinkSim {
    pub fn new(seed: u64, loss_prob: f64, mean_latency_s: f64) -> Self {
        assert!((0.0..1.0).contains(&loss_prob));
        LinkSim {
            rng: Rng::new(seed),
            loss_prob,
            mean_latency_s,
            dropped: 0,
            delivered: 0,
            bytes: 0,
        }
    }

    /// Perfect link.
    pub fn ideal() -> Self {
        LinkSim::new(0, 0.0, 0.0)
    }

    /// Attempt a send: `None` = dropped, `Some(envelope)` = delivered with
    /// a sampled latency.
    pub fn transmit(&mut self, msg: Message) -> Option<Envelope> {
        assert!(
            msg.wire_size() <= Message::MAX_WIRE_SIZE,
            "message exceeds CoAP budget: {} B",
            msg.wire_size()
        );
        if self.rng.uniform() < self.loss_prob {
            self.dropped += 1;
            return None;
        }
        // Exponential-ish latency: -ln(U) * mean.
        let latency_s = -self.rng.uniform().max(1e-12).ln() * self.mean_latency_s;
        self.delivered += 1;
        self.bytes += msg.wire_size() as u64;
        Some(Envelope { msg, latency_s })
    }

    /// (delivered, dropped, bytes) counters.
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.delivered, self.dropped, self.bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_messages_fit_coap_budget() {
        let msgs = [
            Message::TuneJob { job_id: 1, app: AppKind::Hypre, iterations: 1000, alpha: 0.8, beta: 0.2 },
            Message::SetPowerMode { mode: PowerMode::FiveW },
            Message::Shutdown,
            Message::Progress { job_id: 1, device_id: 2, iterations_done: 10, current_best: 5 },
            Message::JobDone {
                job_id: 1,
                device_id: 2,
                best_index: 7,
                pulls_of_best: 99.0,
                tuner_wall_seconds: 0.2,
                simulated_device_seconds: 100.0,
            },
            Message::Register { device_id: 2, mode: PowerMode::Maxn },
        ];
        for m in msgs {
            assert!(m.wire_size() <= Message::MAX_WIRE_SIZE, "{m:?}");
        }
    }

    #[test]
    fn ideal_link_delivers_everything() {
        let mut link = LinkSim::ideal();
        for _ in 0..100 {
            assert!(link.transmit(Message::Shutdown).is_some());
        }
        assert_eq!(link.stats().1, 0);
    }

    #[test]
    fn lossy_link_drops_roughly_p() {
        let mut link = LinkSim::new(5, 0.3, 0.01);
        let mut dropped = 0;
        for _ in 0..10_000 {
            if link.transmit(Message::Shutdown).is_none() {
                dropped += 1;
            }
        }
        let rate = dropped as f64 / 10_000.0;
        assert!((rate - 0.3).abs() < 0.03, "drop rate {rate}");
    }

    #[test]
    fn latency_positive_mean_close() {
        let mut link = LinkSim::new(7, 0.0, 0.05);
        let lats: Vec<f64> = (0..5000)
            .filter_map(|_| link.transmit(Message::Shutdown))
            .map(|e| e.latency_s)
            .collect();
        let mean = crate::util::stats::mean(&lats);
        assert!((mean - 0.05).abs() < 0.01, "mean latency {mean}");
        assert!(lats.iter().all(|&l| l >= 0.0));
    }
}
