//! Edge-fleet coordinator (paper Fig 1 + §IV-A).
//!
//! The paper's deployment story: a *leader* (the HPC-side controller)
//! dispatches tuning jobs to a fleet of heterogeneous edge devices over a
//! constrained CoAP-like transport; each device runs LASP locally at low
//! fidelity; tuned configurations flow back and are validated at high
//! fidelity on the HPC node before production use.
//!
//! This module builds that system with std threads and bounded channels
//! (no external async runtime exists in this offline build — and bounded
//! channels give us backpressure for free):
//!
//! * [`messages`] — the wire protocol: message enums with CoAP-style
//!   payload-size accounting and a lossy/laggy link simulator.
//! * [`worker`] — one thread per edge device: owns a `JetsonNano`, executes
//!   `TuneJob`s with a local [`crate::bandit::UcbTuner`], streams progress.
//! * [`leader`] — job queue, device registry, least-loaded dispatch,
//!   result collection, retry on device loss.
//! * [`transfer`] — LF→HF transfer validation on the simulated HPC node.

pub mod leader;
pub mod messages;
pub mod transfer;
pub mod worker;

pub use leader::{Fleet, FleetConfig, JobResult, TuneJob};
pub use messages::{Envelope, LinkSim, Message};
pub use transfer::{HfValidation, validate_on_hpc};
pub use worker::{DeviceWorker, WorkerConfig};
