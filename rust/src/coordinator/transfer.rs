//! LF→HF transfer validation (paper Fig 1, right-hand side): take a
//! configuration tuned on an edge device at low fidelity and evaluate it on
//! the HPC node at full fidelity, reporting the paper's §II-A metrics.

use crate::apps::AppModel;
use crate::device::{Device, HpcNode};
use crate::tuning::{oracle_sweep, oracle_distance_pct};
use crate::util::stats;

/// Result of validating a tuned configuration at high fidelity.
#[derive(Debug, Clone)]
pub struct HfValidation {
    /// The validated configuration.
    pub index: usize,
    /// Measured HF execution time, seconds.
    pub hf_time_s: f64,
    /// Measured HF power, watts.
    pub hf_power_w: f64,
    /// HF execution time of the Table II default configuration.
    pub default_time_s: f64,
    /// Eq. 8 performance gain over the default, percent.
    pub gain_pct: f64,
    /// §II-A distance from the HF oracle, percent.
    pub oracle_distance_pct: f64,
}

/// Evaluate `index` on the simulated i7-14700 at `q = 1` and score it
/// against the default configuration and the HF oracle.
pub fn validate_on_hpc(app: &dyn AppModel, index: usize, seed: u64) -> HfValidation {
    let mut node = HpcNode::new(seed);
    let m = node.run(&app.workload(index, 1.0));
    let m_default = node.run(&app.workload(app.default_index(), 1.0));

    // Oracle sweep on the HF spec (noise-free).
    let sweep = oracle_sweep(app, node.spec(), 1.0);
    let dist = oracle_distance_pct(&sweep, index);
    let gain_pct = (m_default.time_s - m.time_s) / m_default.time_s * 100.0;

    HfValidation {
        index,
        hf_time_s: m.time_s,
        hf_power_w: m.power_w,
        default_time_s: m_default.time_s,
        gain_pct,
        oracle_distance_pct: dist,
    }
}

/// Fig 2(a) helper: average HF oracle distance of the LF top-`k` configs.
pub fn lf_topk_hf_distance(
    app: &dyn AppModel,
    edge_spec: &crate::device::DeviceSpec,
    hpc_spec: &crate::device::DeviceSpec,
    lf: f64,
    k: usize,
) -> f64 {
    let lf_sweep = oracle_sweep(app, edge_spec, lf);
    let hf_sweep = oracle_sweep(app, hpc_spec, 1.0);
    let lf_times: Vec<f64> = lf_sweep.iter().map(|m| m.time_s).collect();
    let top = stats::bottom_k(&lf_times, k);
    let dists: Vec<f64> = top
        .iter()
        .map(|&i| oracle_distance_pct(&hf_sweep, i))
        .collect();
    stats::mean(&dists)
}

/// Fig 2(b) helper: |top-k(LF) ∩ top-k(HF)|.
pub fn lf_hf_topk_overlap(
    app: &dyn AppModel,
    edge_spec: &crate::device::DeviceSpec,
    hpc_spec: &crate::device::DeviceSpec,
    lf: f64,
    k: usize,
) -> usize {
    let lf_sweep = oracle_sweep(app, edge_spec, lf);
    let hf_sweep = oracle_sweep(app, hpc_spec, 1.0);
    let lf_times: Vec<f64> = lf_sweep.iter().map(|m| m.time_s).collect();
    let hf_times: Vec<f64> = hf_sweep.iter().map(|m| m.time_s).collect();
    let a: std::collections::HashSet<usize> =
        stats::bottom_k(&lf_times, k).into_iter().collect();
    let b: std::collections::HashSet<usize> =
        stats::bottom_k(&hf_times, k).into_iter().collect();
    a.intersection(&b).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{self, AppKind};
    use crate::device::PowerMode;

    #[test]
    fn oracle_validates_at_zero_distance() {
        let app = apps::build(AppKind::Lulesh);
        let node = HpcNode::new(0);
        let sweep = oracle_sweep(app.as_ref(), node.spec(), 1.0);
        let times: Vec<f64> = sweep.iter().map(|m| m.time_s).collect();
        let oracle = stats::argmin(&times);
        let v = validate_on_hpc(app.as_ref(), oracle, 3);
        assert!(v.oracle_distance_pct.abs() < 1e-9);
        assert!(v.gain_pct > 0.0, "oracle beats default");
    }

    #[test]
    fn default_config_gains_zero() {
        let app = apps::build(AppKind::Kripke);
        let v = validate_on_hpc(app.as_ref(), app.default_index(), 5);
        // Default vs default: gain within run-to-run noise of zero.
        assert!(v.gain_pct.abs() < 5.0, "gain {}", v.gain_pct);
    }

    #[test]
    fn fig2_metrics_reasonable() {
        let app = apps::build(AppKind::Kripke);
        let edge = PowerMode::Maxn.spec();
        let hpc = HpcNode::new(0);
        let d = lf_topk_hf_distance(app.as_ref(), &edge, hpc.spec(), 0.15, 20);
        // Paper: LF top-20 within ~25% of HF oracle.
        assert!(d >= 0.0 && d < 60.0, "distance {d}");
        let overlap = lf_hf_topk_overlap(app.as_ref(), &edge, hpc.spec(), 0.15, 20);
        assert!(overlap >= 8, "overlap {overlap}");
    }
}
