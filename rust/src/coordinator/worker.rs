//! Edge-device worker: one thread per simulated Jetson, executing tuning
//! jobs with a local UCB tuner and streaming progress beacons to the
//! leader. The tuning loop itself is one manually-stepped
//! [`crate::sim::Episode`] (the worker polls its mailbox between steps);
//! Python never appears here — if the PJRT backend is enabled the worker
//! scores arms through the shared [`crate::runtime::EngineHandle`].

use super::messages::{LinkSim, Message};
use crate::apps::{self};
use crate::bandit::{Policy, SubsetTuner, UcbTuner};
use crate::device::{Device, JetsonNano, NoiseModel, PowerMode};
use crate::runtime::{EngineHandle, PjrtScoreBackend};
use crate::sim::{Episode, EpisodeSpec, PolicyStep};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

/// Static worker parameters.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub device_id: u32,
    pub mode: PowerMode,
    pub seed: u64,
    /// LF evaluation point for this device.
    pub fidelity: f64,
    /// Send a Progress beacon every this many iterations.
    pub progress_every: usize,
    /// Injected measurement error (Fig 12 studies).
    pub injected_noise: NoiseModel,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            device_id: 0,
            mode: PowerMode::Maxn,
            seed: 1,
            fidelity: 0.15,
            progress_every: 100,
            injected_noise: NoiseModel::none(),
        }
    }
}

/// A running worker thread (joined on drop of the fleet).
pub struct DeviceWorker {
    pub device_id: u32,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Leader -> worker mailbox.
    pub mailbox: Sender<Message>,
}

impl DeviceWorker {
    /// Spawn the worker loop. `uplink` carries worker->leader messages
    /// through the lossy link owned by the worker (each edge device has its
    /// own radio).
    pub fn spawn(
        config: WorkerConfig,
        uplink: Sender<Message>,
        mut link: LinkSim,
        engine: Option<EngineHandle>,
    ) -> DeviceWorker {
        let (tx, rx): (Sender<Message>, Receiver<Message>) = std::sync::mpsc::channel();
        let device_id = config.device_id;
        let handle = std::thread::Builder::new()
            .name(format!("edge-{device_id}"))
            .spawn(move || worker_loop(config, rx, uplink, &mut link, engine))
            .expect("spawn worker");
        DeviceWorker { device_id, handle: Some(handle), mailbox: tx }
    }

    /// Wait for the worker to exit (after a Shutdown message).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Non-confirmable send (CoAP NON): progress beacons may be lost.
fn send_up(link: &mut LinkSim, uplink: &Sender<Message>, msg: Message) {
    // Lossy transmit: drops vanish, deliveries carry simulated latency
    // which we surface as ordering only (no wall-clock sleep in tests).
    if let Some(env) = link.transmit(msg) {
        let _ = uplink.send(env.msg);
    }
}

/// Confirmable send (CoAP CON): retransmit until the link delivers.
/// Registration and JobDone must not be lost, or the leader would leak the
/// job; CoAP's acknowledged retransmission provides exactly this.
fn send_up_confirmable(link: &mut LinkSim, uplink: &Sender<Message>, msg: Message) {
    for _ in 0..1000 {
        if let Some(env) = link.transmit(msg.clone()) {
            let _ = uplink.send(env.msg);
            return;
        }
    }
    // Pathologically lossy link: give up (leader's timeout handles it).
}

fn worker_loop(
    config: WorkerConfig,
    rx: Receiver<Message>,
    uplink: Sender<Message>,
    link: &mut LinkSim,
    engine: Option<EngineHandle>,
) {
    let mut device = JetsonNano::new(config.mode, config.seed)
        .with_fidelity(config.fidelity)
        .with_injected_noise(config.injected_noise);
    send_up_confirmable(
        link,
        &uplink,
        Message::Register { device_id: config.device_id, mode: config.mode },
    );

    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // leader gone
        };
        match msg {
            Message::Shutdown => return,
            Message::SetPowerMode { mode } => {
                // Mode switch mid-fleet: new operating point in place,
                // thermals persist.
                device.switch_mode(mode);
            }
            Message::TuneJob { job_id, app, iterations, alpha, beta } => {
                let model = apps::build(app);
                let k = model.space().len();
                // Large spaces tune over a seeded candidate subset
                // (bandit::subset); otherwise full UCB1 — through the PJRT
                // artifact when the engine is attached.
                let mut tuner: Box<dyn Policy> = if k > iterations / 2 && k > 256 {
                    let m = SubsetTuner::recommended_size(k, iterations);
                    Box::new(SubsetTuner::new(k, m, alpha, beta, config.seed))
                } else {
                    match &engine {
                        Some(h) => Box::new(UcbTuner::with_backend(
                            k,
                            alpha,
                            beta,
                            Box::new(PjrtScoreBackend::new(h.clone(), app.name())),
                        )),
                        None => Box::new(UcbTuner::new(k, alpha, beta)),
                    }
                };
                let started = std::time::Instant::now();
                let spec = EpisodeSpec { iterations, ..Default::default() };
                let mut step = PolicyStep::new(tuner.as_mut());
                let mut episode = Episode::new(model.as_ref(), &mut device, &mut step, &[], &spec);
                loop {
                    // Mid-job control: handle mode switches without abandoning
                    // the job (the bandit adapts to the new distribution).
                    match rx.try_recv() {
                        Ok(Message::SetPowerMode { mode }) => episode.switch_mode(mode),
                        Ok(Message::Shutdown) => return,
                        Ok(_) | Err(TryRecvError::Empty) => {}
                        Err(TryRecvError::Disconnected) => return,
                    }
                    if episode.step().expect("policy episodes cannot fail").is_none() {
                        break;
                    }
                    let it = episode.t();
                    if it % config.progress_every == 0 {
                        let current_best = episode.recommend();
                        send_up(
                            link,
                            &uplink,
                            Message::Progress {
                                job_id,
                                device_id: config.device_id,
                                iterations_done: it,
                                current_best,
                            },
                        );
                    }
                }
                let out = episode.finish();
                send_up_confirmable(
                    link,
                    &uplink,
                    Message::JobDone {
                        job_id,
                        device_id: config.device_id,
                        best_index: out.best_index,
                        pulls_of_best: out.counts.expect("policy counts")[out.best_index],
                        tuner_wall_seconds: started.elapsed().as_secs_f64(),
                        simulated_device_seconds: out.simulated_device_seconds,
                    },
                );
            }
            // Leader-bound messages are ignored if misrouted.
            Message::Progress { .. } | Message::JobDone { .. } | Message::Register { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::AppKind;

    #[test]
    fn worker_registers_runs_job_and_shuts_down() {
        let (up_tx, up_rx) = std::sync::mpsc::channel();
        let w = DeviceWorker::spawn(
            WorkerConfig { device_id: 7, progress_every: 50, ..Default::default() },
            up_tx,
            LinkSim::ideal(),
            None,
        );
        // Registration arrives first.
        match up_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
            Message::Register { device_id, .. } => assert_eq!(device_id, 7),
            other => panic!("expected Register, got {other:?}"),
        }
        w.mailbox
            .send(Message::TuneJob {
                job_id: 42,
                app: AppKind::Clomp,
                iterations: 200,
                alpha: 1.0,
                beta: 0.0,
            })
            .unwrap();
        let mut progress_seen = 0;
        let done = loop {
            match up_rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap() {
                Message::Progress { job_id, .. } => {
                    assert_eq!(job_id, 42);
                    progress_seen += 1;
                }
                Message::JobDone { job_id, best_index, .. } => {
                    assert_eq!(job_id, 42);
                    break best_index;
                }
                other => panic!("unexpected {other:?}"),
            }
        };
        assert!(progress_seen >= 3, "progress beacons: {progress_seen}");
        assert!(done < 125);
        w.mailbox.send(Message::Shutdown).unwrap();
        w.join();
    }

    #[test]
    fn worker_survives_mode_switch_mid_job() {
        let (up_tx, up_rx) = std::sync::mpsc::channel();
        let w = DeviceWorker::spawn(
            WorkerConfig { device_id: 1, progress_every: 25, ..Default::default() },
            up_tx,
            LinkSim::ideal(),
            None,
        );
        let _ = up_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        w.mailbox
            .send(Message::TuneJob {
                job_id: 1,
                app: AppKind::Lulesh,
                iterations: 300,
                alpha: 0.8,
                beta: 0.2,
            })
            .unwrap();
        // Switch power mode while the job runs.
        w.mailbox.send(Message::SetPowerMode { mode: PowerMode::FiveW }).unwrap();
        loop {
            match up_rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap() {
                Message::JobDone { job_id, .. } => {
                    assert_eq!(job_id, 1);
                    break;
                }
                _ => continue,
            }
        }
        w.mailbox.send(Message::Shutdown).unwrap();
        w.join();
    }
}
