//! High-fidelity target node — the paper's Intel i7-14700 system
//! (20 cores / 28 threads, 5.3 GHz max turbo, 64 GB DDR5, Ubuntu 24.04).
//!
//! Configurations tuned at low fidelity on the edge device are validated
//! here at `q = 1` (paper Fig 1's right-hand side). Actively cooled and
//! effectively uncapped for our workloads.

use super::{ideal_run, run_with_cap, Device, DeviceSpec, Measurement, NoiseModel};
use crate::apps::Workload;
use crate::device::thermal::ThermalModel;
use crate::util::Rng;

/// Simulated i7-14700 workstation.
pub struct HpcNode {
    spec: DeviceSpec,
    thermal: ThermalModel,
    rng: Rng,
    seed: u64,
    intrinsic_noise: NoiseModel,
}

impl HpcNode {
    /// i7-14700 class node, deterministic from `seed`.
    pub fn new(seed: u64) -> Self {
        HpcNode {
            spec: DeviceSpec {
                name: "i7-14700".into(),
                cores: 20,
                freq_ghz: 5.3,
                ipc: 3.2,
                mem_bw_gbs: 89.6, // dual-channel DDR5-5600
                power_budget_w: 219.0,
                idle_power_w: 18.0,
                core_power_w: 9.0,
                mem_power_w: 8.0,
            },
            thermal: ThermalModel::active_cooling(),
            rng: Rng::new(seed),
            seed,
            intrinsic_noise: NoiseModel::uniform(0.01),
        }
    }

    /// Builder: override intrinsic variability.
    pub fn with_intrinsic_noise(mut self, noise: NoiseModel) -> Self {
        self.intrinsic_noise = noise;
        self
    }
}

impl Device for HpcNode {
    fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    /// High fidelity: the production problem size.
    fn fidelity(&self) -> f64 {
        1.0
    }

    fn run(&mut self, w: &Workload) -> Measurement {
        let scale = self.thermal.freq_scale();
        let ideal = if scale < 1.0 {
            ideal_run(&self.spec, w, scale)
        } else {
            run_with_cap(&self.spec, w)
        };
        self.thermal.advance(ideal.power_w, ideal.time_s);
        self.intrinsic_noise.perturb(ideal, &mut self.rng)
    }

    fn reset(&mut self) {
        self.thermal.reset();
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{JetsonNano, PowerMode};

    fn wl() -> Workload {
        Workload { compute: 3.0, mem_intensity: 0.45, parallel_frac: 0.92, overhead: 0.02 }
    }

    #[test]
    fn much_faster_than_edge() {
        let mut hpc = HpcNode::new(1).with_intrinsic_noise(NoiseModel::none());
        let mut edge = JetsonNano::new(PowerMode::Maxn, 1)
            .with_intrinsic_noise(NoiseModel::none());
        let (h, e) = (hpc.run(&wl()), edge.run(&wl()));
        assert!(e.time_s / h.time_s > 4.0, "speedup {}", e.time_s / h.time_s);
    }

    #[test]
    fn full_fidelity() {
        assert_eq!(HpcNode::new(0).fidelity(), 1.0);
    }

    #[test]
    fn deterministic_and_resettable() {
        let mut a = HpcNode::new(5);
        let first = a.run(&wl());
        a.run(&wl());
        a.reset();
        assert_eq!(a.run(&wl()), first);
    }

    #[test]
    fn draws_more_power_than_edge() {
        let mut hpc = HpcNode::new(2).with_intrinsic_noise(NoiseModel::none());
        let m = hpc.run(&wl());
        assert!(m.power_w > 20.0);
    }
}
