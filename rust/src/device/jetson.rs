//! NVIDIA Jetson Nano simulator — the paper's edge testbed (Table I).
//!
//! | Parameter               | MAXN  | 5W  |
//! |--------------------------|-------|-----|
//! | Power budget (watts)     | 10    | 5   |
//! | Online CPU               | 4     | 2   |
//! | CPU max frequency (MHz)  | 1479  | 918 |
//! | GPU TPC (MHz)            | 921.6 | 640 |
//!
//! The CPU-side model executes the Table I operating point with the shared
//! roofline core ([`super::ideal_run`]), power-cap throttling
//! ([`super::run_with_cap`]), a passive-cooling thermal governor, and
//! intrinsic run-to-run noise. The GPU clock appears only through the
//! board's idle/aux power (our four workloads are CPU codes).

use super::{run_with_cap, Device, DeviceSpec, ideal_run, Measurement, NoiseModel};
use crate::apps::Workload;
use crate::device::thermal::ThermalModel;
use crate::util::Rng;

/// Table I operating modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PowerMode {
    /// 10 W budget, 4 cores @ 1479 MHz.
    Maxn,
    /// 5 W budget, 2 cores @ 918 MHz.
    FiveW,
}

impl PowerMode {
    pub fn name(&self) -> &'static str {
        match self {
            PowerMode::Maxn => "MAXN",
            PowerMode::FiveW => "5W",
        }
    }

    /// Lowercase wire form — exactly what [`std::str::FromStr`] accepts,
    /// so clients can echo it back without re-casing.
    pub fn lower_name(&self) -> &'static str {
        match self {
            PowerMode::Maxn => "maxn",
            PowerMode::FiveW => "5w",
        }
    }

    /// Table I row for this mode.
    pub fn spec(&self) -> DeviceSpec {
        match self {
            PowerMode::Maxn => DeviceSpec {
                name: "jetson-nano-maxn".into(),
                cores: 4,
                freq_ghz: 1.479,
                ipc: 1.6, // Cortex-A57 class
                mem_bw_gbs: 25.6,
                power_budget_w: 10.0,
                idle_power_w: 1.25,
                core_power_w: 1.65,
                mem_power_w: 1.1,
            },
            PowerMode::FiveW => DeviceSpec {
                name: "jetson-nano-5w".into(),
                cores: 2,
                freq_ghz: 0.918,
                ipc: 1.6,
                mem_bw_gbs: 25.6,
                power_budget_w: 5.0,
                idle_power_w: 1.0,
                core_power_w: 1.65,
                mem_power_w: 1.1,
            },
        }
    }
}

impl std::str::FromStr for PowerMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "maxn" => Ok(PowerMode::Maxn),
            "5w" | "fivew" => Ok(PowerMode::FiveW),
            other => Err(anyhow::anyhow!("unknown power mode '{other}' (maxn|5w)")),
        }
    }
}

/// A stateful simulated Jetson Nano.
pub struct JetsonNano {
    spec: DeviceSpec,
    mode: PowerMode,
    thermal: ThermalModel,
    rng: Rng,
    seed: u64,
    /// Low-fidelity evaluation point for this device (paper §II-C).
    fidelity: f64,
    /// Intrinsic run-to-run variability (always present on real boards).
    intrinsic_noise: NoiseModel,
    /// Injected synthetic error (Fig 12); default none.
    injected_noise: NoiseModel,
    runs: u64,
}

impl JetsonNano {
    /// Standard board at `mode`, deterministic from `seed`. LF point 0.15.
    pub fn new(mode: PowerMode, seed: u64) -> Self {
        JetsonNano {
            spec: mode.spec(),
            mode,
            thermal: ThermalModel::edge(),
            rng: Rng::new(seed),
            seed,
            fidelity: 0.15,
            intrinsic_noise: NoiseModel::uniform(0.015),
            injected_noise: NoiseModel::none(),
            runs: 0,
        }
    }

    /// Builder: set the LF evaluation fidelity.
    pub fn with_fidelity(mut self, q: f64) -> Self {
        self.fidelity = q.clamp(0.0, 1.0);
        self
    }

    /// Builder: inject Fig 12 synthetic measurement error.
    pub fn with_injected_noise(mut self, noise: NoiseModel) -> Self {
        self.injected_noise = noise;
        self
    }

    /// Builder: override intrinsic variability (0 = ideal board).
    pub fn with_intrinsic_noise(mut self, noise: NoiseModel) -> Self {
        self.intrinsic_noise = noise;
        self
    }

    pub fn mode(&self) -> PowerMode {
        self.mode
    }

    /// The seed this board was constructed with (preserved across
    /// builder-style reconfiguration — see `experiments::harness::AppEval`).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Current die temperature (for telemetry).
    pub fn temperature_c(&self) -> f64 {
        self.thermal.temperature()
    }

    /// Number of runs executed since the last reset.
    pub fn run_count(&self) -> u64 {
        self.runs
    }
}

impl Device for JetsonNano {
    fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    fn fidelity(&self) -> f64 {
        self.fidelity
    }

    fn run(&mut self, w: &Workload) -> Measurement {
        // Thermal governor picks the clock before the run...
        let thermal_scale = self.thermal.freq_scale();
        let ideal = if thermal_scale < 1.0 {
            ideal_run(&self.spec, w, thermal_scale)
        } else {
            run_with_cap(&self.spec, w)
        };
        // ...and the dissipated heat advances the RC state.
        self.thermal.advance(ideal.power_w, ideal.time_s);
        self.runs += 1;

        let measured = self.intrinsic_noise.perturb(ideal, &mut self.rng);
        self.injected_noise.perturb(measured, &mut self.rng)
    }

    fn reset(&mut self) {
        self.thermal.reset();
        self.rng = Rng::new(self.seed);
        self.runs = 0;
    }

    fn switch_mode(&mut self, mode: PowerMode) {
        // In-place operating-point change: thermal state, RNG stream and
        // run counter persist, exactly like `nvpmodel -m` on a live board.
        self.mode = mode;
        self.spec = mode.spec();
    }

    fn set_injected_noise(&mut self, noise: NoiseModel) {
        self.injected_noise = noise;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl() -> Workload {
        Workload { compute: 1.5, mem_intensity: 0.45, parallel_frac: 0.9, overhead: 0.01 }
    }

    #[test]
    fn table1_specs() {
        let maxn = PowerMode::Maxn.spec();
        assert_eq!(maxn.cores, 4);
        assert!((maxn.freq_ghz - 1.479).abs() < 1e-9);
        assert_eq!(maxn.power_budget_w, 10.0);
        let five = PowerMode::FiveW.spec();
        assert_eq!(five.cores, 2);
        assert!((five.freq_ghz - 0.918).abs() < 1e-9);
        assert_eq!(five.power_budget_w, 5.0);
    }

    #[test]
    fn five_watt_slower_than_maxn() {
        let mut a = JetsonNano::new(PowerMode::Maxn, 1).with_intrinsic_noise(NoiseModel::none());
        let mut b = JetsonNano::new(PowerMode::FiveW, 1).with_intrinsic_noise(NoiseModel::none());
        let (ma, mb) = (a.run(&wl()), b.run(&wl()));
        assert!(mb.time_s > ma.time_s * 1.2, "{} vs {}", mb.time_s, ma.time_s);
        assert!(mb.power_w <= 5.0 + 1e-6);
        assert!(ma.power_w <= 10.0 + 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = JetsonNano::new(PowerMode::Maxn, 99);
        let mut b = JetsonNano::new(PowerMode::Maxn, 99);
        for _ in 0..10 {
            assert_eq!(a.run(&wl()), b.run(&wl()));
        }
    }

    #[test]
    fn reset_restores_stream() {
        let mut d = JetsonNano::new(PowerMode::Maxn, 7);
        let first = d.run(&wl());
        for _ in 0..5 {
            d.run(&wl());
        }
        d.reset();
        assert_eq!(d.run(&wl()), first);
        assert_eq!(d.run_count(), 1);
    }

    #[test]
    fn sustained_load_heats_and_throttles() {
        let mut d = JetsonNano::new(PowerMode::Maxn, 3).with_intrinsic_noise(NoiseModel::none());
        let heavy = Workload { compute: 40.0, mem_intensity: 0.2, parallel_frac: 0.97, overhead: 0.0 };
        let cold = d.run(&heavy);
        for _ in 0..30 {
            d.run(&heavy);
        }
        let hot = d.run(&heavy);
        assert!(d.temperature_c() > 60.0, "temp {}", d.temperature_c());
        assert!(hot.time_s >= cold.time_s * 0.99, "no slowdown under heat");
    }

    #[test]
    fn injected_noise_widens_spread() {
        let spread = |noise: NoiseModel| {
            let mut d = JetsonNano::new(PowerMode::Maxn, 5)
                .with_intrinsic_noise(NoiseModel::none())
                .with_injected_noise(noise);
            let light = Workload { compute: 0.2, ..wl() };
            let xs: Vec<f64> = (0..200).map(|_| d.run(&light).time_s).collect();
            crate::util::stats::std_dev(&xs) / crate::util::stats::mean(&xs)
        };
        assert!(spread(NoiseModel::uniform(0.15)) > spread(NoiseModel::uniform(0.05)));
    }

    #[test]
    fn fidelity_builder() {
        let d = JetsonNano::new(PowerMode::Maxn, 1).with_fidelity(0.3);
        assert_eq!(d.fidelity(), 0.3);
    }

    #[test]
    fn switch_mode_changes_spec_keeps_state() {
        let mut d = JetsonNano::new(PowerMode::Maxn, 11).with_intrinsic_noise(NoiseModel::none());
        let before = d.run(&wl());
        d.switch_mode(PowerMode::FiveW);
        assert_eq!(d.mode(), PowerMode::FiveW);
        assert_eq!(d.spec().cores, 2);
        let after = d.run(&wl());
        assert!(after.time_s > before.time_s, "{} !> {}", after.time_s, before.time_s);
        assert!(after.power_w <= 5.0 + 1e-6);
        // Run counter survived the switch.
        assert_eq!(d.run_count(), 2);
    }

    #[test]
    fn injected_noise_settable_mid_run() {
        let mut d = JetsonNano::new(PowerMode::Maxn, 12).with_intrinsic_noise(NoiseModel::none());
        let light = Workload { compute: 0.2, ..wl() };
        let clean = d.run(&light);
        d.set_injected_noise(NoiseModel::uniform(0.15));
        let noisy: Vec<f64> = (0..50).map(|_| d.run(&light).time_s).collect();
        let spread = crate::util::stats::std_dev(&noisy) / crate::util::stats::mean(&noisy);
        assert!(spread > 0.01, "noise burst had no effect: {spread}");
        assert!(clean.time_s > 0.0);
    }

    #[test]
    fn seed_accessor_reports_construction_seed() {
        assert_eq!(JetsonNano::new(PowerMode::Maxn, 77).seed(), 77);
    }
}
