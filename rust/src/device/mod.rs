//! Device simulators: the NVIDIA Jetson Nano edge board (Table I) and the
//! high-fidelity HPC node (i7-14700) the paper transfers configurations to.
//!
//! A device turns an abstract [`crate::apps::Workload`] into a measured
//! `(execution time, average power)` pair using a roofline-flavoured model:
//!
//! * per-core throughput falls with the workload's memory intensity
//!   (edge DRAM bandwidth is the scarce resource: 25.6 GB/s on the Nano);
//! * multi-core speedup follows Amdahl with the workload's parallel
//!   fraction over the mode's online cores;
//! * power = idle + dynamic(cores, utilization, memory traffic), **capped**
//!   by the mode's power budget — exceeding the cap throttles the clock,
//!   stretching execution time. This produces the power saturation the
//!   paper observes (§V-D: power rewards are flatter than time rewards);
//! * a thermal state (RC model) throttles sustained heavy loads — the
//!   "volatile edge environment" the bandit must adapt to;
//! * run-to-run measurement noise (uniform relative), plus optional
//!   injected synthetic error for the Fig 12 sensitivity study.

pub mod hpc;
pub mod jetson;
pub mod noise;
pub mod thermal;

pub use hpc::HpcNode;
pub use jetson::{JetsonNano, PowerMode};
pub use noise::NoiseModel;

use crate::apps::Workload;

/// One measured application run (paper: "sample evaluation").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    /// Wall-clock execution time, seconds.
    pub time_s: f64,
    /// Average power draw over the run, watts.
    pub power_w: f64,
}

impl Measurement {
    /// Energy consumed by the run, joules.
    pub fn energy_j(&self) -> f64 {
        self.time_s * self.power_w
    }
}

/// Static description of a device's operating point.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: String,
    /// Online CPU cores.
    pub cores: u32,
    /// Sustained CPU clock, GHz.
    pub freq_ghz: f64,
    /// Peak instructions-per-cycle per core for compute-bound code.
    pub ipc: f64,
    /// Memory bandwidth, GB/s (relative penalty scale for memory-bound code).
    pub mem_bw_gbs: f64,
    /// Power budget, watts (throttling cap). `f64::INFINITY` = uncapped.
    pub power_budget_w: f64,
    /// Idle power, watts.
    pub idle_power_w: f64,
    /// Dynamic power per active core at full clock, watts.
    pub core_power_w: f64,
    /// Additional power for memory traffic at full intensity, watts.
    pub mem_power_w: f64,
}

/// A device that can execute workloads. `run` mutates internal state
/// (thermals, RNG) — devices are stateful simulators, one per tuning agent.
pub trait Device: Send {
    /// The device's current operating spec.
    fn spec(&self) -> &DeviceSpec;

    /// Execute a workload, returning a (noisy) measurement.
    fn run(&mut self, w: &Workload) -> Measurement;

    /// The fidelity this device evaluates at (paper: `q` < 1 on the edge,
    /// 1.0 on the HPC target).
    fn fidelity(&self) -> f64;

    /// Reset mutable state (thermals, noise stream) between experiments.
    fn reset(&mut self);

    /// Switch the operating power mode mid-run, keeping thermal and RNG
    /// state (a real board's `nvpmodel -m` does not cool the die or reseed
    /// the universe). Devices without power modes ignore the request.
    fn switch_mode(&mut self, _mode: jetson::PowerMode) {}

    /// Replace the injected synthetic measurement error mid-run (noise
    /// bursts in nonstationary scenarios). Devices without an injection
    /// port ignore the request.
    fn set_injected_noise(&mut self, _noise: NoiseModel) {}
}

/// Deterministic core of the device model, shared by Jetson and HPC node:
/// maps a workload to *noise-free* (time, power) under `spec`.
pub fn ideal_run(spec: &DeviceSpec, w: &Workload, freq_scale: f64) -> Measurement {
    let w = w.sanitized();
    let freq = spec.freq_ghz * freq_scale.clamp(0.2, 1.0);

    // Effective per-core throughput (reference core-seconds per second):
    // compute-bound work scales with freq·ipc; memory-bound work is pinned
    // to the bandwidth term and does not speed up with clock.
    let compute_rate = freq * spec.ipc;
    let mem_rate = spec.mem_bw_gbs / 8.0; // normalized: ref core ≈ 8 GB/s
    let core_rate = 1.0
        / ((1.0 - w.mem_intensity) / compute_rate + w.mem_intensity / mem_rate);

    // Amdahl over online cores; memory-bound parallel work also contends
    // for the shared bandwidth (cores beyond bw saturation don't help).
    let cores = spec.cores as f64;
    let bw_limited_cores = (mem_rate * 4.0 / core_rate).max(1.0);
    let eff_cores = cores.min(1.0 + (bw_limited_cores - 1.0).max(0.0));
    let speedup = 1.0 / ((1.0 - w.parallel_frac) + w.parallel_frac / eff_cores.max(1.0));

    let time_s = w.overhead / freq + w.compute / (core_rate * speedup);

    // Power: idle + active cores at utilization + memory traffic. The
    // parallel phase keeps all cores busy, the serial phase one.
    let util_cores = 1.0 + (cores - 1.0) * w.parallel_frac;
    // Dynamic power ~ f³ for the capped-clock regime (V scales with f).
    let dyn_power = util_cores * spec.core_power_w * freq_scale.powi(3)
        + spec.mem_power_w * w.mem_intensity;
    let power_w = spec.idle_power_w + dyn_power;

    Measurement { time_s, power_w }
}

/// Resolve power-cap throttling: find the frequency scale at which the
/// modelled power fits the budget, and return the throttled measurement.
pub fn run_with_cap(spec: &DeviceSpec, w: &Workload) -> Measurement {
    let full = ideal_run(spec, w, 1.0);
    if full.power_w <= spec.power_budget_w {
        return full;
    }
    // Bisect the frequency scale; dyn power ~ scale³ makes this monotone.
    let (mut lo, mut hi) = (0.2f64, 1.0f64);
    for _ in 0..24 {
        let mid = 0.5 * (lo + hi);
        if ideal_run(spec, w, mid).power_w > spec.power_budget_w {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    ideal_run(spec, w, lo)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> DeviceSpec {
        DeviceSpec {
            name: "test".into(),
            cores: 4,
            freq_ghz: 1.5,
            ipc: 2.0,
            mem_bw_gbs: 25.6,
            power_budget_w: 10.0,
            idle_power_w: 1.5,
            core_power_w: 1.8,
            mem_power_w: 1.2,
        }
    }

    fn wl() -> Workload {
        Workload { compute: 2.0, mem_intensity: 0.4, parallel_frac: 0.9, overhead: 0.01 }
    }

    #[test]
    fn more_compute_more_time() {
        let s = spec();
        let a = ideal_run(&s, &wl(), 1.0);
        let b = ideal_run(&s, &Workload { compute: 4.0, ..wl() }, 1.0);
        assert!(b.time_s > a.time_s * 1.5);
    }

    #[test]
    fn parallel_work_faster_than_serial() {
        let s = spec();
        let par = ideal_run(&s, &Workload { parallel_frac: 0.95, ..wl() }, 1.0);
        let ser = ideal_run(&s, &Workload { parallel_frac: 0.0, ..wl() }, 1.0);
        assert!(par.time_s < ser.time_s);
        // ...and draws more power (more cores busy).
        assert!(par.power_w > ser.power_w);
    }

    #[test]
    fn memory_bound_insensitive_to_clock() {
        let s = spec();
        let membound = Workload { mem_intensity: 1.0, ..wl() };
        let fast = ideal_run(&s, &membound, 1.0);
        let slow = ideal_run(&s, &membound, 0.5);
        // Memory-bound time barely moves with clock (only overhead scales).
        assert!(slow.time_s / fast.time_s < 1.15);
    }

    #[test]
    fn throttling_respects_budget() {
        let mut s = spec();
        s.power_budget_w = 5.0;
        let heavy = Workload { compute: 5.0, mem_intensity: 0.2, parallel_frac: 0.98, overhead: 0.0 };
        let uncapped = ideal_run(&s, &heavy, 1.0);
        assert!(uncapped.power_w > 5.0, "test needs a hot workload");
        let capped = run_with_cap(&s, &heavy);
        assert!(capped.power_w <= 5.0 + 1e-6);
        assert!(capped.time_s > uncapped.time_s);
    }

    #[test]
    fn uncapped_fast_path() {
        let s = spec();
        let light = Workload { compute: 0.1, mem_intensity: 0.9, parallel_frac: 0.2, overhead: 0.0 };
        assert_eq!(run_with_cap(&s, &light), ideal_run(&s, &light, 1.0));
    }

    #[test]
    fn energy_is_time_times_power() {
        let m = Measurement { time_s: 2.0, power_w: 5.0 };
        assert_eq!(m.energy_j(), 10.0);
    }
}
