//! Measurement-noise models.
//!
//! Two layers, matching the paper:
//! * intrinsic run-to-run variability of a real device (always on, small);
//! * *synthetic injected error* for the Fig 12 sensitivity study: "random
//!   noise … within a range of 5%, 10%, and 15%", which the paper also
//!   treats as a proxy for network fluctuation between edge devices.

use super::Measurement;
use crate::util::Rng;

/// Noise distribution shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseKind {
    /// `x · (1 + U(-pct, +pct))` — the paper's Fig 12 model.
    Uniform,
    /// `x · (1 + N(0, pct/2))`, truncated at ±3σ.
    Gaussian,
}

/// Relative measurement noise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NoiseModel {
    pub kind: NoiseKind,
    /// Relative magnitude (0.05 = 5%).
    pub pct: f64,
}

impl NoiseModel {
    pub fn none() -> Self {
        NoiseModel { kind: NoiseKind::Uniform, pct: 0.0 }
    }

    pub fn uniform(pct: f64) -> Self {
        assert!(pct >= 0.0);
        NoiseModel { kind: NoiseKind::Uniform, pct }
    }

    pub fn gaussian(pct: f64) -> Self {
        assert!(pct >= 0.0);
        NoiseModel { kind: NoiseKind::Gaussian, pct }
    }

    /// Draw one multiplicative noise factor (always > 0).
    pub fn factor(&self, rng: &mut Rng) -> f64 {
        if self.pct == 0.0 {
            return 1.0;
        }
        match self.kind {
            NoiseKind::Uniform => rng.relative_noise(self.pct),
            NoiseKind::Gaussian => {
                let z = rng.normal().clamp(-3.0, 3.0);
                (1.0 + z * self.pct / 2.0).max(0.05)
            }
        }
    }

    /// Apply independent noise to time and power of a measurement.
    pub fn perturb(&self, m: Measurement, rng: &mut Rng) -> Measurement {
        Measurement {
            time_s: m.time_s * self.factor(rng),
            power_w: m.power_w * self.factor(rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_noise_is_identity() {
        let mut rng = Rng::new(1);
        let m = Measurement { time_s: 2.0, power_w: 5.0 };
        assert_eq!(NoiseModel::none().perturb(m, &mut rng), m);
    }

    #[test]
    fn uniform_bounded() {
        let mut rng = Rng::new(2);
        let nm = NoiseModel::uniform(0.10);
        for _ in 0..10_000 {
            let f = nm.factor(&mut rng);
            assert!((0.9..=1.1).contains(&f), "{f}");
        }
    }

    #[test]
    fn uniform_unbiased() {
        let mut rng = Rng::new(3);
        let nm = NoiseModel::uniform(0.15);
        let mean: f64 =
            (0..100_000).map(|_| nm.factor(&mut rng)).sum::<f64>() / 100_000.0;
        assert!((mean - 1.0).abs() < 0.002, "{mean}");
    }

    #[test]
    fn gaussian_positive() {
        let mut rng = Rng::new(4);
        let nm = NoiseModel::gaussian(0.15);
        for _ in 0..10_000 {
            assert!(nm.factor(&mut rng) > 0.0);
        }
    }

    #[test]
    fn perturb_moves_both_fields_independently() {
        let mut rng = Rng::new(5);
        let nm = NoiseModel::uniform(0.10);
        let m = Measurement { time_s: 1.0, power_w: 1.0 };
        let p = nm.perturb(m, &mut rng);
        assert_ne!(p.time_s, p.power_w); // independent draws
    }
}
