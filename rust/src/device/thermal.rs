//! First-order RC thermal model for the edge board.
//!
//! The Jetson Nano throttles under sustained load (passively cooled). We
//! model die temperature as an RC circuit driven by dissipated power; above
//! the throttle threshold the clock is scaled down linearly until the hard
//! limit. This supplies the "dynamic environment" volatility the paper's
//! online bandit is designed to absorb.


/// RC thermal state + throttle law.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    /// Ambient temperature, °C.
    pub ambient_c: f64,
    /// Thermal resistance, °C per watt (steady state rise = R·P).
    pub r_c_per_w: f64,
    /// Thermal time constant, seconds.
    pub tau_s: f64,
    /// Throttling starts here.
    pub throttle_start_c: f64,
    /// Hard limit: clock pinned to `min_scale` at/above this temperature.
    pub throttle_max_c: f64,
    /// Lowest frequency scale the governor will apply.
    pub min_scale: f64,
    /// Current die temperature, °C.
    temp_c: f64,
}

impl ThermalModel {
    /// Passive-cooled edge board defaults (Nano-like): at the 10 W MAXN
    /// budget the steady-state die temperature (25 + 5.5·10 = 80 °C) sits
    /// inside the throttle band, so sustained full-power load throttles.
    pub fn edge() -> Self {
        ThermalModel {
            ambient_c: 25.0,
            r_c_per_w: 5.5,
            tau_s: 30.0,
            throttle_start_c: 70.0,
            throttle_max_c: 95.0,
            min_scale: 0.5,
            temp_c: 25.0,
        }
    }

    /// Actively-cooled node: effectively never throttles.
    pub fn active_cooling() -> Self {
        ThermalModel {
            ambient_c: 25.0,
            r_c_per_w: 0.4,
            tau_s: 10.0,
            throttle_start_c: 90.0,
            throttle_max_c: 105.0,
            min_scale: 0.8,
            temp_c: 25.0,
        }
    }

    /// Current temperature, °C.
    pub fn temperature(&self) -> f64 {
        self.temp_c
    }

    /// Frequency scale the governor applies at the current temperature.
    pub fn freq_scale(&self) -> f64 {
        if self.temp_c <= self.throttle_start_c {
            1.0
        } else if self.temp_c >= self.throttle_max_c {
            self.min_scale
        } else {
            let frac = (self.temp_c - self.throttle_start_c)
                / (self.throttle_max_c - self.throttle_start_c);
            1.0 - frac * (1.0 - self.min_scale)
        }
    }

    /// Advance the RC state by a run dissipating `power_w` for `dt_s`.
    pub fn advance(&mut self, power_w: f64, dt_s: f64) {
        let steady = self.ambient_c + self.r_c_per_w * power_w;
        let a = (-dt_s / self.tau_s).exp();
        self.temp_c = steady + (self.temp_c - steady) * a;
    }

    /// Cool back to ambient (between experiments).
    pub fn reset(&mut self) {
        self.temp_c = self.ambient_c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heats_toward_steady_state() {
        let mut t = ThermalModel::edge();
        t.advance(10.0, 1000.0); // long enough to converge
        assert!((t.temperature() - (25.0 + 5.5 * 10.0)).abs() < 0.5);
    }

    #[test]
    fn no_throttle_when_cool() {
        let t = ThermalModel::edge();
        assert_eq!(t.freq_scale(), 1.0);
    }

    #[test]
    fn throttles_when_hot() {
        let mut t = ThermalModel::edge();
        t.advance(15.0, 1000.0); // steady ~85°C
        let s = t.freq_scale();
        assert!(s < 1.0 && s >= t.min_scale, "scale {s}");
    }

    #[test]
    fn hard_limit_pins_min_scale() {
        let mut t = ThermalModel::edge();
        t.advance(30.0, 10_000.0); // way past max
        assert_eq!(t.freq_scale(), t.min_scale);
    }

    #[test]
    fn cools_back_down() {
        let mut t = ThermalModel::edge();
        t.advance(15.0, 500.0);
        let hot = t.temperature();
        t.advance(0.0, 500.0);
        assert!(t.temperature() < hot);
        t.reset();
        assert_eq!(t.temperature(), 25.0);
    }

    #[test]
    fn active_cooling_stays_cool() {
        let mut t = ThermalModel::active_cooling();
        t.advance(100.0, 1000.0); // 100 W server load
        assert_eq!(t.freq_scale(), 1.0);
    }
}
