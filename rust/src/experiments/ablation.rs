//! Ablation study (beyond the paper's figures, motivated by its §IV-B
//! challenges): LASP's UCB1 against the other bandit families and the
//! search baselines, on the same apps + budget; plus a non-stationary
//! mode-switch scenario where sliding-window UCB earns its keep.
//!
//! Every run — bandit policies and search baselines alike — is one
//! [`Scenario`] cell fanned out by the [`SweepRunner`]; the nonstationary
//! scenario is the same grid entry with a `bus@600` event attached.

use super::harness::{edge_oracle, print_table, LF_FIDELITY};
use crate::apps::{self, AppKind};
use crate::device::PowerMode;
use crate::sim::{Event, EventAction, Scenario, StrategySpec, SweepRunner};
use crate::tuning::oracle_distance_pct;

/// One ablation row.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub strategy: String,
    pub app: AppKind,
    /// §II-A oracle distance of the recommendation (time objective).
    pub oracle_distance_pct: f64,
    /// Evaluations consumed.
    pub evaluations: usize,
}

/// Ablation result.
#[derive(Debug, Clone)]
pub struct Ablation {
    pub rows: Vec<AblationRow>,
    /// Non-stationary scenario: post-switch near-optimal pull rate,
    /// (UCB, SW-UCB).
    pub nonstationary: (f64, f64),
}

/// Display name ↔ engine spec for every ablated strategy.
const STRATEGIES: [(&str, StrategySpec); 8] = [
    ("lasp-ucb1", StrategySpec::Ucb),
    ("epsilon-greedy", StrategySpec::Epsilon(0.1)),
    ("thompson", StrategySpec::Thompson),
    ("sw-ucb", StrategySpec::SwUcb(0)),
    ("random", StrategySpec::Random),
    ("simulated-annealing", StrategySpec::Annealing),
    ("bliss-bo", StrategySpec::Bliss),
    ("successive-halving", StrategySpec::Halving),
];

/// Non-stationary check: halfway through, a co-located tenant saturates
/// the memory bus (the paper's "volatile edge environment"), slowing
/// memory-heavy configurations and *reordering* the runtime ranking —
/// expressed as a `BusContention` event on an otherwise ordinary cell.
/// Scores the fraction of last-quarter pulls landing within 5% of the
/// post-shift best arm.
const NS_BUDGET: usize = 1200;
const NS_SLOPE: f64 = 4.0;
const NS_THRESHOLD: f64 = 0.45;

fn nonstationary_cell(strategy: StrategySpec, seed: u64) -> Scenario {
    Scenario::lasp(AppKind::Clomp, PowerMode::Maxn, NS_BUDGET, seed)
        .with_objective(1.0, 0.0)
        .with_strategy(strategy)
        .with_events(vec![Event {
            at: NS_BUDGET / 2,
            action: EventAction::BusContention { slope: NS_SLOPE, threshold: NS_THRESHOLD },
        }])
        .recording_trace()
}

fn nonstationary_score(trace: &[usize]) -> f64 {
    let app = apps::build(AppKind::Clomp);
    let interference =
        |mem_intensity: f64| 1.0 + NS_SLOPE * (mem_intensity - NS_THRESHOLD).max(0.0);
    // Post-shift expected times (noise-free): baseline sweep × interference.
    let sweep = edge_oracle(AppKind::Clomp, PowerMode::Maxn, LF_FIDELITY);
    let post_times: Vec<f64> = app
        .space()
        .indices()
        .map(|i| sweep[i].time_s * interference(app.workload(i, LF_FIDELITY).mem_intensity))
        .collect();
    let post_best = crate::util::stats::argmin(&post_times);

    // Credit near-optimal arms (within 5% of post-shift best) over the
    // last quarter.
    let tail = &trace[3 * NS_BUDGET / 4..];
    let hits = tail
        .iter()
        .filter(|&&arm| post_times[arm] <= post_times[post_best] * 1.05)
        .count();
    hits as f64 / tail.len() as f64
}

/// Run the ablation on Kripke + Clomp with a shared budget — all strategy
/// cells plus the two nonstationary cells in one parallel sweep.
pub fn run(budget: usize) -> Ablation {
    let mut cells: Vec<Scenario> = vec![];
    for app in [AppKind::Kripke, AppKind::Clomp] {
        for (_, spec) in STRATEGIES {
            // BO's per-iteration GP cost caps its budget, as in §V-D.
            let iterations = if spec == StrategySpec::Bliss { budget.min(120) } else { budget };
            cells.push(
                Scenario::lasp(app, PowerMode::Maxn, iterations, 5)
                    .with_objective(1.0, 0.0)
                    .with_strategy(spec),
            );
        }
    }
    cells.push(nonstationary_cell(StrategySpec::Ucb, 9));
    cells.push(nonstationary_cell(StrategySpec::SwUcb(500), 9));
    let mut outcomes = SweepRunner::new(0).run(&cells).expect("ablation sweep");

    let ns_sw = outcomes.pop().expect("sw-ucb nonstationary cell");
    let ns_ucb = outcomes.pop().expect("ucb nonstationary cell");
    let nonstationary = (
        nonstationary_score(ns_ucb.trace.as_deref().expect("trace recorded")),
        nonstationary_score(ns_sw.trace.as_deref().expect("trace recorded")),
    );

    let mut rows = vec![];
    let mut cursor = outcomes.into_iter();
    for app in [AppKind::Kripke, AppKind::Clomp] {
        let sweep = edge_oracle(app, PowerMode::Maxn, LF_FIDELITY);
        for (name, _) in STRATEGIES {
            let out = cursor.next().expect("ablation cell");
            rows.push(AblationRow {
                strategy: name.to_string(),
                app,
                oracle_distance_pct: oracle_distance_pct(&sweep, out.best_index),
                evaluations: out.evaluations,
            });
        }
    }
    Ablation { rows, nonstationary }
}

impl Ablation {
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.strategy.clone(),
                    r.app.to_string(),
                    format!("{:.1}%", r.oracle_distance_pct),
                    format!("{}", r.evaluations),
                ]
            })
            .collect();
        print_table(
            "Ablation — strategy vs oracle distance (time objective)",
            &["strategy", "app", "oracle distance", "evals"],
            &rows,
        );
        println!(
            "\nNon-stationary (mode switch): near-optimal pull rate last quarter — \
             UCB1 {:.2} vs SW-UCB {:.2}",
            self.nonstationary.0, self.nonstationary.1
        );
    }

    /// Rank of `strategy` (0 = closest to oracle) among the rows for `app`.
    pub fn rank_of(&self, app: AppKind, strategy: &str) -> Option<usize> {
        let mut ds: Vec<(&str, f64)> = self
            .rows
            .iter()
            .filter(|r| r.app == app)
            .map(|r| (r.strategy.as_str(), r.oracle_distance_pct))
            .collect();
        ds.sort_by(|x, y| x.1.total_cmp(&y.1));
        ds.iter().position(|(s, _)| *s == strategy)
    }

    /// Shape: LASP never in the bottom quarter of the eight strategies on
    /// either app (rank ≤ 5, the historical gate — substrate noise makes a
    /// strict top-half bound flaky at quick budgets), and SW-UCB at least
    /// holding UCB's line after the mid-episode shift.
    pub fn matches_paper_shape(&self) -> bool {
        let competitive = [AppKind::Kripke, AppKind::Clomp].into_iter().all(|app| {
            self.rank_of(app, "lasp-ucb1").map(|r| r <= 5).unwrap_or(false)
        });
        competitive && self.nonstationary.1 >= self.nonstationary.0 * 0.8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_runs_all_strategies() {
        let a = run(300);
        assert_eq!(a.rows.len(), 16);
        // LASP must be competitive: within the top half of strategies on
        // both apps (also the registry's shape predicate).
        for app in [AppKind::Kripke, AppKind::Clomp] {
            let rank = a.rank_of(app, "lasp-ucb1").unwrap();
            assert!(rank <= 5, "{app}: lasp ranked {rank}: {:?}", a.rows);
        }
        // Search baselines may stop early (halving's ladder), never over.
        assert!(a.rows.iter().all(|r| r.evaluations <= 300));
        assert!(a.matches_paper_shape());
    }

    #[test]
    fn swucb_beats_ucb_after_mode_switch() {
        let a = run(300);
        assert!(
            a.nonstationary.1 >= a.nonstationary.0 * 0.8,
            "sw-ucb {} vs ucb {}",
            a.nonstationary.1,
            a.nonstationary.0
        );
    }
}
