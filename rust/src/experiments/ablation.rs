//! Ablation study (beyond the paper's figures, motivated by its §IV-B
//! challenges): LASP's UCB1 against the other bandit families and the
//! search baselines, on the same apps + budget; plus a non-stationary
//! mode-switch scenario where sliding-window UCB earns its keep.

use super::harness::{edge_oracle, print_table, LF_FIDELITY};
use crate::apps::{self, AppKind};
use crate::bandit::{EpsilonGreedy, Policy, SlidingWindowUcb, ThompsonSampler, UcbTuner};
use crate::baselines::{BlissBo, FnEval, RandomSearch, Searcher, SimulatedAnnealing, SuccessiveHalving};
use crate::device::{Device, JetsonNano, PowerMode};
use crate::tuning::oracle_distance_pct;

/// One ablation row.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub strategy: String,
    pub app: AppKind,
    /// §II-A oracle distance of the recommendation (time objective).
    pub oracle_distance_pct: f64,
    /// Evaluations consumed.
    pub evaluations: usize,
}

/// Ablation result.
#[derive(Debug, Clone)]
pub struct Ablation {
    pub rows: Vec<AblationRow>,
    /// Non-stationary scenario: post-switch regret rate, UCB vs SW-UCB.
    pub nonstationary: (f64, f64),
}

fn run_policy(mut p: Box<dyn Policy>, app: AppKind, budget: usize, seed: u64) -> usize {
    let model = apps::build(app);
    let mut device = JetsonNano::new(PowerMode::Maxn, seed).with_fidelity(LF_FIDELITY);
    for _ in 0..budget {
        let arm = p.select();
        let m = device.run(&model.workload(arm, device.fidelity()));
        p.update(arm, m.time_s, m.power_w);
    }
    p.most_selected()
}

fn run_searcher(
    s: &mut dyn Searcher,
    app: AppKind,
    budget: usize,
    seed: u64,
) -> (usize, usize) {
    let model = apps::build(app);
    let k = model.space().len();
    let mut device = JetsonNano::new(PowerMode::Maxn, seed).with_fidelity(LF_FIDELITY);
    let mut eval = FnEval {
        f: move |i: usize, q: f64| device.run(&model.workload(i, q)),
        fidelity: LF_FIDELITY,
    };
    let out = s.run(k, budget, &mut eval).expect("searcher run");
    (out.best_index, out.evaluations())
}

/// Non-stationary check: halfway through, a co-located tenant saturates the
/// memory bus (the paper's "volatile edge environment"), slowing
/// memory-heavy configurations and *reordering* the runtime ranking.
/// Compare the fraction of late pulls landing within 5% of the post-shift
/// best arm.
fn nonstationary_score(window: Option<usize>, seed: u64) -> f64 {
    let app = apps::build(AppKind::Clomp);
    let k = app.space().len();
    let budget = 1200;
    let mut policy: Box<dyn Policy> = match window {
        Some(w) => Box::new(SlidingWindowUcb::new(k, 1.0, 0.0, w)),
        None => Box::new(UcbTuner::new(k, 1.0, 0.0)),
    };
    let mut device = JetsonNano::new(PowerMode::Maxn, seed).with_fidelity(LF_FIDELITY);
    // Interference multiplier: memory-bound configs stall on the shared bus.
    let interference = |mem_intensity: f64| 1.0 + 4.0 * (mem_intensity - 0.45).max(0.0);
    // Post-shift expected times (noise-free): baseline sweep × interference.
    let sweep = edge_oracle(AppKind::Clomp, PowerMode::Maxn, LF_FIDELITY);
    let post_times: Vec<f64> = app
        .space()
        .indices()
        .map(|i| sweep[i].time_s * interference(app.workload(i, LF_FIDELITY).mem_intensity))
        .collect();
    let post_best = crate::util::stats::argmin(&post_times);

    let mut hits = 0usize;
    for t in 0..budget {
        let arm = policy.select();
        let w = app.workload(arm, device.fidelity());
        let mut m = device.run(&w);
        if t >= budget / 2 {
            m.time_s *= interference(w.mem_intensity);
        }
        policy.update(arm, m.time_s, m.power_w);
        // Credit near-optimal arms (within 5% of post-shift best).
        if t >= 3 * budget / 4 && post_times[arm] <= post_times[post_best] * 1.05 {
            hits += 1;
        }
    }
    hits as f64 / (budget / 4) as f64
}

/// Run the ablation on Kripke + Clomp with a shared budget.
pub fn run(budget: usize) -> Ablation {
    let mut rows = vec![];
    for app in [AppKind::Kripke, AppKind::Clomp] {
        let sweep = edge_oracle(app, PowerMode::Maxn, LF_FIDELITY);
        let k = apps::build(app).space().len();
        let mut add = |strategy: &str, best: usize, evals: usize| {
            rows.push(AblationRow {
                strategy: strategy.to_string(),
                app,
                oracle_distance_pct: oracle_distance_pct(&sweep, best),
                evaluations: evals,
            });
        };
        add("lasp-ucb1", run_policy(Box::new(UcbTuner::new(k, 1.0, 0.0)), app, budget, 5), budget);
        add(
            "epsilon-greedy",
            run_policy(Box::new(EpsilonGreedy::new(k, 1.0, 0.0, 0.1, 5)), app, budget, 5),
            budget,
        );
        add(
            "thompson",
            run_policy(Box::new(ThompsonSampler::new(k, 1.0, 0.0, 5)), app, budget, 5),
            budget,
        );
        add(
            "sw-ucb",
            run_policy(Box::new(SlidingWindowUcb::new(k, 1.0, 0.0, budget.max(k))), app, budget, 5),
            budget,
        );
        let (b, e) = run_searcher(&mut RandomSearch::new(5, 1.0, 0.0), app, budget, 5);
        add("random", b, e);
        let (b, e) = run_searcher(&mut SimulatedAnnealing::new(5, 1.0, 0.0), app, budget, 5);
        add("simulated-annealing", b, e);
        let (b, e) = run_searcher(&mut BlissBo::new(5, 1.0, 0.0), app, budget.min(120), 5);
        add("bliss-bo", b, e);
        let (b, e) = run_searcher(&mut SuccessiveHalving::new(5, 1.0, 0.0), app, budget, 5);
        add("successive-halving", b, e);
    }
    let nonstationary = (nonstationary_score(None, 9), nonstationary_score(Some(500), 9));
    Ablation { rows, nonstationary }
}

impl Ablation {
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.strategy.clone(),
                    r.app.to_string(),
                    format!("{:.1}%", r.oracle_distance_pct),
                    format!("{}", r.evaluations),
                ]
            })
            .collect();
        print_table(
            "Ablation — strategy vs oracle distance (time objective)",
            &["strategy", "app", "oracle distance", "evals"],
            &rows,
        );
        println!(
            "\nNon-stationary (mode switch): near-optimal pull rate last quarter — \
             UCB1 {:.2} vs SW-UCB {:.2}",
            self.nonstationary.0, self.nonstationary.1
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_runs_all_strategies() {
        let a = run(300);
        assert_eq!(a.rows.len(), 16);
        // LASP must be competitive: within the top half of strategies on
        // at least one app.
        for app in [AppKind::Kripke, AppKind::Clomp] {
            let mut ds: Vec<(String, f64)> = a
                .rows
                .iter()
                .filter(|r| r.app == app)
                .map(|r| (r.strategy.clone(), r.oracle_distance_pct))
                .collect();
            ds.sort_by(|x, y| x.1.total_cmp(&y.1));
            let rank = ds.iter().position(|(s, _)| s == "lasp-ucb1").unwrap();
            assert!(rank <= 5, "{app}: lasp ranked {rank} of {}: {ds:?}", ds.len());
        }
    }

    #[test]
    fn swucb_beats_ucb_after_mode_switch() {
        let a = run(300);
        assert!(
            a.nonstationary.1 >= a.nonstationary.0 * 0.8,
            "sw-ucb {} vs ucb {}",
            a.nonstationary.1,
            a.nonstationary.0
        );
    }
}
