//! Fig 10 — resource utilization of LASP vs BLISS on the Jetson's two
//! power modes (MAXN, 5W).
//!
//! Two complementary measurements:
//! * **modelled Jetson footprint** — the analytic
//!   [`crate::telemetry::jetson_footprint`] model, which puts both tuners
//!   on the paper's axes (CPU %, memory MiB on the edge board);
//! * **measured host footprint** — real RSS/CPU of *our* implementations
//!   tuning Hypre on this host, demonstrating the asymmetry is intrinsic
//!   (GP linear algebra vs one O(K) vector pass), not an artifact of the
//!   model.

use super::harness::{print_table, AppEval};
use crate::apps::AppKind;
use crate::baselines::{BlissBo, RandomSearch, Searcher};
use crate::device::PowerMode;
use crate::telemetry::{jetson_footprint, FootprintModel, ResourceTracker};

/// One Fig 10 bar.
#[derive(Debug, Clone)]
pub struct Fig10Bar {
    pub tuner: &'static str,
    pub mode: PowerMode,
    pub cpu_pct: f64,
    pub rss_mib: f64,
}

/// Measured host-side footprint for one tuner run.
#[derive(Debug, Clone)]
pub struct HostFootprint {
    pub tuner: &'static str,
    pub cpu_seconds: f64,
    pub wall_seconds: f64,
    pub peak_rss_mib: f64,
}

/// Fig 10 result.
#[derive(Debug, Clone)]
pub struct Fig10 {
    pub bars: Vec<Fig10Bar>,
    pub host: Vec<HostFootprint>,
}

/// Run both the model and the host measurement.
pub fn run() -> Fig10 {
    let arms = 92_160; // Hypre, the heaviest space
    let lasp = FootprintModel { arms, surrogate_obs: 0, surrogate_pool: 0 };
    let bliss = FootprintModel { arms, surrogate_obs: 64, surrogate_pool: 4 };
    let mut bars = vec![];
    for mode in [PowerMode::Maxn, PowerMode::FiveW] {
        let (c, r) = jetson_footprint(&lasp, mode);
        bars.push(Fig10Bar { tuner: "LASP", mode, cpu_pct: c, rss_mib: r });
        let (c, r) = jetson_footprint(&bliss, mode);
        bars.push(Fig10Bar { tuner: "BLISS", mode, cpu_pct: c, rss_mib: r });
    }

    // Host measurement: run each tuner for the same evaluation budget on
    // Hypre and record our own process deltas. LASP is represented by the
    // UCB tuner; BLISS by the GP searcher. Budget small enough for tests.
    let budget = 120;
    let mut host = vec![];

    let tracker = ResourceTracker::start();
    let mut eval = AppEval::new(AppKind::Hypre, PowerMode::Maxn, 7);
    let (best, _, _) = super::harness::run_lasp(
        AppKind::Hypre,
        PowerMode::Maxn,
        budget,
        0.8,
        0.2,
        7,
        crate::device::NoiseModel::none(),
    );
    assert!(best < eval.k());
    let r = tracker.report();
    host.push(HostFootprint {
        tuner: "LASP",
        cpu_seconds: r.cpu_seconds,
        wall_seconds: r.wall_seconds,
        peak_rss_mib: r.peak_rss_mib,
    });

    let tracker = ResourceTracker::start();
    let mut bo = BlissBo::new(7, 0.8, 0.2);
    let _ = bo.run(92_160, budget, &mut eval).expect("bliss run");
    let r = tracker.report();
    host.push(HostFootprint {
        tuner: "BLISS",
        cpu_seconds: r.cpu_seconds,
        wall_seconds: r.wall_seconds,
        peak_rss_mib: r.peak_rss_mib,
    });

    // Random search as the floor reference.
    let tracker = ResourceTracker::start();
    let mut rs = RandomSearch::new(7, 0.8, 0.2);
    let _ = rs.run(92_160, budget, &mut eval).expect("random run");
    let r = tracker.report();
    host.push(HostFootprint {
        tuner: "random",
        cpu_seconds: r.cpu_seconds,
        wall_seconds: r.wall_seconds,
        peak_rss_mib: r.peak_rss_mib,
    });

    Fig10 { bars, host }
}

impl Fig10 {
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = self
            .bars
            .iter()
            .map(|b| {
                vec![
                    b.tuner.to_string(),
                    b.mode.name().to_string(),
                    format!("{:.1}%", b.cpu_pct),
                    format!("{:.1} MiB", b.rss_mib),
                ]
            })
            .collect();
        print_table(
            "Fig 10 — modelled tuner footprint on the Jetson (Hypre, 92,160 arms)",
            &["tuner", "mode", "CPU", "memory"],
            &rows,
        );
        let rows: Vec<Vec<String>> = self
            .host
            .iter()
            .map(|h| {
                vec![
                    h.tuner.to_string(),
                    format!("{:.3}s", h.cpu_seconds),
                    format!("{:.3}s", h.wall_seconds),
                    format!("{:.1} MiB", h.peak_rss_mib),
                ]
            })
            .collect();
        print_table(
            "Fig 10 (host check) — measured footprint of our tuners, 120 evals",
            &["tuner", "cpu", "wall", "peak ΔRSS"],
            &rows,
        );
    }

    /// Shape: LASP's bars sit strictly below BLISS's on both modes, and the
    /// measured host CPU time shows the same asymmetry.
    pub fn matches_paper_shape(&self) -> bool {
        for mode in [PowerMode::Maxn, PowerMode::FiveW] {
            let get = |tuner: &str| {
                self.bars
                    .iter()
                    .find(|b| b.tuner == tuner && b.mode == mode)
                    .unwrap()
            };
            let (l, b) = (get("LASP"), get("BLISS"));
            if l.cpu_pct >= b.cpu_pct || l.rss_mib >= b.rss_mib {
                return false;
            }
        }
        let cpu = |tuner: &str| {
            self.host
                .iter()
                .find(|h| h.tuner == tuner)
                .map(|h| h.cpu_seconds)
                .unwrap_or(0.0)
        };
        cpu("LASP") <= cpu("BLISS")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_shape_holds() {
        let fig = run();
        assert_eq!(fig.bars.len(), 4);
        assert!(fig.matches_paper_shape(), "{:?} host={:?}", fig.bars, fig.host);
    }
}
