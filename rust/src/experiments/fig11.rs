//! Fig 11 — best-run cumulative regret (Eq. 1) for the four applications
//! at α = 0.8 (time focus) and α = 0.2 (power focus). The paper shows
//! regret saturating after an initial trial-and-error phase.

use super::harness::print_table;
use crate::apps::AppKind;
use crate::device::PowerMode;
use crate::sim::{Scenario, SweepRunner};

/// One regret curve.
#[derive(Debug, Clone)]
pub struct RegretCurve {
    pub app: AppKind,
    pub alpha: f64,
    /// Cumulative regret per iteration (best of `tries` seeds — the paper
    /// plots the one-time least-regret run).
    pub trajectory: Vec<f64>,
}

impl RegretCurve {
    /// Regret accumulated in the last quarter vs the first quarter — the
    /// saturation signature.
    pub fn saturation_ratio(&self) -> f64 {
        let n = self.trajectory.len();
        let first = self.trajectory[n / 4 - 1];
        let last = self.trajectory[n - 1] - self.trajectory[3 * n / 4 - 1];
        last / first.max(1e-9)
    }

    pub fn total(&self) -> f64 {
        *self.trajectory.last().unwrap_or(&0.0)
    }
}

/// Fig 11 result.
#[derive(Debug, Clone)]
pub struct Fig11 {
    pub curves: Vec<RegretCurve>,
    pub iterations: usize,
}

/// Best-of-`tries` regret runs per (app, α), all tries fanned out as one
/// parallel sweep with the regret oracle installed per cell.
pub fn run(iterations: usize, tries: usize) -> Fig11 {
    let mut grid = vec![];
    for app in AppKind::all() {
        for alpha in [0.8, 0.2] {
            for t in 0..tries {
                grid.push(
                    Scenario::lasp(app, PowerMode::Maxn, iterations, 1100 + t as u64)
                        .with_objective(alpha, 1.0 - alpha)
                        .recording_regret(),
                );
            }
        }
    }
    let outcomes = SweepRunner::new(0).run(&grid).expect("fig11 sweep");

    let mut curves = vec![];
    let mut cursor = outcomes.into_iter();
    for app in AppKind::all() {
        for alpha in [0.8, 0.2] {
            let best = cursor
                .by_ref()
                .take(tries)
                .map(|out| out.regret.expect("regret installed"))
                .min_by(|a, b| {
                    a.last().unwrap_or(&f64::INFINITY).total_cmp(b.last().unwrap_or(&f64::INFINITY))
                })
                .expect("at least one try");
            curves.push(RegretCurve { app, alpha, trajectory: best });
        }
    }
    Fig11 { curves, iterations }
}

impl Fig11 {
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = self
            .curves
            .iter()
            .map(|c| {
                let n = c.trajectory.len();
                vec![
                    c.app.to_string(),
                    format!("{}", c.alpha),
                    format!("{:.1}", c.trajectory[n / 4 - 1]),
                    format!("{:.1}", c.trajectory[n / 2 - 1]),
                    format!("{:.1}", c.total()),
                    format!("{:.2}", c.saturation_ratio()),
                ]
            })
            .collect();
        print_table(
            &format!("Fig 11 — cumulative regret over {} iterations (best run)", self.iterations),
            &["app", "α", "R @T/4", "R @T/2", "R @T", "late/early ratio"],
            &rows,
        );
    }

    /// Shape: regret saturates — strictly for time-focused curves, loosely
    /// for power-focused ones (the paper itself observes LASP "is more
    /// effective in finding configurations with shorter execution times";
    /// power rewards are flatter, so those curves bend later).
    pub fn matches_paper_shape(&self) -> bool {
        let time_ok = self
            .curves
            .iter()
            .filter(|c| c.alpha >= 0.5)
            .all(|c| c.saturation_ratio() < 0.85);
        let power_ok = self
            .curves
            .iter()
            .filter(|c| c.alpha < 0.5)
            .all(|c| c.saturation_ratio() < 1.0);
        let means: Vec<f64> = self.curves.iter().map(|c| c.saturation_ratio()).collect();
        time_ok && power_ok && crate::util::stats::mean(&means) < 0.6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_shape_holds() {
        let fig = run(1000, 2);
        assert_eq!(fig.curves.len(), 8);
        assert!(
            fig.matches_paper_shape(),
            "{:?}",
            fig.curves
                .iter()
                .map(|c| (c.app, c.alpha, c.saturation_ratio()))
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn regret_monotone_nondecreasing() {
        let fig = run(400, 1);
        for c in &fig.curves {
            assert!(c.trajectory.windows(2).all(|w| w[1] >= w[0] - 1e-9));
        }
    }
}
