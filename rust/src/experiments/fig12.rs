//! Fig 12 — performance under synthetic measurement error: random noise of
//! 5%, 10% and 15% injected into the measured data (also a proxy for
//! network fluctuation). The paper's claim: gains degrade gracefully, LASP
//! keeps finding good configurations.

use super::harness::{edge_oracle, print_table, LF_FIDELITY};
use crate::apps::{self, AppKind};
use crate::device::{NoiseModel, PowerMode};
use crate::sim::{Scenario, SweepRunner};
use crate::util::stats;

/// One (app, noise level) cell.
#[derive(Debug, Clone)]
pub struct Fig12Cell {
    pub app: AppKind,
    pub noise_pct: f64,
    /// Eq. 8 time gain vs default under this noise level (mean over seeds).
    pub gain_pct: f64,
}

/// Fig 12 result.
#[derive(Debug, Clone)]
pub struct Fig12 {
    pub cells: Vec<Fig12Cell>,
    pub iterations: usize,
}

/// Run all apps × noise ∈ {0, 5, 10, 15}% × seeds as one parallel sweep.
pub fn run(iterations: usize, seeds: usize) -> Fig12 {
    const NOISE_PCTS: [f64; 4] = [0.0, 0.05, 0.10, 0.15];
    let mut grid = vec![];
    for app in AppKind::all() {
        for noise_pct in NOISE_PCTS {
            let noise = if noise_pct > 0.0 {
                NoiseModel::uniform(noise_pct)
            } else {
                NoiseModel::none()
            };
            for s in 0..seeds {
                grid.push(
                    Scenario::lasp(app, PowerMode::Maxn, iterations, 1200 + s as u64)
                        .with_objective(0.8, 0.2)
                        .with_noise(noise),
                );
            }
        }
    }
    let outcomes = SweepRunner::new(0).run(&grid).expect("fig12 sweep");

    let mut cells = vec![];
    let mut cursor = outcomes.into_iter();
    for app in AppKind::all() {
        let sweep = edge_oracle(app, PowerMode::Maxn, LF_FIDELITY);
        let default = apps::build(app).default_index();
        for noise_pct in NOISE_PCTS {
            let gains: Vec<f64> = cursor
                .by_ref()
                .take(seeds)
                .map(|out| {
                    (sweep[default].time_s - sweep[out.best_index].time_s)
                        / sweep[default].time_s
                        * 100.0
                })
                .collect();
            cells.push(Fig12Cell { app, noise_pct, gain_pct: stats::mean(&gains) });
        }
    }
    Fig12 { cells, iterations }
}

impl Fig12 {
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = AppKind::all()
            .into_iter()
            .map(|app| {
                let mut row = vec![app.to_string()];
                for n in [0.0, 0.05, 0.10, 0.15] {
                    let c = self
                        .cells
                        .iter()
                        .find(|c| c.app == app && c.noise_pct == n)
                        .unwrap();
                    row.push(format!("{:+.1}%", c.gain_pct));
                }
                row
            })
            .collect();
        print_table(
            &format!("Fig 12 — time gain vs default under measurement error ({} iters)", self.iterations),
            &["app", "no noise", "5% noise", "10% noise", "15% noise"],
            &rows,
        );
    }

    /// Shape: considerable gains survive even at 15% noise.
    pub fn matches_paper_shape(&self) -> bool {
        self.cells
            .iter()
            .filter(|c| c.noise_pct == 0.15)
            .all(|c| c.gain_pct > 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_shape_holds() {
        let fig = run(500, 2);
        assert_eq!(fig.cells.len(), 16);
        assert!(
            fig.matches_paper_shape(),
            "{:?}",
            fig.cells
                .iter()
                .map(|c| (c.app, c.noise_pct, c.gain_pct))
                .collect::<Vec<_>>()
        );
    }
}
