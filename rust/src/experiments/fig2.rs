//! Fig 2 — overlap of optimal configurations between low- and high-fidelity
//! settings: (a) average HF-oracle distance of the LF top-20; (b) number of
//! common configurations in the LF and HF top-20.
//!
//! Paper workloads: Lulesh (mesh 50 vs 80), Kripke (zones 32 vs 64), Hypre
//! (grid 32 vs 64) — i.e. LF on the Jetson vs HF on the i7-14700.

use super::harness::{print_table, LF_FIDELITY};
use crate::apps::{self, AppKind};
use crate::coordinator::transfer::{lf_hf_topk_overlap, lf_topk_hf_distance};
use crate::device::{Device, HpcNode, PowerMode};

/// One Fig 2 row.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub app: AppKind,
    /// (a) mean HF-oracle distance (%) of the LF top-20.
    pub avg_distance_pct: f64,
    /// (b) |top-20(LF) ∩ top-20(HF)|.
    pub common_in_top20: usize,
}

/// Full Fig 2 result.
#[derive(Debug, Clone)]
pub struct Fig2 {
    pub rows: Vec<Fig2Row>,
}

/// Run the experiment for the apps the paper uses in this figure, one app
/// per pool slot (Hypre's 92k-arm LF+HF sweeps dominate).
pub fn run() -> Fig2 {
    let edge = PowerMode::Maxn.spec();
    let hpc_node = HpcNode::new(0);
    let hpc = hpc_node.spec();
    let kinds = [AppKind::Lulesh, AppKind::Kripke, AppKind::Clomp, AppKind::Hypre];
    let rows = crate::sim::SweepRunner::new(0).map(kinds.len(), |i| {
        let kind = kinds[i];
        let app = apps::build(kind);
        Fig2Row {
            app: kind,
            avg_distance_pct: lf_topk_hf_distance(app.as_ref(), &edge, hpc, LF_FIDELITY, 20),
            common_in_top20: lf_hf_topk_overlap(app.as_ref(), &edge, hpc, LF_FIDELITY, 20),
        }
    });
    Fig2 { rows }
}

impl Fig2 {
    /// Print the figure's two panels as tables.
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.app.to_string(),
                    format!("{:.1}%", r.avg_distance_pct),
                    format!("{}/20", r.common_in_top20),
                ]
            })
            .collect();
        print_table(
            "Fig 2 — LF/HF optimal-configuration overlap",
            &["app", "(a) avg distance of LF top-20 on HF", "(b) common in top-20"],
            &rows,
        );
    }

    /// Paper-shape acceptance: distances bounded, overlap significant.
    pub fn matches_paper_shape(&self) -> bool {
        self.rows.iter().all(|r| {
            // Paper: "within 25% of the oracle" on average (we allow 2x
            // slack for the simulated substrate) and meaningful overlap.
            r.avg_distance_pct < 50.0 && r.common_in_top20 >= 5
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shape_holds() {
        let fig = run();
        assert_eq!(fig.rows.len(), 4);
        assert!(fig.matches_paper_shape(), "{:?}", fig.rows);
    }

    #[test]
    fn small_apps_overlap_heavily() {
        let fig = run();
        for r in &fig.rows {
            if matches!(r.app, AppKind::Lulesh | AppKind::Kripke | AppKind::Clomp) {
                assert!(r.common_in_top20 >= 8, "{:?}", r);
            }
        }
    }
}
