//! Fig 3 — distribution of Kripke execution times over the configuration
//! space: (a) variance induced by tuning only two parameter groups;
//! (b) histogram over all 216 configurations.

use super::harness::{edge_oracle, print_table};
use crate::apps::{self, AppKind};
use crate::device::PowerMode;
use crate::util::stats;

/// Fig 3 result.
#[derive(Debug, Clone)]
pub struct Fig3 {
    /// (a) spread of execution time when tuning only (gset, dset) at the
    /// default layout: (min, median, max) seconds.
    pub two_param_spread: (f64, f64, f64),
    /// (a) same spread when tuning all three parameters.
    pub full_spread: (f64, f64, f64),
    /// (b) histogram over all configurations: (lo, hi, count) bins.
    pub histogram: Vec<(f64, f64, usize)>,
    /// All execution times (for downstream analysis).
    pub times: Vec<f64>,
}

/// Run on Kripke at HF (the paper plots the target-size distribution).
pub fn run() -> Fig3 {
    let sweep = edge_oracle(AppKind::Kripke, PowerMode::Maxn, 1.0);
    let times: Vec<f64> = sweep.iter().map(|m| m.time_s).collect();

    // Two-parameter slice: default layout (position 0), vary gset & dset.
    let app = apps::build(AppKind::Kripke);
    let mut slice = vec![];
    for g in 0..6 {
        for d in 0..6 {
            let idx = app.space().encode_positions(&[0, g, d]);
            slice.push(times[idx]);
        }
    }
    let spread = |xs: &[f64]| {
        (
            xs.iter().cloned().fold(f64::INFINITY, f64::min),
            stats::quantile(xs, 0.5),
            xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        )
    };
    Fig3 {
        two_param_spread: spread(&slice),
        full_spread: spread(&times),
        histogram: stats::histogram(&times, 12),
        times,
    }
}

impl Fig3 {
    pub fn report(&self) {
        let fmt = |s: (f64, f64, f64)| {
            vec![format!("{:.2}s", s.0), format!("{:.2}s", s.1), format!("{:.2}s", s.2)]
        };
        let mut rows = vec![];
        let mut a = vec!["2 params (gset,dset)".to_string()];
        a.extend(fmt(self.two_param_spread));
        rows.push(a);
        let mut b = vec!["all 3 params".to_string()];
        b.extend(fmt(self.full_spread));
        rows.push(b);
        print_table(
            "Fig 3(a) — Kripke execution-time spread",
            &["tuned set", "min", "median", "max"],
            &rows,
        );
        let hist_rows: Vec<Vec<String>> = self
            .histogram
            .iter()
            .map(|(lo, hi, c)| {
                vec![
                    format!("{lo:.2}-{hi:.2}s"),
                    format!("{c}"),
                    "#".repeat(*c / 2 + usize::from(*c > 0)),
                ]
            })
            .collect();
        print_table("Fig 3(b) — distribution over all configurations", &["bin", "count", ""], &hist_rows);
    }

    /// Shape: wide variance from 2 params; wider with 3; long tail.
    pub fn matches_paper_shape(&self) -> bool {
        let (lo2, _, hi2) = self.two_param_spread;
        let (lo3, med3, hi3) = self.full_spread;
        hi2 / lo2 > 1.3 // two params alone already move runtime a lot
            && hi3 / lo3 >= hi2 / lo2 // full space is wider
            && (med3 - lo3) < (hi3 - med3) // right-skewed tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_holds() {
        let fig = run();
        assert!(fig.matches_paper_shape(), "{:?} {:?}", fig.two_param_spread, fig.full_spread);
    }

    #[test]
    fn histogram_covers_all_configs() {
        let fig = run();
        assert_eq!(fig.histogram.iter().map(|(_, _, c)| c).sum::<usize>(), 216);
    }
}
