//! Fig 4 — runtime variability of Kripke when each parameter is tuned
//! independently (all others held at their defaults).

use super::harness::{edge_oracle, print_table};
use crate::apps::{self, AppKind};
use crate::device::PowerMode;

/// Per-parameter sweep result.
#[derive(Debug, Clone)]
pub struct ParamSweep {
    pub param: String,
    /// Execution time per value of this parameter (others default).
    pub times: Vec<(String, f64)>,
    /// max/min ratio — the parameter's individual leverage.
    pub spread: f64,
}

/// Fig 4 result.
#[derive(Debug, Clone)]
pub struct Fig4 {
    pub sweeps: Vec<ParamSweep>,
}

/// Sweep each Kripke parameter independently at HF, one parameter per
/// pool slot.
pub fn run() -> Fig4 {
    let app = apps::build(AppKind::Kripke);
    let sweep = edge_oracle(AppKind::Kripke, PowerMode::Maxn, 1.0);
    let times: Vec<f64> = sweep.iter().map(|m| m.time_s).collect();
    let defaults = app.space().default_positions();

    let params = app.space().params();
    let sweeps = crate::sim::SweepRunner::new(0).map(params.len(), |pi| {
        let p = &params[pi];
        let mut rows = vec![];
        for (vi, v) in p.values().iter().enumerate() {
            let mut pos = defaults.clone();
            pos[pi] = vi;
            let idx = app.space().encode_positions(&pos);
            rows.push((v.to_string(), times[idx]));
        }
        let lo = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
        let hi = rows.iter().map(|r| r.1).fold(f64::NEG_INFINITY, f64::max);
        ParamSweep { param: p.name().to_string(), times: rows, spread: hi / lo }
    });
    Fig4 { sweeps }
}

impl Fig4 {
    pub fn report(&self) {
        for s in &self.sweeps {
            let rows: Vec<Vec<String>> = s
                .times
                .iter()
                .map(|(v, t)| vec![v.clone(), format!("{t:.3}s")])
                .collect();
            print_table(
                &format!("Fig 4 — Kripke runtime vs `{}` (spread {:.2}x)", s.param, s.spread),
                &["value", "time"],
                &rows,
            );
        }
    }

    /// Shape: every parameter matters individually; none is a no-op.
    pub fn matches_paper_shape(&self) -> bool {
        self.sweeps.iter().all(|s| s.spread > 1.02)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_covers_all_params() {
        let fig = run();
        let names: Vec<&str> = fig.sweeps.iter().map(|s| s.param.as_str()).collect();
        assert_eq!(names, vec!["layout", "gset", "dset"]);
        assert_eq!(fig.sweeps[0].times.len(), 6);
    }

    #[test]
    fn fig4_shape_holds() {
        let fig = run();
        assert!(fig.matches_paper_shape());
    }
}
