//! Fig 6 — Lulesh selection-frequency heatmaps over (r, s), for 500 and
//! 1000 iterations, with power and with execution time as the objective.
//! Darker cell = selected more often by LASP.

use super::harness::{ALPHA_POWER, ALPHA_TIME};
use crate::apps::{self, AppKind};
use crate::device::PowerMode;
use crate::sim::{Scenario, SweepRunner};

/// One heatmap: counts[r_pos][s_pos].
#[derive(Debug, Clone)]
pub struct Heatmap {
    pub label: String,
    pub iterations: usize,
    pub counts: Vec<Vec<f64>>,
    /// Eq. 4 output of the run.
    pub best_index: usize,
}

/// Fig 6 result: the four panels.
#[derive(Debug, Clone)]
pub struct Fig6 {
    pub panels: Vec<Heatmap>,
}

/// Run the four panels (paper: power/time × 1000/500 iterations) as one
/// parallel sweep.
pub fn run() -> Fig6 {
    let panels = [
        ("(a) power, 1000 iters", 1000usize, ALPHA_POWER, 61u64),
        ("(b) power, 500 iters", 500, ALPHA_POWER, 62),
        ("(c) time, 1000 iters", 1000, ALPHA_TIME, 63),
        ("(d) time, 500 iters", 500, ALPHA_TIME, 64),
    ];
    let cells: Vec<Scenario> = panels
        .iter()
        .map(|&(_, iterations, (alpha, beta), seed)| {
            Scenario::lasp(AppKind::Lulesh, PowerMode::Maxn, iterations, seed)
                .with_objective(alpha, beta)
        })
        .collect();
    let outcomes = SweepRunner::new(0).run(&cells).expect("fig6 sweep");

    let app = apps::build(AppKind::Lulesh);
    let heatmaps = panels
        .iter()
        .zip(outcomes)
        .map(|(&(label, iterations, _, _), out)| {
            // Fold dense counts into the (r: 16, s: 8) grid.
            let mut grid = vec![vec![0.0; 8]; 16];
            for (idx, &c) in out.counts.as_ref().expect("policy counts").iter().enumerate() {
                let pos = app.space().positions(idx);
                grid[pos[0]][pos[1]] += c;
            }
            Heatmap { label: label.into(), iterations, counts: grid, best_index: out.best_index }
        })
        .collect();
    Fig6 { panels: heatmaps }
}

impl Fig6 {
    /// ASCII heatmaps (darker = more pulls).
    pub fn report(&self) {
        const SHADES: [char; 5] = [' ', '.', 'o', 'O', '@'];
        for p in &self.panels {
            println!("\n## Fig 6 {} — Lulesh selection frequency (rows r=1..16, cols s=1..8)", p.label);
            let max = p
                .counts
                .iter()
                .flatten()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max)
                .max(1.0);
            for (ri, row) in p.counts.iter().enumerate() {
                let cells: String = row
                    .iter()
                    .map(|&c| {
                        let shade = ((c / max) * (SHADES.len() - 1) as f64).round() as usize;
                        SHADES[shade.min(SHADES.len() - 1)]
                    })
                    .collect();
                println!("r={:>2} |{cells}|", ri + 1);
            }
            println!("best (Eq.4): config #{}", p.best_index);
        }
    }

    /// Shape: selection mass concentrates — the top cell dominates, and
    /// more iterations concentrate at least comparably.
    pub fn matches_paper_shape(&self) -> bool {
        self.panels.iter().all(|p| {
            let total: f64 = p.counts.iter().flatten().sum();
            let max = p.counts.iter().flatten().cloned().fold(f64::NEG_INFINITY, f64::max);
            max / total > 0.05 // one cell holds a clearly-visible mass
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_panels_with_conserved_mass() {
        let fig = run();
        assert_eq!(fig.panels.len(), 4);
        for p in &fig.panels {
            let total: f64 = p.counts.iter().flatten().sum();
            assert_eq!(total, p.iterations as f64);
        }
    }

    #[test]
    fn fig6_shape_holds() {
        let fig = run();
        assert!(fig.matches_paper_shape());
    }

    #[test]
    fn time_and_power_panels_differ() {
        let fig = run();
        // The (time, 1000) and (power, 1000) concentration cells differ in
        // general; at minimum the full count grids are not identical.
        assert_ne!(fig.panels[0].counts, fig.panels[2].counts);
    }
}
