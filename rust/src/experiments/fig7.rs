//! Fig 7 — efficient exploration of the parameter space for Kripke (a, b)
//! and Clomp (c, d), with execution time and power as objective metrics.
//! Shows convergence of the selection distribution toward the oracle.

use super::harness::{oracle_index, ALPHA_POWER, ALPHA_TIME};
use crate::apps::AppKind;
use crate::device::PowerMode;
use crate::sim::{Scenario, SweepRunner};
use crate::util::stats;

/// One panel: an app × objective exploration run.
#[derive(Debug, Clone)]
pub struct Fig7Panel {
    pub label: String,
    pub app: AppKind,
    /// Pull counts per arm after the run.
    pub counts: Vec<f64>,
    /// Eq. 4 recommendation.
    pub best_index: usize,
    /// Noise-free oracle arm for this objective.
    pub oracle: usize,
    /// Fraction of pulls on the top-5 most-pulled arms (concentration).
    pub top5_mass: f64,
}

/// Fig 7 result (four panels).
#[derive(Debug, Clone)]
pub struct Fig7 {
    pub panels: Vec<Fig7Panel>,
}

/// Run the four panels as one parallel sweep.
pub fn run() -> Fig7 {
    let iterations = 1000usize;
    let panels = [
        ("(a) kripke, time", AppKind::Kripke, ALPHA_TIME, 71u64),
        ("(b) kripke, power", AppKind::Kripke, ALPHA_POWER, 72),
        ("(c) clomp, time", AppKind::Clomp, ALPHA_TIME, 73),
        ("(d) clomp, power", AppKind::Clomp, ALPHA_POWER, 74),
    ];
    let cells: Vec<Scenario> = panels
        .iter()
        .map(|&(_, app, (alpha, beta), seed)| {
            Scenario::lasp(app, PowerMode::Maxn, iterations, seed).with_objective(alpha, beta)
        })
        .collect();
    let outcomes = SweepRunner::new(0).run(&cells).expect("fig7 sweep");
    let built = panels
        .iter()
        .zip(outcomes)
        .map(|(&(label, app, (alpha, beta), _), out)| {
            let counts = out.counts.expect("policy counts");
            let oracle = oracle_index(app, PowerMode::Maxn, alpha, beta);
            let mut sorted = counts.clone();
            sorted.sort_by(|a, b| b.total_cmp(a));
            let top5_mass: f64 = sorted.iter().take(5).sum::<f64>() / iterations as f64;
            Fig7Panel {
                label: label.into(),
                app,
                counts,
                best_index: out.best_index,
                oracle,
                top5_mass,
            }
        })
        .collect();
    Fig7 { panels: built }
}

impl Fig7 {
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = self
            .panels
            .iter()
            .map(|p| {
                vec![
                    p.label.clone(),
                    format!("#{}", p.best_index),
                    format!("#{}", p.oracle),
                    format!("{:.0}%", p.top5_mass * 100.0),
                    format!("{:.0}", p.counts[p.best_index]),
                ]
            })
            .collect();
        super::harness::print_table(
            "Fig 7 — exploration convergence (Kripke & Clomp)",
            &["panel", "LASP pick", "oracle", "top-5 pull mass", "pulls of pick"],
            &rows,
        );
    }

    /// Shape: selection concentrates and the pick is near-oracle in the
    /// sense of pull mass (paper: "converges to the optimal configuration,
    /// as indicated by the oracle").
    pub fn matches_paper_shape(&self) -> bool {
        self.panels.iter().all(|p| {
            let k = p.counts.len() as f64;
            // Top-5 arms hold far more than uniform mass...
            p.top5_mass > 5.0 / k * 4.0
            // ...and the pick is itself heavily pulled.
            && p.counts[p.best_index] > stats::mean(&p.counts) * 3.0
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_shape_holds() {
        let fig = run();
        assert_eq!(fig.panels.len(), 4);
        assert!(fig.matches_paper_shape(), "{:?}",
            fig.panels.iter().map(|p| (p.label.clone(), p.top5_mass)).collect::<Vec<_>>());
    }

    #[test]
    fn time_panels_pick_fast_arms() {
        let fig = run();
        for p in &fig.panels {
            if p.label.contains("time") {
                // The pick's expected time must be well inside the fast
                // half of the space.
                let sweep = super::super::harness::edge_oracle(
                    p.app,
                    PowerMode::Maxn,
                    super::super::harness::LF_FIDELITY,
                );
                let times: Vec<f64> = sweep.iter().map(|m| m.time_s).collect();
                let med = stats::quantile(&times, 0.5);
                assert!(times[p.best_index] < med, "{}: {} vs median {med}", p.label, times[p.best_index]);
            }
        }
    }
}
