//! Fig 8 — performance gain (Eq. 8) for the four applications under
//! varying α. Paper anchors at α = 0.2 (power focus): Clomp 10%, Lulesh
//! 14%, Hypre 9%, Kripke 6%; gains in execution time at α = 0.8 are larger.

use super::harness::{edge_oracle, print_table, LF_FIDELITY};
use crate::apps::{self, AppKind};
use crate::device::PowerMode;
use crate::sim::{Scenario, SweepRunner};

/// One (app, α) cell.
#[derive(Debug, Clone)]
pub struct GainCell {
    pub app: AppKind,
    pub alpha: f64,
    /// Eq. 8 gain in the α-weighted objective's primary metric, percent.
    pub gain_pct: f64,
}

/// Fig 8 result.
#[derive(Debug, Clone)]
pub struct Fig8 {
    pub cells: Vec<GainCell>,
    pub iterations: usize,
}

/// Run for α ∈ {0.2, 0.35, 0.65, 0.8} across all four apps (the paper
/// varies α; 0.5 is ill-posed for a *single-metric* Eq. 8 readout since
/// the tuner legitimately trades the two metrics there) — one parallel
/// sweep over the 16-cell grid, Eq. 8 gain computed against the
/// noise-free expected metric (time for α ≥ 0.5, else power).
pub fn run(iterations: usize) -> Fig8 {
    let mut grid = vec![];
    for app in AppKind::all() {
        for (i, alpha) in [0.2, 0.35, 0.65, 0.8].into_iter().enumerate() {
            grid.push(
                Scenario::lasp(app, PowerMode::Maxn, iterations, 80 + i as u64)
                    .with_objective(alpha, 1.0 - alpha),
            );
        }
    }
    let outcomes = SweepRunner::new(0).run(&grid).expect("fig8 sweep");

    let cells = grid
        .iter()
        .zip(outcomes)
        .map(|(cell, out)| {
            let sweep = edge_oracle(cell.app, PowerMode::Maxn, LF_FIDELITY);
            let default = apps::build(cell.app).default_index();
            let metric = |i: usize| {
                if cell.alpha >= 0.5 {
                    sweep[i].time_s
                } else {
                    sweep[i].power_w
                }
            };
            let gain_pct = (metric(default) - metric(out.best_index)) / metric(default) * 100.0;
            GainCell { app: cell.app, alpha: cell.alpha, gain_pct }
        })
        .collect();
    Fig8 { cells, iterations }
}

impl Fig8 {
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = AppKind::all()
            .into_iter()
            .map(|app| {
                let mut row = vec![app.to_string()];
                for alpha in [0.2, 0.35, 0.65, 0.8] {
                    let c = self
                        .cells
                        .iter()
                        .find(|c| c.app == app && c.alpha == alpha)
                        .unwrap();
                    row.push(format!("{:+.1}%", c.gain_pct));
                }
                row
            })
            .collect();
        print_table(
            &format!("Fig 8 — performance gain vs default ({} iterations)", self.iterations),
            &["app", "α=0.2 (power)", "α=0.35 (power)", "α=0.65 (time)", "α=0.8 (time)"],
            &rows,
        );
    }

    /// Shape: positive gains everywhere; time-focused gains ≥ power-focused
    /// on average (paper §V-D/E: power rewards are flatter on the edge).
    pub fn matches_paper_shape(&self) -> bool {
        let positive = self.cells.iter().all(|c| c.gain_pct > 0.0);
        let avg = |alpha: f64| {
            let xs: Vec<f64> = self
                .cells
                .iter()
                .filter(|c| c.alpha == alpha)
                .map(|c| c.gain_pct)
                .collect();
            crate::util::stats::mean(&xs)
        };
        positive && avg(0.8) >= avg(0.2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_shape_holds() {
        let fig = run(600);
        assert_eq!(fig.cells.len(), 16);
        assert!(
            fig.matches_paper_shape(),
            "{:?}",
            fig.cells.iter().map(|c| (c.app, c.alpha, c.gain_pct)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn power_gains_in_paper_ballpark() {
        // Paper: 6-14% at power focus. Allow a generous band: >1%, <40%.
        let fig = run(600);
        for c in fig.cells.iter().filter(|c| c.alpha == 0.2) {
            assert!(c.gain_pct > 0.5 && c.gain_pct < 40.0, "{:?} {:.1}%", c.app, c.gain_pct);
        }
    }
}
