//! Fig 9 — mean distance from the Oracle configuration (§II-A metric)
//! across repeated LASP runs. Paper: within 12% of the optimal even on
//! Hypre's 92k-arm space when optimizing execution time; power-focused
//! runs land farther (power rewards are flatter).

use super::harness::{edge_oracle, print_table, LF_FIDELITY};
use crate::apps::AppKind;
use crate::device::PowerMode;
use crate::sim::{Scenario, SweepRunner};
use crate::tuning::oracle_distance_pct;
use crate::util::stats;

/// One (app, objective) row.
#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub app: AppKind,
    pub objective: &'static str,
    /// Mean distance from Oracle over the runs, percent.
    pub mean_distance_pct: f64,
    /// Std-dev across runs.
    pub std_pct: f64,
    /// Best run.
    pub min_pct: f64,
}

/// Fig 9 result.
#[derive(Debug, Clone)]
pub struct Fig9 {
    pub rows: Vec<Fig9Row>,
    pub runs: usize,
    pub iterations: usize,
}

fn distance_of_best(best: usize, alpha: f64, sweep: &[crate::device::Measurement]) -> f64 {
    if alpha >= 0.5 {
        oracle_distance_pct(sweep, best)
    } else {
        // Power objective: same §II-A formula over power draw.
        let powers: Vec<f64> = sweep.iter().map(|m| m.power_w).collect();
        let oracle = powers[stats::argmin(&powers)];
        (powers[best] / oracle - 1.0) * 100.0
    }
}

/// Run `runs` repetitions per (app, objective) pair — one flat sweep of
/// `4 apps × 2 objectives × runs` cells across the pool (the paper's full
/// setting is 100 × 1000 iterations; serial seed-era code ground through
/// it one episode at a time).
pub fn run(runs: usize, iterations: usize) -> Fig9 {
    const OBJECTIVES: [(&str, f64, f64); 2] = [("time", 0.8, 0.2), ("power", 0.2, 0.8)];
    let mut grid = vec![];
    for app in AppKind::all() {
        for (_, alpha, beta) in OBJECTIVES {
            for r in 0..runs {
                grid.push(
                    Scenario::lasp(app, PowerMode::Maxn, iterations, 900 + r as u64)
                        .with_objective(alpha, beta),
                );
            }
        }
    }
    let outcomes = SweepRunner::new(0).run(&grid).expect("fig9 sweep");

    let mut rows = vec![];
    let mut cursor = grid.iter().zip(outcomes);
    for app in AppKind::all() {
        let sweep = edge_oracle(app, PowerMode::Maxn, LF_FIDELITY);
        for (objective, alpha, _) in OBJECTIVES {
            let dists: Vec<f64> = cursor
                .by_ref()
                .take(runs)
                .map(|(cell, out)| {
                    debug_assert_eq!((cell.app, cell.alpha), (app, alpha));
                    distance_of_best(out.best_index, alpha, &sweep)
                })
                .collect();
            rows.push(Fig9Row {
                app,
                objective,
                mean_distance_pct: stats::mean(&dists),
                std_pct: stats::std_dev(&dists),
                min_pct: dists.iter().cloned().fold(f64::INFINITY, f64::min),
            });
        }
    }
    Fig9 { rows, runs, iterations }
}

impl Fig9 {
    pub fn report(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.app.to_string(),
                    r.objective.to_string(),
                    format!("{:.1}%", r.mean_distance_pct),
                    format!("{:.1}%", r.std_pct),
                    format!("{:.1}%", r.min_pct),
                ]
            })
            .collect();
        print_table(
            &format!(
                "Fig 9 — distance from Oracle ({} runs × {} iterations)",
                self.runs, self.iterations
            ),
            &["app", "objective", "mean", "std", "best run"],
            &rows,
        );
    }

    /// Shape: small spaces land close to the oracle; time-focused runs on
    /// every app are within a modest band; power-focused runs are allowed
    /// to be worse (the paper's own observation).
    pub fn matches_paper_shape(&self) -> bool {
        self.rows.iter().all(|r| {
            let bound = match (r.app, r.objective) {
                // Paper: within 12% even for Hypre (time focus). Our band
                // doubles it for substrate slack.
                (AppKind::Hypre, "time") => 25.0,
                (_, "time") => 15.0,
                _ => 60.0, // power focus: flatter rewards, larger distances
            };
            r.mean_distance_pct < bound && r.mean_distance_pct >= 0.0
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_shape_holds_small_runs() {
        // Keep CI cheap: 5 runs; the bench runs the paper's 100.
        let fig = run(5, 600);
        assert_eq!(fig.rows.len(), 8);
        assert!(
            fig.matches_paper_shape(),
            "{:?}",
            fig.rows
                .iter()
                .map(|r| (r.app, r.objective, r.mean_distance_pct))
                .collect::<Vec<_>>()
        );
    }
}
