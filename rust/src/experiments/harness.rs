//! Shared experiment harness: app+device evaluation closures, LASP runs,
//! and the default experiment constants (iteration counts, seeds, α/β
//! pairs) used across figures.
//!
//! Since the scenario-engine refactor the run helpers here are thin
//! wrappers over [`crate::sim`]: `run_lasp` and `run_with_regret` declare
//! one [`Scenario`] cell and execute it through the shared episode
//! stepper (`rust/tests/sim_engine.rs` pins their output bit-for-bit to
//! the pre-refactor loops).

use crate::apps::{self, AppKind, AppModel};
use crate::baselines::EvalFn;
use crate::device::{Device, JetsonNano, Measurement, NoiseModel, PowerMode};
use crate::sim::{run_scenario, Scenario};
use crate::tuning::expected_rewards;
use crate::util::stats;

pub use crate::sim::lasp_policy;

/// The paper's two user-priority settings (§V-D/E): time-focused and
/// power-focused.
pub const ALPHA_TIME: (f64, f64) = (0.8, 0.2);
pub const ALPHA_POWER: (f64, f64) = (0.2, 0.8);

/// Default LF evaluation point on the edge device.
pub const LF_FIDELITY: f64 = crate::sim::DEFAULT_FIDELITY;

/// [`EvalFn`] over an app model + Jetson device.
pub struct AppEval {
    pub app: Box<dyn AppModel>,
    pub device: JetsonNano,
}

impl AppEval {
    pub fn new(kind: AppKind, mode: PowerMode, seed: u64) -> Self {
        AppEval {
            app: apps::build(kind),
            device: JetsonNano::new(mode, seed).with_fidelity(LF_FIDELITY),
        }
    }

    pub fn with_noise(mut self, noise: NoiseModel) -> Self {
        // In-place injection: the device keeps its construction seed (a
        // seed-era version rebuilt the board with a hardcoded seed 1,
        // silently decorrelating "independent" runs).
        self.device.set_injected_noise(noise);
        self
    }

    pub fn k(&self) -> usize {
        self.app.space().len()
    }
}

impl EvalFn for AppEval {
    fn eval(&mut self, index: usize, fidelity: f64) -> Measurement {
        self.device.run(&self.app.workload(index, fidelity))
    }

    fn native_fidelity(&self) -> f64 {
        self.device.fidelity()
    }
}

/// One complete LASP run; returns (best index by Eq. 4, selection counts,
/// selection trace). Thin wrapper over one scenario-engine cell.
#[allow(clippy::too_many_arguments)]
pub fn run_lasp(
    kind: AppKind,
    mode: PowerMode,
    iterations: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
    noise: NoiseModel,
) -> (usize, Vec<f64>, Vec<usize>) {
    let cell = Scenario::lasp(kind, mode, iterations, seed)
        .with_objective(alpha, beta)
        .with_noise(noise)
        .recording_trace();
    let out = run_scenario(&cell).expect("LASP episode");
    (out.best_index, out.counts.expect("policy counts"), out.trace.expect("trace recorded"))
}

/// Expected per-arm (time, power) on the edge device at LF, noise-free —
/// the oracle table behind Figs 2/3/4/9/11, fanned over the sweep pool.
pub fn edge_oracle(kind: AppKind, mode: PowerMode, q: f64) -> Vec<Measurement> {
    let app = apps::build(kind);
    let spec = mode.spec();
    crate::sim::oracle_sweep_parallel(app.as_ref(), &spec, q)
}

/// Index of the noise-free oracle configuration for (α, β) on the edge.
pub fn oracle_index(kind: AppKind, mode: PowerMode, alpha: f64, beta: f64) -> usize {
    let sweep = edge_oracle(kind, mode, LF_FIDELITY);
    let mu = expected_rewards(&sweep, alpha, beta);
    stats::argmax(&mu)
}

/// A full regret-instrumented LASP run (Fig 11): one scenario cell with
/// the regret oracle installed.
pub fn run_with_regret(
    kind: AppKind,
    mode: PowerMode,
    iterations: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
) -> Vec<f64> {
    let cell = Scenario::lasp(kind, mode, iterations, seed)
        .with_objective(alpha, beta)
        .recording_regret();
    run_scenario(&cell).expect("regret episode").regret.expect("regret installed")
}

/// Markdown-ish table printer shared by the experiment reports.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}");
    println!("| {} |", headers.join(" | "));
    println!("|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        println!("| {} |", row.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_lasp_returns_consistent_counts() {
        let (best, counts, trace) = run_lasp(
            AppKind::Clomp,
            PowerMode::Maxn,
            250,
            1.0,
            0.0,
            3,
            NoiseModel::none(),
        );
        assert_eq!(trace.len(), 250);
        assert_eq!(counts.iter().sum::<f64>(), 250.0);
        assert_eq!(counts[best], counts.iter().cloned().fold(0.0, f64::max));
    }

    #[test]
    fn oracle_index_depends_on_objective() {
        let t = oracle_index(AppKind::Kripke, PowerMode::Maxn, 1.0, 0.0);
        let p = oracle_index(AppKind::Kripke, PowerMode::Maxn, 0.0, 1.0);
        // Not necessarily different, but both valid arms.
        assert!(t < 216 && p < 216);
    }

    #[test]
    fn app_eval_is_an_evalfn() {
        let mut e = AppEval::new(AppKind::Lulesh, PowerMode::Maxn, 1);
        let m = e.eval(0, e.native_fidelity());
        assert!(m.time_s > 0.0 && m.power_w > 0.0);
        assert_eq!(e.k(), 128);
    }

    #[test]
    fn with_noise_preserves_the_device_seed() {
        // Regression: `with_noise` used to rebuild the Jetson with a
        // hardcoded seed 1, so every "independently seeded" noisy eval
        // replayed the same stream. The seed must survive the builder.
        let noise = NoiseModel::uniform(0.10);
        let mut a = AppEval::new(AppKind::Clomp, PowerMode::Maxn, 5).with_noise(noise);
        let mut a2 = AppEval::new(AppKind::Clomp, PowerMode::Maxn, 5).with_noise(noise);
        let mut b = AppEval::new(AppKind::Clomp, PowerMode::Maxn, 1).with_noise(noise);
        assert_eq!(a.device.seed(), 5, "builder dropped the seed");
        let q = a.native_fidelity();
        let (ma, ma2, mb) = (a.eval(0, q), a2.eval(0, q), b.eval(0, q));
        assert_eq!(ma, ma2, "same seed must reproduce");
        assert_ne!(ma, mb, "different seeds must diverge");
        // Fidelity and noise survive alongside the seed.
        assert_eq!(a.native_fidelity(), LF_FIDELITY);
    }
}
