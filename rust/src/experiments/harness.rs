//! Shared experiment harness: app+device evaluation closures, LASP runs,
//! and the default experiment constants (iteration counts, seeds, α/β
//! pairs) used across figures.

use crate::apps::{self, AppKind, AppModel};
use crate::baselines::EvalFn;
use crate::bandit::{Policy, SubsetTuner, UcbTuner};
use crate::device::{Device, JetsonNano, Measurement, NoiseModel, PowerMode};
use crate::tuning::{expected_rewards, oracle_sweep, SessionConfig, TuningSession};
use crate::util::stats;

/// The paper's two user-priority settings (§V-D/E): time-focused and
/// power-focused.
pub const ALPHA_TIME: (f64, f64) = (0.8, 0.2);
pub const ALPHA_POWER: (f64, f64) = (0.2, 0.8);

/// Default LF evaluation point on the edge device.
pub const LF_FIDELITY: f64 = 0.15;

/// [`EvalFn`] over an app model + Jetson device.
pub struct AppEval {
    pub app: Box<dyn AppModel>,
    pub device: JetsonNano,
}

impl AppEval {
    pub fn new(kind: AppKind, mode: PowerMode, seed: u64) -> Self {
        AppEval {
            app: apps::build(kind),
            device: JetsonNano::new(mode, seed).with_fidelity(LF_FIDELITY),
        }
    }

    pub fn with_noise(mut self, noise: NoiseModel) -> Self {
        self.device = JetsonNano::new(self.device.mode(), 1)
            .with_fidelity(LF_FIDELITY)
            .with_injected_noise(noise);
        self
    }

    pub fn k(&self) -> usize {
        self.app.space().len()
    }
}

impl EvalFn for AppEval {
    fn eval(&mut self, index: usize, fidelity: f64) -> Measurement {
        self.device.run(&self.app.workload(index, fidelity))
    }

    fn native_fidelity(&self) -> f64 {
        self.device.fidelity()
    }
}

/// Build the LASP policy for a space of size `k`: plain UCB1 when the
/// budget covers the init sweep, candidate-subset LASP otherwise
/// (paper §IV-B scalability adaptation — see `bandit::subset`).
pub fn lasp_policy(k: usize, iterations: usize, alpha: f64, beta: f64, seed: u64) -> Box<dyn Policy> {
    if k > iterations / 2 && k > 256 {
        let m = SubsetTuner::recommended_size(k, iterations);
        Box::new(SubsetTuner::new(k, m, alpha, beta, seed ^ 0xA5A5))
    } else {
        Box::new(UcbTuner::new(k, alpha, beta))
    }
}

/// One complete LASP run; returns (best index by Eq. 4, selection counts,
/// selection trace).
#[allow(clippy::too_many_arguments)]
pub fn run_lasp(
    kind: AppKind,
    mode: PowerMode,
    iterations: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
    noise: NoiseModel,
) -> (usize, Vec<f64>, Vec<usize>) {
    let app = apps::build(kind);
    let k = app.space().len();
    let mut device = JetsonNano::new(mode, seed)
        .with_fidelity(LF_FIDELITY)
        .with_injected_noise(noise);
    let mut tuner = lasp_policy(k, iterations, alpha, beta, seed);
    let mut trace = Vec::with_capacity(iterations);
    for _ in 0..iterations {
        let arm = tuner.select();
        let m = device.run(&app.workload(arm, device.fidelity()));
        tuner.update(arm, m.time_s, m.power_w);
        trace.push(arm);
    }
    (tuner.most_selected(), tuner.counts().to_vec(), trace)
}

/// Expected per-arm (time, power) on the edge device at LF, noise-free —
/// the oracle table behind Figs 2/3/4/9/11.
pub fn edge_oracle(kind: AppKind, mode: PowerMode, q: f64) -> Vec<Measurement> {
    let app = apps::build(kind);
    let spec = mode.spec();
    oracle_sweep(app.as_ref(), &spec, q)
}

/// Index of the noise-free oracle configuration for (α, β) on the edge.
pub fn oracle_index(kind: AppKind, mode: PowerMode, alpha: f64, beta: f64) -> usize {
    let sweep = edge_oracle(kind, mode, LF_FIDELITY);
    let mu = expected_rewards(&sweep, alpha, beta);
    stats::argmax(&mu)
}

/// A full regret-instrumented session (Fig 11).
pub fn run_with_regret(
    kind: AppKind,
    mode: PowerMode,
    iterations: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
) -> Vec<f64> {
    let app = apps::build(kind);
    let sweep = edge_oracle(kind, mode, LF_FIDELITY);
    let mu = expected_rewards(&sweep, alpha, beta);
    let device = JetsonNano::new(mode, seed).with_fidelity(LF_FIDELITY);
    let policy = lasp_policy(app.space().len(), iterations, alpha, beta, seed);
    let mut session = TuningSession::with_policy(
        app,
        Box::new(device),
        policy,
        SessionConfig { iterations, alpha, beta, record_history: false },
    )
    .with_regret_oracle(mu);
    session.run().expect("session").regret.expect("regret installed")
}

/// Markdown-ish table printer shared by the experiment reports.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}");
    println!("| {} |", headers.join(" | "));
    println!("|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        println!("| {} |", row.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_lasp_returns_consistent_counts() {
        let (best, counts, trace) = run_lasp(
            AppKind::Clomp,
            PowerMode::Maxn,
            250,
            1.0,
            0.0,
            3,
            NoiseModel::none(),
        );
        assert_eq!(trace.len(), 250);
        assert_eq!(counts.iter().sum::<f64>(), 250.0);
        assert_eq!(counts[best], counts.iter().cloned().fold(0.0, f64::max));
    }

    #[test]
    fn oracle_index_depends_on_objective() {
        let t = oracle_index(AppKind::Kripke, PowerMode::Maxn, 1.0, 0.0);
        let p = oracle_index(AppKind::Kripke, PowerMode::Maxn, 0.0, 1.0);
        // Not necessarily different, but both valid arms.
        assert!(t < 216 && p < 216);
    }

    #[test]
    fn app_eval_is_an_evalfn() {
        let mut e = AppEval::new(AppKind::Lulesh, PowerMode::Maxn, 1);
        let m = e.eval(0, e.native_fidelity());
        assert!(m.time_s > 0.0 && m.power_w > 0.0);
        assert_eq!(e.k(), 128);
    }
}
