//! Experiment drivers — one module per table/figure in the paper's
//! evaluation (see DESIGN.md §2 for the full index). Each driver exposes
//! `run(...) -> FigN` with a `report()` printer and a
//! `matches_paper_shape()` acceptance predicate; the `benches/figN_*`
//! binaries and the `lasp experiment` CLI subcommand are thin wrappers.
//!
//! Every experiment is one [`REGISTRY`] entry (id → runner + shape
//! check); `run_by_name` and the id list are both derived from that one
//! table, so they cannot drift apart.

pub mod ablation;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod harness;
pub mod tables;

use anyhow::{anyhow, Result};

/// One registered experiment: a stable id and a runner that regenerates
/// the artifact (honouring quick mode), prints its report, and returns
/// whether the paper-shape acceptance check passed.
pub struct ExperimentSpec {
    pub id: &'static str,
    pub run: fn(quick: bool) -> bool,
}

/// Every experiment, in paper order — the single source of truth for both
/// dispatch and the id list.
pub const REGISTRY: &[ExperimentSpec] = &[
    ExperimentSpec {
        id: "table1",
        run: |_quick| {
            tables::table1_report();
            true
        },
    },
    ExperimentSpec {
        id: "table2",
        run: |_quick| {
            tables::table2_report();
            true
        },
    },
    ExperimentSpec {
        id: "fig2",
        run: |_quick| {
            let f = fig2::run();
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig3",
        run: |_quick| {
            let f = fig3::run();
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig4",
        run: |_quick| {
            let f = fig4::run();
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig6",
        run: |_quick| {
            let f = fig6::run();
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig7",
        run: |_quick| {
            let f = fig7::run();
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig8",
        run: |quick| {
            let f = fig8::run(if quick { 400 } else { 1000 });
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig9",
        run: |quick| {
            let f = fig9::run(if quick { 10 } else { 100 }, if quick { 500 } else { 1000 });
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig10",
        run: |_quick| {
            let f = fig10::run();
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig11",
        run: |quick| {
            let f = fig11::run(if quick { 600 } else { 1500 }, if quick { 2 } else { 5 });
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "fig12",
        run: |quick| {
            let f = fig12::run(if quick { 400 } else { 800 }, if quick { 2 } else { 5 });
            f.report();
            f.matches_paper_shape()
        },
    },
    ExperimentSpec {
        id: "ablation",
        run: |quick| {
            let f = ablation::run(if quick { 400 } else { 1000 });
            f.report();
            f.matches_paper_shape()
        },
    },
];

/// Run an experiment by figure/table id, printing its report. Returns
/// whether the paper-shape acceptance check passed.
pub fn run_by_name(name: &str, quick: bool) -> Result<bool> {
    let spec = REGISTRY
        .iter()
        .find(|e| e.id == name)
        .ok_or_else(|| anyhow!("unknown experiment '{name}' (try one of {:?})", all_ids()))?;
    Ok((spec.run)(quick))
}

/// All experiment ids, in paper order (derived from [`REGISTRY`]).
pub fn all_ids() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.id).collect()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_experiment_rejected() {
        assert!(super::run_by_name("fig99", true).is_err());
    }

    #[test]
    fn registry_ids_unique_and_complete() {
        let ids = super::all_ids();
        assert!(ids.len() >= 13, "registry shrank: {ids:?}");
        let set: std::collections::BTreeSet<_> = ids.iter().collect();
        assert_eq!(set.len(), ids.len(), "duplicate experiment ids");
        for expected in ["table1", "table2", "fig9", "fig12", "ablation"] {
            assert!(ids.contains(&expected), "registry lost '{expected}'");
        }
    }
}
