//! Experiment drivers — one module per table/figure in the paper's
//! evaluation (see DESIGN.md §2 for the full index). Each driver exposes
//! `run(...) -> FigN` with a `report()` printer and a
//! `matches_paper_shape()` acceptance predicate; the `benches/figN_*`
//! binaries and the `lasp experiment` CLI subcommand are thin wrappers.

pub mod ablation;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod harness;
pub mod tables;

use anyhow::{anyhow, Result};

/// Run an experiment by figure/table id, printing its report. Returns
/// whether the paper-shape acceptance check passed.
pub fn run_by_name(name: &str, quick: bool) -> Result<bool> {
    let ok = match name {
        "table1" => {
            tables::table1_report();
            true
        }
        "table2" => {
            tables::table2_report();
            true
        }
        "fig2" => {
            let f = fig2::run();
            f.report();
            f.matches_paper_shape()
        }
        "fig3" => {
            let f = fig3::run();
            f.report();
            f.matches_paper_shape()
        }
        "fig4" => {
            let f = fig4::run();
            f.report();
            f.matches_paper_shape()
        }
        "fig6" => {
            let f = fig6::run();
            f.report();
            f.matches_paper_shape()
        }
        "fig7" => {
            let f = fig7::run();
            f.report();
            f.matches_paper_shape()
        }
        "fig8" => {
            let f = fig8::run(if quick { 400 } else { 1000 });
            f.report();
            f.matches_paper_shape()
        }
        "fig9" => {
            let f = fig9::run(if quick { 10 } else { 100 }, if quick { 500 } else { 1000 });
            f.report();
            f.matches_paper_shape()
        }
        "fig10" => {
            let f = fig10::run();
            f.report();
            f.matches_paper_shape()
        }
        "fig11" => {
            let f = fig11::run(if quick { 600 } else { 1500 }, if quick { 2 } else { 5 });
            f.report();
            f.matches_paper_shape()
        }
        "fig12" => {
            let f = fig12::run(if quick { 400 } else { 800 }, if quick { 2 } else { 5 });
            f.report();
            f.matches_paper_shape()
        }
        "ablation" => {
            let f = ablation::run(if quick { 400 } else { 1000 });
            f.report();
            true
        }
        other => return Err(anyhow!("unknown experiment '{other}'")),
    };
    Ok(ok)
}

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "table1", "table2", "fig2", "fig3", "fig4", "fig6", "fig7", "fig8", "fig9",
    "fig10", "fig11", "fig12", "ablation",
];

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_experiment_rejected() {
        assert!(super::run_by_name("fig99", true).is_err());
    }
}
