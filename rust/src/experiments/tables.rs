//! Table I (Jetson power modes) and Table II (application parameter
//! spaces) as printable reports backed by the live definitions — the
//! tables are *derived from the code*, so they cannot drift.

use super::harness::print_table;
use crate::apps::{self, AppKind};
use crate::device::PowerMode;

/// Print Table I from the device-model constants.
pub fn table1_report() {
    let rows: Vec<Vec<String>> = [PowerMode::Maxn, PowerMode::FiveW]
        .iter()
        .map(|m| {
            let s = m.spec();
            vec![
                m.name().to_string(),
                format!("{:.0}", s.power_budget_w),
                format!("{}", s.cores),
                format!("{:.0}", s.freq_ghz * 1000.0),
            ]
        })
        .collect();
    print_table(
        "Table I — Jetson Nano power modes",
        &["mode", "power budget (W)", "online CPU", "CPU max freq (MHz)"],
        &rows,
    );
}

/// Print Table II from the live parameter spaces.
pub fn table2_report() {
    let mut rows = vec![];
    for kind in AppKind::all() {
        let app = apps::build(kind);
        for p in app.space().params() {
            let vals: Vec<String> = p.values().iter().map(|v| v.to_string()).collect();
            let range = if vals.len() > 6 {
                format!("{}..{} ({} values)", vals[0], vals[vals.len() - 1], vals.len())
            } else {
                vals.join(", ")
            };
            rows.push(vec![
                kind.to_string(),
                p.name().to_string(),
                format!("{}", app.space().len()),
                range,
                p.default_value().to_string(),
            ]);
        }
    }
    print_table(
        "Table II — application configuration parameters",
        &["application", "parameter", "size", "range", "default"],
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_do_not_panic() {
        table1_report();
        table2_report();
    }
}
