//! # LASP — Lightweight Autotuning of Scientific Application Parameters
//!
//! A reproduction of *"HPC Application Parameter Autotuning on Edge Devices:
//! A Bandit Learning Approach"* (Hossain et al., 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the tuning coordinator: bandit engine, simulated
//!   HPC applications and edge devices, baselines, fleet orchestration and
//!   the experiment drivers that regenerate every table/figure in the paper.
//! * **L2/L1 (`python/compile/`)** — the UCB scoring / GP surrogate compute
//!   graphs and their Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`
//!   at build time and executed here through the PJRT CPU client
//!   ([`runtime`]). Python never runs on the tuning path.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `examples/` for runnable entry points (`quickstart`, `end_to_end`,
//! `multi_device_fleet`, `lf_hf_transfer`).

// CI denies clippy warnings (`cargo clippy --all-targets -- -D warnings`).
// The PJRT artifact entry points (`runtime::Engine::lasp_step` and
// friends) mirror the lowered HLO signatures argument-for-argument and
// carry targeted `#[allow(clippy::too_many_arguments)]` at the function
// level — collapsing their parameter lists into structs would only
// obscure the artifact ABI.

pub mod apps;
pub mod bandit;
pub mod baselines;
pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod experiments;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod space;
pub mod telemetry;
pub mod tuning;
pub mod util;

pub use anyhow::Result;
