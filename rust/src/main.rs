//! `lasp` — command-line entry point for the LASP reproduction.
//!
//! Subcommands:
//!   tune        run LASP on one application (single device)
//!   fleet       run tuning jobs across a simulated edge fleet
//!   serve       run the online tuning service (HTTP + JSON)
//!   loadgen     drive suggest/report load against a running server
//!   compare     LASP vs baselines on one application
//!   experiment  regenerate a paper table/figure (or `all`)
//!   simulate    run a TOML scenario grid through the parallel engine
//!   trace       decode a flight-recorder capture (dump | stats)
//!   spaces      print Table II (application parameter spaces)
//!   devices     print Table I (Jetson power modes)
//!
//! Flag parsing is hand-rolled (offline build: no clap). `--config
//! <file.toml>` loads defaults; explicit flags override it.

use anyhow::{anyhow, Context, Result};
use lasp::apps;
use lasp::config::{Backend, LaspConfig};
use lasp::coordinator::transfer::validate_on_hpc;
use lasp::coordinator::{Fleet, FleetConfig, TuneJob};
use lasp::device::{JetsonNano, PowerMode};
use lasp::runtime::EngineHandle;
use lasp::tuning::{SessionConfig, TuningSession};
use std::collections::HashMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    if cmd == "trace" {
        // `trace` takes a positional verb (dump|stats) before its flags.
        return cmd_trace(&args[1..]);
    }
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "tune" => cmd_tune(&flags),
        "fleet" => cmd_fleet(&flags),
        "serve" => cmd_serve(&flags),
        "loadgen" => cmd_loadgen(&flags),
        "compare" => cmd_compare(&flags),
        "experiment" => cmd_experiment(&flags),
        "simulate" => cmd_simulate(&flags),
        "spaces" => {
            lasp::experiments::tables::table2_report();
            Ok(())
        }
        "devices" => {
            lasp::experiments::tables::table1_report();
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            // Full usage on stderr so a typo is immediately recoverable.
            eprintln!("{}", usage_text());
            Err(anyhow!("unknown command '{other}' (try `lasp help`)"))
        }
    }
}

fn usage_text() -> &'static str {
    "lasp — Lightweight Autotuning of Scientific Application Parameters\n\
     \n\
     USAGE: lasp <command> [flags]\n\
     \n\
     COMMANDS\n\
     \x20 tune        run LASP on one application\n\
     \x20 fleet       run jobs across a simulated edge fleet\n\
     \x20 serve       run the online tuning service (HTTP + JSON)\n\
     \x20 loadgen     drive suggest/report load against a running server\n\
     \x20 compare     LASP vs baselines on one application\n\
     \x20 experiment  regenerate a paper artifact: table1|table2|fig2..fig12|ablation|all\n\
     \x20 simulate    run a TOML scenario grid through the parallel engine\n\
     \x20 trace       decode a flight-recorder capture: dump | stats\n\
     \x20 spaces      print Table II\n\
     \x20 devices     print Table I\n\
     \x20 help        print this message\n\
     \n\
     FLAGS (tune/fleet/compare)\n\
     \x20 --config <file>      TOML config (flags override)\n\
     \x20 --app <name>         lulesh|kripke|clomp|hypre   [kripke]\n\
     \x20 --iters <n>          tuning iterations           [500]\n\
     \x20 --alpha <f> --beta <f>  objective weights        [0.8/0.2]\n\
     \x20 --mode <m>           maxn|5w                     [maxn]\n\
     \x20 --seed <n>           RNG seed                    [42]\n\
     \x20 --backend <b>        scalar|pjrt                 [scalar]\n\
     \x20 --noise <pct>        injected error, e.g. 0.10   [0]\n\
     \x20 --devices <n>        fleet size                  [2]\n\
     \x20 --budget <n>         compare: evaluation budget  [--iters]\n\
     \x20 --name <id>          experiment id               [all]\n\
     \x20 --all                experiment: run every artifact\n\
     \x20 --quick              experiment: reduced repetitions\n\
     \x20 --bench-out <file>   experiment --all: wall-clock/steps report\n\
     \x20                      [BENCH_experiments.json]\n\
     \x20 --hf-validate        tune: validate result on the HPC node\n\
     \x20 --save-state <file>  tune: checkpoint the tuner state (JSON)\n\
     \x20 --load-state <file>  tune: warm-start from a checkpoint\n\
     \n\
     FLAGS (simulate)\n\
     \x20 --scenario <file>    TOML scenario grid (required; see\n\
     \x20                      docs/scenarios/ and DESIGN.md)\n\
     \x20 --threads <n>        sweep pool size             [host cores]\n\
     \x20 --out <file>         write machine-readable JSON [sim_result.json]\n\
     \x20                      (`--out -` prints JSON to stdout)\n\
     \n\
     FLAGS (serve)\n\
     \x20 --port <n>             bind 127.0.0.1:<port>     [8787]\n\
     \x20 --addr <host:port>     explicit bind address (overrides --port)\n\
     \x20 --transport <t>        reactor | blocking        [reactor]\n\
     \x20 --event-loops <n>      reactor event loops; 0 = one per core [0]\n\
     \x20 --workers <n>          worker threads (blocking transport) [8]\n\
     \x20 --shards <n>           session-store shards; must be a multiple\n\
     \x20                        of the event-loop count; 0 = match loops [0]\n\
     \x20 --queue-cap <n>        per-shard report queue    [4096]\n\
     \x20 --batch <n>            max updates per drain     [128]\n\
     \x20 --checkpoint-dir <d>   snapshot sessions here    [off]\n\
     \x20 --checkpoint-secs <s>  snapshot period           [30]\n\
     \x20 --retain <f>           warm-start retention      [0.5]\n\
     \x20 --leader <host:port>   fleet leader to sync with [standalone]\n\
     \x20 --node-id <id>         sync identity             [node-<addr>]\n\
     \x20 --sync-secs <s>        fleet sync period         [10]\n\
     \x20 --fleet-retain <f>     fleet-prior retention     [0.3]\n\
     \x20 --half-life-secs <s>   fleet evidence half-life  [600]\n\
     \x20 --trace-file <path>    stream flight-recorder events to disk [off]\n\
     \x20 --chaos <file>         TOML fault-injection config ([chaos]\n\
     \x20                        section; see DESIGN.md §Failure model) [off]\n\
     \n\
     FLAGS (loadgen)\n\
     \x20 --addr <a[,b,...]>     server(s) to hammer       [127.0.0.1:8787]\n\
     \x20 --port <n>             shorthand for 127.0.0.1:<port>\n\
     \x20 --sessions <n>         concurrent sessions       [128]\n\
     \x20 --connections <n>      also hold <n> mostly-idle keep-alive\n\
     \x20                        connections open (open-loop)  [0]\n\
     \x20 --rounds <n>           suggest/report round-trips [12000]\n\
     \x20 --threads <n>          client threads            [8]\n\
     \x20 --apps <list>          all | comma list          [all]\n\
     \x20 --timeout-secs <s>     socket read/write timeout [30]\n\
     \x20 --batch <n>            entries per request via the\n\
     \x20                        /v1/*/batch endpoints (1..=256) [1]\n\
     \x20 --record <path>        capture measurements for `lasp trace` /\n\
     \x20                        the sim engine's replay strategy  [off]\n\
     \n\
     FLAGS (trace dump|stats)\n\
     \x20 --file <path>          LASPTRC1 capture to decode (required)\n\
     \x20 --format <f>           dump output: json|csv     [json]"
}

fn print_usage() {
    println!("{}", usage_text());
}

/// Parsed `--flag value` pairs (+ boolean flags).
struct Flags {
    values: HashMap<String, String>,
    bools: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut values = HashMap::new();
        let mut bools = vec![];
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let name = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{a}'"))?;
            match name {
                "quick" | "hf-validate" | "all" => {
                    bools.push(name.to_string());
                    i += 1;
                }
                _ => {
                    let v = args
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("--{name} needs a value"))?;
                    values.insert(name.to_string(), v.clone());
                    i += 2;
                }
            }
        }
        Ok(Flags { values, bools })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    /// Build the effective config: file (if given) + flag overrides.
    fn config(&self) -> Result<LaspConfig> {
        let mut cfg = match self.get("config") {
            Some(path) => LaspConfig::from_file(std::path::Path::new(path))?,
            None => LaspConfig::default(),
        };
        if let Some(v) = self.get("app") {
            cfg.app = v.parse()?;
        }
        if let Some(v) = self.get("iters") {
            cfg.iterations = v.parse().context("--iters")?;
        }
        if let Some(v) = self.get("alpha") {
            cfg.alpha = v.parse().context("--alpha")?;
        }
        if let Some(v) = self.get("beta") {
            cfg.beta = v.parse().context("--beta")?;
        }
        if let Some(v) = self.get("mode") {
            cfg.mode = v.parse()?;
        }
        if let Some(v) = self.get("seed") {
            cfg.seed = v.parse().context("--seed")?;
        }
        if let Some(v) = self.get("backend") {
            cfg.backend = v.parse()?;
        }
        if let Some(v) = self.get("noise") {
            cfg.noise_pct = v.parse().context("--noise")?;
        }
        if let Some(v) = self.get("devices") {
            cfg.devices = v.parse().context("--devices")?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

fn engine_for(cfg: &LaspConfig) -> Result<Option<EngineHandle>> {
    match cfg.backend {
        Backend::Scalar => Ok(None),
        Backend::Pjrt => {
            let h = EngineHandle::spawn_default()
                .context("spawning PJRT engine (run `make artifacts` first)")?;
            println!("# backend: pjrt ({})", h.platform()?);
            Ok(Some(h))
        }
    }
}

fn cmd_tune(flags: &Flags) -> Result<()> {
    let cfg = flags.config()?;
    println!(
        "# lasp tune: app={} iters={} α={} β={} mode={} backend={:?} noise={:.0}%",
        cfg.app,
        cfg.iterations,
        cfg.alpha,
        cfg.beta,
        cfg.mode.name(),
        cfg.backend,
        cfg.noise_pct * 100.0
    );
    let app = apps::build(cfg.app);
    let device = JetsonNano::new(cfg.mode, cfg.seed)
        .with_fidelity(cfg.fidelity)
        .with_injected_noise(cfg.noise());
    let engine = engine_for(&cfg)?;
    let k = app.space().len();
    let mut tuner = match engine {
        Some(h) => lasp::bandit::UcbTuner::with_backend(
            k,
            cfg.alpha,
            cfg.beta,
            Box::new(lasp::runtime::PjrtScoreBackend::new(h, cfg.app.name())),
        ),
        None => lasp::bandit::UcbTuner::new(k, cfg.alpha, cfg.beta),
    };
    if let Some(path) = flags.get("load-state") {
        let cp = lasp::bandit::persist::load(std::path::Path::new(path))?;
        if cp.app != cfg.app.name() {
            return Err(anyhow!(
                "checkpoint is for '{}', tuning '{}'",
                cp.app,
                cfg.app
            ));
        }
        println!("# warm start from {path} (t={})", cp.state.t());
        tuner = tuner.with_state(lasp::bandit::persist::discounted(&cp.state, 0.2));
    }
    let save_state = flags.get("save-state").map(String::from);
    let policy: Box<dyn lasp::bandit::Policy> = Box::new(tuner);
    let mut session = TuningSession::with_policy(
        app,
        Box::new(device),
        policy,
        SessionConfig {
            iterations: cfg.iterations,
            alpha: cfg.alpha,
            beta: cfg.beta,
            record_history: false,
        },
    );
    let out = session.run()?;
    println!("tuned configuration (Eq.4): {}", out.best_config);
    println!(
        "pulls of best: {:.0}/{}  |  simulated device time: {:.1}s  |  tuner overhead: {:.3}s",
        out.counts[out.best_index],
        cfg.iterations,
        out.simulated_device_seconds,
        out.tuner_wall_seconds
    );
    println!(
        "tuner footprint: cpu {:.2}s over {:.2}s wall, ΔRSS {:.1} MiB",
        out.resources.cpu_seconds, out.resources.wall_seconds, out.resources.peak_rss_mib
    );
    if let Some(path) = save_state {
        // The session owns the policy; recover state through the counts it
        // reports plus sums reconstructed by replay would be lossy — so the
        // session exposes the policy state directly.
        session.save_policy_state(std::path::Path::new(&path), cfg.app.name(), cfg.alpha, cfg.beta)?;
        println!("# checkpoint written to {path}");
    }
    if flags.has("hf-validate") {
        let app = apps::build(cfg.app);
        let v = validate_on_hpc(app.as_ref(), out.best_index, cfg.seed);
        println!(
            "HF validation (i7-14700, q=1): time {:.3}s vs default {:.3}s -> gain {:+.1}% | oracle distance {:.1}%",
            v.hf_time_s, v.default_time_s, v.gain_pct, v.oracle_distance_pct
        );
    }
    Ok(())
}

fn cmd_fleet(flags: &Flags) -> Result<()> {
    let cfg = flags.config()?;
    println!(
        "# lasp fleet: {} devices, app={} iters={} loss={:.0}%",
        cfg.devices,
        cfg.app,
        cfg.iterations,
        cfg.loss_prob * 100.0
    );
    let engine = engine_for(&cfg)?;
    let mut fleet = Fleet::spawn(
        FleetConfig {
            devices: cfg.devices,
            modes: vec![PowerMode::Maxn, PowerMode::FiveW],
            seed: cfg.seed,
            fidelity: cfg.fidelity,
            loss_prob: cfg.loss_prob,
            mean_latency_s: cfg.latency_s,
            injected_noise: cfg.noise(),
            progress_every: (cfg.iterations / 5).max(1),
        },
        engine,
    )?;
    for app in apps::AppKind::all() {
        fleet.submit(TuneJob {
            app,
            iterations: cfg.iterations,
            alpha: cfg.alpha,
            beta: cfg.beta,
        })?;
    }
    let results = fleet.drain(std::time::Duration::from_secs(600))?;
    for r in &results {
        let app = apps::build(r.app);
        let v = validate_on_hpc(app.as_ref(), r.best_index, cfg.seed);
        println!(
            "device {} tuned {:>7}: {} | HF gain {:+.1}% | oracle dist {:.1}% | tuner {:.2}s",
            r.device_id,
            r.app.to_string(),
            app.space().describe(r.best_index),
            v.gain_pct,
            v.oracle_distance_pct,
            r.tuner_wall_seconds,
        );
    }
    fleet.shutdown();
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let cfg = flags.config()?;
    let mut serve_cfg = cfg.serve_config();
    if let Some(v) = flags.get("port") {
        let port: u16 = v.parse().context("--port")?;
        serve_cfg.addr = format!("127.0.0.1:{port}");
    }
    if let Some(v) = flags.get("addr") {
        serve_cfg.addr = v.to_string();
    }
    if let Some(v) = flags.get("workers") {
        serve_cfg.workers = v.parse().context("--workers")?;
    }
    if let Some(v) = flags.get("event-loops") {
        serve_cfg.event_loops = v.parse().context("--event-loops")?;
    }
    if let Some(v) = flags.get("transport") {
        serve_cfg.transport = lasp::serve::TransportKind::parse(v)
            .ok_or_else(|| anyhow!("--transport must be reactor|blocking, got {v}"))?;
    }
    if let Some(v) = flags.get("shards") {
        serve_cfg.shards = v.parse().context("--shards")?;
    }
    if let Some(v) = flags.get("queue-cap") {
        serve_cfg.queue_cap = v.parse().context("--queue-cap")?;
    }
    if let Some(v) = flags.get("batch") {
        serve_cfg.max_batch = v.parse().context("--batch")?;
    }
    if let Some(v) = flags.get("checkpoint-dir") {
        serve_cfg.checkpoint_dir = Some(std::path::PathBuf::from(v));
    }
    if let Some(v) = flags.get("checkpoint-secs") {
        let secs: f64 = v.parse().context("--checkpoint-secs")?;
        if secs <= 0.0 {
            return Err(anyhow!("--checkpoint-secs must be positive"));
        }
        serve_cfg.checkpoint_every = std::time::Duration::from_secs_f64(secs);
    }
    if let Some(v) = flags.get("retain") {
        serve_cfg.warm_retain = v.parse().context("--retain")?;
    }
    if let Some(v) = flags.get("leader") {
        serve_cfg.leader = Some(v.to_string());
    }
    if let Some(v) = flags.get("node-id") {
        serve_cfg.node_id = Some(v.to_string());
    }
    if let Some(v) = flags.get("sync-secs") {
        let secs: f64 = v.parse().context("--sync-secs")?;
        if !(secs.is_finite() && secs > 0.0) {
            return Err(anyhow!("--sync-secs must be positive"));
        }
        serve_cfg.sync_every = std::time::Duration::from_secs_f64(secs);
    }
    if let Some(v) = flags.get("fleet-retain") {
        serve_cfg.fleet_retain = v.parse().context("--fleet-retain")?;
    }
    if let Some(v) = flags.get("half-life-secs") {
        let secs: f64 = v.parse().context("--half-life-secs")?;
        if !(secs.is_finite() && secs > 0.0) {
            return Err(anyhow!("--half-life-secs must be positive"));
        }
        serve_cfg.fleet_half_life = std::time::Duration::from_secs_f64(secs);
    }
    if let Some(v) = flags.get("trace-file") {
        serve_cfg.trace_file = Some(std::path::PathBuf::from(v));
    }
    if let Some(v) = flags.get("chaos") {
        serve_cfg.chaos =
            Some(lasp::chaos::ChaosConfig::from_file(std::path::Path::new(v))?);
    }
    let ckpt = serve_cfg
        .checkpoint_dir
        .as_ref()
        .map(|d| d.display().to_string())
        .unwrap_or_else(|| "off".to_string());
    // Resolve the topology up front so the banner shows the actual
    // shard/thread counts (0 means "derive") and so a non-multiple
    // --shards/--event-loops pair fails here with the CLI error rather
    // than deep inside server startup.
    let (resolved_shards, resolved_threads) = serve_cfg.resolved_topology()?;
    let handle = lasp::serve::start(serve_cfg.clone())?;
    println!(
        "# lasp serve: listening on {} | transport={} threads={} shards={} queue={} batch={} \
         checkpoints={}",
        handle.addr(),
        serve_cfg.transport.name(),
        resolved_threads,
        resolved_shards,
        serve_cfg.queue_cap,
        serve_cfg.max_batch,
        ckpt,
    );
    if handle.restored_sessions() > 0 {
        println!(
            "# warm start: {} session(s) restored (retain={})",
            handle.restored_sessions(),
            serve_cfg.warm_retain
        );
    }
    match &serve_cfg.leader {
        Some(leader) => println!(
            "# fleet sync: node {} -> leader {} every {:.1}s (retain={}, half-life={:.0}s)",
            handle.node_id(),
            leader,
            serve_cfg.sync_every.as_secs_f64(),
            serve_cfg.fleet_retain,
            serve_cfg.fleet_half_life.as_secs_f64(),
        ),
        None => println!("# fleet sync: standalone (this node can serve as a leader)"),
    }
    if let Some(path) = &serve_cfg.trace_file {
        println!("# flight recorder: streaming to {}", path.display());
    }
    if let Some(chaos) = &serve_cfg.chaos {
        println!("# chaos: ENABLED (seed={}) — injected faults are deliberate", chaos.seed);
    }
    println!(
        "# endpoints: POST /v1/suggest  POST /v1/report  GET /v1/best  POST /v1/checkpoint  \
         POST /v1/sync/push  POST /v1/sync/pull  GET /v1/trace  GET /v1/debug/session  \
         GET /healthz  GET /metrics"
    );
    handle.wait();
    Ok(())
}

fn cmd_loadgen(flags: &Flags) -> Result<()> {
    let cfg = flags.config()?;
    let mut lg = lasp::serve::LoadgenConfig {
        alpha: cfg.alpha,
        beta: cfg.beta,
        fidelity: cfg.fidelity,
        seed: cfg.seed,
        ..Default::default()
    };
    if let Some(v) = flags.get("addr") {
        lg.addr = v.to_string();
    } else if let Some(v) = flags.get("port") {
        let port: u16 = v.parse().context("--port")?;
        lg.addr = format!("127.0.0.1:{port}");
    }
    if let Some(v) = flags.get("sessions") {
        lg.sessions = v.parse().context("--sessions")?;
    }
    if let Some(v) = flags.get("connections") {
        lg.connections = v.parse().context("--connections")?;
    }
    if let Some(v) = flags.get("rounds") {
        lg.rounds = v.parse().context("--rounds")?;
    }
    if let Some(v) = flags.get("threads") {
        lg.threads = v.parse().context("--threads")?;
    }
    if let Some(v) = flags.get("apps") {
        if v != "all" {
            lg.apps = v
                .split(',')
                .map(|s| s.trim().parse())
                .collect::<Result<Vec<_>>>()?;
        }
    }
    if let Some(v) = flags.get("record") {
        lg.record = Some(std::path::PathBuf::from(v));
    }
    if let Some(v) = flags.get("timeout-secs") {
        let secs: u64 = v.parse().context("--timeout-secs")?;
        if secs == 0 {
            return Err(anyhow!("--timeout-secs must be positive"));
        }
        lg.timeout_secs = secs;
    }
    if let Some(v) = flags.get("batch") {
        lg.batch = v.parse().context("--batch")?;
    }
    println!(
        "# lasp loadgen: {} | sessions={} connections={} rounds={} threads={} batch={} apps={:?}",
        lg.addr,
        lg.sessions,
        lg.connections,
        lg.rounds,
        lg.threads,
        lg.batch,
        lg.apps.iter().map(|a| a.name()).collect::<Vec<_>>(),
    );
    let report = lasp::serve::loadgen::run(&lg)?;
    report.print();
    if let Some(path) = &lg.record {
        println!("# capture written to {}", path.display());
    }
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let Some(verb) = args.first() else {
        return Err(anyhow!("trace needs a verb: lasp trace dump|stats --file <capture>"));
    };
    let flags = Flags::parse(&args[1..])?;
    let path = flags
        .get("file")
        .ok_or_else(|| anyhow!("trace {verb} needs --file <capture>"))?;
    let events = lasp::obs::read_trace_file(std::path::Path::new(path))?;
    match verb.as_str() {
        "dump" => trace_dump(&events, flags.get("format").unwrap_or("json")),
        "stats" => {
            trace_stats(path, &events);
            Ok(())
        }
        other => Err(anyhow!("unknown trace verb '{other}' (dump|stats)")),
    }
}

/// Decode a capture to stdout: a JSON array of semantically-decoded
/// events, or raw-word CSV for spreadsheet work.
fn trace_dump(events: &[lasp::obs::TraceEvent], format: &str) -> Result<()> {
    match format {
        "json" => {
            let mut buf = Vec::with_capacity(events.len() * 96 + 64);
            let mut w = lasp::util::json::JsonWriter::new(&mut buf);
            w.begin_arr();
            for ev in events {
                lasp::obs::write_event_json(ev, &mut w);
            }
            w.end_arr();
            println!("{}", String::from_utf8(buf).expect("trace JSON is UTF-8"));
        }
        "csv" => {
            println!("seq,t_us,kind,a,b,c");
            for ev in events {
                println!("{},{},{},{},{},{}", ev.seq, ev.t_us, ev.kind_name(), ev.a, ev.b, ev.c);
            }
        }
        other => return Err(anyhow!("unknown trace format '{other}' (json|csv)")),
    }
    Ok(())
}

/// Capture summary: span, event rate, per-kind counts, dropped seqs.
fn trace_stats(path: &str, events: &[lasp::obs::TraceEvent]) {
    println!("# lasp trace stats: {path}");
    println!("events: {}", events.len());
    if events.is_empty() {
        return;
    }
    let t0 = events.iter().map(|e| e.t_us).min().unwrap_or(0);
    let t1 = events.iter().map(|e| e.t_us).max().unwrap_or(0);
    let span_s = (t1.saturating_sub(t0)) as f64 / 1e6;
    println!("span: {span_s:.3}s ({:.0} events/s)", events.len() as f64 / span_s.max(1e-9));
    let max_seq = events.iter().map(|e| e.seq).max().unwrap_or(0);
    let dropped = (max_seq + 1).saturating_sub(events.len() as u64);
    println!("sequence range: 0..={max_seq} ({dropped} missing — ring overwrites or drains)");
    let mut by_kind: std::collections::BTreeMap<&'static str, u64> = std::collections::BTreeMap::new();
    for ev in events {
        *by_kind.entry(ev.kind_name()).or_insert(0) += 1;
    }
    for (kind, n) in by_kind {
        println!("  {kind:<16} {n}");
    }
}

fn cmd_compare(flags: &Flags) -> Result<()> {
    let cfg = flags.config()?;
    let budget: usize = match flags.get("budget") {
        Some(v) => v.parse().context("--budget")?,
        None => cfg.iterations,
    };
    println!("# lasp compare: app={} budget={budget}", cfg.app);
    let a = lasp::experiments::ablation::run(budget);
    a.report();
    Ok(())
}

fn cmd_experiment(flags: &Flags) -> Result<()> {
    let name = flags.get("name").unwrap_or("all");
    let quick = flags.has("quick");
    let run_all = flags.has("all") || name == "all";
    let names: Vec<&str> = if run_all {
        lasp::experiments::all_ids()
    } else {
        vec![name]
    };
    let mut failures = vec![];
    let mut timings: Vec<(String, f64, u64)> = vec![];
    for n in names {
        println!("\n=== experiment {n} ===");
        let steps_before = lasp::sim::steps_executed();
        let t0 = std::time::Instant::now();
        match lasp::experiments::run_by_name(n, quick) {
            Ok(true) => println!("[shape OK] {n} matches the paper's qualitative shape"),
            Ok(false) => {
                println!("[shape MISMATCH] {n}");
                failures.push(n.to_string());
            }
            Err(e) => return Err(e),
        }
        timings.push((
            n.to_string(),
            t0.elapsed().as_secs_f64(),
            lasp::sim::steps_executed() - steps_before,
        ));
    }
    if run_all {
        let path = flags.get("bench-out").unwrap_or("BENCH_experiments.json");
        write_experiment_bench(path, quick, &timings, failures.is_empty())?;
        println!("\nwrote {path}");
    }
    if !failures.is_empty() {
        return Err(anyhow!("shape mismatches: {failures:?}"));
    }
    Ok(())
}

/// Machine-readable per-figure wall-clock + engine steps/sec, uploaded as
/// a CI artifact so experiment-suite latency is tracked PR-over-PR.
fn write_experiment_bench(
    path: &str,
    quick: bool,
    timings: &[(String, f64, u64)],
    shapes_ok: bool,
) -> Result<()> {
    use lasp::util::json::Json;
    use std::collections::BTreeMap;

    let mut figures = BTreeMap::new();
    let (mut total_wall, mut total_steps) = (0.0f64, 0u64);
    for (id, wall, steps) in timings {
        let mut o = BTreeMap::new();
        o.insert("wall_s".to_string(), Json::Num(*wall));
        o.insert("engine_steps".to_string(), Json::Num(*steps as f64));
        o.insert(
            "steps_per_s".to_string(),
            Json::Num(*steps as f64 / wall.max(1e-9)),
        );
        figures.insert(id.clone(), Json::Obj(o));
        total_wall += wall;
        total_steps += steps;
    }
    let mut out = BTreeMap::new();
    out.insert("bench".to_string(), Json::Str("experiments".to_string()));
    out.insert(
        "mode".to_string(),
        Json::Str(if quick { "quick" } else { "full" }.to_string()),
    );
    out.insert("shapes_ok".to_string(), Json::Bool(shapes_ok));
    out.insert("total_wall_s".to_string(), Json::Num(total_wall));
    out.insert("total_engine_steps".to_string(), Json::Num(total_steps as f64));
    out.insert(
        "steps_per_s".to_string(),
        Json::Num(total_steps as f64 / total_wall.max(1e-9)),
    );
    out.insert("figures".to_string(), Json::Obj(figures));
    std::fs::write(path, Json::Obj(out).to_string() + "\n")
        .with_context(|| format!("writing {path}"))?;
    Ok(())
}

fn cmd_simulate(flags: &Flags) -> Result<()> {
    let path = flags
        .get("scenario")
        .ok_or_else(|| anyhow!("simulate needs --scenario <file.toml> (see docs/scenarios/)"))?;
    let grid = lasp::sim::ScenarioGrid::from_file(std::path::Path::new(path))?;
    let threads: usize = match flags.get("threads") {
        Some(v) => v.parse().context("--threads")?,
        None => 0,
    };
    let runner = lasp::sim::SweepRunner::new(threads);
    println!(
        "# lasp simulate: {} | {} cells ({} apps × {} modes × {} noises × {} objectives × {} strategies × {} seeds), {} iterations",
        path,
        grid.len(),
        grid.apps.len(),
        grid.modes.len(),
        grid.noise_pcts.len(),
        grid.objectives.len(),
        grid.strategies.len(),
        grid.seeds.len(),
        grid.iterations,
    );
    let steps_before = lasp::sim::steps_executed();
    let t0 = std::time::Instant::now();
    let result = runner.sweep(&grid)?;
    let wall = t0.elapsed().as_secs_f64();
    let steps = lasp::sim::steps_executed() - steps_before;
    result.report();
    println!(
        "\n# engine: {} steps in {:.2}s ({:.0} steps/s)",
        steps,
        wall,
        steps as f64 / wall.max(1e-9)
    );
    let json = result.to_json();
    match flags.get("out") {
        Some("-") => println!("{json}"),
        out => {
            let out = out.unwrap_or("sim_result.json");
            std::fs::write(out, json + "\n").with_context(|| format!("writing {out}"))?;
            println!("# wrote {out}");
        }
    }
    Ok(())
}
