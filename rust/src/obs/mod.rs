//! Flight recorder: lock-free, fixed-capacity decision tracing.
//!
//! Every layer of the serve stack (transport, suggest path, batch
//! updaters, fleet sync, checkpointer) logs compact binary events into a
//! per-lane ring buffer. Recording is O(1) atomic stores with **zero
//! allocations in steady state** — the contract is enforced end-to-end by
//! `rust/tests/serve_hotpath.rs` and per-event by
//! `benches/trace_overhead.rs` under the counting global allocator.
//!
//! The recorder is exposed three ways:
//!
//! 1. live, over HTTP: `GET /v1/trace?since=<seq>` drains decoded events
//!    as JSON (plus `GET /v1/debug/session` for full per-session arm
//!    statistics);
//! 2. streamed to disk: `lasp serve --trace-file <path>` attaches a
//!    [`TraceWriter`] that drains the ring into the `LASPTRC1` binary
//!    format, and `lasp loadgen --record <path>` captures the observed
//!    (arm, time, power) stream client-side in the same format;
//! 3. replayed offline: `lasp simulate` with `trace = "<path>"` feeds a
//!    recorded file back through the sim `Episode` engine
//!    ([`crate::sim::replay`]).
//!
//! ## Ring semantics
//!
//! Events carry a global, monotonically increasing sequence number. Each
//! lane is a fixed-capacity ring; writers claim a slot with a relaxed
//! `fetch_add` and publish through a seqlock stamp (`0` = slot being
//! written / empty, otherwise `seq + 1`). When the ring wraps, the oldest
//! events are overwritten — readers observe the loss as a gap in the
//! sequence numbers, and the recorder counts it in
//! [`Recorder::overwritten`]. Torn slots (read racing a writer) are
//! detected by re-checking the stamp and skipped. Tracing is therefore
//! lossy under overload by design: it degrades by dropping history, never
//! by blocking or allocating on the hot path.

use crate::apps::AppKind;
use crate::device::PowerMode;
use crate::util::json::JsonWriter;
use std::cell::Cell;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Binary trace-file magic; the trailing digit is the format version.
pub const TRACE_MAGIC: [u8; 8] = *b"LASPTRC1";
/// Fixed record width: six little-endian u64 words
/// `[seq][t_us][kind][a][b][c]`.
pub const TRACE_RECORD_BYTES: usize = 48;

/// Default events retained per lane.
pub const DEFAULT_LANE_CAP: usize = 4096;

/// What happened. The payload words `a`/`b`/`c` are packed per kind; see
/// the `pack_*`/`decode_*` helpers and DESIGN.md §Observability.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A request started: `a` = route code, `b` = handling event-loop
    /// index (0 on the blocking transport), so a drained trace maps
    /// every request back to the `lasp-loop-<i>` thread that owned it.
    ReqStart = 1,
    /// A request finished: `a` = route code, `b` = status, `c` =
    /// latency in µs.
    ReqEnd = 2,
    /// A suggest decision: `a` = session | arm<<32, `b` = top-2 score
    /// gap (f64 bits), `c` = policy code | explore<<8 | total_pulls<<16.
    Suggest = 3,
    /// A report applied to a session: `a` = session | arm<<32, `b` =
    /// time_s (f64 bits), `c` = power_w (f64 bits).
    ReportApply = 4,
    /// A batched-updater flush: `a` = shard, `b` = reports applied.
    BatchFlush = 5,
    /// Fleet sync pushed local state: `a` = snapshots sent.
    FleetPush = 6,
    /// Fleet sync pulled priors: `a` = priors installed.
    FleetPull = 7,
    /// The leader merged a pushed snapshot set: `a` = snapshots
    /// absorbed, `b` = known nodes after the merge.
    FleetMerge = 8,
    /// A checkpoint was written: `a` = sessions, `b` = duration in µs.
    Checkpoint = 9,
    /// A session was created: `a` = session id, `b` = arm count, `c` =
    /// warm-start flag | policy code<<8.
    SessionCreate = 10,
    /// A loadgen-side observation: `a` = app code | mode code<<8 |
    /// arm<<16, `b` = time_s (f64 bits), `c` = power_w (f64 bits).
    Measure = 11,
    /// A chaos-layer fault injection: `a` = fault-point code
    /// ([`crate::chaos::FaultPoint`]), `b` = injection ordinal, `c` =
    /// point-specific context (shard, delay ms, attempt).
    Chaos = 12,
    /// The reactor transport accepted a connection onto an event loop:
    /// `a` = event-loop index, `b` = slab token.
    ConnOpen = 13,
    /// A reactor connection closed: `a` = event-loop index, `b` = slab
    /// token, `c` = requests served over the connection's lifetime.
    ConnClose = 14,
}

impl EventKind {
    pub fn code(self) -> u64 {
        self as u64
    }

    pub fn from_code(code: u64) -> Option<EventKind> {
        Some(match code {
            1 => EventKind::ReqStart,
            2 => EventKind::ReqEnd,
            3 => EventKind::Suggest,
            4 => EventKind::ReportApply,
            5 => EventKind::BatchFlush,
            6 => EventKind::FleetPush,
            7 => EventKind::FleetPull,
            8 => EventKind::FleetMerge,
            9 => EventKind::Checkpoint,
            10 => EventKind::SessionCreate,
            11 => EventKind::Measure,
            12 => EventKind::Chaos,
            13 => EventKind::ConnOpen,
            14 => EventKind::ConnClose,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EventKind::ReqStart => "req_start",
            EventKind::ReqEnd => "req_end",
            EventKind::Suggest => "suggest",
            EventKind::ReportApply => "report_apply",
            EventKind::BatchFlush => "batch_flush",
            EventKind::FleetPush => "fleet_push",
            EventKind::FleetPull => "fleet_pull",
            EventKind::FleetMerge => "fleet_merge",
            EventKind::Checkpoint => "checkpoint",
            EventKind::SessionCreate => "session_create",
            EventKind::Measure => "measure",
            EventKind::Chaos => "chaos",
            EventKind::ConnOpen => "conn_open",
            EventKind::ConnClose => "conn_close",
        }
    }
}

/// Route codes for `ReqStart`/`ReqEnd` payloads.
pub mod route {
    pub const OTHER: u64 = 0;
    pub const SUGGEST: u64 = 1;
    pub const REPORT: u64 = 2;
    pub const BEST: u64 = 3;
    pub const CHECKPOINT: u64 = 4;
    pub const SYNC_PUSH: u64 = 5;
    pub const SYNC_PULL: u64 = 6;
    pub const HEALTHZ: u64 = 7;
    pub const METRICS: u64 = 8;
    pub const TRACE: u64 = 9;
    pub const DEBUG_SESSION: u64 = 10;
    pub const SUGGEST_BATCH: u64 = 11;
    pub const REPORT_BATCH: u64 = 12;
}

pub fn route_name(code: u64) -> &'static str {
    match code {
        route::SUGGEST => "/v1/suggest",
        route::REPORT => "/v1/report",
        route::BEST => "/v1/best",
        route::CHECKPOINT => "/v1/checkpoint",
        route::SYNC_PUSH => "/v1/sync/push",
        route::SYNC_PULL => "/v1/sync/pull",
        route::HEALTHZ => "/healthz",
        route::METRICS => "/metrics",
        route::TRACE => "/v1/trace",
        route::DEBUG_SESSION => "/v1/debug/session",
        route::SUGGEST_BATCH => "/v1/suggest/batch",
        route::REPORT_BATCH => "/v1/report/batch",
        _ => "other",
    }
}

/// App wire code for `Measure` payloads — the index in
/// [`AppKind::all`]'s paper order.
pub fn app_code(app: AppKind) -> u64 {
    AppKind::all().iter().position(|&a| a == app).unwrap_or(0) as u64
}

pub fn app_from_code(code: u64) -> Option<AppKind> {
    AppKind::all().get(code as usize).copied()
}

/// Power-mode wire code for `Measure` payloads.
pub fn mode_code(mode: PowerMode) -> u64 {
    match mode {
        PowerMode::Maxn => 0,
        PowerMode::FiveW => 1,
    }
}

pub fn mode_from_code(code: u64) -> Option<PowerMode> {
    match code {
        0 => Some(PowerMode::Maxn),
        1 => Some(PowerMode::FiveW),
        _ => None,
    }
}

/// Pack a suggest decision into `(a, b, c)`.
pub fn pack_suggest(
    session: u32,
    arm: u32,
    gap: f64,
    explore: bool,
    policy_code: u8,
    total_pulls: u64,
) -> (u64, u64, u64) {
    let a = session as u64 | (arm as u64) << 32;
    let b = gap.to_bits();
    let c = policy_code as u64 | (explore as u64) << 8 | total_pulls << 16;
    (a, b, c)
}

/// Pack a loadgen observation into `(a, b, c)`.
pub fn pack_measure(app: AppKind, mode: PowerMode, arm: u32, time_s: f64, power_w: f64) -> (u64, u64, u64) {
    let a = app_code(app) | mode_code(mode) << 8 | (arm as u64) << 16;
    (a, time_s.to_bits(), power_w.to_bits())
}

/// Unpack a `Measure` payload: `(app, mode, arm, time_s, power_w)`.
pub fn decode_measure(ev: &TraceEvent) -> Option<(AppKind, PowerMode, usize, f64, f64)> {
    if ev.kind != EventKind::Measure.code() {
        return None;
    }
    let app = app_from_code(ev.a & 0xff)?;
    let mode = mode_from_code(ev.a >> 8 & 0xff)?;
    let arm = (ev.a >> 16) as usize;
    Some((app, mode, arm, f64::from_bits(ev.b), f64::from_bits(ev.c)))
}

/// One decoded ring slot / trace-file record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Global sequence number (gaps mark ring overwrites).
    pub seq: u64,
    /// Microseconds since the recorder's epoch (serve start / file
    /// capture start).
    pub t_us: u64,
    /// Raw kind code — kept raw so newer files decode as `unknown`
    /// instead of failing.
    pub kind: u64,
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

impl TraceEvent {
    pub fn kind_name(&self) -> &'static str {
        EventKind::from_code(self.kind).map_or("unknown", EventKind::name)
    }
}

/// A published slot: seqlock stamp plus an all-atomic payload (torn
/// reads are *detected*, never undefined behaviour).
struct Slot {
    /// `0` = empty or mid-write; otherwise `seq + 1`.
    stamp: AtomicU64,
    t_us: AtomicU64,
    kind: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
    c: AtomicU64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            stamp: AtomicU64::new(0),
            t_us: AtomicU64::new(0),
            kind: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
            c: AtomicU64::new(0),
        }
    }
}

struct Lane {
    cursor: AtomicU64,
    slots: Box<[Slot]>,
}

static NEXT_THREAD: AtomicU64 = AtomicU64::new(0);
thread_local! {
    static THREAD_SLOT: Cell<u64> = const { Cell::new(u64::MAX) };
}

/// Process-wide dense thread index; lane choice is `index % lanes`, so
/// the mapping works for any recorder regardless of its lane count.
fn thread_index() -> u64 {
    THREAD_SLOT.with(|s| {
        let v = s.get();
        if v != u64::MAX {
            return v;
        }
        let v = NEXT_THREAD.fetch_add(1, Ordering::Relaxed);
        s.set(v);
        v
    })
}

/// The flight recorder. Cheap enough to be always on: the serve stack
/// records into it unconditionally and `--trace-file` merely attaches a
/// background drain.
pub struct Recorder {
    lanes: Box<[Lane]>,
    cap: u64,
    seq: AtomicU64,
    overwritten: AtomicU64,
    epoch: Instant,
}

impl Recorder {
    /// `lanes` rings of `cap` slots each. Writers sharing a lane remain
    /// correct (the slot claim is atomic); distinct lanes only remove
    /// cursor contention.
    pub fn new(lanes: usize, cap: usize) -> Recorder {
        let lanes = lanes.max(1);
        let cap = cap.max(16);
        let lanes = (0..lanes)
            .map(|_| Lane {
                cursor: AtomicU64::new(0),
                slots: (0..cap).map(|_| Slot::empty()).collect(),
            })
            .collect();
        Recorder {
            lanes,
            cap: cap as u64,
            seq: AtomicU64::new(0),
            overwritten: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    /// Sized for a serve deployment: one lane per worker plus slack for
    /// the batch updaters, fleet-sync and checkpoint threads.
    pub fn for_workers(workers: usize) -> Recorder {
        Recorder::new(workers.max(1) + 4, DEFAULT_LANE_CAP)
    }

    /// Record one event. O(1): a handful of atomic stores, no locks, no
    /// allocation, never blocks.
    pub fn record(&self, kind: EventKind, a: u64, b: u64, c: u64) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let lane = &self.lanes[(thread_index() % self.lanes.len() as u64) as usize];
        let pos = (lane.cursor.fetch_add(1, Ordering::Relaxed) % self.cap) as usize;
        let slot = &lane.slots[pos];
        if slot.stamp.swap(0, Ordering::AcqRel) != 0 {
            self.overwritten.fetch_add(1, Ordering::Relaxed);
        }
        slot.t_us.store(t_us, Ordering::Relaxed);
        slot.kind.store(kind.code(), Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        slot.c.store(c, Ordering::Relaxed);
        slot.stamp.store(seq + 1, Ordering::Release);
    }

    /// Total events ever recorded (= the next sequence number).
    pub fn recorded(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Events lost to ring wrap-around since start.
    pub fn overwritten(&self) -> u64 {
        self.overwritten.load(Ordering::Relaxed)
    }

    /// Microseconds since the recorder's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Snapshot every live slot with `seq >= since` into `out`, sorted
    /// by sequence number. Cold path: allocates freely, skips torn
    /// slots, tolerates concurrent writers.
    pub fn drain_since(&self, since: u64, out: &mut Vec<TraceEvent>) {
        out.clear();
        for lane in self.lanes.iter() {
            for slot in lane.slots.iter() {
                let s1 = slot.stamp.load(Ordering::Acquire);
                if s1 == 0 || s1 - 1 < since {
                    continue;
                }
                let ev = TraceEvent {
                    seq: s1 - 1,
                    t_us: slot.t_us.load(Ordering::Relaxed),
                    kind: slot.kind.load(Ordering::Relaxed),
                    a: slot.a.load(Ordering::Relaxed),
                    b: slot.b.load(Ordering::Relaxed),
                    c: slot.c.load(Ordering::Relaxed),
                };
                // Seqlock re-check: the payload loads must not sink
                // below the second stamp read.
                std::sync::atomic::fence(Ordering::Acquire);
                if slot.stamp.load(Ordering::Relaxed) == s1 {
                    out.push(ev);
                }
            }
        }
        out.sort_unstable_by_key(|e| e.seq);
    }
}

/// Append one event as a JSON object, decoding the packed payload into
/// per-kind semantic fields. Raw `u64` payloads (f64 bit patterns,
/// packed words) exceed the f64-exact integer range, so the wire format
/// always decodes — `a`/`b`/`c` leak out only for unknown kinds.
pub fn write_event_json(ev: &TraceEvent, w: &mut JsonWriter) {
    w.begin_obj();
    w.field_num("seq", ev.seq as f64);
    w.field_num("t_us", ev.t_us as f64);
    w.field_str("kind", ev.kind_name());
    match EventKind::from_code(ev.kind) {
        Some(EventKind::ReqStart) => {
            w.field_str("route", route_name(ev.a));
            w.field_num("loop", ev.b as f64);
        }
        Some(EventKind::ReqEnd) => {
            w.field_str("route", route_name(ev.a));
            w.field_num("status", ev.b as f64);
            w.field_num("latency_us", ev.c as f64);
        }
        Some(EventKind::Suggest) => {
            w.field_num("session", (ev.a & 0xffff_ffff) as f64);
            w.field_num("arm", (ev.a >> 32) as f64);
            w.field_num("gap", f64::from_bits(ev.b));
            w.field_str("policy", policy_code_name((ev.c & 0xff) as u8));
            w.field_bool("explore", ev.c >> 8 & 1 == 1);
            w.field_num("pulls", (ev.c >> 16) as f64);
        }
        Some(EventKind::ReportApply) => {
            w.field_num("session", (ev.a & 0xffff_ffff) as f64);
            w.field_num("arm", (ev.a >> 32) as f64);
            w.field_num("time_s", f64::from_bits(ev.b));
            w.field_num("power_w", f64::from_bits(ev.c));
        }
        Some(EventKind::BatchFlush) => {
            w.field_num("shard", ev.a as f64);
            w.field_num("reports", ev.b as f64);
        }
        Some(EventKind::FleetPush) => {
            w.field_num("snapshots", ev.a as f64);
        }
        Some(EventKind::FleetPull) => {
            w.field_num("installed", ev.a as f64);
        }
        Some(EventKind::FleetMerge) => {
            w.field_num("snapshots", ev.a as f64);
            w.field_num("nodes", ev.b as f64);
        }
        Some(EventKind::Checkpoint) => {
            w.field_num("sessions", ev.a as f64);
            w.field_num("duration_us", ev.b as f64);
        }
        Some(EventKind::SessionCreate) => {
            w.field_num("session", ev.a as f64);
            w.field_num("arms", ev.b as f64);
            w.field_bool("warm", ev.c & 1 == 1);
            w.field_str("policy", policy_code_name((ev.c >> 8 & 0xff) as u8));
        }
        Some(EventKind::Measure) => match decode_measure(ev) {
            Some((app, mode, arm, time_s, power_w)) => {
                w.field_str("app", app.name());
                w.field_str("mode", mode.lower_name());
                w.field_num("arm", arm as f64);
                w.field_num("time_s", time_s);
                w.field_num("power_w", power_w);
            }
            None => {
                w.field_num("a", ev.a as f64);
            }
        },
        Some(EventKind::Chaos) => {
            w.field_str("point", crate::chaos::fault_point_name(ev.a));
            w.field_num("injection", ev.b as f64);
            w.field_num("arg", ev.c as f64);
        }
        Some(EventKind::ConnOpen) => {
            w.field_num("event_loop", ev.a as f64);
            w.field_num("token", ev.b as f64);
        }
        Some(EventKind::ConnClose) => {
            w.field_num("event_loop", ev.a as f64);
            w.field_num("token", ev.b as f64);
            w.field_num("requests", ev.c as f64);
        }
        None => {
            w.field_num("a", ev.a as f64);
            w.field_num("b", ev.b as f64);
            w.field_num("c", ev.c as f64);
        }
    }
    w.end_obj();
}

/// Policy wire-code names — must mirror `serve::store::PolicyKind::code`.
fn policy_code_name(code: u8) -> &'static str {
    match code {
        0 => "ucb",
        1 => "swucb",
        2 => "thompson",
        3 => "epsilon",
        4 => "subset",
        _ => "unknown",
    }
}

/// Serialize events into the binary on-disk body (no magic header).
pub fn encode_events(events: &[TraceEvent], out: &mut Vec<u8>) {
    out.reserve(events.len() * TRACE_RECORD_BYTES);
    for ev in events {
        for v in [ev.seq, ev.t_us, ev.kind, ev.a, ev.b, ev.c] {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

fn decode_u64(bytes: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(bytes[at..at + 8].try_into().expect("8-byte window"))
}

/// Decode a binary body (magic already stripped).
pub fn decode_events(body: &[u8]) -> anyhow::Result<Vec<TraceEvent>> {
    if body.len() % TRACE_RECORD_BYTES != 0 {
        anyhow::bail!(
            "trace body length {} is not a multiple of the {TRACE_RECORD_BYTES}-byte record size",
            body.len()
        );
    }
    let mut out = Vec::with_capacity(body.len() / TRACE_RECORD_BYTES);
    for rec in body.chunks_exact(TRACE_RECORD_BYTES) {
        out.push(TraceEvent {
            seq: decode_u64(rec, 0),
            t_us: decode_u64(rec, 8),
            kind: decode_u64(rec, 16),
            a: decode_u64(rec, 24),
            b: decode_u64(rec, 32),
            c: decode_u64(rec, 40),
        });
    }
    Ok(out)
}

/// Write a complete `LASPTRC1` trace file.
pub fn write_trace_file(path: &Path, events: &[TraceEvent]) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(TRACE_MAGIC.len() + events.len() * TRACE_RECORD_BYTES);
    buf.extend_from_slice(&TRACE_MAGIC);
    encode_events(events, &mut buf);
    std::fs::write(path, buf)
        .map_err(|e| anyhow::anyhow!("writing trace file {}: {e}", path.display()))
}

/// Read a complete `LASPTRC1` trace file.
pub fn read_trace_file(path: &Path) -> anyhow::Result<Vec<TraceEvent>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading trace file {}: {e}", path.display()))?;
    if bytes.len() < TRACE_MAGIC.len() || bytes[..TRACE_MAGIC.len()] != TRACE_MAGIC {
        anyhow::bail!(
            "{} is not a LASP trace file (expected magic {:?})",
            path.display(),
            std::str::from_utf8(&TRACE_MAGIC).unwrap_or("LASPTRC1")
        );
    }
    decode_events(&bytes[TRACE_MAGIC.len()..])
}

/// Background drain attached by `lasp serve --trace-file`: every ~50 ms
/// it snapshots new events off the ring and appends them to the file.
/// Events overwritten between drains are lost (they show up as sequence
/// gaps in the file) — the server's hot path never waits on disk.
pub struct TraceWriter {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    path: PathBuf,
}

impl TraceWriter {
    pub fn start(recorder: Arc<Recorder>, path: PathBuf) -> anyhow::Result<TraceWriter> {
        let file = std::fs::File::create(&path)
            .map_err(|e| anyhow::anyhow!("creating trace file {}: {e}", path.display()))?;
        let mut file = std::io::BufWriter::new(file);
        file.write_all(&TRACE_MAGIC)
            .map_err(|e| anyhow::anyhow!("writing trace header: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("lasp-trace-writer".to_string())
            .spawn(move || {
                let mut since = 0u64;
                let mut events = Vec::new();
                let mut buf = Vec::new();
                loop {
                    let stopping = stop2.load(Ordering::Relaxed);
                    recorder.drain_since(since, &mut events);
                    if let Some(last) = events.last() {
                        since = last.seq + 1;
                        buf.clear();
                        encode_events(&events, &mut buf);
                        let _ = file.write_all(&buf);
                    }
                    if stopping {
                        let _ = file.flush();
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            })
            .expect("spawn trace writer");
        Ok(TraceWriter { stop, handle: Some(handle), path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Final drain + flush; idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_all(r: &Recorder) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        r.drain_since(0, &mut out);
        out
    }

    #[test]
    fn records_and_drains_in_sequence_order() {
        let r = Recorder::new(2, 64);
        for i in 0..10u64 {
            r.record(EventKind::Suggest, i, 0, 0);
        }
        let evs = drain_all(&r);
        assert_eq!(evs.len(), 10);
        assert_eq!(evs.iter().map(|e| e.seq).collect::<Vec<_>>(), (0..10).collect::<Vec<_>>());
        assert_eq!(r.recorded(), 10);
        assert_eq!(r.overwritten(), 0);
        // since-cursor filters.
        let mut out = Vec::new();
        r.drain_since(7, &mut out);
        assert_eq!(out.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![7, 8, 9]);
    }

    #[test]
    fn ring_wrap_counts_overwrites_and_keeps_newest() {
        let r = Recorder::new(1, 16);
        for i in 0..40u64 {
            r.record(EventKind::ReqStart, i, 0, 0);
        }
        let evs = drain_all(&r);
        assert_eq!(evs.len(), 16, "one full ring survives");
        assert_eq!(evs.first().unwrap().seq, 24, "oldest surviving event");
        assert_eq!(evs.last().unwrap().seq, 39);
        assert_eq!(r.overwritten(), 24);
    }

    #[test]
    fn concurrent_writers_never_produce_torn_events() {
        let r = Arc::new(Recorder::new(4, 256));
        let mut handles = vec![];
        for t in 0..4u64 {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    // Payload redundantly encodes itself so tearing is
                    // detectable.
                    let v = t << 32 | i;
                    r.record(EventKind::Measure, v, v, v);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let evs = drain_all(&r);
        assert!(!evs.is_empty());
        for ev in &evs {
            assert_eq!(ev.a, ev.b);
            assert_eq!(ev.b, ev.c);
        }
        // Sequence numbers are unique.
        let mut seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        seqs.dedup();
        assert_eq!(seqs.len(), evs.len());
    }

    #[test]
    fn trace_file_roundtrips() {
        let dir = std::env::temp_dir().join(format!("lasp-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.trc");
        let events: Vec<TraceEvent> = (0..17)
            .map(|i| TraceEvent {
                seq: i,
                t_us: i * 100,
                kind: EventKind::Suggest.code(),
                a: i << 32 | i,
                b: (i as f64 * 0.25).to_bits(),
                c: i * 7,
            })
            .collect();
        write_trace_file(&path, &events).unwrap();
        let back = read_trace_file(&path).unwrap();
        assert_eq!(back, events);
        // Bad magic is rejected, not misparsed.
        let bogus = dir.join("bogus.trc");
        std::fs::write(&bogus, b"NOTATRCE").unwrap();
        assert!(read_trace_file(&bogus).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn suggest_payload_packs_and_decodes() {
        let (a, b, c) = pack_suggest(77, 124, 0.125, true, 1, 990);
        let ev = TraceEvent { seq: 0, t_us: 0, kind: EventKind::Suggest.code(), a, b, c };
        assert_eq!(ev.a & 0xffff_ffff, 77);
        assert_eq!(ev.a >> 32, 124);
        assert_eq!(f64::from_bits(ev.b), 0.125);
        assert_eq!(ev.c & 0xff, 1);
        assert_eq!(ev.c >> 8 & 1, 1);
        assert_eq!(ev.c >> 16, 990);
        let mut out = Vec::new();
        let mut w = JsonWriter::new(&mut out);
        write_event_json(&ev, &mut w);
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("\"kind\":\"suggest\""), "{s}");
        assert!(s.contains("\"arm\":124"), "{s}");
        assert!(s.contains("\"policy\":\"swucb\""), "{s}");
        assert!(s.contains("\"explore\":true"), "{s}");
    }

    #[test]
    fn measure_payload_roundtrips() {
        let (a, b, c) = pack_measure(AppKind::Kripke, PowerMode::FiveW, 201, 1.5, 4.25);
        let ev = TraceEvent { seq: 3, t_us: 9, kind: EventKind::Measure.code(), a, b, c };
        let (app, mode, arm, t, p) = decode_measure(&ev).unwrap();
        assert_eq!(app, AppKind::Kripke);
        assert_eq!(mode, PowerMode::FiveW);
        assert_eq!(arm, 201);
        assert_eq!(t, 1.5);
        assert_eq!(p, 4.25);
    }

    #[test]
    fn trace_writer_streams_to_disk() {
        let dir = std::env::temp_dir().join(format!("lasp-obs-w-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.trc");
        let r = Arc::new(Recorder::new(2, 128));
        let mut w = TraceWriter::start(Arc::clone(&r), path.clone()).unwrap();
        for i in 0..25u64 {
            r.record(EventKind::ReqEnd, route::SUGGEST, 200, i);
        }
        w.stop();
        let back = read_trace_file(&path).unwrap();
        assert_eq!(back.len(), 25);
        assert_eq!(back.last().unwrap().c, 24);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
