//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Tensor shape + dtype descriptor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorDesc {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorDesc {
    fn from_json(v: &Json) -> Result<TensorDesc> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor desc missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string();
        Ok(TensorDesc { shape, dtype })
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// File name within the artifacts dir.
    pub file: String,
    /// Graph kind: `lasp_step`, `ucb_scores`, `reward_norm`, `ucb_episode`,
    /// `gp_propose`.
    pub kind: String,
    /// Application tag if the artifact is app-specific.
    pub app: Option<String>,
    /// Arm count for bandit artifacts.
    pub k: Option<usize>,
    /// Episode length for `ucb_episode`.
    pub steps: Option<usize>,
    pub inputs: Vec<TensorDesc>,
    pub outputs: Vec<TensorDesc>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        if root.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err(anyhow!("unsupported manifest format"));
        }
        if root.get("return_tuple").and_then(Json::as_bool) != Some(true) {
            return Err(anyhow!("artifacts must be lowered with return_tuple"));
        }
        let artifacts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    name: a
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing name"))?
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing file"))?
                        .to_string(),
                    kind: a
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown")
                        .to_string(),
                    app: a.get("app").and_then(Json::as_str).map(String::from),
                    k: a.get("k").and_then(Json::as_usize),
                    steps: a.get("steps").and_then(Json::as_usize),
                    inputs: a
                        .get("inputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorDesc::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .get("outputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorDesc::from_json)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find an artifact by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find an artifact by kind + app (e.g. the `lasp_step` for "kripke").
    pub fn by_kind_app(&self, kind: &str, app: &str) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.app.as_deref() == Some(app))
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lasp-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = tmpdir("ok");
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","return_tuple":true,"artifacts":[
              {"name":"lasp_step_kripke","file":"lasp_step_kripke.hlo.txt",
               "kind":"lasp_step","app":"kripke","k":216,
               "inputs":[{"shape":[216],"dtype":"f32"},{"shape":[],"dtype":"f32"}],
               "outputs":[{"shape":[],"dtype":"s32"}]}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.by_kind_app("lasp_step", "kripke").unwrap();
        assert_eq!(a.k, Some(216));
        assert_eq!(a.inputs[0].elements(), 216);
        assert_eq!(a.inputs[1].elements(), 1); // scalar
        assert!(m.by_name("nope").is_none());
        assert!(m.path_of(a).ends_with("lasp_step_kripke.hlo.txt"));
    }

    #[test]
    fn rejects_wrong_format() {
        let dir = tmpdir("badfmt");
        write_manifest(&dir, r#"{"format":"protobuf","return_tuple":true,"artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_non_tuple() {
        let dir = tmpdir("notuple");
        write_manifest(&dir, r#"{"format":"hlo-text","return_tuple":false,"artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_file_errors() {
        assert!(Manifest::load(Path::new("/nonexistent-lasp")).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        if let Some(dir) = crate::runtime::find_artifacts_dir() {
            let m = Manifest::load(&dir).unwrap();
            for app in ["lulesh", "kripke", "clomp", "hypre"] {
                let a = m.by_kind_app("lasp_step", app).unwrap();
                assert!(m.path_of(a).exists());
            }
        }
    }
}
