//! Direct (single-thread) PJRT engine: compile-once, execute-many.

use super::artifact::{ArtifactMeta, Manifest};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT CPU engine over the AOT artifact set. Not `Send` (PJRT handles are
/// raw pointers) — see [`super::EngineHandle`] for the threaded wrapper.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Compiled executables, keyed by artifact name (compiled on demand).
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Result of a fused `lasp_step` artifact execution.
#[derive(Debug, Clone)]
pub struct PjrtStep {
    pub best: usize,
    pub score: f64,
    pub rewards: Vec<f32>,
}

impl Engine {
    /// Create a CPU engine over `dir` (must contain `manifest.json`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Engine { client, manifest, executables: HashMap::new() })
    }

    /// Engine over the auto-discovered artifacts dir.
    pub fn load_default() -> Result<Engine> {
        let dir = super::find_artifacts_dir()
            .ok_or_else(|| anyhow!("artifacts/ not found — run `make artifacts`"))?;
        Self::load(&dir)
    }

    /// The manifest describing available artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        self.manifest
            .by_name(name)
            .cloned()
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Compile (or fetch the cached) executable for `name`.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self.meta(name)?;
            let path = self.manifest.path_of(&meta);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Pre-compile a set of artifacts (hot-path warmup).
    pub fn warmup(&mut self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    fn run_tuple(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Execute the fused `lasp_step_<app>` artifact.
    #[allow(clippy::too_many_arguments)]
    pub fn lasp_step(
        &mut self,
        app: &str,
        tau_sum: &[f32],
        rho_sum: &[f32],
        counts: &[f32],
        t: f32,
        alpha: f32,
        beta: f32,
        exploration: f32,
    ) -> Result<PjrtStep> {
        let name = format!("lasp_step_{app}");
        let meta = self.meta(&name)?;
        let k = meta.k.ok_or_else(|| anyhow!("{name}: missing k"))?;
        if tau_sum.len() != k || rho_sum.len() != k || counts.len() != k {
            return Err(anyhow!(
                "{name}: expected vectors of len {k}, got {}/{}/{}",
                tau_sum.len(),
                rho_sum.len(),
                counts.len()
            ));
        }
        let inputs = vec![
            xla::Literal::vec1(tau_sum),
            xla::Literal::vec1(rho_sum),
            xla::Literal::vec1(counts),
            xla::Literal::scalar(t),
            xla::Literal::scalar(alpha),
            xla::Literal::scalar(beta),
            xla::Literal::scalar(exploration),
        ];
        let out = self.run_tuple(&name, &inputs)?;
        if out.len() != 3 {
            return Err(anyhow!("{name}: expected 3 outputs, got {}", out.len()));
        }
        let best = out[0]
            .get_first_element::<i32>()
            .map_err(|e| anyhow!("{name} idx: {e:?}"))? as usize;
        let score = out[1]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("{name} score: {e:?}"))? as f64;
        let rewards = out[2]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{name} rewards: {e:?}"))?;
        Ok(PjrtStep { best, score, rewards })
    }

    /// Execute `ucb_scores_<app>`: Eq. 2 scores + argmax.
    pub fn ucb_scores(
        &mut self,
        app: &str,
        rewards: &[f32],
        counts: &[f32],
        t: f32,
        exploration: f32,
    ) -> Result<(Vec<f32>, usize)> {
        let name = format!("ucb_scores_{app}");
        let inputs = vec![
            xla::Literal::vec1(rewards),
            xla::Literal::vec1(counts),
            xla::Literal::scalar(t),
            xla::Literal::scalar(exploration),
        ];
        let out = self.run_tuple(&name, &inputs)?;
        let scores = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let idx = out[1].get_first_element::<i32>().map_err(|e| anyhow!("{e:?}"))? as usize;
        Ok((scores, idx))
    }

    /// Execute `reward_norm_<app>`: Eq. 5 rewards from running sums.
    #[allow(clippy::too_many_arguments)]
    pub fn reward_norm(
        &mut self,
        app: &str,
        tau_sum: &[f32],
        rho_sum: &[f32],
        counts: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<Vec<f32>> {
        let name = format!("reward_norm_{app}");
        let inputs = vec![
            xla::Literal::vec1(tau_sum),
            xla::Literal::vec1(rho_sum),
            xla::Literal::vec1(counts),
            xla::Literal::scalar(alpha),
            xla::Literal::scalar(beta),
        ];
        let out = self.run_tuple(&name, &inputs)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Execute `ucb_episode_<app>_t<steps>`: mean-field episode replay.
    /// Returns (final counts, selection trace).
    #[allow(clippy::too_many_arguments)]
    pub fn ucb_episode(
        &mut self,
        app: &str,
        steps: usize,
        expected_rewards: &[f32],
        counts0: &[f32],
        t0: f32,
        exploration: f32,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let name = format!("ucb_episode_{app}_t{steps}");
        let inputs = vec![
            xla::Literal::vec1(expected_rewards),
            xla::Literal::vec1(counts0),
            xla::Literal::scalar(t0),
            xla::Literal::scalar(exploration),
        ];
        let out = self.run_tuple(&name, &inputs)?;
        let counts = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let trace = out[1].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((counts, trace))
    }

    /// Execute the BLISS `gp_propose` artifact: masked GP posterior + EI.
    /// Shapes are fixed at lowering time (see manifest); `x`/`y`/`mask` are
    /// padded to N, `xs` to M rows.
    #[allow(clippy::too_many_arguments)]
    pub fn gp_propose(
        &mut self,
        x: &[f32],
        y: &[f32],
        mask: &[f32],
        xs: &[f32],
        lengthscale: f32,
        noise: f32,
        best: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> {
        let meta = self.meta("gp_propose")?;
        let (n, d) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
        let m = meta.inputs[3].shape[0];
        if x.len() != n * d || y.len() != n || mask.len() != n || xs.len() != m * d {
            return Err(anyhow!(
                "gp_propose shape mismatch: x {} (want {}), y {} (want {}), xs {} (want {})",
                x.len(),
                n * d,
                y.len(),
                n,
                xs.len(),
                m * d
            ));
        }
        let inputs = vec![
            xla::Literal::vec1(x).reshape(&[n as i64, d as i64]).map_err(|e| anyhow!("{e:?}"))?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(mask),
            xla::Literal::vec1(xs).reshape(&[m as i64, d as i64]).map_err(|e| anyhow!("{e:?}"))?,
            xla::Literal::scalar(lengthscale),
            xla::Literal::scalar(noise),
            xla::Literal::scalar(best),
        ];
        let out = self.run_tuple("gp_propose", &inputs)?;
        let mean = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let var = out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let ei = out[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let idx = out[3].get_first_element::<i32>().map_err(|e| anyhow!("{e:?}"))? as usize;
        Ok((mean, var, ei, idx))
    }

    /// GP surrogate shape constants (N, M, D) from the manifest.
    pub fn gp_shape(&self) -> Result<(usize, usize, usize)> {
        let meta = self.meta("gp_propose")?;
        Ok((
            meta.inputs[0].shape[0],
            meta.inputs[3].shape[0],
            meta.inputs[0].shape[1],
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = crate::runtime::find_artifacts_dir()?;
        Some(Engine::load(&dir).expect("engine load"))
    }

    #[test]
    fn lasp_step_matches_scalar_backend() {
        let Some(mut e) = engine() else { return };
        let k = 216;
        let mut state = crate::bandit::ArmStats::new(k);
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..400 {
            let arm = rng.below(k);
            state.observe(arm, rng.range(0.5, 3.0), rng.range(3.0, 9.0));
        }
        let tau: Vec<f32> = state.tau_sum().iter().map(|&v| v as f32).collect();
        let rho: Vec<f32> = state.rho_sum().iter().map(|&v| v as f32).collect();
        let cnt: Vec<f32> = state.counts().iter().map(|&v| v as f32).collect();
        let out = e
            .lasp_step("kripke", &tau, &rho, &cnt, state.t() as f32, 0.8, 0.2, 1.0)
            .unwrap();
        let mut sb = crate::bandit::ScalarBackend;
        let mut scratch = crate::bandit::Scratch::new();
        let scalar =
            crate::bandit::ScoreBackend::lasp_step(&mut sb, &state, 0.8, 0.2, 1.0, &mut scratch)
                .unwrap();
        // Rewards agree to f32 tolerance...
        for (a, b) in out.rewards.iter().zip(&scratch.rewards) {
            assert!((*a as f64 - b).abs() < 1e-4, "{a} vs {b}");
        }
        // ...and the selected arm matches (or ties within tolerance).
        if out.best != scalar.best {
            let diff = (out.score - scalar.score).abs();
            assert!(diff < 1e-4, "idx {} vs {}, scores differ {diff}", out.best, scalar.best);
        }
    }

    #[test]
    fn lasp_step_rejects_bad_lengths() {
        let Some(mut e) = engine() else { return };
        let err = e.lasp_step("kripke", &[0.0; 5], &[0.0; 5], &[0.0; 5], 1.0, 1.0, 0.0, 1.0);
        assert!(err.is_err());
    }

    #[test]
    fn episode_trace_counts_consistent() {
        let Some(mut e) = engine() else { return };
        let k = 216;
        let rewards: Vec<f32> = (0..k).map(|i| (i % 17) as f32 / 17.0).collect();
        let (counts, trace) = e
            .ucb_episode("kripke", 500, &rewards, &vec![0.0; k], 1.0, 1.0)
            .unwrap();
        assert_eq!(trace.len(), 500);
        assert_eq!(counts.iter().sum::<f32>(), 500.0);
        // Trace histogram equals final counts.
        let mut hist = vec![0f32; k];
        for &i in &trace {
            hist[i as usize] += 1.0;
        }
        assert_eq!(hist, counts);
    }

    #[test]
    fn gp_propose_shapes() {
        let Some(mut e) = engine() else { return };
        let (n, m, d) = e.gp_shape().unwrap();
        let x = vec![0.1f32; n * d];
        let y = vec![0.5f32; n];
        let mut mask = vec![0.0f32; n];
        mask[0] = 1.0;
        mask[1] = 1.0;
        let xs = vec![0.2f32; m * d];
        let (mean, var, ei, idx) = e
            .gp_propose(&x, &y, &mask, &xs, 1.0, 1e-3, 0.5)
            .unwrap();
        assert_eq!(mean.len(), m);
        assert_eq!(var.len(), m);
        assert_eq!(ei.len(), m);
        assert!(idx < m);
        for v in var {
            assert!(v >= 0.0);
        }
    }

    #[test]
    fn missing_artifact_is_error() {
        let Some(mut e) = engine() else { return };
        assert!(e.lasp_step("nope", &[], &[], &[], 1.0, 1.0, 0.0, 1.0).is_err());
    }
}
