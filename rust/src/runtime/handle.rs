//! `Send + Clone` engine handle: a dedicated actor thread owns the PJRT
//! [`Engine`] (whose handles are `!Send`); callers talk to it over
//! channels. This is what [`crate::bandit::UcbTuner`] and the fleet
//! coordinator use when the AOT backend is enabled.

use super::engine::{Engine, PjrtStep};
use crate::bandit::{ArmStats, ScoreBackend, Scratch, Step};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc;

enum Request {
    LaspStep {
        app: String,
        tau_sum: Vec<f32>,
        rho_sum: Vec<f32>,
        counts: Vec<f32>,
        t: f32,
        alpha: f32,
        beta: f32,
        exploration: f32,
        reply: mpsc::Sender<Result<PjrtStep>>,
    },
    Episode {
        app: String,
        steps: usize,
        rewards: Vec<f32>,
        counts0: Vec<f32>,
        t0: f32,
        exploration: f32,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<i32>)>>,
    },
    GpPropose {
        x: Vec<f32>,
        y: Vec<f32>,
        mask: Vec<f32>,
        xs: Vec<f32>,
        lengthscale: f32,
        noise: f32,
        best: f32,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>, Vec<f32>, usize)>>,
    },
    GpShape {
        reply: mpsc::Sender<Result<(usize, usize, usize)>>,
    },
    Warmup {
        names: Vec<String>,
        reply: mpsc::Sender<Result<()>>,
    },
    Platform {
        reply: mpsc::Sender<String>,
    },
}

/// Cloneable, `Send` handle to a PJRT engine actor thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
}

impl EngineHandle {
    /// Spawn the actor over an explicit artifacts dir.
    pub fn spawn(dir: PathBuf) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("lasp-pjrt".into())
            .spawn(move || {
                let mut engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::LaspStep {
                            app, tau_sum, rho_sum, counts, t, alpha, beta, exploration, reply,
                        } => {
                            let r = engine.lasp_step(
                                &app, &tau_sum, &rho_sum, &counts, t, alpha, beta, exploration,
                            );
                            let _ = reply.send(r);
                        }
                        Request::Episode {
                            app, steps, rewards, counts0, t0, exploration, reply,
                        } => {
                            let r = engine
                                .ucb_episode(&app, steps, &rewards, &counts0, t0, exploration);
                            let _ = reply.send(r);
                        }
                        Request::GpPropose {
                            x, y, mask, xs, lengthscale, noise, best, reply,
                        } => {
                            let r = engine
                                .gp_propose(&x, &y, &mask, &xs, lengthscale, noise, best);
                            let _ = reply.send(r);
                        }
                        Request::GpShape { reply } => {
                            let _ = reply.send(engine.gp_shape());
                        }
                        Request::Warmup { names, reply } => {
                            let refs: Vec<&str> = names.iter().map(String::as_str).collect();
                            let _ = reply.send(engine.warmup(&refs));
                        }
                        Request::Platform { reply } => {
                            let _ = reply.send(engine.platform());
                        }
                    }
                }
            })
            .map_err(|e| anyhow!("spawn pjrt thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt thread died during init"))??;
        Ok(EngineHandle { tx })
    }

    /// Spawn over the auto-discovered artifacts dir.
    pub fn spawn_default() -> Result<EngineHandle> {
        let dir = super::find_artifacts_dir()
            .ok_or_else(|| anyhow!("artifacts/ not found — run `make artifacts`"))?;
        Self::spawn(dir)
    }

    fn call<T>(&self, build: impl FnOnce(mpsc::Sender<T>) -> Request) -> Result<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(build(reply_tx))
            .map_err(|_| anyhow!("pjrt actor gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("pjrt actor dropped reply"))
    }

    /// Fused `lasp_step` on the actor.
    #[allow(clippy::too_many_arguments)]
    pub fn lasp_step(
        &self,
        app: &str,
        tau_sum: Vec<f32>,
        rho_sum: Vec<f32>,
        counts: Vec<f32>,
        t: f32,
        alpha: f32,
        beta: f32,
        exploration: f32,
    ) -> Result<PjrtStep> {
        self.call(|reply| Request::LaspStep {
            app: app.to_string(),
            tau_sum,
            rho_sum,
            counts,
            t,
            alpha,
            beta,
            exploration,
            reply,
        })?
    }

    /// Mean-field episode replay on the actor.
    #[allow(clippy::too_many_arguments)]
    pub fn ucb_episode(
        &self,
        app: &str,
        steps: usize,
        rewards: Vec<f32>,
        counts0: Vec<f32>,
        t0: f32,
        exploration: f32,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        self.call(|reply| Request::Episode {
            app: app.to_string(),
            steps,
            rewards,
            counts0,
            t0,
            exploration,
            reply,
        })?
    }

    /// BLISS GP surrogate proposal on the actor.
    #[allow(clippy::too_many_arguments)]
    pub fn gp_propose(
        &self,
        x: Vec<f32>,
        y: Vec<f32>,
        mask: Vec<f32>,
        xs: Vec<f32>,
        lengthscale: f32,
        noise: f32,
        best: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> {
        self.call(|reply| Request::GpPropose {
            x,
            y,
            mask,
            xs,
            lengthscale,
            noise,
            best,
            reply,
        })?
    }

    /// GP shape constants (N, M, D).
    pub fn gp_shape(&self) -> Result<(usize, usize, usize)> {
        self.call(|reply| Request::GpShape { reply })?
    }

    /// Pre-compile artifacts.
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        self.call(|reply| Request::Warmup {
            names: names.iter().map(|s| s.to_string()).collect(),
            reply,
        })?
    }

    /// PJRT platform string.
    pub fn platform(&self) -> Result<String> {
        self.call(|reply| Request::Platform { reply })
    }
}

/// A `ScoreBackend` that routes the per-iteration hot path through the AOT
/// artifact for one application.
pub struct PjrtScoreBackend {
    handle: EngineHandle,
    app: String,
}

impl PjrtScoreBackend {
    pub fn new(handle: EngineHandle, app: impl Into<String>) -> Self {
        PjrtScoreBackend { handle, app: app.into() }
    }
}

impl ScoreBackend for PjrtScoreBackend {
    #[allow(clippy::too_many_arguments)]
    fn lasp_step(
        &mut self,
        stats: &ArmStats,
        alpha: f64,
        beta: f64,
        exploration: f64,
        scratch: &mut Scratch,
    ) -> Result<Step> {
        let tau: Vec<f32> = stats.tau_sum().iter().map(|&v| v as f32).collect();
        let rho: Vec<f32> = stats.rho_sum().iter().map(|&v| v as f32).collect();
        let cnt: Vec<f32> = stats.counts().iter().map(|&v| v as f32).collect();
        let out = self.handle.lasp_step(
            &self.app,
            tau,
            rho,
            cnt,
            stats.t() as f32,
            alpha as f32,
            beta as f32,
            exploration as f32,
        )?;
        // Honour the ScoreBackend contract: rewards land in the scratch.
        // (The f32 staging vectors above still allocate — the PJRT path
        // is the offline differential-testing backend, not the serve hot
        // path, which always runs the scalar backend.)
        scratch.ensure_rewards(stats.k());
        for (dst, &v) in scratch.rewards.iter_mut().zip(&out.rewards) {
            *dst = v as f64;
        }
        Ok(Step { best: out.best, score: out.score })
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::{Policy, UcbTuner};

    #[test]
    fn handle_is_send_and_clone() {
        fn assert_send_clone<T: Send + Clone>() {}
        assert_send_clone::<EngineHandle>();
    }

    #[test]
    fn tuner_over_pjrt_backend_converges() {
        let Some(dir) = crate::runtime::find_artifacts_dir() else { return };
        let handle = EngineHandle::spawn(dir).unwrap();
        let backend = PjrtScoreBackend::new(handle, "clomp");
        let k = 125;
        let mut tuner = UcbTuner::with_backend(k, 1.0, 0.0, Box::new(backend));
        // Arm 40 is the fastest.
        for _ in 0..400 {
            let arm = tuner.select();
            let time = if arm == 40 { 0.5 } else { 2.0 + (arm % 7) as f64 * 0.1 };
            tuner.update(arm, time, 5.0);
        }
        assert_eq!(tuner.most_selected(), 40);
        assert_eq!(tuner.backend_name(), "pjrt");
    }

    #[test]
    fn handle_usable_from_worker_threads() {
        let Some(dir) = crate::runtime::find_artifacts_dir() else { return };
        let handle = EngineHandle::spawn(dir).unwrap();
        let mut joins = vec![];
        for i in 0..4 {
            let h = handle.clone();
            joins.push(std::thread::spawn(move || {
                let k = 125;
                let tau = vec![1.0f32 + i as f32; k];
                let rho = vec![5.0f32; k];
                let cnt = vec![1.0f32; k];
                let out = h.lasp_step("clomp", tau, rho, cnt, 126.0, 0.8, 0.2, 1.0).unwrap();
                assert!(out.best < k);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}
