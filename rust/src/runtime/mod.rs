//! AOT runtime: load `artifacts/*.hlo.txt` (lowered by
//! `python/compile/aot.py`) and execute them on the PJRT CPU client via the
//! `xla` crate. Python never runs here — the HLO text is the only thing
//! that crosses the language boundary.
//!
//! Two entry styles:
//! * [`Engine`] — direct, single-threaded use (PJRT handles are `!Send`).
//! * [`EngineHandle`] — a `Send + Clone` handle backed by a dedicated actor
//!   thread that owns the `Engine`; this is what the tuning loop and the
//!   fleet coordinator use, and it implements
//!   [`crate::bandit::ScoreBackend`].

mod artifact;
mod engine;
mod handle;

pub use artifact::{ArtifactMeta, Manifest};
pub use engine::Engine;
pub use handle::{EngineHandle, PjrtScoreBackend};

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: `$LASP_ARTIFACTS` or `artifacts/`
/// relative to the current dir or the crate root.
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("LASP_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    for base in [
        std::path::PathBuf::from(DEFAULT_ARTIFACTS_DIR),
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACTS_DIR),
    ] {
        if base.join("manifest.json").exists() {
            return Some(base);
        }
    }
    None
}
