//! Batched reward ingestion: the write plane of the tuning service.
//!
//! `POST /v1/report` must not pay for a bandit update inline — the suggest
//! hot path shares the shard lock, so a burst of reports would stretch
//! suggest tail latency. Instead each shard owns a bounded queue drained
//! by a dedicated updater thread that applies reports in batches under a
//! single lock acquisition. The queue bound is the overload valve: when a
//! shard's updater falls behind, the report is *dropped and counted*
//! (`lasp_serve_reports_dropped_total`, answered 503 upstream) rather
//! than blocking an HTTP worker — a report is one measurement a client
//! can resend, and a stalled worker would stall suggests for everyone.
//!
//! Ingestion is idempotent when clients cooperate: a report carrying a
//! `seq` number is checked against its session's
//! [`super::store::SeqWindow`], so at-least-once delivery (retries,
//! duplicated packets, the chaos layer's `flush_duplicate` point) never
//! double-counts a measurement into [`crate::bandit::ArmStats`]
//! (`rust/tests/chaos.rs` pins this).

use super::metrics::Metrics;
use super::store::{AppsCache, SessionId, Shard, ShardedStore};
use crate::apps::AppKind;
use crate::chaos::ChaosLayer;
use crate::obs::{EventKind, Recorder};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One measured evaluation reported by an edge client. Identified by the
/// interned [`SessionId`] (plus the `Copy` app kind for arm-count
/// lookups), so enqueueing a report never clones a session key.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    pub id: SessionId,
    pub app: AppKind,
    pub alpha: f64,
    pub beta: f64,
    pub arm: usize,
    pub time_s: f64,
    pub power_w: f64,
    /// Optional client-assigned sequence number: reports carrying one are
    /// deduplicated through the session's idempotency window.
    pub seq: Option<u64>,
}

/// What [`BatchIngest::enqueue`] did with the report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Enqueue {
    /// Queued for the shard's updater.
    Queued,
    /// Shard queue full: dropped and counted
    /// (`lasp_serve_reports_dropped_total`). The client should resend.
    Dropped,
}

enum Msg {
    Report(Report),
    Stop,
}

/// Per-shard bounded queues + updater threads.
pub struct BatchIngest {
    /// `SyncSender` is wrapped in a `Mutex` per shard so the ingest handle
    /// can be shared across worker threads without requiring `Sync`
    /// senders; the critical section is a single `try_send`.
    txs: Vec<Mutex<SyncSender<Msg>>>,
    updaters: Mutex<Vec<JoinHandle<()>>>,
}

impl BatchIngest {
    /// Spawn one updater thread per shard. `chaos` is the serve-side fault
    /// layer (`None` without `--chaos`: zero overhead on the flush path).
    pub fn start(
        store: Arc<ShardedStore>,
        apps: Arc<AppsCache>,
        metrics: Arc<Metrics>,
        recorder: Arc<Recorder>,
        queue_cap: usize,
        max_batch: usize,
        chaos: Option<Arc<ChaosLayer>>,
    ) -> BatchIngest {
        assert!(queue_cap > 0 && max_batch > 0);
        let shards = store.num_shards();
        let mut txs = Vec::with_capacity(shards);
        let mut updaters = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(queue_cap);
            txs.push(Mutex::new(tx));
            let store = store.clone();
            let apps = apps.clone();
            let metrics = metrics.clone();
            let recorder = recorder.clone();
            let chaos = chaos.clone();
            updaters.push(std::thread::spawn(move || {
                updater_loop(
                    shard,
                    &rx,
                    &store,
                    &apps,
                    &metrics,
                    &recorder,
                    max_batch,
                    chaos.as_deref(),
                )
            }));
        }
        BatchIngest {
            txs,
            updaters: Mutex::new(updaters),
        }
    }

    /// Enqueue a report for its shard's updater. Fast path is a lock-light
    /// `try_send`; a full queue sheds the report — counted, never silent —
    /// instead of blocking the HTTP worker that carried it.
    pub fn enqueue(&self, shard: usize, report: Report, metrics: &Metrics) -> Result<Enqueue, String> {
        let tx = match self.txs[shard].lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        match tx.try_send(Msg::Report(report)) {
            Ok(()) => Ok(Enqueue::Queued),
            Err(TrySendError::Full(_)) => {
                metrics.queue_backpressure.fetch_add(1, Ordering::Relaxed);
                metrics.reports_dropped.fetch_add(1, Ordering::Relaxed);
                Ok(Enqueue::Dropped)
            }
            Err(TrySendError::Disconnected(_)) => Err("updater thread exited".to_string()),
        }
    }

    /// Enqueue a shard-grouped run of reports under *one* sender-lock
    /// acquisition — the batch report endpoint groups its entries by
    /// shard before calling this, so an N-entry batch costs one lock per
    /// shard touched instead of N. Outcomes are pushed onto `out` in
    /// input order; a full queue drops-and-counts the individual report
    /// and keeps going, so one saturated shard degrades entries, never
    /// the whole batch.
    pub fn enqueue_group(
        &self,
        shard: usize,
        reports: &[Report],
        metrics: &Metrics,
        out: &mut Vec<Enqueue>,
    ) -> Result<(), String> {
        let tx = match self.txs[shard].lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        for &r in reports {
            match tx.try_send(Msg::Report(r)) {
                Ok(()) => out.push(Enqueue::Queued),
                Err(TrySendError::Full(_)) => {
                    metrics.queue_backpressure.fetch_add(1, Ordering::Relaxed);
                    metrics.reports_dropped.fetch_add(1, Ordering::Relaxed);
                    out.push(Enqueue::Dropped);
                }
                Err(TrySendError::Disconnected(_)) => {
                    return Err("updater thread exited".to_string())
                }
            }
        }
        Ok(())
    }

    /// Stop all updaters after draining everything queued ahead of the
    /// stop marker. Safe to call once; later enqueues fail cleanly.
    pub fn stop(&self) {
        for tx in &self.txs {
            let tx = match tx.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            let _ = tx.send(Msg::Stop);
        }
        let mut updaters = match self.updaters.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        for h in updaters.drain(..) {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)] // one thread entry point per shard, mirrors start()
fn updater_loop(
    shard: usize,
    rx: &Receiver<Msg>,
    store: &ShardedStore,
    apps: &AppsCache,
    metrics: &Metrics,
    recorder: &Recorder,
    max_batch: usize,
    chaos: Option<&ChaosLayer>,
) {
    loop {
        // Block for the first report, then opportunistically drain up to
        // `max_batch` more so a burst costs one lock acquisition.
        let first = match rx.recv() {
            Ok(Msg::Report(r)) => r,
            Ok(Msg::Stop) | Err(_) => return,
        };
        let mut batch = vec![first];
        let mut stop_after = false;
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(Msg::Report(r)) => batch.push(r),
                Ok(Msg::Stop) => {
                    stop_after = true;
                    break;
                }
                Err(_) => break,
            }
        }
        let n = batch.len();
        apply_batch(shard, batch, store, apps, metrics, recorder, chaos);
        metrics.update_batches.fetch_add(1, Ordering::Relaxed);
        recorder.record(EventKind::BatchFlush, shard as u64, n as u64, 0);
        if stop_after {
            return;
        }
    }
}

fn apply_batch(
    shard: usize,
    batch: Vec<Report>,
    store: &ShardedStore,
    apps: &AppsCache,
    metrics: &Metrics,
    recorder: &Recorder,
    chaos: Option<&ChaosLayer>,
) {
    let mut guard = store.write_shard(shard);
    for r in batch {
        for _ in 0..chaos_copies(chaos, shard) {
            apply_one(&r, store, &mut guard, apps, metrics, recorder);
        }
    }
}

/// How many times to apply one report. The chaos `batch_flush` point
/// models at-least-once delivery by re-applying the report through the
/// *same* path a real duplicate would take — so a seq-carrying duplicate
/// is absorbed by the idempotency window and a seq-less one genuinely
/// double-counts (the contrast `rust/tests/chaos.rs` pins). Shared with
/// the routed data plane's inline apply so the injection point survives
/// the shared-nothing restructure unchanged.
pub(crate) fn chaos_copies(chaos: Option<&ChaosLayer>, shard: usize) -> usize {
    if chaos.is_some_and(|c| c.flush_duplicate(shard)) {
        2
    } else {
        1
    }
}

/// Apply one report to its session inside `guard` — the single reward
/// path for every ingestion mode (shard updater threads in the shared
/// plane, owner event loops in the routed plane). `guard` is a plain
/// `&mut Shard`, so it serves both the locked and the loop-owned access
/// disciplines.
pub(crate) fn apply_one(
    r: &Report,
    store: &ShardedStore,
    guard: &mut Shard,
    apps: &AppsCache,
    metrics: &Metrics,
    recorder: &Recorder,
) {
    let k = apps.arms(r.app);
    // Reports may precede any suggest for the session (e.g. a client
    // replaying measurements after a server restart): create cold.
    match store.get_or_create(guard, r.id, r.alpha, r.beta, k) {
        Ok((session, created)) => {
            if created {
                metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
            }
            // Idempotency check before the reward update: a duplicate or
            // out-of-window straggler is absorbed, never double-counted.
            if let Some(seq) = r.seq {
                if !session.seq_window.accept(seq) {
                    metrics.reports_deduped.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
            match session.tuner.observe(r.arm, r.time_s, r.power_w) {
                Ok(()) => {
                    session.reports += 1;
                    metrics.reports_applied.fetch_add(1, Ordering::Relaxed);
                    recorder.record(
                        EventKind::ReportApply,
                        r.id.0 as u64 | (r.arm as u64) << 32,
                        r.time_s.to_bits(),
                        r.power_w.to_bits(),
                    );
                }
                Err(_) => {
                    metrics.reports_rejected.fetch_add(1, Ordering::Relaxed);
                }
            }
            store.note_scratch(session);
        }
        Err(_) => {
            metrics.reports_rejected.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::PowerMode;
    use crate::serve::store::{PolicyKind, SessionKey};
    use std::time::{Duration, Instant};

    fn key(client: &str) -> SessionKey {
        SessionKey {
            client_id: client.to_string(),
            app: AppKind::Clomp,
            device: PowerMode::Maxn,
            policy: PolicyKind::Ucb,
        }
    }

    fn report(id: SessionId, arm: usize, time_s: f64, power_w: f64) -> Report {
        Report {
            id,
            app: AppKind::Clomp,
            alpha: 1.0,
            beta: 0.0,
            arm,
            time_s,
            power_w,
            seq: None,
        }
    }

    fn wait_for<F: Fn() -> bool>(cond: F, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        cond()
    }

    #[test]
    fn reports_are_applied_asynchronously() {
        let store = Arc::new(ShardedStore::new(4));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let recorder = Arc::new(Recorder::new(2, 256));
        let ingest = BatchIngest::start(
            store.clone(),
            apps,
            metrics.clone(),
            recorder.clone(),
            64,
            16,
            None,
        );

        let k = key("async-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        let shard = store.shard_of(&k);
        for i in 0..50 {
            ingest
                .enqueue(shard, report(id, i % 125, 1.0, 5.0), &metrics)
                .unwrap();
        }
        assert!(
            wait_for(
                || metrics.reports_applied.load(Ordering::Relaxed) == 50,
                Duration::from_secs(5)
            ),
            "applied {} of 50",
            metrics.reports_applied.load(Ordering::Relaxed)
        );
        let guard = store.read_shard(shard);
        let session = guard.sessions.get(&id.0).unwrap();
        assert_eq!(session.tuner.total_pulls(), 50.0);
        drop(guard);
        // Every applied report and at least one flush landed in the
        // flight recorder.
        let mut events = Vec::new();
        recorder.drain_since(0, &mut events);
        let applies =
            events.iter().filter(|e| e.kind == EventKind::ReportApply.code()).count();
        let flushes =
            events.iter().filter(|e| e.kind == EventKind::BatchFlush.code()).count();
        assert_eq!(applies, 50);
        assert!(flushes >= 1);
        ingest.stop();
    }

    #[test]
    fn bad_reports_are_rejected_not_fatal() {
        let store = Arc::new(ShardedStore::new(2));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let ingest = BatchIngest::start(
            store.clone(),
            apps,
            metrics.clone(),
            Arc::new(Recorder::new(2, 256)),
            16,
            8,
            None,
        );
        let k = key("bad-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        let shard = store.shard_of(&k);
        // Arm out of range for clomp (125 arms).
        ingest
            .enqueue(shard, report(id, 10_000, 1.0, 5.0), &metrics)
            .unwrap();
        ingest
            .enqueue(shard, report(id, 3, 1.0, 5.0), &metrics)
            .unwrap();
        assert!(wait_for(
            || metrics.reports_applied.load(Ordering::Relaxed) == 1
                && metrics.reports_rejected.load(Ordering::Relaxed) == 1,
            Duration::from_secs(5)
        ));
        ingest.stop();
    }

    #[test]
    fn stop_drains_pending_reports() {
        let store = Arc::new(ShardedStore::new(1));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let ingest = BatchIngest::start(
            store.clone(),
            apps,
            metrics.clone(),
            Arc::new(Recorder::new(2, 256)),
            256,
            32,
            None,
        );
        let k = key("drain-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        for i in 0..100 {
            ingest
                .enqueue(0, report(id, i % 125, 0.5, 4.0), &metrics)
                .unwrap();
        }
        ingest.stop();
        assert_eq!(metrics.reports_applied.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn duplicate_and_reordered_seqs_are_absorbed() {
        let store = Arc::new(ShardedStore::new(1));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let ingest = BatchIngest::start(
            store.clone(),
            apps,
            metrics.clone(),
            Arc::new(Recorder::new(2, 256)),
            256,
            32,
            None,
        );
        let k = key("seq-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        // 30 distinct seqs delivered at-least-once with reorders: each
        // even seq twice, odds once, and a late straggler at the end.
        for i in 0..30u64 {
            let mut r = report(id, (i % 125) as usize, 1.0, 5.0);
            r.seq = Some(i);
            ingest.enqueue(0, r, &metrics).unwrap();
            if i % 2 == 0 {
                ingest.enqueue(0, r, &metrics).unwrap();
            }
        }
        let mut straggler = report(id, 3, 1.0, 5.0);
        straggler.seq = Some(5);
        ingest.enqueue(0, straggler, &metrics).unwrap();
        ingest.stop();
        assert_eq!(metrics.reports_applied.load(Ordering::Relaxed), 30);
        assert_eq!(metrics.reports_deduped.load(Ordering::Relaxed), 16);
        let guard = store.read_shard(0);
        let session = guard.sessions.get(&id.0).unwrap();
        assert_eq!(session.tuner.total_pulls(), 30.0, "a duplicate reached ArmStats");
    }

    #[test]
    fn enqueue_group_drops_individually_under_one_lock() {
        let store = Arc::new(ShardedStore::new(1));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let ingest = BatchIngest::start(
            store.clone(),
            apps,
            metrics.clone(),
            Arc::new(Recorder::new(2, 256)),
            8,
            4,
            None,
        );
        let k = key("group-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        let reports: Vec<Report> =
            (0..64).map(|i| report(id, i % 125, 1.0, 5.0)).collect();
        let mut out = Vec::new();
        {
            // Hold the shard write lock so the updater cannot drain: the
            // 8-deep queue must shed most of the 64-entry group.
            let _guard = store.write_shard(0);
            ingest.enqueue_group(0, &reports, &metrics, &mut out).unwrap();
        }
        assert_eq!(out.len(), 64, "one outcome per report, in order");
        let queued = out.iter().filter(|&&e| e == Enqueue::Queued).count() as u64;
        let dropped = out.iter().filter(|&&e| e == Enqueue::Dropped).count() as u64;
        assert!(queued >= 8 && dropped >= 1, "queued {queued} dropped {dropped}");
        assert_eq!(metrics.reports_dropped.load(Ordering::Relaxed), dropped);
        ingest.stop();
        // Everything queued was eventually applied; drops stayed dropped.
        assert_eq!(metrics.reports_applied.load(Ordering::Relaxed), queued);
    }

    #[test]
    fn full_queue_drops_are_counted_not_silent() {
        let store = Arc::new(ShardedStore::new(1));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let ingest = BatchIngest::start(
            store.clone(),
            apps,
            metrics.clone(),
            Arc::new(Recorder::new(2, 256)),
            8,
            4,
            None,
        );
        let k = key("drop-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        let total = 64u64;
        let mut dropped_now = 0u64;
        {
            // Hold the shard write lock so the updater cannot drain: the
            // queue must fill and then shed deterministically.
            let _guard = store.write_shard(0);
            for i in 0..total {
                match ingest
                    .enqueue(0, report(id, (i % 125) as usize, 1.0, 5.0), &metrics)
                    .unwrap()
                {
                    Enqueue::Queued => {}
                    Enqueue::Dropped => dropped_now += 1,
                }
            }
            assert!(dropped_now >= 1, "a 8-deep queue cannot hold {total} reports");
        }
        ingest.stop();
        let applied = metrics.reports_applied.load(Ordering::Relaxed);
        let dropped = metrics.reports_dropped.load(Ordering::Relaxed);
        assert_eq!(dropped, dropped_now);
        assert_eq!(applied + dropped, total, "a report vanished without being counted");
        assert!(metrics.queue_backpressure.load(Ordering::Relaxed) >= dropped);
    }
}
