//! Batched reward ingestion: the write plane of the tuning service.
//!
//! `POST /v1/report` must not pay for a bandit update inline — the suggest
//! hot path shares the shard lock, so a burst of reports would stretch
//! suggest tail latency. Instead each shard owns a bounded queue drained
//! by a dedicated updater thread that applies reports in batches under a
//! single lock acquisition. The queue bound is the backpressure: when a
//! shard's updater falls behind, enqueueing blocks the reporting client
//! (never unbounded memory), mirroring the bounded-channel discipline of
//! [`crate::coordinator`].

use super::metrics::Metrics;
use super::store::{AppsCache, SessionId, ShardedStore};
use crate::apps::AppKind;
use crate::obs::{EventKind, Recorder};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One measured evaluation reported by an edge client. Identified by the
/// interned [`SessionId`] (plus the `Copy` app kind for arm-count
/// lookups), so enqueueing a report never clones a session key.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    pub id: SessionId,
    pub app: AppKind,
    pub alpha: f64,
    pub beta: f64,
    pub arm: usize,
    pub time_s: f64,
    pub power_w: f64,
}

enum Msg {
    Report(Report),
    Stop,
}

/// Per-shard bounded queues + updater threads.
pub struct BatchIngest {
    /// `SyncSender` is wrapped in a `Mutex` per shard so the ingest handle
    /// can be shared across worker threads without requiring `Sync`
    /// senders; the critical section is a single `try_send`.
    txs: Vec<Mutex<SyncSender<Msg>>>,
    updaters: Mutex<Vec<JoinHandle<()>>>,
}

impl BatchIngest {
    /// Spawn one updater thread per shard.
    pub fn start(
        store: Arc<ShardedStore>,
        apps: Arc<AppsCache>,
        metrics: Arc<Metrics>,
        recorder: Arc<Recorder>,
        queue_cap: usize,
        max_batch: usize,
    ) -> BatchIngest {
        assert!(queue_cap > 0 && max_batch > 0);
        let shards = store.num_shards();
        let mut txs = Vec::with_capacity(shards);
        let mut updaters = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(queue_cap);
            txs.push(Mutex::new(tx));
            let store = store.clone();
            let apps = apps.clone();
            let metrics = metrics.clone();
            let recorder = recorder.clone();
            updaters.push(std::thread::spawn(move || {
                updater_loop(shard, &rx, &store, &apps, &metrics, &recorder, max_batch)
            }));
        }
        BatchIngest {
            txs,
            updaters: Mutex::new(updaters),
        }
    }

    /// Enqueue a report for its shard's updater. Fast path is a lock-light
    /// `try_send`; a full queue blocks (backpressure) rather than dropping.
    pub fn enqueue(&self, shard: usize, report: Report, metrics: &Metrics) -> Result<(), String> {
        let tx = match self.txs[shard].lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        match tx.try_send(Msg::Report(report)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(m)) => {
                metrics.queue_backpressure.fetch_add(1, Ordering::Relaxed);
                tx.send(m).map_err(|_| "updater thread exited".to_string())
            }
            Err(TrySendError::Disconnected(_)) => Err("updater thread exited".to_string()),
        }
    }

    /// Stop all updaters after draining everything queued ahead of the
    /// stop marker. Safe to call once; later enqueues fail cleanly.
    pub fn stop(&self) {
        for tx in &self.txs {
            let tx = match tx.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            let _ = tx.send(Msg::Stop);
        }
        let mut updaters = match self.updaters.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        for h in updaters.drain(..) {
            let _ = h.join();
        }
    }
}

fn updater_loop(
    shard: usize,
    rx: &Receiver<Msg>,
    store: &ShardedStore,
    apps: &AppsCache,
    metrics: &Metrics,
    recorder: &Recorder,
    max_batch: usize,
) {
    loop {
        // Block for the first report, then opportunistically drain up to
        // `max_batch` more so a burst costs one lock acquisition.
        let first = match rx.recv() {
            Ok(Msg::Report(r)) => r,
            Ok(Msg::Stop) | Err(_) => return,
        };
        let mut batch = vec![first];
        let mut stop_after = false;
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(Msg::Report(r)) => batch.push(r),
                Ok(Msg::Stop) => {
                    stop_after = true;
                    break;
                }
                Err(_) => break,
            }
        }
        let n = batch.len();
        apply_batch(shard, batch, store, apps, metrics, recorder);
        metrics.update_batches.fetch_add(1, Ordering::Relaxed);
        recorder.record(EventKind::BatchFlush, shard as u64, n as u64, 0);
        if stop_after {
            return;
        }
    }
}

fn apply_batch(
    shard: usize,
    batch: Vec<Report>,
    store: &ShardedStore,
    apps: &AppsCache,
    metrics: &Metrics,
    recorder: &Recorder,
) {
    let mut guard = store.write_shard(shard);
    for r in batch {
        let k = apps.arms(r.app);
        // Reports may precede any suggest for the session (e.g. a client
        // replaying measurements after a server restart): create cold.
        match store.get_or_create(&mut guard, r.id, r.alpha, r.beta, k) {
            Ok((session, created)) => {
                if created {
                    metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
                }
                match session.tuner.observe(r.arm, r.time_s, r.power_w) {
                    Ok(()) => {
                        session.reports += 1;
                        metrics.reports_applied.fetch_add(1, Ordering::Relaxed);
                        recorder.record(
                            EventKind::ReportApply,
                            r.id.0 as u64 | (r.arm as u64) << 32,
                            r.time_s.to_bits(),
                            r.power_w.to_bits(),
                        );
                    }
                    Err(_) => {
                        metrics.reports_rejected.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(_) => {
                metrics.reports_rejected.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::PowerMode;
    use crate::serve::store::{PolicyKind, SessionKey};
    use std::time::{Duration, Instant};

    fn key(client: &str) -> SessionKey {
        SessionKey {
            client_id: client.to_string(),
            app: AppKind::Clomp,
            device: PowerMode::Maxn,
            policy: PolicyKind::Ucb,
        }
    }

    fn report(id: SessionId, arm: usize, time_s: f64, power_w: f64) -> Report {
        Report {
            id,
            app: AppKind::Clomp,
            alpha: 1.0,
            beta: 0.0,
            arm,
            time_s,
            power_w,
        }
    }

    fn wait_for<F: Fn() -> bool>(cond: F, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        cond()
    }

    #[test]
    fn reports_are_applied_asynchronously() {
        let store = Arc::new(ShardedStore::new(4));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let recorder = Arc::new(Recorder::new(2, 256));
        let ingest =
            BatchIngest::start(store.clone(), apps, metrics.clone(), recorder.clone(), 64, 16);

        let k = key("async-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        let shard = store.shard_of(&k);
        for i in 0..50 {
            ingest
                .enqueue(shard, report(id, i % 125, 1.0, 5.0), &metrics)
                .unwrap();
        }
        assert!(
            wait_for(
                || metrics.reports_applied.load(Ordering::Relaxed) == 50,
                Duration::from_secs(5)
            ),
            "applied {} of 50",
            metrics.reports_applied.load(Ordering::Relaxed)
        );
        let guard = store.read_shard(shard);
        let session = guard.sessions.get(&id.0).unwrap();
        assert_eq!(session.tuner.total_pulls(), 50.0);
        drop(guard);
        // Every applied report and at least one flush landed in the
        // flight recorder.
        let mut events = Vec::new();
        recorder.drain_since(0, &mut events);
        let applies =
            events.iter().filter(|e| e.kind == EventKind::ReportApply.code()).count();
        let flushes =
            events.iter().filter(|e| e.kind == EventKind::BatchFlush.code()).count();
        assert_eq!(applies, 50);
        assert!(flushes >= 1);
        ingest.stop();
    }

    #[test]
    fn bad_reports_are_rejected_not_fatal() {
        let store = Arc::new(ShardedStore::new(2));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let ingest = BatchIngest::start(
            store.clone(),
            apps,
            metrics.clone(),
            Arc::new(Recorder::new(2, 256)),
            16,
            8,
        );
        let k = key("bad-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        let shard = store.shard_of(&k);
        // Arm out of range for clomp (125 arms).
        ingest
            .enqueue(shard, report(id, 10_000, 1.0, 5.0), &metrics)
            .unwrap();
        ingest
            .enqueue(shard, report(id, 3, 1.0, 5.0), &metrics)
            .unwrap();
        assert!(wait_for(
            || metrics.reports_applied.load(Ordering::Relaxed) == 1
                && metrics.reports_rejected.load(Ordering::Relaxed) == 1,
            Duration::from_secs(5)
        ));
        ingest.stop();
    }

    #[test]
    fn stop_drains_pending_reports() {
        let store = Arc::new(ShardedStore::new(1));
        let apps = Arc::new(AppsCache::new());
        let metrics = Arc::new(Metrics::new());
        let ingest = BatchIngest::start(
            store.clone(),
            apps,
            metrics.clone(),
            Arc::new(Recorder::new(2, 256)),
            256,
            32,
        );
        let k = key("drain-client");
        let id = store.intern(&k.as_ref(), k.hash64());
        for i in 0..100 {
            ingest
                .enqueue(0, report(id, i % 125, 0.5, 4.0), &metrics)
                .unwrap();
        }
        ingest.stop();
        assert_eq!(metrics.reports_applied.load(Ordering::Relaxed), 100);
    }
}
