//! Checkpoint/warm-start for the session store.
//!
//! Every session whose policy exposes reward sufficient statistics is
//! periodically serialized — one JSON file per session, written atomically
//! via [`persist::write_atomic`] so a crash mid-snapshot never leaves a
//! torn file. On boot the service re-reads the directory and rebuilds each
//! session with [`persist::discounted`] applied: prior knowledge is kept
//! but its effective pull counts are shrunk, so a restarted service biases
//! toward what it had learned while still re-verifying a possibly shifted
//! environment (the paper's warm-start story, applied to the service).
//!
//! # File format
//!
//! One file per session, named `sess-<hash16>.json` where `<hash16>` is
//! the session key's stable FNV-1a hash in hex ([`SessionKey::hash64`] —
//! restart-invariant, so a snapshot always overwrites its predecessor).
//! Each file is a versioned *envelope* (session identity, objective
//! weights, traffic counters) embedding the policy's reward state in the
//! [`persist`] checkpoint format:
//!
//! ```json
//! {"version": 1,
//!  "client_id": "edge-1", "app": "kripke", "device": "maxn",
//!  "policy": "ucb", "alpha": 0.8, "beta": 0.2,
//!  "suggests": 420, "reports": 418,
//!  "state": {"version": 1, "app": "kripke", "alpha": 0.8, "beta": 0.2,
//!            "t": 419, "tau_sum": [...], "rho_sum": [...], "counts": [...]}}
//! ```
//!
//! Subset-policy sessions store *subset-space* vectors (positions are
//! candidate indices); the candidate list itself is never persisted
//! because it is re-derived from the session-key seed on restore.
//! Sessions warm-started from a fleet prior additionally carry an
//! optional `fleet_baseline` object (same [`persist`] format) recording
//! the borrowed statistics they were seeded with, so a restored session
//! keeps exporting only locally measured deltas to the sync plane.
//!
//! **Versioning rules.** Envelope and state versions are checked
//! independently; a reader rejects any version it does not know.
//! Restores skip unreadable, corrupt or version-mismatched files instead
//! of failing the boot — a tuning service must come up even if one
//! checkpoint rotted. Format changes bump the version and must keep a
//! reader for every version still in the field (see DESIGN.md
//! §Checkpoint file format).

use super::store::{AppsCache, PolicyKind, Session, SessionKey, SeqWindow, Shard, ShardedStore, Tuner};
use crate::apps::AppKind;
use crate::bandit::persist;
use crate::device::PowerMode;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Session-envelope format version.
const VERSION: f64 = 1.0;

/// Serialize one session (metadata envelope + embedded persist state).
/// Every policy exposes the shared [`crate::bandit::ArmStats`] core, so
/// every session is checkpointable — ε-greedy included. Returns `None`
/// only when the state cannot round-trip through the persist format
/// (e.g. a non-finite statistic): one rotten session must degrade to a
/// skipped snapshot, never a panicking checkpoint thread.
pub fn session_to_json(session: &Session) -> Option<String> {
    let state = session.tuner.stats();
    let inner = persist::to_json(state, session.key.app.name(), session.alpha, session.beta);
    let inner = Json::parse(&inner).ok()?;
    let mut obj = BTreeMap::new();
    obj.insert("version".to_string(), Json::Num(VERSION));
    obj.insert("client_id".to_string(), Json::Str(session.key.client_id.clone()));
    obj.insert("app".to_string(), Json::Str(session.key.app.name().to_string()));
    obj.insert(
        "device".to_string(),
        Json::Str(session.key.device.lower_name().to_string()),
    );
    obj.insert("policy".to_string(), Json::Str(session.key.policy.name().to_string()));
    obj.insert("alpha".to_string(), Json::Num(session.alpha));
    obj.insert("beta".to_string(), Json::Num(session.beta));
    obj.insert("suggests".to_string(), Json::Num(session.suggests as f64));
    obj.insert("reports".to_string(), Json::Num(session.reports as f64));
    obj.insert("state".to_string(), inner);
    // Warm-started sessions carry their fleet baseline across restarts
    // (optional field, same persist format) so restored sessions keep
    // exporting only locally measured deltas — without it a restart
    // would launder borrowed fleet evidence into "own" measurements.
    if let Some(baseline) = &session.fleet_baseline {
        let b = persist::to_json(baseline, session.key.app.name(), session.alpha, session.beta);
        if let Ok(b) = Json::parse(&b) {
            obj.insert("fleet_baseline".to_string(), b);
        }
    }
    Some(Json::Obj(obj).to_string())
}

/// Rebuild a session from an envelope, discounting the prior by `retain`.
pub fn session_from_json(text: &str, apps: &AppsCache, retain: f64) -> Result<Session> {
    let root = Json::parse(text).map_err(|e| anyhow!("session envelope parse: {e}"))?;
    if root.get("version").and_then(Json::as_f64) != Some(VERSION) {
        return Err(anyhow!("unsupported session envelope version"));
    }
    let field = |name: &str| -> Result<&str> {
        root.get(name)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("envelope missing '{name}'"))
    };
    let client_id = field("client_id")?.to_string();
    if client_id.is_empty() {
        return Err(anyhow!("empty client_id"));
    }
    let app: AppKind = field("app")?.parse()?;
    let device: PowerMode = field("device")?.parse()?;
    let policy: PolicyKind = field("policy")?.parse()?;
    let alpha = root.get("alpha").and_then(Json::as_f64).unwrap_or(0.8);
    let beta = root.get("beta").and_then(Json::as_f64).unwrap_or(0.2);
    let state_text = root
        .get("state")
        .ok_or_else(|| anyhow!("envelope missing 'state'"))?
        .to_string();
    let cp = persist::from_json(&state_text)?;
    let key = SessionKey { client_id, app, device, policy };
    let k = apps.arms(app);
    let tuner = Tuner::build(policy, k, alpha, beta, key.hash64(), Some(&cp.state), retain)
        .map_err(|e| anyhow!("rebuilding tuner: {e}"))?;
    // Restore the fleet baseline (optional — absent in cold-started and
    // pre-fleet checkpoints), discounted by the same `retain` as the
    // main state so the exported delta stays proportional. A corrupt
    // baseline degrades to `None` (the session still restores; it may
    // over-export once) rather than failing the whole session.
    let fleet_baseline = root
        .get("fleet_baseline")
        .and_then(|b| persist::from_json(&b.to_string()).ok())
        .map(|b| persist::discounted(&b.state, retain));
    Ok(Session {
        key,
        alpha,
        beta,
        tuner,
        fleet_baseline,
        suggests: root.get("suggests").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        reports: root.get("reports").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        // Deliberately not persisted: a restart re-keys client retry
        // state, so the idempotency window restarts empty (see
        // DESIGN.md §Failure model).
        seq_window: SeqWindow::default(),
        scratch_growths_seen: 0,
    })
}

/// Checkpoint file name for a session (stable across restarts).
fn file_name(key: &SessionKey) -> String {
    format!("sess-{:016x}.json", key.hash64())
}

/// Attempts per session file before giving up on this snapshot cycle.
const WRITE_ATTEMPTS: u32 = 3;

/// Serialize every checkpointable session of one shard into
/// `(file name, payload)` pairs. This is the piece of a snapshot that
/// must run *inside* the shard's owner — under a read lock on the shared
/// data plane, or on the owning event loop under the routed one (see
/// `serve/plane.rs`); the file I/O half ([`write_payloads`]) runs
/// wherever the snapshot was requested.
pub fn shard_payloads(shard: &Shard) -> Vec<(String, String)> {
    shard
        .sessions
        .values()
        .filter_map(|s| session_to_json(s).map(|text| (file_name(&s.key), text)))
        .collect()
}

/// Write pre-serialized session payloads into `dir` with the retry /
/// fault-injection discipline of [`snapshot_with`]. Returns how many
/// files were written.
pub fn write_payloads(
    payloads: &[(String, String)],
    dir: &Path,
    chaos: Option<&crate::chaos::ChaosLayer>,
    failures: Option<&std::sync::atomic::AtomicU64>,
) -> usize {
    use std::sync::atomic::Ordering;
    let mut written = 0usize;
    for (name, text) in payloads {
        let path = dir.join(name);
        for attempt in 0..WRITE_ATTEMPTS {
            let result = if chaos.is_some_and(|c| c.checkpoint_fail(attempt as u64)) {
                Err(anyhow!("chaos: injected checkpoint write failure"))
            } else {
                persist::write_atomic(&path, text)
            };
            match result {
                Ok(()) => {
                    written += 1;
                    break;
                }
                Err(_) => {
                    if let Some(f) = failures {
                        f.fetch_add(1, Ordering::Relaxed);
                    }
                    if attempt + 1 < WRITE_ATTEMPTS {
                        std::thread::sleep(std::time::Duration::from_millis(2 << attempt));
                    }
                }
            }
        }
    }
    written
}

/// Snapshot every checkpointable session into `dir`. Serialization happens
/// under each shard lock; file I/O happens outside it so a slow disk never
/// blocks the suggest path. Returns the number of sessions written.
pub fn snapshot(store: &ShardedStore, dir: &Path) -> Result<usize> {
    snapshot_with(store, dir, None, None)
}

/// As [`snapshot`], with write-failure tolerance and optional fault
/// injection. Each session file gets up to [`WRITE_ATTEMPTS`] tries with
/// a short exponential backoff between them; a file that still cannot be
/// written is skipped for this cycle — [`persist::write_atomic`] renames
/// over the target only on success, so the previous last-good checkpoint
/// stays intact. Every failed *attempt* increments `failures`
/// (`lasp_serve_checkpoint_failures_total`), and the chaos layer's
/// `checkpoint_write` point injects failures before the real I/O.
pub fn snapshot_with(
    store: &ShardedStore,
    dir: &Path,
    chaos: Option<&crate::chaos::ChaosLayer>,
    failures: Option<&std::sync::atomic::AtomicU64>,
) -> Result<usize> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let mut written = 0usize;
    for i in 0..store.num_shards() {
        let payloads: Vec<(String, String)> = {
            // Serialization only reads; a shared lock keeps the suggest
            // write path unblocked on other readers' shards.
            let shard = store.read_shard(i);
            shard_payloads(&shard)
        };
        written += write_payloads(&payloads, dir, chaos, failures);
    }
    Ok(written)
}

/// Restore sessions from `dir` into an (empty) store. Corrupt or stale
/// files are skipped, not fatal — a tuning service must boot even if one
/// checkpoint rotted. Returns the number of sessions restored.
pub fn restore(store: &ShardedStore, apps: &AppsCache, dir: &Path, retain: f64) -> Result<usize> {
    if !dir.is_dir() {
        return Ok(0);
    }
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?;
    let mut restored = 0usize;
    for entry in entries.flatten() {
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let Ok(text) = std::fs::read_to_string(&path) else {
            continue;
        };
        if let Ok(session) = session_from_json(&text, apps, retain) {
            store.insert_session(session);
            restored += 1;
        }
    }
    Ok(restored)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("lasp-serve-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn trained_session(client: &str, pulls: usize) -> Session {
        let key = SessionKey {
            client_id: client.to_string(),
            app: AppKind::Clomp,
            device: PowerMode::Maxn,
            policy: PolicyKind::Ucb,
        };
        let mut tuner = Tuner::build(PolicyKind::Ucb, 125, 1.0, 0.0, key.hash64(), None, 1.0).unwrap();
        for i in 0..pulls {
            let arm = tuner.select();
            // Arm 7 is clearly best.
            let t = if arm == 7 { 0.4 } else { 2.0 + (i % 3) as f64 * 0.1 };
            tuner.observe(arm, t, 5.0).unwrap();
        }
        Session {
            key,
            alpha: 1.0,
            beta: 0.0,
            tuner,
            fleet_baseline: None,
            suggests: pulls as u64,
            reports: pulls as u64,
            seq_window: SeqWindow::default(),
            scratch_growths_seen: 0,
        }
    }

    #[test]
    fn envelope_roundtrip_preserves_identity_and_means() {
        let apps = AppsCache::new();
        let s = trained_session("round", 400);
        let best = s.tuner.most_selected();
        let (mean_before, _) = s.tuner.mean_of(best).unwrap();
        let text = session_to_json(&s).unwrap();
        let restored = session_from_json(&text, &apps, 0.5).unwrap();
        assert_eq!(restored.key, s.key);
        assert_eq!(restored.suggests, 400);
        // Discounting shrinks counts but preserves per-arm means, so the
        // most-selected arm and its mean survive the restart.
        assert_eq!(restored.tuner.most_selected(), best);
        let (mean_after, _) = restored.tuner.mean_of(best).unwrap();
        assert!((mean_before - mean_after).abs() < 1e-9);
        assert!(restored.tuner.total_pulls() > 0.0);
        assert!(restored.tuner.total_pulls() < s.tuner.total_pulls());
    }

    #[test]
    fn fleet_baseline_survives_restart() {
        // A warm-started session's borrowed-prior baseline must round-trip
        // through the envelope, or a restart would launder fleet evidence
        // into "own" measurements (echo amplification across restarts).
        let apps = AppsCache::new();
        let mut s = trained_session("warmed", 50);
        let mut baseline = crate::bandit::ArmStats::new(125);
        for _ in 0..10 {
            baseline.observe(7, 2.0, 5.0);
        }
        s.fleet_baseline = Some(baseline);
        let text = session_to_json(&s).unwrap();
        let restored = session_from_json(&text, &apps, 0.5).unwrap();
        let b = restored.fleet_baseline.expect("baseline lost across restart");
        assert_eq!(b.k(), 125);
        // Discounting shrinks baseline counts but preserves the mean.
        assert!(b.counts()[7] > 0.0 && b.counts()[7] <= 10.0);
        assert!((b.mean_tau()[7] - 2.0).abs() < 1e-9);
        // Cold sessions keep an absent baseline (and old envelopes
        // without the field still parse).
        let cold = trained_session("cold", 10);
        let restored =
            session_from_json(&session_to_json(&cold).unwrap(), &apps, 0.5).unwrap();
        assert!(restored.fleet_baseline.is_none());
    }

    #[test]
    fn epsilon_sessions_checkpoint_and_restore() {
        // The satellite fix: ε-greedy silently could not be checkpointed
        // (no reward_state under the old Policy trait). With the unified
        // core it round-trips exactly like the UCB family.
        let apps = AppsCache::new();
        let key = SessionKey {
            client_id: "eps".to_string(),
            app: AppKind::Clomp,
            device: PowerMode::Maxn,
            policy: PolicyKind::Epsilon,
        };
        let mut tuner =
            Tuner::build(PolicyKind::Epsilon, 125, 1.0, 0.0, key.hash64(), None, 1.0).unwrap();
        for _ in 0..200 {
            let arm = tuner.select();
            let t = if arm == 9 { 0.4 } else { 2.0 };
            tuner.observe(arm, t, 5.0).unwrap();
        }
        let session = Session {
            key,
            alpha: 1.0,
            beta: 0.0,
            tuner,
            fleet_baseline: None,
            suggests: 200,
            reports: 200,
            seq_window: SeqWindow::default(),
            scratch_growths_seen: 0,
        };
        let best = session.tuner.most_selected();
        let (mean_before, _) = session.tuner.mean_of(best).unwrap();
        let restored =
            session_from_json(&session_to_json(&session).unwrap(), &apps, 0.5).unwrap();
        assert_eq!(restored.key.policy, PolicyKind::Epsilon);
        assert_eq!(restored.tuner.name(), "epsilon-greedy");
        assert_eq!(restored.tuner.most_selected(), best);
        let (mean_after, _) = restored.tuner.mean_of(best).unwrap();
        assert!((mean_before - mean_after).abs() < 1e-9);
        assert!(restored.tuner.total_pulls() > 0.0);
        assert!(restored.tuner.total_pulls() < session.tuner.total_pulls());
    }

    #[test]
    fn snapshot_restore_through_store() {
        let d = dir("store");
        let store = ShardedStore::new(4);
        let apps = AppsCache::new();
        for i in 0..6 {
            store.insert_session(trained_session(&format!("c{i}"), 120));
        }
        let written = snapshot(&store, &d).unwrap();
        assert_eq!(written, 6);

        let fresh = ShardedStore::new(4);
        let restored = restore(&fresh, &apps, &d, 0.5).unwrap();
        assert_eq!(restored, 6);
        assert_eq!(fresh.session_count(), 6);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn injected_write_failures_keep_the_last_good_checkpoint() {
        use crate::chaos::{ChaosConfig, ChaosLayer};
        use crate::obs::Recorder;
        use std::sync::atomic::{AtomicU64, Ordering};
        let d = dir("chaos");
        let store = ShardedStore::new(2);
        store.insert_session(trained_session("chaos-a", 60));
        assert_eq!(snapshot(&store, &d).unwrap(), 1);
        let file = std::fs::read_dir(&d)
            .unwrap()
            .flatten()
            .map(|e| e.path())
            .find(|p| p.extension().and_then(|e| e.to_str()) == Some("json"))
            .unwrap();
        let good = std::fs::read_to_string(&file).unwrap();

        // Every write attempt fails: the cycle writes nothing, counts
        // each failed attempt, and never touches the last-good file.
        let cfg = ChaosConfig { seed: 5, checkpoint_fail: 1.0, ..Default::default() };
        let chaos = ChaosLayer::new(cfg, std::sync::Arc::new(Recorder::new(1, 64)));
        let failures = AtomicU64::new(0);
        let written = snapshot_with(&store, &d, Some(&chaos), Some(&failures)).unwrap();
        assert_eq!(written, 0);
        assert_eq!(failures.load(Ordering::Relaxed), 3, "one count per failed attempt");
        assert_eq!(std::fs::read_to_string(&file).unwrap(), good);

        // Chaos gone ⇒ the next cycle recovers without intervention.
        assert_eq!(snapshot(&store, &d).unwrap(), 1);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_checkpoints_are_skipped() {
        let d = dir("corrupt");
        std::fs::write(d.join("sess-bad.json"), "not json at all").unwrap();
        std::fs::write(d.join("ignored.txt"), "not a checkpoint").unwrap();
        let store = ShardedStore::new(2);
        let apps = AppsCache::new();
        assert_eq!(restore(&store, &apps, &d, 0.5).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_dir_restores_nothing() {
        let store = ShardedStore::new(2);
        let apps = AppsCache::new();
        let n = restore(&store, &apps, Path::new("/nonexistent/lasp-ckpt"), 0.5).unwrap();
        assert_eq!(n, 0);
    }
}
