//! Cross-node fleet synchronization: the transfer plane of the tuning
//! service.
//!
//! The paper's deployment story (Fig 1) is a leader coordinating a fleet
//! of edge devices, and the transfer-learning line of autotuning work
//! (multitask exascale autotuning, ensemble-model online tuners) shows
//! that per-configuration statistics learned on one node are exactly the
//! prior another node needs. [`crate::coordinator`] simulates that with
//! in-process threads; this module makes it real over the serve HTTP
//! stack:
//!
//! * **Snapshots** ([`FleetSnapshot`]) are compact, *sparse* per-
//!   `(app, device, policy)` arm statistics — only pulled arms travel,
//!   capped at [`FLEET_MAX_ARMS`] entries — serialized with the borrowed
//!   [`JsonWriter`]/[`JsonSlice`] codecs shared with the request path.
//! * **`POST /v1/sync/push`** lets any node deposit its local aggregate
//!   under its `node_id`. Pushes *replace* the node's previous slot, so
//!   retries and duplicated deliveries are idempotent by construction.
//! * **`POST /v1/sync/pull`** returns the discount-merged knowledge of
//!   every *other* node (plus the serving node's own live aggregate).
//! * **[`FleetSync`]** is the background thread a follower runs: every
//!   `sync_every` it pushes its local deltas to the configured leader and
//!   installs the pulled merge as the node's fleet prior
//!   ([`ShardedStore::install_fleet_prior`]), which
//!   [`ShardedStore::get_or_create`] uses to warm-start new sessions.
//!
//! **Discounted merging.** Remote evidence is weighted by
//! `0.5^(age / half_life)` at merge time (ages travel on the wire as
//! relative `age_s`, so nodes never need synchronized clocks), and the
//! installed prior keeps decaying by the same rule until refreshed. Stale
//! fleet knowledge therefore fades instead of swamping fresh local
//! observations — the same non-stationarity posture as SW-UCB.
//!
//! **Failure posture.** Sync is strictly best-effort: the suggest/report
//! hot path never touches the network, and a dead or unreachable leader
//! never blocks serving. Failures move the loop into an explicit
//! **backoff** state ([`super::metrics::FLEET_STATE_BACKOFF`], visible in
//! `/metrics` and `/v1/trace`): retry delays grow exponentially from
//! `sync_every` with deterministic jitter ([`Backoff`]), capped at
//! [`MAX_BACKOFF_SECS`], so a crashed leader sees a trickle of reconnect
//! attempts instead of a thundering herd when it returns. The first
//! successful cycle resets the delay and flips the state to **syncing**.
//! Lock order is documented on [`ShardedStore`]; the sync plane never
//! takes a shard lock while holding the prior map.

use super::loadgen::HttpClient;
use super::metrics::{Metrics, FLEET_STATE_BACKOFF, FLEET_STATE_SYNCING};
use super::store::{AppsCache, FleetKey, PolicyKind, Shard, ShardedStore, Tuner};
use crate::apps::AppKind;
use crate::bandit::{ArmStats, Policy as _};
use crate::obs::{EventKind, Recorder};
use crate::device::PowerMode;
use crate::util::json::{JsonSlice, JsonWriter};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hard cap on sparse arm entries per snapshot: keeps a Hypre-scale push
/// bounded (~150 KiB of JSON) and inside the transport's 1 MiB body
/// limit. When a node knows more arms than this, the most-pulled arms
/// travel and the long tail of single-pull probes is dropped.
pub const FLEET_MAX_ARMS: usize = 2048;

/// Hard cap on remembered nodes: a leader bombarded with churning node
/// ids evicts the stalest slot instead of growing without bound.
pub const FLEET_MAX_NODES: usize = 256;

/// Merge weights below this are treated as fully aged-out evidence.
const MIN_WEIGHT: f64 = 1e-3;

/// Ceiling on the backed-off retry delay, seconds. A leader that has been
/// gone for an hour still sees a reconnect attempt every five minutes.
pub const MAX_BACKOFF_SECS: u64 = 300;

/// Bounded exponential backoff with deterministic jitter for the sync
/// loop. After `k` consecutive failures the delay is
/// `base · 2^min(k, 4) · jitter` with `jitter ∈ [1.0, 1.5)` drawn from a
/// seeded [`crate::util::Rng`] (same seed ⇒ same retry schedule — chaos
/// runs stay replayable), capped at [`MAX_BACKOFF_SECS`]. Jitter spreads
/// a fleet's reconnect attempts so a recovering leader is not hit by
/// every follower in the same 25 ms poll tick.
pub struct Backoff {
    rng: crate::util::Rng,
    consecutive: u32,
}

impl Backoff {
    pub fn new(seed: u64) -> Backoff {
        Backoff { rng: crate::util::Rng::new(seed), consecutive: 0 }
    }

    /// Consecutive failures since the last success.
    pub fn failures(&self) -> u32 {
        self.consecutive
    }

    /// A cycle succeeded: the next failure starts the ladder over.
    pub fn reset(&mut self) {
        self.consecutive = 0;
    }

    /// Record one failure and return the delay before the next attempt.
    pub fn next_delay(&mut self, base: Duration) -> Duration {
        let k = self.consecutive.min(4);
        self.consecutive = self.consecutive.saturating_add(1);
        let jitter = 1.0 + 0.5 * self.rng.uniform();
        let d = base.mul_f64((1u64 << k) as f64 * jitter);
        d.min(Duration::from_secs(MAX_BACKOFF_SECS))
    }
}

/// Sparse arm statistics for one `(app, device, policy)` scenario.
/// `arms` is strictly ascending; `counts[i]`/`tau_sum[i]`/`rho_sum[i]`
/// are the sufficient statistics of `arms[i]`.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSnapshot {
    pub key: FleetKey,
    /// Age of these statistics when serialized (seconds, relative — no
    /// cross-node clock agreement needed).
    pub age_s: f64,
    pub arms: Vec<u32>,
    pub counts: Vec<f64>,
    pub tau_sum: Vec<f64>,
    pub rho_sum: Vec<f64>,
}

impl FleetSnapshot {
    /// Sparse view of a full-space arm-statistics core. `None` when
    /// nothing has been pulled (empty snapshots never travel).
    pub fn from_state(key: FleetKey, state: &ArmStats, age_s: f64) -> Option<FleetSnapshot> {
        let counts = state.counts();
        let mut idx: Vec<usize> = (0..state.k()).filter(|&i| counts[i] > 0.0).collect();
        if idx.is_empty() {
            return None;
        }
        if idx.len() > FLEET_MAX_ARMS {
            idx.sort_by(|&a, &b| {
                counts[b]
                    .partial_cmp(&counts[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(FLEET_MAX_ARMS);
            idx.sort_unstable();
        }
        Some(FleetSnapshot {
            key,
            age_s: age_s.max(0.0),
            arms: idx.iter().map(|&i| i as u32).collect(),
            counts: idx.iter().map(|&i| counts[i]).collect(),
            tau_sum: idx.iter().map(|&i| state.tau_sum()[i]).collect(),
            rho_sum: idx.iter().map(|&i| state.rho_sum()[i]).collect(),
        })
    }

    /// Densify into a `k`-arm statistics core (entries beyond `k` are
    /// dropped — a snapshot from a node running a different space size
    /// must not panic the receiver).
    pub fn to_state(&self, k: usize) -> ArmStats {
        let mut s = ArmStats::new(k);
        for (i, &arm) in self.arms.iter().enumerate() {
            let a = arm as usize;
            if a < k && self.counts[i] > 0.0 {
                s.add_arm(a, self.counts[i], self.tau_sum[i], self.rho_sum[i]);
            }
        }
        s
    }

    /// Serialize as one JSON object (wire format documented in
    /// `docs/API.md` and DESIGN.md §Fleet sync).
    pub fn write_json(&self, w: &mut JsonWriter<'_>) {
        w.begin_obj();
        w.field_str("app", self.key.app.name());
        w.field_str("device", self.key.device.lower_name());
        w.field_str("policy", self.key.policy.name());
        w.field_num("age_s", self.age_s);
        w.key("arms");
        w.begin_arr();
        for &a in &self.arms {
            w.num_val(a as f64);
        }
        w.end_arr();
        for (name, vals) in [
            ("counts", &self.counts),
            ("tau_sum", &self.tau_sum),
            ("rho_sum", &self.rho_sum),
        ] {
            w.key(name);
            w.begin_arr();
            for &v in vals.iter() {
                w.num_val(v);
            }
            w.end_arr();
        }
        w.end_obj();
    }

    /// Parse and validate one snapshot object. Strict: unknown apps,
    /// ragged vectors, non-finite statistics, negative counts and
    /// unsorted/duplicate arms are errors, never silently repaired.
    pub fn from_slice(v: &JsonSlice<'_>) -> Result<FleetSnapshot, String> {
        let field = |name: &str| {
            v.get(name)
                .and_then(|x| x.as_str())
                .ok_or_else(|| format!("snapshot missing '{name}'"))
        };
        let app: AppKind = field("app")?
            .parse()
            .map_err(|e: anyhow::Error| format!("{e:#}"))?;
        let device: PowerMode = field("device")?
            .parse()
            .map_err(|e: anyhow::Error| format!("{e:#}"))?;
        let policy: PolicyKind = field("policy")?
            .parse()
            .map_err(|e: anyhow::Error| format!("{e:#}"))?;
        let age_s = match v.get("age_s") {
            None => 0.0,
            Some(x) => x.as_f64().ok_or("bad age_s")?,
        };
        if !age_s.is_finite() || age_s < 0.0 {
            return Err("bad age_s".into());
        }
        let read_vec = |name: &str| -> Result<Vec<f64>, String> {
            let arr = v.get(name).ok_or_else(|| format!("snapshot missing '{name}'"))?;
            if !arr.is_arr() {
                return Err(format!("'{name}' must be an array"));
            }
            arr.items()
                .map(|e| e.as_f64().ok_or_else(|| format!("non-numeric entry in '{name}'")))
                .collect()
        };
        let arms_f = read_vec("arms")?;
        if arms_f.len() > FLEET_MAX_ARMS {
            return Err(format!(
                "snapshot has {} arm entries (max {FLEET_MAX_ARMS})",
                arms_f.len()
            ));
        }
        let counts = read_vec("counts")?;
        let tau_sum = read_vec("tau_sum")?;
        let rho_sum = read_vec("rho_sum")?;
        if arms_f.len() != counts.len()
            || tau_sum.len() != counts.len()
            || rho_sum.len() != counts.len()
        {
            return Err("snapshot vector lengths disagree".into());
        }
        let mut arms = Vec::with_capacity(arms_f.len());
        for &a in &arms_f {
            if !(a.is_finite() && a >= 0.0 && a.fract() == 0.0 && a <= u32::MAX as f64) {
                return Err(format!("bad arm index {a}"));
            }
            let arm = a as u32;
            if let Some(&prev) = arms.last() {
                if arm <= prev {
                    return Err("arms must be strictly ascending".into());
                }
            }
            arms.push(arm);
        }
        if counts.iter().any(|&c| !c.is_finite() || c < 0.0) {
            return Err("snapshot counts invalid".into());
        }
        if tau_sum.iter().chain(rho_sum.iter()).any(|x| !x.is_finite()) {
            return Err("snapshot sums invalid".into());
        }
        Ok(FleetSnapshot {
            key: FleetKey { app, device, policy },
            age_s,
            arms,
            counts,
            tau_sum,
            rho_sum,
        })
    }
}

/// Serialize a `/v1/sync/push` request body into `out` (cleared first).
pub fn write_push_body(node_id: &str, snapshots: &[FleetSnapshot], out: &mut Vec<u8>) {
    out.clear();
    let mut w = JsonWriter::new(out);
    w.begin_obj();
    w.field_str("node_id", node_id);
    w.key("snapshots");
    w.begin_arr();
    for s in snapshots {
        s.write_json(&mut w);
    }
    w.end_arr();
    w.end_obj();
}

/// Add one arm's statistics to a scenario accumulator, net of the
/// session's warm-start baseline: only evidence measured *on this node*
/// is exported. Without the subtraction every warm-started session
/// would re-export its borrowed prior as local measurements, and the
/// fleet would amplify its own echo by the session count.
fn add_arm_delta(
    entry: &mut HashMap<u32, [f64; 3]>,
    arm: u32,
    idx: usize,
    st: &ArmStats,
    baseline: Option<&ArmStats>,
) {
    let (bc, bt, br) = match baseline {
        Some(b) if b.k() == st.k() => (b.counts()[idx], b.tau_sum()[idx], b.rho_sum()[idx]),
        _ => (0.0, 0.0, 0.0),
    };
    let c = st.counts()[idx] - bc;
    if c <= 1e-9 {
        return;
    }
    let mut tau = st.tau_sum()[idx] - bt;
    let mut rho = st.rho_sum()[idx] - br;
    if tau < 0.0 || rho < 0.0 {
        // Windowed policies (swucb) evict baseline entries over time, so
        // the lifetime-sum subtraction can go negative while the count
        // delta stays positive. Export the count delta at the arm's
        // *current* observed means (cached by the core) instead of
        // fabricating impossible (e.g. zero-time) statistics.
        tau = c * st.mean_tau()[idx];
        rho = c * st.mean_rho()[idx];
    }
    let e = entry.entry(arm).or_insert([0.0; 3]);
    e[0] += c;
    e[1] += tau;
    e[2] += rho;
}

/// Aggregate every live session into per-scenario sparse snapshots —
/// the node's contribution to the fleet. Each session exports its
/// statistics *net of its warm-start baseline* (see `add_arm_delta`),
/// so fleet-borrowed evidence never circulates a second time. Subset
/// sessions project their subset-space statistics back into full-space
/// arm indices through their candidate lists; different nodes' subsets
/// overlap partially, which is exactly what makes pooling them
/// informative.
pub fn aggregate_local(store: &ShardedStore) -> Vec<FleetSnapshot> {
    let mut acc: HashMap<FleetKey, HashMap<u32, [f64; 3]>> = HashMap::new();
    for i in 0..store.num_shards() {
        let shard = store.read_shard(i);
        aggregate_shard_into(&shard, &mut acc);
    }
    acc_into_snapshots(acc)
}

/// Accumulator map for partial (per-shard) fleet aggregation; the routed
/// data plane has each event loop fold its owned shards into one of
/// these and merges the partials afterwards (see `serve/plane.rs`).
pub(crate) type FleetAcc = HashMap<FleetKey, HashMap<u32, [f64; 3]>>;

/// Fold one shard's sessions into a scenario accumulator — the inner
/// loop of [`aggregate_local`], callable against an owned shard
/// reference so the routed plane can aggregate without shard locks.
pub(crate) fn aggregate_shard_into(shard: &Shard, acc: &mut FleetAcc) {
    for session in shard.sessions.values() {
        let fkey = FleetKey {
            app: session.key.app,
            device: session.key.device,
            policy: session.key.policy,
        };
        let baseline = session.fleet_baseline.as_ref();
        let entry = acc.entry(fkey).or_default();
        // Every policy exposes the shared ArmStats core, so delta
        // extraction reads it directly — ε-greedy sessions included.
        match &session.tuner {
            Tuner::Subset(t) => {
                let st = t.stats();
                for (pos, &full) in t.candidates().iter().enumerate() {
                    add_arm_delta(entry, full as u32, pos, st, baseline);
                }
            }
            other => {
                let st = other.stats();
                for arm in 0..st.k() {
                    add_arm_delta(entry, arm as u32, arm, st, baseline);
                }
            }
        }
    }
}

/// Merge one partial accumulator into another (routed aggregation).
pub(crate) fn merge_acc(into: &mut FleetAcc, from: FleetAcc) {
    for (key, by_arm) in from {
        let entry = into.entry(key).or_default();
        for (arm, v) in by_arm {
            let e = entry.entry(arm).or_insert([0.0; 3]);
            e[0] += v[0];
            e[1] += v[1];
            e[2] += v[2];
        }
    }
}

/// Turn accumulated `(key → arm → [count, τΣ, ρΣ])` maps into sorted,
/// capped snapshots (deterministic output for tests and idempotent
/// re-serialization).
pub(crate) fn acc_into_snapshots(acc: FleetAcc) -> Vec<FleetSnapshot> {
    let mut out = Vec::with_capacity(acc.len());
    for (key, by_arm) in acc {
        let mut arms: Vec<u32> = by_arm
            .iter()
            .filter(|(_, v)| v[0] > 0.0)
            .map(|(&a, _)| a)
            .collect();
        if arms.is_empty() {
            continue;
        }
        if arms.len() > FLEET_MAX_ARMS {
            arms.sort_by(|&a, &b| {
                by_arm[&b][0]
                    .partial_cmp(&by_arm[&a][0])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            arms.truncate(FLEET_MAX_ARMS);
        }
        arms.sort_unstable();
        let mut snap = FleetSnapshot {
            key,
            age_s: 0.0,
            arms: Vec::with_capacity(arms.len()),
            counts: Vec::with_capacity(arms.len()),
            tau_sum: Vec::with_capacity(arms.len()),
            rho_sum: Vec::with_capacity(arms.len()),
        };
        for a in arms {
            let v = by_arm[&a];
            snap.arms.push(a);
            snap.counts.push(v[0]);
            snap.tau_sum.push(v[1]);
            snap.rho_sum.push(v[2]);
        }
        out.push(snap);
    }
    out.sort_by_key(|s| (s.key.app.name(), s.key.device.name(), s.key.policy.name()));
    out
}

/// One remembered node: its latest pushed snapshots and when they
/// arrived (receive-side clock, used together with the carried `age_s`
/// to age the evidence).
struct NodeSlot {
    snapshots: Vec<FleetSnapshot>,
    received: Instant,
}

/// The leader-side registry of per-node snapshots. Every serve node owns
/// one (any node can act as a leader — "leader" is purely which address
/// the followers point at).
pub struct FleetStore {
    nodes: Mutex<HashMap<String, NodeSlot>>,
    half_life: Duration,
}

impl FleetStore {
    pub fn new(half_life: Duration) -> FleetStore {
        FleetStore {
            nodes: Mutex::new(HashMap::new()),
            half_life,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, NodeSlot>> {
        match self.nodes.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Store (replace) a node's snapshots. Replacement — not
    /// accumulation — is what makes repeated pushes idempotent: a node
    /// retrying the same cumulative snapshot cannot double-count itself.
    /// Returns the number of snapshots stored.
    pub fn absorb(&self, node_id: &str, snapshots: Vec<FleetSnapshot>) -> usize {
        let n = snapshots.len();
        let mut nodes = self.lock();
        if !nodes.contains_key(node_id) && nodes.len() >= FLEET_MAX_NODES {
            let stalest = nodes
                .iter()
                .max_by_key(|(_, slot)| slot.received.elapsed())
                .map(|(id, _)| id.clone());
            if let Some(id) = stalest {
                nodes.remove(&id);
            }
        }
        nodes.insert(
            node_id.to_string(),
            NodeSlot { snapshots, received: Instant::now() },
        );
        n
    }

    /// Nodes currently remembered.
    pub fn node_count(&self) -> usize {
        self.lock().len()
    }

    /// Discount-merge every remembered node's snapshots (each weighted by
    /// `0.5^(age / half_life)`, where age = carried `age_s` + time since
    /// receipt), optionally excluding one node (a puller must not be fed
    /// its own echo) and optionally folding in the serving node's live
    /// local aggregate at full weight.
    pub fn merged(
        &self,
        exclude: Option<&str>,
        local: Option<(&str, &[FleetSnapshot])>,
    ) -> Vec<FleetSnapshot> {
        let half = self.half_life.as_secs_f64().max(1e-9);
        let mut acc: HashMap<FleetKey, HashMap<u32, [f64; 3]>> = HashMap::new();
        let mut add = |snap: &FleetSnapshot, w: f64| {
            let entry = acc.entry(snap.key).or_default();
            for (i, &arm) in snap.arms.iter().enumerate() {
                let e = entry.entry(arm).or_insert([0.0; 3]);
                e[0] += snap.counts[i] * w;
                e[1] += snap.tau_sum[i] * w;
                e[2] += snap.rho_sum[i] * w;
            }
        };
        {
            let nodes = self.lock();
            for (id, slot) in nodes.iter() {
                if exclude == Some(id.as_str()) {
                    continue;
                }
                let since = slot.received.elapsed().as_secs_f64();
                for snap in &slot.snapshots {
                    let w = 0.5_f64.powf((snap.age_s + since) / half);
                    if w >= MIN_WEIGHT {
                        add(snap, w);
                    }
                }
            }
        }
        if let Some((id, snaps)) = local {
            if exclude != Some(id) {
                for snap in snaps {
                    add(snap, 1.0);
                }
            }
        }
        drop(add);
        acc_into_snapshots(acc)
    }
}

/// Install a set of pulled/merged snapshots as the node's fleet priors.
/// Returns how many scenarios were installed.
pub fn install_priors(
    snapshots: &[FleetSnapshot],
    store: &ShardedStore,
    apps: &AppsCache,
) -> usize {
    let mut installed = 0;
    for snap in snapshots {
        let k = apps.arms(snap.key.app);
        let state = snap.to_state(k);
        if state.total_pulls() > 0.0 {
            store.install_fleet_prior(snap.key, state);
            installed += 1;
        }
    }
    installed
}

/// Parse a `/v1/sync/pull` response body and install the merged priors.
pub fn apply_pull_body(
    body: &[u8],
    store: &ShardedStore,
    apps: &AppsCache,
) -> Result<usize, String> {
    let v = JsonSlice::parse(body)?;
    let snaps_v = v
        .get("snapshots")
        .ok_or_else(|| "pull response missing 'snapshots'".to_string())?;
    if !snaps_v.is_arr() {
        return Err("'snapshots' must be an array".into());
    }
    let mut snapshots = Vec::new();
    for item in snaps_v.items() {
        snapshots.push(FleetSnapshot::from_slice(&item)?);
    }
    Ok(install_priors(&snapshots, store, apps))
}

/// How the sync thread obtains this node's local aggregate. Injected by
/// the service so the data-plane choice stays out of this module: the
/// shared plane scans shard read locks ([`aggregate_local`]), the routed
/// plane scatter-gathers partials from each shard's owning event loop.
pub type LocalAggregateFn = Arc<dyn Fn() -> Vec<FleetSnapshot> + Send + Sync>;

/// What the background sync thread needs to know.
#[derive(Debug, Clone)]
pub struct FleetSyncConfig {
    /// Leader address (`host:port`).
    pub leader: String,
    /// This node's stable identity on the wire.
    pub node_id: String,
    /// Period between push/pull cycles.
    pub every: Duration,
}

/// The follower-side background thread: push local aggregate, pull the
/// fleet merge, install it as warm-start priors. Strictly best-effort —
/// every failure increments a counter and the next cycle retries from a
/// fresh connection; the serving path is never involved.
pub struct FleetSync {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl FleetSync {
    pub fn start(
        cfg: FleetSyncConfig,
        store: Arc<ShardedStore>,
        apps: Arc<AppsCache>,
        metrics: Arc<Metrics>,
        recorder: Arc<Recorder>,
        chaos: Option<Arc<crate::chaos::ChaosLayer>>,
        local_agg: LocalAggregateFn,
    ) -> FleetSync {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            run_loop(
                &cfg,
                &store,
                &apps,
                &metrics,
                &recorder,
                &stop2,
                chaos.as_deref(),
                &local_agg,
            )
        });
        FleetSync {
            stop,
            handle: Some(handle),
        }
    }

    /// Signal the loop and join it. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FleetSync {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Stable jitter seed from the node identity: the same node re-derives
/// the same retry schedule across restarts (FNV-1a over the id bytes).
fn backoff_seed(node_id: &str) -> u64 {
    node_id
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3))
}

#[allow(clippy::too_many_arguments)]
fn run_loop(
    cfg: &FleetSyncConfig,
    store: &ShardedStore,
    apps: &AppsCache,
    metrics: &Metrics,
    recorder: &Recorder,
    stop: &AtomicBool,
    chaos: Option<&crate::chaos::ChaosLayer>,
    local_agg: &LocalAggregateFn,
) {
    let mut client: Option<HttpClient> = None;
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let mut last = Instant::now();
    let mut backoff = Backoff::new(backoff_seed(&cfg.node_id));
    // Until the first success the node serves standalone; `wait` is the
    // current cycle period — `every` while healthy, the backoff ladder
    // after failures.
    let mut wait = cfg.every;
    loop {
        std::thread::sleep(Duration::from_millis(25));
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if last.elapsed() < wait {
            continue;
        }
        last = Instant::now();
        // The chaos `fleet_sync` point severs the cycle before any byte
        // reaches the leader — indistinguishable from a link failure, so
        // it exercises the same backoff transitions.
        let result = if chaos.is_some_and(|c| c.fleet_fail()) {
            client = None;
            Err("chaos: injected fleet sync failure".to_string())
        } else {
            sync_once(cfg, &mut client, &mut buf, store, apps, local_agg)
        };
        match result {
            Ok((pushed, installed)) => {
                metrics.fleet_pushes.fetch_add(1, Ordering::Relaxed);
                metrics.fleet_pulls.fetch_add(1, Ordering::Relaxed);
                metrics.fleet_state.store(FLEET_STATE_SYNCING, Ordering::Relaxed);
                backoff.reset();
                wait = cfg.every;
                recorder.record(EventKind::FleetPush, pushed as u64, 0, 0);
                recorder.record(EventKind::FleetPull, installed as u64, 0, 0);
            }
            Err(_) => {
                // Reconnect from scratch after backing off; the node
                // keeps serving standalone in the meantime.
                client = None;
                metrics.fleet_sync_errors.fetch_add(1, Ordering::Relaxed);
                metrics.fleet_state.store(FLEET_STATE_BACKOFF, Ordering::Relaxed);
                wait = backoff.next_delay(cfg.every);
            }
        }
    }
}

/// One push + pull cycle against the leader. Returns `(snapshots
/// pushed, priors installed from the pull)`.
fn sync_once(
    cfg: &FleetSyncConfig,
    client: &mut Option<HttpClient>,
    buf: &mut Vec<u8>,
    store: &ShardedStore,
    apps: &AppsCache,
    local_agg: &LocalAggregateFn,
) -> Result<(usize, usize), String> {
    if client.is_none() {
        *client = Some(HttpClient::connect(&cfg.leader).map_err(|e| format!("{e:#}"))?);
    }
    let c = client.as_mut().expect("client just ensured");

    let local = local_agg();
    let pushed = local.len();
    write_push_body(&cfg.node_id, &local, buf);
    let status = c.post_slice("/v1/sync/push", buf).map_err(|e| format!("{e:#}"))?;
    if status != 200 {
        return Err(format!("push rejected: HTTP {status}"));
    }

    buf.clear();
    {
        let mut w = JsonWriter::new(buf);
        w.begin_obj();
        w.field_str("node_id", &cfg.node_id);
        w.end_obj();
    }
    let status = c.post_slice("/v1/sync/pull", buf).map_err(|e| format!("{e:#}"))?;
    if status != 200 {
        return Err(format!("pull rejected: HTTP {status}"));
    }
    let installed = apply_pull_body(c.last_body(), store, apps)?;
    Ok((pushed, installed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::store::SessionKey;

    fn fkey(app: AppKind, policy: PolicyKind) -> FleetKey {
        FleetKey {
            app,
            device: PowerMode::Maxn,
            policy,
        }
    }

    fn snap(app: AppKind, arms: &[u32], counts: &[f64]) -> FleetSnapshot {
        FleetSnapshot {
            key: fkey(app, PolicyKind::Ucb),
            age_s: 0.0,
            arms: arms.to_vec(),
            counts: counts.to_vec(),
            tau_sum: counts.iter().map(|c| c * 1.5).collect(),
            rho_sum: counts.iter().map(|c| c * 5.0).collect(),
        }
    }

    fn roundtrip(s: &FleetSnapshot) -> FleetSnapshot {
        let mut buf = Vec::new();
        let mut w = JsonWriter::new(&mut buf);
        s.write_json(&mut w);
        let v = JsonSlice::parse(&buf).unwrap();
        FleetSnapshot::from_slice(&v).unwrap()
    }

    #[test]
    fn backoff_grows_jitters_caps_and_resets() {
        let base = Duration::from_secs(10);
        let mut b = Backoff::new(7);
        let mut delays = Vec::new();
        for k in 0..8u32 {
            let d = b.next_delay(base);
            delays.push(d);
            assert_eq!(b.failures(), k + 1);
            // Within the jittered envelope of base · 2^min(k,4), capped.
            let lo = base.mul_f64((1u64 << k.min(4)) as f64);
            let hi = lo.mul_f64(1.5).min(Duration::from_secs(MAX_BACKOFF_SECS));
            assert!(d >= lo.min(hi) && d <= hi, "step {k}: {d:?} not in [{lo:?}, {hi:?}]");
        }
        // The ladder grows strictly while the exponent still grows.
        for k in 0..4 {
            assert!(delays[k + 1] > delays[k], "ladder did not grow at step {k}");
        }
        assert!(delays.last().unwrap() <= &Duration::from_secs(MAX_BACKOFF_SECS));
        b.reset();
        assert_eq!(b.failures(), 0);
        let after = b.next_delay(base);
        assert!(after < base.mul_f64(1.5) + Duration::from_millis(1));
        // Same seed ⇒ same schedule (replayable chaos runs).
        let schedule = |seed: u64| -> Vec<Duration> {
            let mut b = Backoff::new(seed);
            (0..6).map(|_| b.next_delay(base)).collect()
        };
        assert_eq!(schedule(3), schedule(3));
        assert_ne!(schedule(3), schedule(4));
    }

    #[test]
    fn snapshot_json_roundtrip() {
        let s = FleetSnapshot {
            key: fkey(AppKind::Clomp, PolicyKind::SwUcb),
            age_s: 2.5,
            arms: vec![3, 7, 120],
            counts: vec![4.0, 9.5, 1.0],
            tau_sum: vec![3.25, 4.0, 2.0],
            rho_sum: vec![20.0, 45.0, 5.0],
        };
        assert_eq!(roundtrip(&s), s);
    }

    #[test]
    fn snapshot_parse_rejects_malformed() {
        let good = r#"{"app":"clomp","device":"maxn","policy":"ucb","age_s":0,
            "arms":[1,2],"counts":[1,1],"tau_sum":[1,1],"rho_sum":[1,1]}"#;
        let v = JsonSlice::parse(good.as_bytes()).unwrap();
        assert!(FleetSnapshot::from_slice(&v).is_ok());
        for bad in [
            // Unknown app.
            r#"{"app":"doom","device":"maxn","policy":"ucb","arms":[1],"counts":[1],"tau_sum":[1],"rho_sum":[1]}"#,
            // Ragged vectors.
            r#"{"app":"clomp","device":"maxn","policy":"ucb","arms":[1,2],"counts":[1],"tau_sum":[1,1],"rho_sum":[1,1]}"#,
            // Unsorted arms.
            r#"{"app":"clomp","device":"maxn","policy":"ucb","arms":[2,1],"counts":[1,1],"tau_sum":[1,1],"rho_sum":[1,1]}"#,
            // Duplicate arms.
            r#"{"app":"clomp","device":"maxn","policy":"ucb","arms":[1,1],"counts":[1,1],"tau_sum":[1,1],"rho_sum":[1,1]}"#,
            // Fractional arm index.
            r#"{"app":"clomp","device":"maxn","policy":"ucb","arms":[1.5],"counts":[1],"tau_sum":[1],"rho_sum":[1]}"#,
            // Negative counts.
            r#"{"app":"clomp","device":"maxn","policy":"ucb","arms":[1],"counts":[-1],"tau_sum":[1],"rho_sum":[1]}"#,
            // Non-array stats.
            r#"{"app":"clomp","device":"maxn","policy":"ucb","arms":7,"counts":[1],"tau_sum":[1],"rho_sum":[1]}"#,
            // Missing policy.
            r#"{"app":"clomp","device":"maxn","arms":[1],"counts":[1],"tau_sum":[1],"rho_sum":[1]}"#,
            // Negative age.
            r#"{"app":"clomp","device":"maxn","policy":"ucb","age_s":-3,"arms":[1],"counts":[1],"tau_sum":[1],"rho_sum":[1]}"#,
        ] {
            let v = JsonSlice::parse(bad.as_bytes()).unwrap();
            assert!(FleetSnapshot::from_slice(&v).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn sparse_state_roundtrip_and_cap() {
        let mut state = ArmStats::new(10_000);
        for arm in 0..5_000 {
            for _ in 0..(1 + arm % 7) {
                state.observe(arm, 1.0, 5.0);
            }
        }
        let s = FleetSnapshot::from_state(fkey(AppKind::Hypre, PolicyKind::Subset), &state, 0.0)
            .unwrap();
        assert!(s.arms.len() <= FLEET_MAX_ARMS, "cap not applied: {}", s.arms.len());
        // Capping keeps the most-pulled arms.
        assert!(s.counts.iter().all(|&c| c >= 4.0), "kept a low-count arm over a high one");
        // Ascending and unique.
        assert!(s.arms.windows(2).all(|w| w[0] < w[1]));
        // Densify: kept arms match exactly.
        let dense = s.to_state(10_000);
        for (i, &arm) in s.arms.iter().enumerate() {
            assert_eq!(dense.counts()[arm as usize], s.counts[i]);
        }
        // Empty states never serialize.
        assert!(FleetSnapshot::from_state(
            fkey(AppKind::Clomp, PolicyKind::Ucb),
            &ArmStats::new(8),
            0.0
        )
        .is_none());
    }

    #[test]
    fn absorb_is_idempotent() {
        let fs = FleetStore::new(Duration::from_secs(3600));
        let s = snap(AppKind::Clomp, &[5], &[10.0]);
        fs.absorb("edge-a", vec![s.clone()]);
        let once = fs.merged(None, None);
        fs.absorb("edge-a", vec![s.clone()]);
        fs.absorb("edge-a", vec![s]);
        let thrice = fs.merged(None, None);
        assert_eq!(fs.node_count(), 1);
        assert_eq!(once.len(), 1);
        // Counts are within decay noise of each other (sub-second ages).
        assert!((once[0].counts[0] - thrice[0].counts[0]).abs() < 0.01);
    }

    #[test]
    fn merged_excludes_requester_and_folds_local() {
        let fs = FleetStore::new(Duration::from_secs(3600));
        fs.absorb("edge-a", vec![snap(AppKind::Clomp, &[1], &[4.0])]);
        fs.absorb("edge-b", vec![snap(AppKind::Clomp, &[1, 2], &[2.0, 6.0])]);
        let local = [snap(AppKind::Kripke, &[9], &[3.0])];
        let merged = fs.merged(Some("edge-a"), Some(("leader", &local)));
        // Clomp comes only from edge-b; kripke from the local aggregate.
        let clomp = merged.iter().find(|s| s.key.app == AppKind::Clomp).unwrap();
        assert_eq!(clomp.arms, vec![1, 2]);
        assert!((clomp.counts[0] - 2.0).abs() < 0.01, "echoed the excluded node");
        let kripke = merged.iter().find(|s| s.key.app == AppKind::Kripke).unwrap();
        assert_eq!(kripke.arms, vec![9]);
        // Without exclusion both nodes pool.
        let all = fs.merged(None, None);
        let clomp = all.iter().find(|s| s.key.app == AppKind::Clomp).unwrap();
        assert!((clomp.counts[0] - 6.0).abs() < 0.01, "nodes did not pool");
    }

    #[test]
    fn merged_decays_stale_evidence() {
        // Tiny half-life: evidence a few ms old is already worthless.
        let fs = FleetStore::new(Duration::from_millis(1));
        fs.absorb("edge-a", vec![snap(AppKind::Clomp, &[1], &[1000.0])]);
        std::thread::sleep(Duration::from_millis(30));
        assert!(fs.merged(None, None).is_empty(), "stale evidence survived");
        // Carried age counts too: a snapshot pushed as already-old decays
        // even when received just now.
        let fs = FleetStore::new(Duration::from_secs(1));
        let mut old = snap(AppKind::Clomp, &[1], &[1000.0]);
        old.age_s = 3600.0;
        fs.absorb("edge-a", vec![old]);
        assert!(fs.merged(None, None).is_empty(), "carried age ignored");
    }

    #[test]
    fn aggregate_local_pools_sessions_per_scenario() {
        let store = ShardedStore::new(4);
        for (client, pulls) in [("a", 3usize), ("b", 5usize)] {
            let key = SessionKey {
                client_id: client.to_string(),
                app: AppKind::Clomp,
                device: PowerMode::Maxn,
                policy: PolicyKind::Ucb,
            };
            let hash = key.hash64();
            let id = store.intern(&key.as_ref(), hash);
            let i = store.shard_of_hash(hash);
            let mut shard = store.write_shard(i);
            let (s, _) = store.get_or_create(&mut shard, id, 1.0, 0.0, 125).unwrap();
            for _ in 0..pulls {
                s.tuner.observe(7, 0.5, 5.0).unwrap();
            }
        }
        let snaps = aggregate_local(&store);
        assert_eq!(snaps.len(), 1, "one scenario expected");
        let s = &snaps[0];
        assert_eq!(s.key, fkey(AppKind::Clomp, PolicyKind::Ucb));
        assert_eq!(s.arms, vec![7]);
        assert!((s.counts[0] - 8.0).abs() < 1e-9, "sessions did not pool: {:?}", s.counts);
        // Round-trip through the wire and back into a store prior.
        let apps = AppsCache::new();
        let fresh = ShardedStore::new(2);
        let installed = install_priors(&snaps, &fresh, &apps);
        assert_eq!(installed, 1);
        assert_eq!(fresh.fleet_prior_keys(), 1);
    }

    #[test]
    fn snapshot_parse_rejects_oversized() {
        let n = FLEET_MAX_ARMS + 1;
        let arms: Vec<String> = (0..n).map(|i| i.to_string()).collect();
        let ones = vec!["1"; n].join(",");
        let big = format!(
            r#"{{"app":"clomp","device":"maxn","policy":"ucb","arms":[{}],"counts":[{ones}],"tau_sum":[{ones}],"rho_sum":[{ones}]}}"#,
            arms.join(",")
        );
        let v = JsonSlice::parse(big.as_bytes()).unwrap();
        let err = FleetSnapshot::from_slice(&v).unwrap_err();
        assert!(err.contains("arm entries"), "{err}");
    }

    #[test]
    fn aggregate_local_exports_only_local_deltas() {
        // A warm-started session must not re-export its borrowed fleet
        // prior as this node's own evidence (echo amplification).
        let store = ShardedStore::new(1).with_fleet_tuning(0.5, Duration::from_secs(3600));
        let mut prior = ArmStats::new(125);
        for _ in 0..40 {
            prior.observe(7, 0.3, 5.0);
        }
        store.install_fleet_prior(fkey(AppKind::Clomp, PolicyKind::Ucb), prior);
        let key = SessionKey {
            client_id: "warm".to_string(),
            app: AppKind::Clomp,
            device: PowerMode::Maxn,
            policy: PolicyKind::Ucb,
        };
        let id = store.intern(&key.as_ref(), key.hash64());
        {
            let mut shard = store.write_shard(0);
            let (s, created) = store.get_or_create(&mut shard, id, 1.0, 0.0, 125).unwrap();
            assert!(created);
            assert!(s.fleet_baseline.is_some(), "warm start did not record a baseline");
            assert!(s.tuner.total_pulls() > 0.0, "prior not applied");
        }
        assert!(
            aggregate_local(&store).is_empty(),
            "borrowed prior was re-exported as local evidence"
        );
        // Local measurements, and only they, are exported.
        {
            let mut shard = store.write_shard(0);
            let (s, _) = store.get_or_create(&mut shard, id, 1.0, 0.0, 125).unwrap();
            s.tuner.observe(7, 0.3, 5.0).unwrap();
            s.tuner.observe(9, 2.0, 5.0).unwrap();
        }
        let snaps = aggregate_local(&store);
        assert_eq!(snaps.len(), 1);
        assert_eq!(snaps[0].arms, vec![7, 9]);
        assert!((snaps[0].counts[0] - 1.0).abs() < 1e-9, "{:?}", snaps[0].counts);
        assert!((snaps[0].counts[1] - 1.0).abs() < 1e-9, "{:?}", snaps[0].counts);
    }

    #[test]
    fn push_body_and_pull_body_roundtrip() {
        let snaps = vec![
            snap(AppKind::Clomp, &[5, 9], &[10.0, 2.0]),
            snap(AppKind::Kripke, &[0], &[1.0]),
        ];
        let mut buf = Vec::new();
        write_push_body("edge-a", &snaps, &mut buf);
        let v = JsonSlice::parse(&buf).unwrap();
        assert_eq!(v.get("node_id").unwrap().as_str().unwrap(), "edge-a");
        let parsed: Vec<FleetSnapshot> = v
            .get("snapshots")
            .unwrap()
            .items()
            .map(|i| FleetSnapshot::from_slice(&i).unwrap())
            .collect();
        assert_eq!(parsed, snaps);

        // The same wire shape is a valid pull body for apply_pull_body.
        let apps = AppsCache::new();
        let store = ShardedStore::new(2);
        assert_eq!(apply_pull_body(&buf, &store, &apps).unwrap(), 2);
        assert_eq!(store.fleet_prior_keys(), 2);
        assert!(apply_pull_body(b"{\"snapshots\":3}", &store, &apps).is_err());
        assert!(apply_pull_body(b"{}", &store, &apps).is_err());
        assert!(apply_pull_body(b"not json", &store, &apps).is_err());
    }
}
