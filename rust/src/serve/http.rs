//! Minimal HTTP/1.1 + JSON transport for the tuning service.
//!
//! No async runtime exists in this offline build, so this is the same
//! std-threads-and-bounded-channels idiom as [`crate::coordinator`]: one
//! accept thread feeds accepted connections into a bounded channel drained
//! by a fixed pool of worker threads (the bound is the backpressure — a
//! flood of connections blocks in `accept`, not in unbounded memory).
//! Supported surface: request line + headers + `Content-Length` bodies,
//! keep-alive, and nothing else (no chunked encoding, no TLS, no HTTP/2);
//! that is exactly what the loadgen, the integration tests and a curl
//! smoke test need.
//!
//! Each worker owns one connection at a time, so the pool size bounds the
//! number of concurrent keep-alive clients — size `workers` to the client
//! population (the `serve` CLI default of 8 matches the loadgen default).

use crate::util::json::Json;
use anyhow::{Context as _, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Request bodies above this are rejected (a suggest/report payload is
/// a few hundred bytes).
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Header-section ceiling.
const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Idle keep-alive connections wake this often to check for shutdown.
const READ_TIMEOUT: Duration = Duration::from_millis(500);

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/v1/suggest`.
    pub path: String,
    /// Decoded query parameters.
    pub query: HashMap<String, String>,
    pub body: Vec<u8>,
    /// Client sent `Connection: close`.
    pub close: bool,
}

impl Request {
    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json, String> {
        let text = std::str::from_utf8(&self.body).map_err(|_| "body is not UTF-8".to_string())?;
        Json::parse(text)
    }
}

/// An HTTP response ready to serialize.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    /// JSON response.
    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.to_string().into_bytes(),
        }
    }

    /// Plain-text response.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
        }
    }

    /// JSON error envelope `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("error".to_string(), Json::Str(msg.to_string()));
        Response::json(status, &Json::Obj(obj))
    }
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Outcome of trying to read one request off a connection.
enum ReadOutcome {
    Request(Request),
    /// Peer closed cleanly between requests.
    Closed,
    /// Idle read timeout between requests (connection still healthy).
    Idle,
    /// Protocol violation; connection must be dropped after a 400.
    Malformed(String),
}

fn read_request(reader: &mut BufReader<TcpStream>) -> ReadOutcome {
    // Request line. A timeout with nothing read means an idle keep-alive
    // connection; a timeout after partial bytes (read_line appends what it
    // consumed before erroring) means a stalled half-written request —
    // retrying would lose the consumed prefix and desync the stream.
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return ReadOutcome::Closed,
        Ok(_) => {}
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            if line.is_empty() {
                return ReadOutcome::Idle;
            }
            return ReadOutcome::Malformed("timed out mid-request".into());
        }
        Err(_) => return ReadOutcome::Closed,
    }
    let mut parts = line.split_whitespace();
    let (Some(method), Some(target), Some(version)) =
        (parts.next(), parts.next(), parts.next())
    else {
        return ReadOutcome::Malformed("bad request line".into());
    };
    if !version.starts_with("HTTP/1.") {
        return ReadOutcome::Malformed("unsupported HTTP version".into());
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target.to_string(), HashMap::new()),
    };

    // Headers.
    let mut content_length = 0usize;
    let mut close = false;
    let mut header_bytes = 0usize;
    loop {
        let mut h = String::new();
        match reader.read_line(&mut h) {
            Ok(0) => return ReadOutcome::Malformed("eof in headers".into()),
            Ok(n) => header_bytes += n,
            Err(_) => return ReadOutcome::Malformed("read error in headers".into()),
        }
        if header_bytes > MAX_HEADER_BYTES {
            return ReadOutcome::Malformed("headers too large".into());
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let Some((name, value)) = h.split_once(':') else {
            return ReadOutcome::Malformed("bad header".into());
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        if name == "content-length" {
            match value.parse::<usize>() {
                Ok(n) if n <= MAX_BODY_BYTES => content_length = n,
                Ok(_) => return ReadOutcome::Malformed("body too large".into()),
                Err(_) => return ReadOutcome::Malformed("bad content-length".into()),
            }
        } else if name == "connection" && value.eq_ignore_ascii_case("close") {
            close = true;
        }
    }

    // Body.
    let mut body = vec![0u8; content_length];
    if content_length > 0 && reader.read_exact(&mut body).is_err() {
        return ReadOutcome::Malformed("short body".into());
    }
    ReadOutcome::Request(Request {
        method: method.to_string(),
        path,
        query,
        body,
        close,
    })
}

/// Decode `a=b&c=d` with minimal percent-decoding (`%XX` and `+`).
fn parse_query(q: &str) -> HashMap<String, String> {
    q.split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect()
}

fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                let hex = bytes.get(i + 1..i + 3);
                match hex.and_then(|h| u8::from_str_radix(std::str::from_utf8(h).ok()?, 16).ok())
                {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Serialize a response.
fn write_response(stream: &mut TcpStream, resp: &Response, keep_alive: bool) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    // One buffer, one write: head and body in the same segment keeps the
    // hot suggest path at a single syscall per response.
    let mut frame = Vec::with_capacity(head.len() + resp.body.len());
    frame.extend_from_slice(head.as_bytes());
    frame.extend_from_slice(&resp.body);
    stream.write_all(&frame)?;
    stream.flush()
}

/// The request handler shared by all worker threads.
pub type HttpHandler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A running HTTP server: accept thread + fixed worker pool.
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Start serving `listener` with `workers` handler threads.
    pub fn start(listener: TcpListener, workers: usize, handler: HttpHandler) -> Result<HttpServer> {
        assert!(workers > 0);
        let addr = listener.local_addr().context("reading bound address")?;
        let shutdown = Arc::new(AtomicBool::new(false));

        // Bounded hand-off: a connection flood blocks the accept thread
        // (kernel backlog) instead of queueing unboundedly in memory.
        let (tx, rx): (SyncSender<TcpStream>, Receiver<TcpStream>) = sync_channel(workers * 4);
        let rx = Arc::new(Mutex::new(rx));

        let mut pool = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = rx.clone();
            let handler = handler.clone();
            let shutdown = shutdown.clone();
            pool.push(std::thread::spawn(move || loop {
                let stream = {
                    let guard = match rx.lock() {
                        Ok(g) => g,
                        Err(p) => p.into_inner(),
                    };
                    guard.recv()
                };
                match stream {
                    Ok(s) => handle_connection(s, &handler, &shutdown),
                    Err(_) => return, // accept thread gone: shutdown
                }
            }));
        }

        let accept_thread = {
            let shutdown = shutdown.clone();
            std::thread::spawn(move || {
                // `tx` lives in this thread; dropping it on exit releases
                // the worker pool.
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    let Ok(stream) = conn else { continue };
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
                    if tx.send(stream).is_err() {
                        return;
                    }
                }
            })
        };

        Ok(HttpServer {
            addr,
            shutdown,
            accept_thread,
            workers: pool,
        })
    }

    /// The bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close workers, join all threads.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the accept thread out of its blocking accept.
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept_thread.join();
        for w in self.workers {
            let _ = w.join();
        }
    }

    /// Block until the server exits on its own (never, in practice) —
    /// used by the `lasp serve` CLI to park the main thread.
    pub fn join(self) {
        let _ = self.accept_thread.join();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn handle_connection(stream: TcpStream, handler: &HttpHandler, shutdown: &AtomicBool) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut write_half = stream;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match read_request(&mut reader) {
            ReadOutcome::Request(req) => {
                let resp = handler(&req);
                let keep = !req.close;
                if write_response(&mut write_half, &resp, keep).is_err() || !keep {
                    return;
                }
            }
            ReadOutcome::Idle => continue,
            ReadOutcome::Closed => return,
            ReadOutcome::Malformed(msg) => {
                let _ = write_response(&mut write_half, &Response::error(400, &msg), false);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handler: HttpHandler = Arc::new(|req: &Request| {
            let mut obj = std::collections::BTreeMap::new();
            obj.insert("method".into(), Json::Str(req.method.clone()));
            obj.insert("path".into(), Json::Str(req.path.clone()));
            obj.insert(
                "body_len".into(),
                Json::Num(req.body.len() as f64),
            );
            if let Some(v) = req.query.get("q") {
                obj.insert("q".into(), Json::Str(v.clone()));
            }
            Response::json(200, &Json::Obj(obj))
        });
        HttpServer::start(listener, 2, handler).unwrap()
    }

    fn raw_roundtrip(addr: SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_get_with_query() {
        let server = echo_server();
        let resp = raw_roundtrip(
            server.addr(),
            "GET /hello?q=a%20b HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("\"path\":\"/hello\""), "{resp}");
        assert!(resp.contains("\"q\":\"a b\""), "{resp}");
        server.stop();
    }

    #[test]
    fn serves_post_body_and_keep_alive() {
        let server = echo_server();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        for _ in 0..3 {
            let body = "{\"x\":1}";
            let req = format!(
                "POST /v1/echo HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            s.write_all(req.as_bytes()).unwrap();
            // Read the response head + body off the same connection
            // (looping in case the head and body arrive in two segments).
            let mut text = String::new();
            let mut buf = [0u8; 4096];
            while !text.contains("body_len") {
                let n = s.read(&mut buf).unwrap();
                assert!(n > 0, "connection closed early: {text}");
                text.push_str(&String::from_utf8_lossy(&buf[..n]));
            }
            assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
            assert!(text.contains("\"body_len\":7"), "{text}");
        }
        server.stop();
    }

    #[test]
    fn rejects_malformed_request_line() {
        let server = echo_server();
        let resp = raw_roundtrip(server.addr(), "NOT-HTTP\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        server.stop();
    }

    #[test]
    fn rejects_oversized_body_declaration() {
        let server = echo_server();
        let resp = raw_roundtrip(
            server.addr(),
            "POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        server.stop();
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c"), "a b c");
        assert_eq!(percent_decode("plain"), "plain");
        assert_eq!(percent_decode("bad%zz"), "bad%zz");
        assert_eq!(percent_decode("%41"), "A");
    }
}
