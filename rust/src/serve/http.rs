//! Minimal HTTP/1.1 transport for the tuning service — allocation-free in
//! steady state.
//!
//! No async runtime exists in this offline build, so this is the same
//! std-threads-and-bounded-channels idiom as [`crate::coordinator`]: one
//! accept thread feeds accepted connections into a bounded channel drained
//! by a fixed pool of worker threads (the bound is the backpressure — a
//! flood of connections blocks in `accept`, not in unbounded memory).
//! Supported surface: request line + headers + `Content-Length` bodies,
//! keep-alive (with pipelining), and nothing else (no chunked encoding, no
//! TLS, no HTTP/2); that is exactly what the loadgen, the integration
//! tests and a curl smoke test need.
//!
//! ## Buffer lifecycle (the zero-allocation contract)
//!
//! Each worker owns one connection at a time and three reusable buffers
//! that live for the whole connection:
//!
//! * a **read buffer** ([`ConnBuf`]) that raw socket bytes land in; the
//!   request line, headers and body are parsed as *slices* into it
//!   (never copied into `String`s), and consumed bytes are reclaimed by
//!   compaction, so back-to-back (pipelined) requests parse with zero
//!   reads wasted and zero allocations;
//! * a **response buffer** ([`ResponseBuf`]) the handler serializes into
//!   (cleared, not freed, between requests);
//! * a **frame buffer** the status line + headers + body are assembled in
//!   so each response is a single `write_all` (one syscall).
//!
//! All three grow to their high-water mark during warmup and are then only
//! overwritten. Every growth event is counted in [`TransportStats`] —
//! `alloc_events` staying flat under steady load *is* the zero-allocation
//! property, and the tests assert exactly that.
//!
//! Each worker owns one connection at a time, so the pool size bounds the
//! number of concurrent keep-alive clients — size `workers` to the client
//! population (the `serve` CLI default of 8 matches the loadgen default).

use crate::util::json::JsonWriter;
use anyhow::{Context as _, Result};
use std::borrow::Cow;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Request bodies above this are rejected with 413 (a suggest/report
/// payload is a few hundred bytes).
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Header-section ceiling: request line + all headers must fit (431).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Header-count ceiling (431) — a malicious client cannot make the server
/// spend unbounded parse work per request.
pub const MAX_HEADERS: usize = 64;
/// Initial per-connection read-buffer size; grows (counted) on demand up
/// to the header + body ceilings.
const INITIAL_BUF: usize = 4 * 1024;
/// Idle keep-alive connections wake this often to check for shutdown.
const READ_TIMEOUT: Duration = Duration::from_millis(500);
/// A request must arrive in full within this window of its first byte.
/// Bounds slow-loris hold time: a client trickling a request (or stalling
/// mid-request) is evicted with 408 instead of pinning a pool worker
/// forever. Generous enough for any legitimate client on a bad link.
const REQUEST_DEADLINE: Duration = Duration::from_secs(10);

/// Transport-level counters, shared by every worker of one server.
/// `alloc_events` is the serve hot path's allocation proxy: it counts
/// buffer growth in the HTTP + JSON layers (read buffer, response body,
/// frame scratch), so a flat value under steady load certifies the
/// request path performs zero heap allocations in those layers.
#[derive(Default)]
pub struct TransportStats {
    /// Connections accepted.
    pub connections: AtomicU64,
    /// Requests parsed and dispatched.
    pub requests: AtomicU64,
    /// Buffer growth events in the HTTP+JSON layers (see above).
    pub alloc_events: AtomicU64,
    /// Requests rejected with 431 (header limits).
    pub rejected_431: AtomicU64,
}

impl TransportStats {
    fn note_alloc(&self) {
        self.alloc_events.fetch_add(1, Ordering::Relaxed);
    }
}

/// A parsed HTTP request, borrowing from the connection's read buffer.
#[derive(Debug)]
pub struct Request<'a> {
    pub method: &'a str,
    /// Path without the query string, e.g. `/v1/suggest` (undecoded).
    pub path: &'a str,
    /// Raw query string after `?` (may be empty; decode via
    /// [`Request::query_get`]).
    pub query: &'a str,
    pub body: &'a [u8],
    /// Client asked for the connection to be closed after this response.
    pub close: bool,
}

impl<'a> Request<'a> {
    /// Look up and percent-decode one query parameter. Borrows from the
    /// request unless the value actually contains `%`/`+` escapes.
    /// Values that decode to invalid UTF-8 are rejected (`None`) rather
    /// than lossy-decoded — deterministic for the caller, and a malformed
    /// parameter can never impersonate a different (valid) string.
    pub fn query_get(&self, name: &str) -> Option<Cow<'a, str>> {
        query_get(self.query, name)
    }
}

/// Look up `name` in a raw `a=b&c=d` query string, returning the value
/// still percent-encoded. Lets callers distinguish "absent" from
/// "present but undecodable" (the latter must be a 400, not a silent
/// fall-back to defaults).
pub fn query_get_raw<'a>(query: &'a str, name: &str) -> Option<&'a str> {
    for pair in query.split('&') {
        if pair.is_empty() {
            continue;
        }
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        match percent_decode(k) {
            Some(key) if key == name => return Some(v),
            _ => {}
        }
    }
    None
}

/// Look up and decode `name` (shared with tests and the loadgen client).
/// `None` for both absent and undecodable values; use
/// [`query_get_raw`] + [`percent_decode`] to tell them apart.
pub fn query_get<'a>(query: &'a str, name: &str) -> Option<Cow<'a, str>> {
    percent_decode(query_get_raw(query, name)?)
}

/// Percent-decode (`%XX` and `+`). Borrowed when no escapes are present;
/// `None` when the decoded bytes are not valid UTF-8 (deterministic
/// rejection instead of silent U+FFFD substitution). A `%` not followed
/// by two hex digits passes through literally, matching common lenient
/// parsers.
pub fn percent_decode(s: &str) -> Option<Cow<'_, str>> {
    if !s.bytes().any(|b| b == b'%' || b == b'+') {
        return Some(Cow::Borrowed(s));
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                let hex = bytes.get(i + 1..i + 3);
                match hex.and_then(|h| u8::from_str_radix(std::str::from_utf8(h).ok()?, 16).ok())
                {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out).ok().map(Cow::Owned)
}

/// The response a handler fills in. The body buffer is cleared — not
/// freed — between requests, so steady-state serialization into it is
/// allocation-free.
pub struct ResponseBuf {
    status: u16,
    content_type: &'static str,
    /// Serialized response body; handlers append (via [`JsonWriter`] or
    /// `extend_from_slice`) after [`ResponseBuf::reset`].
    pub body: Vec<u8>,
    /// Reusable text scratch for handlers (e.g. config descriptions
    /// streamed into the body) — same lifecycle as `body`, and its
    /// growth is counted as an alloc event too.
    pub scratch: String,
}

impl ResponseBuf {
    pub fn new() -> ResponseBuf {
        ResponseBuf {
            status: 200,
            content_type: "application/json",
            body: Vec::with_capacity(512),
            scratch: String::with_capacity(128),
        }
    }

    /// Clear for the next request (keeps capacity).
    pub fn reset(&mut self) {
        self.status = 200;
        self.content_type = "application/json";
        self.body.clear();
        self.scratch.clear();
    }

    pub fn status(&self) -> u16 {
        self.status
    }

    pub fn set_status(&mut self, status: u16) {
        self.status = status;
    }

    /// Replace the response with a plain-text body.
    pub fn text(&mut self, status: u16, body: &str) {
        self.status = status;
        self.content_type = "text/plain; charset=utf-8";
        self.body.clear();
        self.body.extend_from_slice(body.as_bytes());
    }

    /// Replace the response with a `{"error": msg}` JSON envelope.
    pub fn error(&mut self, status: u16, msg: &str) {
        self.status = status;
        self.content_type = "application/json";
        self.body.clear();
        let mut w = JsonWriter::new(&mut self.body);
        w.begin_obj();
        w.field_str("error", msg);
        w.end_obj();
    }
}

impl Default for ResponseBuf {
    fn default() -> Self {
        Self::new()
    }
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Reusable per-connection read buffer. Bytes live in `data[start..filled]`;
/// parsing slices into that window, and `consume` reclaims the prefix.
struct ConnBuf {
    data: Vec<u8>,
    start: usize,
    filled: usize,
    /// When the first byte of the currently pending request arrived
    /// (None = no partial request buffered). Drives [`REQUEST_DEADLINE`].
    since: Option<Instant>,
}

impl ConnBuf {
    fn new() -> ConnBuf {
        ConnBuf { data: vec![0u8; INITIAL_BUF], start: 0, filled: 0, since: None }
    }

    /// Forget any buffered bytes (new connection); keeps capacity.
    fn reset(&mut self) {
        self.start = 0;
        self.filled = 0;
        self.since = None;
    }

    fn window(&self) -> &[u8] {
        &self.data[self.start..self.filled]
    }

    fn len(&self) -> usize {
        self.filled - self.start
    }

    /// The pending (partial) request has overstayed [`REQUEST_DEADLINE`].
    fn deadline_exceeded(&self) -> bool {
        matches!(self.since, Some(t) if t.elapsed() > REQUEST_DEADLINE)
    }

    /// Drop `n` parsed bytes from the front of the window.
    fn consume(&mut self, n: usize) {
        self.start = (self.start + n).min(self.filled);
        if self.start == self.filled {
            self.start = 0;
            self.filled = 0;
            self.since = None;
        } else {
            // Pipelined follow-up already buffered: its clock starts now.
            self.since = Some(Instant::now());
        }
    }

    /// Read more bytes from `stream`, compacting or growing first if the
    /// tail is full. Growth is a counted alloc event; steady state hits
    /// the high-water capacity and never grows again.
    fn fill(&mut self, stream: &mut TcpStream, stats: &TransportStats) -> std::io::Result<usize> {
        if self.filled == self.data.len() {
            if self.start > 0 {
                self.data.copy_within(self.start..self.filled, 0);
                self.filled -= self.start;
                self.start = 0;
            } else {
                let new_len = (self.data.len() * 2).min(MAX_HEADER_BYTES + MAX_BODY_BYTES + 1024);
                if new_len > self.data.len() {
                    self.data.resize(new_len, 0);
                    stats.note_alloc();
                } else {
                    // Window already at the absolute ceiling; the parser
                    // rejects such requests before asking for more.
                    return Ok(0);
                }
            }
        }
        let was_empty = self.len() == 0;
        let n = stream.read(&mut self.data[self.filled..])?;
        self.filled += n;
        if was_empty && n > 0 {
            self.since = Some(Instant::now());
        }
        Ok(n)
    }
}

/// Byte ranges of one parsed request, relative to the buffer window at
/// parse time (no borrows, so the caller can keep mutating the buffer
/// before re-slicing).
struct Parsed {
    method: std::ops::Range<usize>,
    path: std::ops::Range<usize>,
    query: std::ops::Range<usize>,
    body: std::ops::Range<usize>,
    total_len: usize,
    close: bool,
}

enum TryParse {
    /// A complete request is buffered.
    Complete(Parsed),
    /// Not enough bytes yet.
    NeedMore,
    /// Protocol violation; respond with `status` and drop the connection.
    Bad(u16, &'static str),
}

/// Find the blank line ending the header section: a line break followed
/// immediately by another line break, where each break is `\n` or `\r\n`
/// (the old line-based parser tolerated LF-only and mixed endings; keep
/// accepting them). One short-circuiting pass — never scans past the
/// header region into buffered body bytes. Returns `(head_len,
/// body_start)`.
fn find_head_end(data: &[u8]) -> Option<(usize, usize)> {
    let mut i = 0;
    while i < data.len() {
        if data[i] == b'\n' {
            match data.get(i + 1) {
                Some(b'\n') => return Some((i, i + 2)),
                Some(b'\r') if data.get(i + 2) == Some(&b'\n') => return Some((i, i + 3)),
                _ => {}
            }
        }
        i += 1;
    }
    None
}

/// Attempt to parse one request from `data` (the buffer window).
fn try_parse(data: &[u8]) -> TryParse {
    // Locate the end of the header section.
    let Some((hdr_end, body_start)) = find_head_end(data) else {
        return if data.len() > MAX_HEADER_BYTES {
            TryParse::Bad(431, "headers too large")
        } else {
            TryParse::NeedMore
        };
    };
    if hdr_end > MAX_HEADER_BYTES {
        return TryParse::Bad(431, "headers too large");
    }
    let Ok(head) = std::str::from_utf8(&data[..hdr_end]) else {
        return TryParse::Bad(400, "non-ASCII request head");
    };
    let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(target), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return TryParse::Bad(400, "bad request line");
    };
    if !version.starts_with("HTTP/1.") {
        return TryParse::Bad(400, "unsupported HTTP version");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };

    // Headers.
    let mut content_length: Option<usize> = None;
    let mut close = version == "HTTP/1.0";
    let mut n_headers = 0usize;
    for line in lines {
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return TryParse::Bad(431, "too many headers");
        }
        let Some((name, value)) = line.split_once(':') else {
            return TryParse::Bad(400, "bad header");
        };
        let name = name.trim();
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            match value.parse::<usize>() {
                Ok(n) if n <= MAX_BODY_BYTES => {
                    // Conflicting duplicates are a framing-desync
                    // (request smuggling) vector: reject per RFC 7230.
                    if matches!(content_length, Some(prev) if prev != n) {
                        return TryParse::Bad(400, "conflicting content-length");
                    }
                    content_length = Some(n);
                }
                Ok(_) => return TryParse::Bad(413, "body too large"),
                Err(_) => return TryParse::Bad(400, "bad content-length"),
            }
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            // Chunked framing is not implemented; silently ignoring it
            // would desync the pipelined stream at the chunk headers.
            return TryParse::Bad(501, "transfer-encoding not supported");
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                close = true;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                close = false;
            }
        }
    }
    let content_length = content_length.unwrap_or(0);

    let total_len = body_start + content_length;
    if data.len() < total_len {
        return TryParse::NeedMore;
    }

    let range_in = |s: &str| -> std::ops::Range<usize> {
        let off = s.as_ptr() as usize - data.as_ptr() as usize;
        off..off + s.len()
    };
    // An absent query is the static "" (not inside `data`): empty range.
    let query = if query.is_empty() { 0..0 } else { range_in(query) };
    TryParse::Complete(Parsed {
        method: range_in(method),
        path: range_in(path),
        query,
        body: body_start..total_len,
        total_len,
        close,
    })
}

pub(crate) fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Outcome of waiting for one request on a connection.
enum ReadOutcome {
    Request(Parsed),
    /// Peer closed cleanly between requests.
    Closed,
    /// Idle read timeout (connection still healthy; buffered partial
    /// bytes are preserved for the next attempt).
    Idle,
    /// Protocol violation; connection must be dropped after `status`.
    Malformed(u16, &'static str),
}

/// Drive the buffer until one complete request is available (or a
/// terminal outcome). Pipelined requests already in the buffer parse
/// without touching the socket.
fn read_request(
    conn: &mut ConnBuf,
    stream: &mut TcpStream,
    stats: &TransportStats,
) -> ReadOutcome {
    loop {
        if conn.len() > 0 {
            match try_parse(conn.window()) {
                TryParse::Complete(p) => return ReadOutcome::Request(p),
                TryParse::Bad(status, msg) => return ReadOutcome::Malformed(status, msg),
                TryParse::NeedMore => {
                    // A partial request must complete within its deadline
                    // — a trickling client (slow-loris) cannot pin a pool
                    // worker indefinitely.
                    if conn.deadline_exceeded() {
                        return ReadOutcome::Malformed(408, "request timeout");
                    }
                }
            }
        }
        match conn.fill(stream, stats) {
            Ok(0) => {
                return if conn.len() == 0 {
                    ReadOutcome::Closed
                } else {
                    ReadOutcome::Malformed(400, "eof mid-request")
                };
            }
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Partial bytes stay buffered; surface Idle so the worker
                // can check for shutdown and resume exactly where the
                // stream paused (no desync, unlike a line-based parser).
                return ReadOutcome::Idle;
            }
            Err(_) => return ReadOutcome::Closed,
        }
    }
}

/// Assemble head + body into the reusable frame buffer and write it as
/// one segment (single syscall on the hot path).
fn write_response(
    stream: &mut TcpStream,
    resp: &ResponseBuf,
    keep_alive: bool,
    frame: &mut Vec<u8>,
    stats: &TransportStats,
) -> std::io::Result<()> {
    use std::io::Write as _;
    let cap_before = frame.capacity();
    frame.clear();
    let _ = write!(
        frame,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    frame.extend_from_slice(&resp.body);
    if frame.capacity() != cap_before {
        stats.note_alloc();
    }
    stream.write_all(frame)?;
    stream.flush()
}

/// The request handler shared by all worker threads: parse the borrowed
/// request, serialize into the reusable response buffer.
pub type HttpHandler = Arc<dyn Fn(&Request<'_>, &mut ResponseBuf) + Send + Sync>;

/// A running HTTP server: accept thread + fixed worker pool.
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    stats: Arc<TransportStats>,
    accept_thread: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Start serving `listener` with `workers` handler threads.
    pub fn start(listener: TcpListener, workers: usize, handler: HttpHandler) -> Result<HttpServer> {
        Self::start_with_stats(listener, workers, handler, Arc::new(TransportStats::default()))
    }

    /// As [`HttpServer::start`], but share externally owned transport
    /// stats (the service exports them on `/metrics`).
    pub fn start_with_stats(
        listener: TcpListener,
        workers: usize,
        handler: HttpHandler,
        stats: Arc<TransportStats>,
    ) -> Result<HttpServer> {
        Self::start_with_opts(listener, workers, handler, stats, None)
    }

    /// Full-option start: as [`HttpServer::start_with_stats`] plus the
    /// serve-side chaos layer. When armed, its `accept` fault point closes
    /// a just-accepted connection before a byte is served — the client
    /// sees a reset, exactly like a flaky edge link. `None` keeps the
    /// accept loop untouched (zero overhead without `--chaos`).
    pub fn start_with_opts(
        listener: TcpListener,
        workers: usize,
        handler: HttpHandler,
        stats: Arc<TransportStats>,
        chaos: Option<Arc<crate::chaos::ChaosLayer>>,
    ) -> Result<HttpServer> {
        assert!(workers > 0);
        let addr = listener.local_addr().context("reading bound address")?;
        let shutdown = Arc::new(AtomicBool::new(false));

        // Bounded hand-off: a connection flood blocks the accept thread
        // (kernel backlog) instead of queueing unboundedly in memory.
        let (tx, rx): (SyncSender<TcpStream>, Receiver<TcpStream>) = sync_channel(workers * 4);
        let rx = Arc::new(Mutex::new(rx));

        let mut pool = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = rx.clone();
            let handler = handler.clone();
            let shutdown = shutdown.clone();
            let stats = stats.clone();
            pool.push(std::thread::spawn(move || {
                // Connection-lifetime buffers (see module docs). They are
                // per-worker so a long-lived keep-alive client reuses the
                // same memory for every request it sends.
                let mut conn = ConnBuf::new();
                let mut resp = ResponseBuf::new();
                let mut frame: Vec<u8> = Vec::with_capacity(1024);
                loop {
                    let stream = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            Err(p) => p.into_inner(),
                        };
                        guard.recv()
                    };
                    match stream {
                        Ok(s) => {
                            // Reset per-connection state, keep capacity.
                            conn.reset();
                            handle_connection(
                                s, &handler, &shutdown, &stats, &mut conn, &mut resp, &mut frame,
                            );
                        }
                        Err(_) => return, // accept thread gone: shutdown
                    }
                }
            }));
        }

        let accept_thread = {
            let shutdown = shutdown.clone();
            let stats = stats.clone();
            std::thread::spawn(move || {
                // `tx` lives in this thread; dropping it on exit releases
                // the worker pool.
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    let Ok(stream) = conn else { continue };
                    if let Some(c) = &chaos {
                        if c.accept_drop() {
                            // Close before a byte is served; the client
                            // sees a reset, as on a flaky edge link.
                            drop(stream);
                            continue;
                        }
                    }
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
                    stats.connections.fetch_add(1, Ordering::Relaxed);
                    if tx.send(stream).is_err() {
                        return;
                    }
                }
            })
        };

        Ok(HttpServer {
            addr,
            shutdown,
            stats,
            accept_thread,
            workers: pool,
        })
    }

    /// The bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Transport counters (connections, requests, alloc events).
    pub fn stats(&self) -> Arc<TransportStats> {
        self.stats.clone()
    }

    /// Stop accepting, close workers, join all threads.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the accept thread out of its blocking accept.
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept_thread.join();
        for w in self.workers {
            let _ = w.join();
        }
    }

    /// Block until the server exits on its own (never, in practice) —
    /// used by the `lasp serve` CLI to park the main thread.
    pub fn join(self) {
        let _ = self.accept_thread.join();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    mut stream: TcpStream,
    handler: &HttpHandler,
    shutdown: &AtomicBool,
    stats: &TransportStats,
    conn: &mut ConnBuf,
    resp: &mut ResponseBuf,
    frame: &mut Vec<u8>,
) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match read_request(conn, &mut stream, stats) {
            ReadOutcome::Request(p) => {
                stats.requests.fetch_add(1, Ordering::Relaxed);
                let close = {
                    // Borrow the parsed slices out of the buffer window.
                    let base = conn.start;
                    let data = &conn.data[base..conn.filled];
                    // The head was validated as UTF-8 by try_parse.
                    let req = Request {
                        method: std::str::from_utf8(&data[p.method.clone()]).unwrap_or(""),
                        path: std::str::from_utf8(&data[p.path.clone()]).unwrap_or(""),
                        query: std::str::from_utf8(&data[p.query.clone()]).unwrap_or(""),
                        body: &data[p.body.clone()],
                        close: p.close,
                    };
                    resp.reset();
                    let body_cap = resp.body.capacity();
                    let scratch_cap = resp.scratch.capacity();
                    handler(&req, resp);
                    if resp.body.capacity() != body_cap || resp.scratch.capacity() != scratch_cap
                    {
                        stats.note_alloc();
                    }
                    req.close
                };
                if write_response(&mut stream, resp, !close, frame, stats).is_err() || close {
                    return;
                }
                conn.consume(p.total_len);
            }
            ReadOutcome::Idle => continue,
            ReadOutcome::Closed => return,
            ReadOutcome::Malformed(status, msg) => {
                if status == 431 {
                    stats.rejected_431.fetch_add(1, Ordering::Relaxed);
                }
                resp.reset();
                resp.error(status, msg);
                let _ = write_response(&mut stream, resp, false, frame, stats);
                // Lingering close: drain (bounded) whatever the client is
                // still sending, so closing the socket with unread bytes
                // cannot RST the error response away before the client
                // reads it.
                let deadline = Instant::now() + Duration::from_millis(250);
                let mut scratch = [0u8; 1024];
                while Instant::now() < deadline {
                    match stream.read(&mut scratch) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {}
                    }
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handler: HttpHandler = Arc::new(|req: &Request<'_>, out: &mut ResponseBuf| {
            let mut w = JsonWriter::new(&mut out.body);
            w.begin_obj();
            w.field_str("method", req.method);
            w.field_str("path", req.path);
            w.field_num("body_len", req.body.len() as f64);
            if let Some(v) = req.query_get("q") {
                w.field_str("q", &v);
            }
            w.end_obj();
        });
        HttpServer::start(listener, 2, handler).unwrap()
    }

    fn raw_roundtrip(addr: SocketAddr, request: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    /// Read one full response (head + declared body) off a keep-alive
    /// connection.
    fn read_one_response(s: &mut TcpStream) -> String {
        let mut raw = Vec::new();
        let mut buf = [0u8; 4096];
        loop {
            if let Some(hdr_end) = find_subsequence(&raw, b"\r\n\r\n") {
                let head = String::from_utf8_lossy(&raw[..hdr_end]);
                let clen: usize = head
                    .lines()
                    .find_map(|l| {
                        let (name, value) = l.split_once(':')?;
                        name.trim()
                            .eq_ignore_ascii_case("content-length")
                            .then(|| value.trim().parse().ok())?
                    })
                    .unwrap_or(0);
                if raw.len() >= hdr_end + 4 + clen {
                    return String::from_utf8_lossy(&raw[..hdr_end + 4 + clen]).into_owned();
                }
            }
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0, "connection closed early: {}", String::from_utf8_lossy(&raw));
            raw.extend_from_slice(&buf[..n]);
        }
    }

    #[test]
    fn serves_get_with_query() {
        let server = echo_server();
        let resp = raw_roundtrip(
            server.addr(),
            b"GET /hello?q=a%20b HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("\"path\":\"/hello\""), "{resp}");
        assert!(resp.contains("\"q\":\"a b\""), "{resp}");
        server.stop();
    }

    #[test]
    fn serves_post_body_and_keep_alive() {
        let server = echo_server();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        for _ in 0..3 {
            let body = "{\"x\":1}";
            let req = format!(
                "POST /v1/echo HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            s.write_all(req.as_bytes()).unwrap();
            let text = read_one_response(&mut s);
            assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
            assert!(text.contains("\"body_len\":7"), "{text}");
        }
        server.stop();
    }

    #[test]
    fn pipelined_requests_are_all_answered() {
        let server = echo_server();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        // Three requests in a single segment; responses must come back
        // in order on the same connection.
        let mut burst = Vec::new();
        for i in 0..3 {
            burst.extend_from_slice(
                format!("GET /pipe{i} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes(),
            );
        }
        s.write_all(&burst).unwrap();
        for i in 0..3 {
            let text = read_one_response(&mut s);
            assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
            assert!(text.contains(&format!("\"path\":\"/pipe{i}\"")), "{text}");
        }
        server.stop();
    }

    #[test]
    fn split_reads_across_tcp_segments() {
        let server = echo_server();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let body = "{\"split\":true}";
        let req = format!(
            "POST /seg HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let bytes = req.as_bytes();
        // Dribble the request out in 5-byte chunks with pauses: the
        // parser must accumulate across reads without dropping state.
        for chunk in bytes.chunks(5) {
            s.write_all(chunk).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        let text = read_one_response(&mut s);
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
        assert!(text.contains(&format!("\"body_len\":{}", body.len())), "{text}");
        server.stop();
    }

    #[test]
    fn accepts_bare_lf_line_endings() {
        // Hand-rolled clients (printf | nc) often send LF-only heads;
        // the old line-based parser accepted them, so keep doing so.
        let server = echo_server();
        let resp = raw_roundtrip(
            server.addr(),
            b"GET /lf?q=ok HTTP/1.1\nHost: x\nConnection: close\n\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("\"path\":\"/lf\""), "{resp}");
        assert!(resp.contains("\"q\":\"ok\""), "{resp}");
        server.stop();
    }

    #[test]
    fn head_end_handles_all_line_ending_mixes() {
        // CRLF throughout.
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\nHost: x\r\n\r\nBODY"), Some((24, 27)));
        // LF throughout.
        assert_eq!(find_head_end(b"A\nB\n\nrest"), Some((3, 5)));
        // LF lines closed by a CRLF blank line (old parser accepted it).
        assert_eq!(find_head_end(b"A\nB\n\r\nrest"), Some((3, 6)));
        // Incomplete head.
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\nHost"), None);
    }

    #[test]
    fn accepts_lf_lines_with_crlf_blank() {
        let server = echo_server();
        let resp = raw_roundtrip(
            server.addr(),
            b"GET /mixed HTTP/1.1\nHost: x\nConnection: close\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("\"path\":\"/mixed\""), "{resp}");
        server.stop();
    }

    #[test]
    fn partial_request_deadline_trips() {
        // The stall guard itself (no 10 s wait): a pending request whose
        // first byte is older than the deadline must be evicted.
        // checked_sub: Instant is monotonic-since-boot on Linux, and
        // subtracting past the clock origin panics (fresh containers).
        let Some(stale) =
            Instant::now().checked_sub(REQUEST_DEADLINE + Duration::from_millis(10))
        else {
            return; // uptime < deadline: cannot fabricate a stale instant
        };
        let mut conn = ConnBuf::new();
        conn.filled = 4; // pretend 4 bytes arrived
        conn.since = Some(stale);
        assert!(conn.deadline_exceeded());
        conn.reset();
        assert!(!conn.deadline_exceeded());
    }

    #[test]
    fn rejects_malformed_request_line() {
        let server = echo_server();
        let resp = raw_roundtrip(server.addr(), b"NOT-HTTP\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        server.stop();
    }

    #[test]
    fn rejects_oversized_body_declaration() {
        let server = echo_server();
        let resp = raw_roundtrip(
            server.addr(),
            b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
        server.stop();
    }

    #[test]
    fn rejects_conflicting_content_length() {
        let server = echo_server();
        let resp = raw_roundtrip(
            server.addr(),
            b"POST / HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 38\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        // Identical duplicates are mergeable per RFC 7230 and accepted.
        let resp = raw_roundtrip(
            server.addr(),
            b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nok",
        );
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        server.stop();
    }

    #[test]
    fn rejects_transfer_encoding_501() {
        let server = echo_server();
        let resp = raw_roundtrip(
            server.addr(),
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 501"), "{resp}");
        server.stop();
    }

    #[test]
    fn rejects_oversized_headers_with_431() {
        let server = echo_server();
        let stats = server.stats();
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        req.extend_from_slice(b"X-Big: ");
        req.extend(std::iter::repeat(b'a').take(MAX_HEADER_BYTES + 100));
        req.extend_from_slice(b"\r\n\r\n");
        let resp = raw_roundtrip(server.addr(), &req);
        assert!(resp.starts_with("HTTP/1.1 431"), "{resp}");
        assert!(stats.rejected_431.load(Ordering::Relaxed) >= 1);
        server.stop();
    }

    #[test]
    fn rejects_too_many_headers_with_431() {
        let server = echo_server();
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..(MAX_HEADERS + 8) {
            req.extend_from_slice(format!("X-H{i}: v\r\n").as_bytes());
        }
        req.extend_from_slice(b"\r\n");
        let resp = raw_roundtrip(server.addr(), &req);
        assert!(resp.starts_with("HTTP/1.1 431"), "{resp}");
        server.stop();
    }

    #[test]
    fn steady_state_is_allocation_free() {
        let server = echo_server();
        let stats = server.stats();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let body = "{\"client_id\":\"warm\",\"app\":\"clomp\",\"alpha\":0.8,\"beta\":0.2}";
        let req = format!(
            "POST /v1/echo HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        // Warmup: let every buffer reach its high-water mark.
        for _ in 0..10 {
            s.write_all(req.as_bytes()).unwrap();
            read_one_response(&mut s);
        }
        let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
        let requests_before = stats.requests.load(Ordering::Relaxed);
        for _ in 0..200 {
            s.write_all(req.as_bytes()).unwrap();
            read_one_response(&mut s);
        }
        let allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
        let requests = stats.requests.load(Ordering::Relaxed) - requests_before;
        assert_eq!(requests, 200);
        assert_eq!(
            allocs, 0,
            "HTTP+JSON layers allocated {allocs} times over {requests} steady-state requests"
        );
        server.stop();
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c").unwrap(), "a b c");
        let plain = percent_decode("plain").unwrap();
        assert_eq!(plain, "plain");
        assert!(matches!(plain, Cow::Borrowed(_)), "plain values must borrow");
        assert_eq!(percent_decode("bad%zz").unwrap(), "bad%zz");
        assert_eq!(percent_decode("%41").unwrap(), "A");
        // Invalid UTF-8 after decoding is rejected deterministically,
        // never lossy-substituted.
        assert_eq!(percent_decode("%FF"), None);
        assert_eq!(percent_decode("ok%FFtail"), None);
    }

    #[test]
    fn query_lookup() {
        assert_eq!(query_get("a=1&b=two", "b").unwrap(), "two");
        assert_eq!(query_get("a=1&b=two", "a").unwrap(), "1");
        assert_eq!(query_get("flag", "flag").unwrap(), "");
        assert_eq!(query_get("a=1", "missing"), None);
        assert_eq!(query_get("k=%FF", "k"), None);
    }
}
