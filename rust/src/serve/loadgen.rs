//! Closed-loop load generator for a running `lasp serve` instance.
//!
//! Simulates a fleet of edge clients: each session asks the service for a
//! configuration (`/v1/suggest`), runs it on a *local* device simulator
//! ([`JetsonNano`]) at low fidelity, and reports the measurement back
//! (`/v1/report`). Sessions are partitioned across client threads
//! (round-robin), each thread reuses one keep-alive connection, and every
//! HTTP round-trip is timed; the report prints throughput plus p50/p99
//! latency — the numbers the service exists to keep flat under load.

use crate::apps::{self, AppKind, AppModel};
use crate::device::{Device, JetsonNano, PowerMode};
use crate::util::json::Json;
use crate::util::stats;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:8787`.
    pub addr: String,
    /// Concurrent tuning sessions to maintain.
    pub sessions: usize,
    /// Total suggest+report round-trips across all sessions.
    pub rounds: usize,
    /// Client threads (each owns `sessions / threads` sessions).
    pub threads: usize,
    /// Applications to spread sessions over.
    pub apps: Vec<AppKind>,
    /// Objective weights sent with every request.
    pub alpha: f64,
    pub beta: f64,
    /// Device-simulator fidelity and seed.
    pub fidelity: f64,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8787".to_string(),
            sessions: 128,
            rounds: 12_000,
            threads: 8,
            apps: AppKind::all().to_vec(),
            alpha: 0.8,
            beta: 0.2,
            fidelity: 0.15,
            seed: 42,
        }
    }
}

/// Aggregated load-generation results.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Completed suggest+report round-trips.
    pub rounds: usize,
    pub sessions: usize,
    /// Requests that failed (after one reconnect attempt) or returned an
    /// unexpected status.
    pub errors: usize,
    pub elapsed_s: f64,
    /// Round-trips (suggest+report pairs) per second.
    pub round_trips_per_s: f64,
    /// Per-HTTP-request latency quantiles, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
}

impl LoadgenReport {
    /// Print the human-readable summary the CLI shows.
    pub fn print(&self) {
        println!(
            "loadgen: {} round-trips over {} sessions in {:.2}s ({} errors)",
            self.rounds, self.sessions, self.elapsed_s, self.errors
        );
        println!(
            "throughput: {:.0} round-trips/s ({:.0} req/s) | latency p50 {:.2}ms p99 {:.2}ms mean {:.2}ms",
            self.round_trips_per_s,
            self.round_trips_per_s * 2.0,
            self.p50_ms,
            self.p99_ms,
            self.mean_ms
        );
    }
}

/// A tiny keep-alive HTTP/1.1 client (shared with the integration tests).
pub struct HttpClient {
    addr: String,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl HttpClient {
    pub fn connect(addr: &str) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .ok();
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(HttpClient { addr: addr.to_string(), reader, writer: stream })
    }

    /// POST a JSON body; reconnects once on a broken connection.
    pub fn post(&mut self, path: &str, body: &Json) -> Result<(u16, Json)> {
        let payload = body.to_string();
        match self.roundtrip("POST", path, Some(&payload)) {
            Ok(r) => Ok(r),
            Err(_) => {
                *self = HttpClient::connect(&self.addr)?;
                self.roundtrip("POST", path, Some(&payload))
            }
        }
    }

    /// GET a path (with query string); reconnects once on failure.
    pub fn get(&mut self, path_and_query: &str) -> Result<(u16, Json)> {
        match self.roundtrip("GET", path_and_query, None) {
            Ok(r) => Ok(r),
            Err(_) => {
                *self = HttpClient::connect(&self.addr)?;
                self.roundtrip("GET", path_and_query, None)
            }
        }
    }

    fn roundtrip(&mut self, method: &str, target: &str, body: Option<&str>) -> Result<(u16, Json)> {
        let body = body.unwrap_or("");
        let req = format!(
            "{method} {target} HTTP/1.1\r\nHost: lasp\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.writer.write_all(req.as_bytes()).context("writing request")?;
        self.writer.flush().ok();

        // Status line.
        let mut line = String::new();
        self.reader.read_line(&mut line).context("reading status line")?;
        if line.is_empty() {
            return Err(anyhow!("connection closed"));
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status line: {line:?}"))?;

        // Headers.
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            let n = self.reader.read_line(&mut h).context("reading header")?;
            if n == 0 {
                return Err(anyhow!("eof in headers"));
            }
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((name, value)) = h.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().unwrap_or(0);
                }
            }
        }

        // Body.
        let mut raw = vec![0u8; content_length];
        self.reader.read_exact(&mut raw).context("reading body")?;
        let text = String::from_utf8_lossy(&raw);
        // Non-JSON bodies (e.g. the Prometheus text of /metrics) come
        // back as a raw string value.
        let json = if text.trim().is_empty() {
            Json::Null
        } else {
            Json::parse(&text).unwrap_or_else(|_| Json::Str(text.into_owned()))
        };
        Ok((status, json))
    }
}

/// One simulated edge-client session.
struct ClientSession {
    client_id: String,
    app_index: usize,
    kind: AppKind,
    mode: PowerMode,
    device: JetsonNano,
}

fn request_body(cfg: &LoadgenConfig, s: &ClientSession) -> BTreeMap<String, Json> {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(s.client_id.clone()));
    obj.insert("app".to_string(), Json::Str(s.kind.name().to_string()));
    obj.insert(
        "device".to_string(),
        Json::Str(s.mode.name().to_ascii_lowercase()),
    );
    obj.insert("alpha".to_string(), Json::Num(cfg.alpha));
    obj.insert("beta".to_string(), Json::Num(cfg.beta));
    obj
}

/// Drive the configured load and aggregate the per-thread results.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.sessions == 0 || cfg.rounds == 0 || cfg.threads == 0 || cfg.apps.is_empty() {
        return Err(anyhow!("loadgen: sessions/rounds/threads/apps must be non-empty"));
    }
    let t0 = Instant::now();
    let threads = cfg.threads.min(cfg.sessions);
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        let cfg = cfg.clone();
        // Rounds split evenly; the first threads absorb the remainder.
        let my_rounds = cfg.rounds / threads + usize::from(t < cfg.rounds % threads);
        handles.push(std::thread::spawn(move || worker(t, threads, my_rounds, &cfg)));
    }

    let mut latencies: Vec<f64> = Vec::with_capacity(cfg.rounds * 2);
    let mut errors = 0usize;
    let mut rounds_done = 0usize;
    for h in handles {
        let (lat, errs, rounds) = h
            .join()
            .map_err(|_| anyhow!("loadgen worker panicked"))??;
        latencies.extend(lat);
        errors += errs;
        rounds_done += rounds;
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(LoadgenReport {
        rounds: rounds_done,
        sessions: cfg.sessions,
        errors,
        elapsed_s: elapsed,
        round_trips_per_s: rounds_done as f64 / elapsed,
        p50_ms: stats::quantile(&latencies, 0.5) * 1e3,
        p99_ms: stats::quantile(&latencies, 0.99) * 1e3,
        mean_ms: stats::mean(&latencies) * 1e3,
    })
}

fn worker(
    thread_id: usize,
    threads: usize,
    my_rounds: usize,
    cfg: &LoadgenConfig,
) -> Result<(Vec<f64>, usize, usize)> {
    // This thread owns sessions thread_id, thread_id+threads, ...
    let mut sessions: Vec<ClientSession> = (0..cfg.sessions)
        .skip(thread_id)
        .step_by(threads)
        .map(|s| {
            let app_index = s % cfg.apps.len();
            let mode = if s % 2 == 0 { PowerMode::Maxn } else { PowerMode::FiveW };
            ClientSession {
                client_id: format!("lg-{s}"),
                app_index,
                kind: cfg.apps[app_index],
                mode,
                device: JetsonNano::new(mode, cfg.seed.wrapping_add(s as u64))
                    .with_fidelity(cfg.fidelity),
            }
        })
        .collect();
    if sessions.is_empty() {
        return Ok((vec![], 0, 0));
    }
    let models: Vec<Box<dyn AppModel>> = cfg.apps.iter().map(|&k| apps::build(k)).collect();
    let mut client = HttpClient::connect(&cfg.addr)?;
    let mut latencies = Vec::with_capacity(my_rounds * 2);
    let mut errors = 0usize;
    let mut rounds_done = 0usize;

    for round in 0..my_rounds {
        let idx = round % sessions.len();
        let s = &mut sessions[idx];

        // Suggest.
        let body = Json::Obj(request_body(cfg, s));
        let t0 = Instant::now();
        let (status, resp) = match client.post("/v1/suggest", &body) {
            Ok(r) => r,
            Err(_) => {
                errors += 1;
                continue;
            }
        };
        latencies.push(t0.elapsed().as_secs_f64());
        if status != 200 {
            errors += 1;
            continue;
        }
        let Some(arm) = resp.get("arm").and_then(Json::as_usize) else {
            errors += 1;
            continue;
        };

        // Evaluate locally on the simulated device.
        let workload = models[s.app_index].workload(arm, cfg.fidelity);
        let m = s.device.run(&workload);

        // Report.
        let mut obj = request_body(cfg, s);
        obj.insert("arm".to_string(), Json::Num(arm as f64));
        obj.insert("time_s".to_string(), Json::Num(m.time_s));
        obj.insert("power_w".to_string(), Json::Num(m.power_w));
        let body = Json::Obj(obj);
        let t0 = Instant::now();
        match client.post("/v1/report", &body) {
            Ok((202, _)) | Ok((200, _)) => {
                latencies.push(t0.elapsed().as_secs_f64());
                rounds_done += 1;
            }
            Ok(_) | Err(_) => {
                errors += 1;
            }
        }
    }
    Ok((latencies, errors, rounds_done))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_coherent() {
        let cfg = LoadgenConfig::default();
        assert!(cfg.sessions >= 64, "acceptance needs >= 64 sessions");
        assert!(cfg.rounds >= 10_000, "acceptance needs >= 10k round-trips");
        assert_eq!(cfg.apps.len(), 4);
    }

    #[test]
    fn rejects_empty_config() {
        let cfg = LoadgenConfig { sessions: 0, ..Default::default() };
        assert!(run(&cfg).is_err());
    }
}
