//! Closed-loop load generator for a running `lasp serve` instance.
//!
//! Simulates a fleet of edge clients: each session asks the service for a
//! configuration (`/v1/suggest`), runs it on a *local* device simulator
//! ([`JetsonNano`]) at low fidelity, and reports the measurement back
//! (`/v1/report`). Sessions are partitioned across client threads
//! (round-robin). Each thread owns one persistent keep-alive connection —
//! a pool of `threads` connections total — and reuses it for every
//! request, reconnecting only when the server drops it; the report
//! includes connection-reuse stats (requests per connection, reconnects)
//! so regressions in keep-alive behaviour are visible. Request bodies are
//! serialized with [`JsonWriter`] into reusable buffers and responses are
//! read with [`JsonSlice`], so the client side of the loop is as
//! allocation-light as the server side and does not become the
//! bottleneck it is supposed to be measuring.

use super::transport::find_subsequence;
use crate::apps::{self, AppKind, AppModel};
use crate::device::{Device, JetsonNano, PowerMode};
use crate::obs::{self, EventKind, TraceEvent};
use crate::util::json::{Json, JsonSlice, JsonWriter};
use crate::util::stats;
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address(es): `127.0.0.1:8787`, or a comma-separated list
    /// for a multi-node fleet (`host1:8787,host2:8787`) — client threads
    /// round-robin over the targets, so a two-node fleet-sync deployment
    /// is driven with one loadgen invocation.
    pub addr: String,
    /// Concurrent tuning sessions to maintain.
    pub sessions: usize,
    /// Open-loop held connections (`--connections <n>`): additionally
    /// hold `n` keep-alive connections open for the duration of the run.
    /// They are mostly idle — a single holder thread activates one at a
    /// time, chosen by a Zipf(1) rank distribution so a few connections
    /// are hot and the long tail barely speaks, which is what a reactor
    /// transport has to be good at. The report carries held-connection
    /// latency quantiles and connect failures. `0` disables the mode.
    pub connections: usize,
    /// Total suggest+report round-trips across all sessions.
    pub rounds: usize,
    /// Client threads (each owns `sessions / threads` sessions and one
    /// persistent keep-alive connection).
    pub threads: usize,
    /// Applications to spread sessions over.
    pub apps: Vec<AppKind>,
    /// Objective weights sent with every request.
    pub alpha: f64,
    pub beta: f64,
    /// Device-simulator fidelity and seed.
    pub fidelity: f64,
    pub seed: u64,
    /// Socket read timeout, seconds (`--timeout-secs`). Long sweeps
    /// against a checkpoint-heavy server want more than the default.
    pub timeout_secs: u64,
    /// Entries per request (`--batch <n>`). With `batch > 1` each thread
    /// drives its sessions through `/v1/suggest/batch` and
    /// `/v1/report/batch`, carrying up to `n` sessions per HTTP
    /// round-trip; `1` keeps the classic single-entry endpoints.
    pub batch: usize,
    /// Capture the observed `(app, mode, arm, time, power)` stream to a
    /// `LASPTRC1` trace file (`lasp loadgen --record`); replayable via
    /// `lasp simulate` with `trace = "<path>"`.
    pub record: Option<PathBuf>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8787".to_string(),
            sessions: 128,
            connections: 0,
            rounds: 12_000,
            threads: 8,
            apps: AppKind::all().to_vec(),
            alpha: 0.8,
            beta: 0.2,
            fidelity: 0.15,
            seed: 42,
            timeout_secs: 30,
            batch: 1,
            record: None,
        }
    }
}

/// Aggregated load-generation results.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Completed suggest+report round-trips.
    pub rounds: usize,
    pub sessions: usize,
    /// Requests that failed (after one reconnect attempt) or returned an
    /// unexpected status.
    pub errors: usize,
    pub elapsed_s: f64,
    /// Round-trips (suggest+report pairs) per second.
    pub round_trips_per_s: f64,
    /// Per-HTTP-request latency quantiles, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Keep-alive pool stats: connections opened (threads + reconnects),
    /// reconnects forced by the server, HTTP requests sent.
    pub connections: usize,
    pub reconnects: usize,
    pub requests: usize,
    /// Initial connects that only succeeded on the backoff retry
    /// (transient refusals while the server was still binding).
    pub connect_retries: usize,
    /// Distinct server addresses the load was spread over.
    pub targets: usize,
    /// Open-loop held connections actually established (`--connections`).
    pub held_connections: usize,
    /// Held-connection dials that failed outright.
    pub connect_failures: usize,
    /// Latency quantiles over held-connection activations, milliseconds.
    pub per_conn_p50_ms: f64,
    pub per_conn_p99_ms: f64,
}

impl LoadgenReport {
    /// Mean HTTP requests served per TCP connection (the keep-alive
    /// reuse factor; ~2x rounds/threads when reuse is healthy).
    pub fn requests_per_connection(&self) -> f64 {
        if self.connections == 0 {
            0.0
        } else {
            self.requests as f64 / self.connections as f64
        }
    }

    /// Print the human-readable summary the CLI shows.
    pub fn print(&self) {
        println!(
            "loadgen: {} round-trips over {} sessions across {} target(s) in {:.2}s ({} errors)",
            self.rounds, self.sessions, self.targets, self.elapsed_s, self.errors
        );
        println!(
            "throughput: {:.0} round-trips/s ({:.0} req/s) | latency p50 {:.2}ms p99 {:.2}ms mean {:.2}ms",
            self.round_trips_per_s,
            self.round_trips_per_s * 2.0,
            self.p50_ms,
            self.p99_ms,
            self.mean_ms
        );
        println!(
            "connections: {} ({} reconnects, {} connect retries) | {:.0} requests/connection",
            self.connections,
            self.reconnects,
            self.connect_retries,
            self.requests_per_connection()
        );
        if self.held_connections > 0 || self.connect_failures > 0 {
            println!(
                "held connections: {} open ({} connect failures) | activation p50 {:.2}ms p99 {:.2}ms",
                self.held_connections,
                self.connect_failures,
                self.per_conn_p50_ms,
                self.per_conn_p99_ms
            );
        }
    }
}

/// A tiny keep-alive HTTP/1.1 client (shared with the integration tests
/// and benches). All buffers are connection-lifetime and reused: the
/// request frame, the response accumulation buffer, and the parsed body
/// span all live in the client, so a steady request loop does not
/// allocate.
pub struct HttpClient {
    addr: String,
    timeout: Duration,
    stream: TcpStream,
    /// Response accumulation buffer (reused; grows to high-water mark).
    rbuf: Vec<u8>,
    rfilled: usize,
    /// Last response body span inside `rbuf` (valid until the next call).
    body_span: (usize, usize),
    /// Request frame scratch (head + body, one write syscall).
    frame: Vec<u8>,
    requests: u64,
    reconnects: u64,
}

impl HttpClient {
    pub fn connect(addr: &str) -> Result<HttpClient> {
        Self::connect_with_timeout(addr, Duration::from_secs(30))
    }

    /// Connect with an explicit socket read timeout (`--timeout-secs`).
    pub fn connect_with_timeout(addr: &str, timeout: Duration) -> Result<HttpClient> {
        let stream = Self::dial(addr, timeout)?;
        Ok(HttpClient {
            addr: addr.to_string(),
            timeout,
            stream,
            rbuf: vec![0u8; 4096],
            rfilled: 0,
            body_span: (0, 0),
            frame: Vec::with_capacity(1024),
            requests: 0,
            reconnects: 0,
        })
    }

    fn dial(addr: &str, timeout: Duration) -> Result<TcpStream> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(timeout)).ok();
        Ok(stream)
    }

    /// HTTP requests sent on this client (across reconnects).
    pub fn requests(&self) -> u64 {
        self.requests
    }

    /// Times the connection had to be re-established.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// The body of the last response (valid until the next request).
    pub fn last_body(&self) -> &[u8] {
        &self.rbuf[self.body_span.0..self.body_span.1]
    }

    /// POST raw bytes; returns the status. Reconnects once on a broken
    /// connection. The hot path of the load generator.
    pub fn post_slice(&mut self, path: &str, body: &[u8]) -> Result<u16> {
        match self.roundtrip("POST", path, body) {
            Ok(s) => Ok(s),
            Err(_) => {
                self.stream = Self::dial(&self.addr, self.timeout)?;
                self.reconnects += 1;
                self.roundtrip("POST", path, body)
            }
        }
    }

    /// GET a path (with query string); returns the status. Reconnects
    /// once on failure.
    pub fn get_slice(&mut self, path_and_query: &str) -> Result<u16> {
        match self.roundtrip("GET", path_and_query, b"") {
            Ok(s) => Ok(s),
            Err(_) => {
                self.stream = Self::dial(&self.addr, self.timeout)?;
                self.reconnects += 1;
                self.roundtrip("GET", path_and_query, b"")
            }
        }
    }

    /// POST a JSON tree body (test/compat surface; allocates).
    pub fn post(&mut self, path: &str, body: &Json) -> Result<(u16, Json)> {
        let payload = body.to_string();
        let status = self.post_slice(path, payload.as_bytes())?;
        Ok((status, self.parse_body()))
    }

    /// GET returning a parsed JSON tree (test/compat surface; allocates).
    pub fn get(&mut self, path_and_query: &str) -> Result<(u16, Json)> {
        let status = self.get_slice(path_and_query)?;
        Ok((status, self.parse_body()))
    }

    fn parse_body(&self) -> Json {
        let text = String::from_utf8_lossy(self.last_body());
        // Non-JSON bodies (e.g. the Prometheus text of /metrics) come
        // back as a raw string value.
        if text.trim().is_empty() {
            Json::Null
        } else {
            Json::parse(&text).unwrap_or_else(|_| Json::Str(text.into_owned()))
        }
    }

    fn roundtrip(&mut self, method: &str, target: &str, body: &[u8]) -> Result<u16> {
        // One frame, one write.
        self.frame.clear();
        let _ = write!(
            self.frame,
            "{method} {target} HTTP/1.1\r\nHost: lasp\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        self.frame.extend_from_slice(body);
        self.stream.write_all(&self.frame).context("writing request")?;
        self.requests += 1;

        // Accumulate the response into the reused buffer. The previous
        // response is dead by contract, so start from scratch.
        self.rfilled = 0;
        loop {
            if let Some(hdr_end) = find_subsequence(&self.rbuf[..self.rfilled], b"\r\n\r\n") {
                let head = std::str::from_utf8(&self.rbuf[..hdr_end])
                    .map_err(|_| anyhow!("non-UTF-8 response head"))?;
                let mut lines = head.split("\r\n");
                let status: u16 = lines
                    .next()
                    .and_then(|l| l.split_whitespace().nth(1))
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| anyhow!("bad status line"))?;
                let mut content_length = 0usize;
                for line in lines {
                    if let Some((name, value)) = line.split_once(':') {
                        if name.trim().eq_ignore_ascii_case("content-length") {
                            content_length = value.trim().parse().unwrap_or(0);
                        }
                    }
                }
                let body_start = hdr_end + 4;
                let total = body_start + content_length;
                while self.rfilled < total {
                    self.fill()?;
                }
                self.body_span = (body_start, total);
                return Ok(status);
            }
            self.fill()?;
        }
    }

    fn fill(&mut self) -> Result<()> {
        if self.rfilled == self.rbuf.len() {
            let new_len = self.rbuf.len() * 2;
            self.rbuf.resize(new_len, 0);
        }
        let n = self
            .stream
            .read(&mut self.rbuf[self.rfilled..])
            .context("reading response")?;
        if n == 0 {
            return Err(anyhow!("connection closed"));
        }
        self.rfilled += n;
        Ok(())
    }
}

/// One simulated edge-client session.
struct ClientSession {
    client_id: String,
    app_index: usize,
    kind: AppKind,
    mode: PowerMode,
    device: JetsonNano,
}

/// Serialize a suggest/report body into `buf` (cleared first). The
/// measurement fields are appended only when `Some`.
fn write_body(
    buf: &mut Vec<u8>,
    cfg: &LoadgenConfig,
    s: &ClientSession,
    measurement: Option<(usize, f64, f64)>,
) {
    buf.clear();
    let mut w = JsonWriter::new(buf);
    w.begin_obj();
    w.field_str("client_id", &s.client_id);
    w.field_str("app", s.kind.name());
    w.field_str("device", s.mode.lower_name());
    w.field_num("alpha", cfg.alpha);
    w.field_num("beta", cfg.beta);
    if let Some((arm, time_s, power_w)) = measurement {
        w.field_num("arm", arm as f64);
        w.field_num("time_s", time_s);
        w.field_num("power_w", power_w);
    }
    w.end_obj();
}

/// Serialize a `{"entries": [...]}` batch body into `buf` (cleared
/// first). Entry `j` describes session `(cursor + j) % sessions.len()`;
/// when `measurements` is `Some` each entry carries its measurement
/// triple (report batch), otherwise the entries are suggest-shaped.
fn write_batch_body(
    buf: &mut Vec<u8>,
    cfg: &LoadgenConfig,
    sessions: &[ClientSession],
    cursor: usize,
    n: usize,
    measurements: Option<&[(usize, f64, f64)]>,
) {
    buf.clear();
    let mut w = JsonWriter::new(buf);
    w.begin_obj();
    w.key("entries");
    w.begin_arr();
    for j in 0..n {
        let s = &sessions[(cursor + j) % sessions.len()];
        w.begin_obj();
        w.field_str("client_id", &s.client_id);
        w.field_str("app", s.kind.name());
        w.field_str("device", s.mode.lower_name());
        w.field_num("alpha", cfg.alpha);
        w.field_num("beta", cfg.beta);
        if let Some(ms) = measurements {
            let (arm, time_s, power_w) = ms[j];
            w.field_num("arm", arm as f64);
            w.field_num("time_s", time_s);
            w.field_num("power_w", power_w);
        }
        w.end_obj();
    }
    w.end_arr();
    w.end_obj();
}

impl LoadgenConfig {
    /// The target address list (see [`LoadgenConfig::addr`]).
    pub fn targets(&self) -> Vec<String> {
        self.addr
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    }
}

/// Drive the configured load and aggregate the per-thread results.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.sessions == 0 || cfg.rounds == 0 || cfg.threads == 0 || cfg.apps.is_empty() {
        return Err(anyhow!("loadgen: sessions/rounds/threads/apps must be non-empty"));
    }
    if cfg.batch == 0 || cfg.batch > super::service::MAX_BATCH_ENTRIES {
        return Err(anyhow!(
            "loadgen: --batch must be in 1..={} (got {})",
            super::service::MAX_BATCH_ENTRIES,
            cfg.batch
        ));
    }
    let targets = cfg.targets();
    if targets.is_empty() {
        return Err(anyhow!("loadgen: no target address"));
    }
    let t0 = Instant::now();
    let threads = cfg.threads.min(cfg.sessions);
    // Threads map onto targets round-robin; fewer threads than targets
    // would silently leave trailing nodes with zero traffic while the
    // report claims fleet-wide coverage.
    if threads < targets.len() {
        return Err(anyhow!(
            "loadgen: {threads} client thread(s) cannot cover {} targets; raise --threads/--sessions",
            targets.len()
        ));
    }
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        let cfg = cfg.clone();
        // Threads round-robin over the target nodes; each session stays
        // pinned to one node (its owning thread's target) so per-node
        // session state remains coherent.
        let target = targets[t % targets.len()].clone();
        // Rounds split evenly; the first threads absorb the remainder.
        let my_rounds = cfg.rounds / threads + usize::from(t < cfg.rounds % threads);
        handles
            .push(std::thread::spawn(move || worker(t, threads, my_rounds, &cfg, &target, t0)));
    }
    // Open-loop holder: runs alongside the closed loop and stops when the
    // workers have drained their rounds.
    let stop = Arc::new(AtomicBool::new(false));
    let holder = (cfg.connections > 0).then(|| {
        let cfg = cfg.clone();
        let targets = targets.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || hold_connections(&cfg, &targets, &stop))
    });

    let mut latencies: Vec<f64> = Vec::with_capacity(cfg.rounds * 2);
    let mut errors = 0usize;
    let mut rounds_done = 0usize;
    let mut reconnects = 0usize;
    let mut requests = 0usize;
    let mut connect_retries = 0usize;
    // Per-worker capture streams, concatenated in thread order (joins
    // follow spawn order) so a given (sessions, threads, seed) config
    // yields a stable event layout.
    let mut records: Vec<TraceEvent> = Vec::new();
    for h in handles {
        let w = h.join().map_err(|_| anyhow!("loadgen worker panicked"))??;
        latencies.extend(w.latencies);
        errors += w.errors;
        rounds_done += w.rounds;
        reconnects += w.reconnects;
        requests += w.requests;
        connect_retries += w.connect_retries;
        records.extend(w.records);
    }
    stop.store(true, Ordering::Relaxed);
    let (held_connections, connect_failures, held_latencies) = match holder {
        Some(h) => {
            let out = h.join().map_err(|_| anyhow!("loadgen holder panicked"))?;
            (out.held, out.connect_failures, out.latencies)
        }
        None => (0, 0, Vec::new()),
    };
    if let Some(path) = &cfg.record {
        for (i, ev) in records.iter_mut().enumerate() {
            ev.seq = i as u64;
        }
        obs::write_trace_file(path, &records)?;
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(LoadgenReport {
        rounds: rounds_done,
        sessions: cfg.sessions,
        errors,
        elapsed_s: elapsed,
        round_trips_per_s: rounds_done as f64 / elapsed,
        p50_ms: stats::quantile(&latencies, 0.5) * 1e3,
        p99_ms: stats::quantile(&latencies, 0.99) * 1e3,
        mean_ms: stats::mean(&latencies) * 1e3,
        connections: threads + reconnects,
        reconnects,
        requests,
        connect_retries,
        targets: targets.len(),
        held_connections,
        connect_failures,
        per_conn_p50_ms: stats::quantile(&held_latencies, 0.5) * 1e3,
        per_conn_p99_ms: stats::quantile(&held_latencies, 0.99) * 1e3,
    })
}

/// Results from the open-loop connection holder.
struct HolderOut {
    /// Connections still alive when the run ended.
    held: usize,
    /// Dials that failed plus held connections the server dropped.
    connect_failures: usize,
    /// Seconds per activation round-trip.
    latencies: Vec<f64>,
}

/// Hold `cfg.connections` keep-alive connections open until `stop`
/// flips, activating one at a time by a Zipf(1) rank draw. Activations
/// are plain `GET /healthz` round-trips, so the quantiles measure how
/// quickly the transport wakes a long-idle connection while the closed
/// loop saturates it — not tuner work.
fn hold_connections(cfg: &LoadgenConfig, targets: &[String], stop: &AtomicBool) -> HolderOut {
    let timeout = Duration::from_secs(cfg.timeout_secs);
    let mut conns: Vec<TcpStream> = Vec::with_capacity(cfg.connections);
    let mut connect_failures = 0usize;
    for i in 0..cfg.connections {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match TcpStream::connect(targets[i % targets.len()].as_str()) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                s.set_read_timeout(Some(timeout)).ok();
                conns.push(s);
            }
            Err(_) => connect_failures += 1,
        }
    }
    // Zipf(1) cumulative weights over connection ranks: rank r is drawn
    // with weight 1/(r+1), so a handful of connections are hot and the
    // long tail sits idle — the access pattern a reactor must multiplex.
    let mut cdf: Vec<f64> = Vec::with_capacity(conns.len());
    let mut total = 0.0f64;
    for r in 0..conns.len() {
        total += 1.0 / (r + 1) as f64;
        cdf.push(total);
    }
    let mut rng = cfg.seed | 1; // xorshift64 state; must be non-zero
    let mut latencies: Vec<f64> = Vec::new();
    let mut rbuf = vec![0u8; 4096];
    while !stop.load(Ordering::Relaxed) && !conns.is_empty() {
        rng ^= rng << 13;
        rng ^= rng >> 7;
        rng ^= rng << 17;
        let u = (rng >> 11) as f64 / (1u64 << 53) as f64 * total;
        let idx = cdf.partition_point(|&c| c < u).min(conns.len() - 1);
        let t0 = Instant::now();
        match holder_roundtrip(&mut conns[idx], &mut rbuf) {
            Ok(()) => latencies.push(t0.elapsed().as_secs_f64()),
            Err(_) => {
                // A held connection the server dropped is a transport
                // regression signal: count it and stop exercising it. The
                // popped cdf entry keeps weights 1/(r+1) for the rest.
                connect_failures += 1;
                conns.swap_remove(idx);
                cdf.pop();
                total = cdf.last().copied().unwrap_or(0.0);
            }
        }
        // Mostly idle: ~100 activations/s across the whole held pool.
        std::thread::sleep(Duration::from_millis(10));
    }
    HolderOut { held: conns.len(), connect_failures, latencies }
}

/// One `GET /healthz` round-trip on a held connection, draining the full
/// response so the next activation starts on a clean stream.
fn holder_roundtrip(stream: &mut TcpStream, rbuf: &mut Vec<u8>) -> Result<()> {
    stream
        .write_all(b"GET /healthz HTTP/1.1\r\nHost: lasp\r\n\r\n")
        .context("held-connection write")?;
    let mut filled = 0usize;
    loop {
        if let Some(hdr_end) = find_subsequence(&rbuf[..filled], b"\r\n\r\n") {
            let head = std::str::from_utf8(&rbuf[..hdr_end])
                .map_err(|_| anyhow!("non-UTF-8 response head"))?;
            let mut content_length = 0usize;
            for line in head.split("\r\n").skip(1) {
                if let Some((name, value)) = line.split_once(':') {
                    if name.trim().eq_ignore_ascii_case("content-length") {
                        content_length = value.trim().parse().unwrap_or(0);
                    }
                }
            }
            let total = hdr_end + 4 + content_length;
            while filled < total {
                filled += fill_some(stream, rbuf, filled)?;
            }
            return Ok(());
        }
        filled += fill_some(stream, rbuf, filled)?;
    }
}

/// Read at least one byte into `rbuf[filled..]`, growing the buffer when
/// it is full; EOF is an error (held connections must stay open).
fn fill_some(stream: &mut TcpStream, rbuf: &mut Vec<u8>, filled: usize) -> Result<usize> {
    if filled == rbuf.len() {
        let doubled = rbuf.len() * 2;
        rbuf.resize(doubled, 0);
    }
    let n = stream.read(&mut rbuf[filled..]).context("held-connection read")?;
    if n == 0 {
        return Err(anyhow!("held connection closed by server"));
    }
    Ok(n)
}

/// Per-thread results.
struct WorkerOut {
    latencies: Vec<f64>,
    errors: usize,
    rounds: usize,
    reconnects: usize,
    requests: usize,
    /// 1 when the initial connect only succeeded on the backoff retry.
    connect_retries: usize,
    /// Captured `Measure` events when `--record` is active (seq numbers
    /// assigned by the aggregator).
    records: Vec<TraceEvent>,
}

fn worker(
    thread_id: usize,
    threads: usize,
    my_rounds: usize,
    cfg: &LoadgenConfig,
    target: &str,
    epoch: Instant,
) -> Result<WorkerOut> {
    // This thread owns sessions thread_id, thread_id+threads, ...
    let mut sessions: Vec<ClientSession> = (0..cfg.sessions)
        .skip(thread_id)
        .step_by(threads)
        .map(|s| {
            let app_index = s % cfg.apps.len();
            let mode = if s % 2 == 0 { PowerMode::Maxn } else { PowerMode::FiveW };
            ClientSession {
                client_id: format!("lg-{s}"),
                app_index,
                kind: cfg.apps[app_index],
                mode,
                device: JetsonNano::new(mode, cfg.seed.wrapping_add(s as u64))
                    .with_fidelity(cfg.fidelity),
            }
        })
        .collect();
    if sessions.is_empty() {
        return Ok(WorkerOut {
            latencies: vec![],
            errors: 0,
            rounds: 0,
            reconnects: 0,
            requests: 0,
            connect_retries: 0,
            records: vec![],
        });
    }
    let models: Vec<Box<dyn AppModel>> = cfg.apps.iter().map(|&k| apps::build(k)).collect();
    // One backoff retry on the initial connect: loadgen regularly races
    // the server's bind (CI scripts start both back to back), and a
    // single transient refusal should not abort a whole worker's rounds.
    let timeout = Duration::from_secs(cfg.timeout_secs);
    let (mut client, connect_retries) = match HttpClient::connect_with_timeout(target, timeout) {
        Ok(c) => (c, 0usize),
        Err(_) => {
            std::thread::sleep(Duration::from_millis(100 + 50 * thread_id as u64));
            (HttpClient::connect_with_timeout(target, timeout)?, 1)
        }
    };
    let mut latencies = Vec::with_capacity(my_rounds * 2);
    let mut body = Vec::with_capacity(512);
    let mut errors = 0usize;
    let mut rounds_done = 0usize;
    let mut records: Vec<TraceEvent> =
        Vec::with_capacity(if cfg.record.is_some() { my_rounds } else { 0 });

    if cfg.batch > 1 {
        // Batched closed loop: up to `batch` sessions advance one round
        // per suggest/report *pair* of HTTP requests. Buffers (body,
        // arms, measurements) are reused across iterations so the client
        // stays allocation-light like the single-entry path.
        let mut arms: Vec<usize> = Vec::with_capacity(cfg.batch);
        let mut measurements: Vec<(usize, f64, f64)> = Vec::with_capacity(cfg.batch);
        let mut cursor = 0usize;
        let mut attempted = 0usize;
        while attempted < my_rounds {
            let n = cfg.batch.min(sessions.len()).min(my_rounds - attempted);
            attempted += n;
            let base = cursor;
            cursor = (cursor + n) % sessions.len();

            // Batched suggest.
            write_batch_body(&mut body, cfg, &sessions, base, n, None);
            let t0 = Instant::now();
            let status = match client.post_slice("/v1/suggest/batch", &body) {
                Ok(st) => st,
                Err(_) => {
                    errors += 1;
                    continue;
                }
            };
            latencies.push(t0.elapsed().as_secs_f64());
            if status != 200 {
                errors += 1;
                continue;
            }
            arms.clear();
            let parsed = (|| -> Option<()> {
                let v = JsonSlice::parse(client.last_body()).ok()?;
                for item in v.get("results")?.items() {
                    arms.push(item.get("arm")?.as_usize()?);
                }
                (arms.len() == n).then_some(())
            })();
            if parsed.is_none() {
                errors += 1;
                continue;
            }

            // Evaluate every entry locally on its simulated device.
            measurements.clear();
            for (j, &arm) in arms.iter().enumerate() {
                let idx = (base + j) % sessions.len();
                let s = &mut sessions[idx];
                let workload = models[s.app_index].workload(arm, cfg.fidelity);
                let m = s.device.run(&workload);
                if cfg.record.is_some() {
                    let (a, b, c) =
                        obs::pack_measure(s.kind, s.mode, arm as u32, m.time_s, m.power_w);
                    records.push(TraceEvent {
                        seq: 0,
                        t_us: epoch.elapsed().as_micros() as u64,
                        kind: EventKind::Measure.code(),
                        a,
                        b,
                        c,
                    });
                }
                measurements.push((arm, m.time_s, m.power_w));
            }

            // Batched report.
            write_batch_body(&mut body, cfg, &sessions, base, n, Some(&measurements));
            let t0 = Instant::now();
            match client.post_slice("/v1/report/batch", &body) {
                Ok(202) | Ok(200) => {
                    latencies.push(t0.elapsed().as_secs_f64());
                    rounds_done += n;
                }
                Ok(_) | Err(_) => {
                    errors += 1;
                }
            }
        }
        return Ok(WorkerOut {
            latencies,
            errors,
            rounds: rounds_done,
            reconnects: client.reconnects() as usize,
            requests: client.requests() as usize,
            connect_retries,
            records,
        });
    }

    for round in 0..my_rounds {
        let idx = round % sessions.len();
        let s = &mut sessions[idx];

        // Suggest.
        write_body(&mut body, cfg, s, None);
        let t0 = Instant::now();
        let status = match client.post_slice("/v1/suggest", &body) {
            Ok(st) => st,
            Err(_) => {
                errors += 1;
                continue;
            }
        };
        latencies.push(t0.elapsed().as_secs_f64());
        if status != 200 {
            errors += 1;
            continue;
        }
        let arm = match JsonSlice::parse(client.last_body())
            .ok()
            .and_then(|v| v.get("arm"))
            .and_then(|v| v.as_usize())
        {
            Some(a) => a,
            None => {
                errors += 1;
                continue;
            }
        };

        // Evaluate locally on the simulated device.
        let workload = models[s.app_index].workload(arm, cfg.fidelity);
        let m = s.device.run(&workload);
        if cfg.record.is_some() {
            let (a, b, c) = obs::pack_measure(s.kind, s.mode, arm as u32, m.time_s, m.power_w);
            records.push(TraceEvent {
                seq: 0,
                t_us: epoch.elapsed().as_micros() as u64,
                kind: EventKind::Measure.code(),
                a,
                b,
                c,
            });
        }

        // Report.
        write_body(&mut body, cfg, s, Some((arm, m.time_s, m.power_w)));
        let t0 = Instant::now();
        match client.post_slice("/v1/report", &body) {
            Ok(202) | Ok(200) => {
                latencies.push(t0.elapsed().as_secs_f64());
                rounds_done += 1;
            }
            Ok(_) | Err(_) => {
                errors += 1;
            }
        }
    }
    Ok(WorkerOut {
        latencies,
        errors,
        rounds: rounds_done,
        reconnects: client.reconnects() as usize,
        requests: client.requests() as usize,
        connect_retries,
        records,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_coherent() {
        let cfg = LoadgenConfig::default();
        assert!(cfg.sessions >= 64, "acceptance needs >= 64 sessions");
        assert!(cfg.rounds >= 10_000, "acceptance needs >= 10k round-trips");
        assert_eq!(cfg.apps.len(), 4);
        assert_eq!(cfg.timeout_secs, 30, "historical read-timeout default");
        assert_eq!(cfg.batch, 1, "single-entry endpoints are the default");
        assert_eq!(cfg.connections, 0, "open-loop holder is opt-in");
    }

    #[test]
    fn rejects_bad_batch_sizes() {
        let cfg = LoadgenConfig { batch: 0, ..Default::default() };
        assert!(run(&cfg).is_err(), "batch 0 must be rejected");
        let cfg = LoadgenConfig { batch: 10_000, ..Default::default() };
        assert!(run(&cfg).is_err(), "batch beyond the server cap must be rejected");
    }

    #[test]
    fn batch_body_shape_matches_endpoints() {
        let cfg = LoadgenConfig::default();
        let sessions: Vec<ClientSession> = (0..2)
            .map(|s| ClientSession {
                client_id: format!("lg-{s}"),
                app_index: 0,
                kind: cfg.apps[0],
                mode: PowerMode::Maxn,
                device: JetsonNano::new(PowerMode::Maxn, s as u64),
            })
            .collect();
        let mut buf = Vec::new();
        write_batch_body(&mut buf, &cfg, &sessions, 0, 2, None);
        let v = JsonSlice::parse(&buf).expect("suggest batch body parses");
        let entries: Vec<_> = v.get("entries").expect("entries").items().collect();
        assert_eq!(entries.len(), 2);
        assert!(entries[0].get("arm").is_none(), "suggest entries carry no measurement");
        assert_eq!(entries[1].get("client_id").unwrap().as_str().unwrap(), "lg-1");

        write_batch_body(&mut buf, &cfg, &sessions, 1, 2, Some(&[(3, 0.5, 4.0), (7, 0.25, 2.0)]));
        let v = JsonSlice::parse(&buf).expect("report batch body parses");
        let entries: Vec<_> = v.get("entries").unwrap().items().collect();
        assert_eq!(entries.len(), 2);
        // cursor=1 wraps: first entry is session lg-1.
        assert_eq!(entries[0].get("client_id").unwrap().as_str().unwrap(), "lg-1");
        assert_eq!(entries[0].get("arm").unwrap().as_usize().unwrap(), 3);
        assert_eq!(entries[1].get("power_w").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn rejects_empty_config() {
        let cfg = LoadgenConfig { sessions: 0, ..Default::default() };
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn report_reuse_factor() {
        let r = LoadgenReport {
            rounds: 100,
            sessions: 8,
            errors: 0,
            elapsed_s: 1.0,
            round_trips_per_s: 100.0,
            p50_ms: 1.0,
            p99_ms: 2.0,
            mean_ms: 1.2,
            connections: 4,
            reconnects: 0,
            requests: 200,
            connect_retries: 0,
            targets: 1,
            held_connections: 0,
            connect_failures: 0,
            per_conn_p50_ms: 0.0,
            per_conn_p99_ms: 0.0,
        };
        assert!((r.requests_per_connection() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn addr_lists_split_into_targets() {
        let cfg = LoadgenConfig {
            addr: "127.0.0.1:8787, 127.0.0.1:8788 ,".to_string(),
            ..Default::default()
        };
        assert_eq!(cfg.targets(), vec!["127.0.0.1:8787", "127.0.0.1:8788"]);
        let cfg = LoadgenConfig { addr: " , ".to_string(), ..Default::default() };
        assert!(run(&cfg).is_err(), "empty target list must be rejected");
        // Fewer threads than targets would leave nodes untouched while
        // the report claimed coverage: refuse up front.
        let cfg = LoadgenConfig {
            addr: "h1:1,h2:1".to_string(),
            threads: 1,
            ..Default::default()
        };
        assert!(run(&cfg).is_err(), "threads < targets must be rejected");
    }
}
