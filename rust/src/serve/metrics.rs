//! Service metrics: lock-free counters and fixed-bucket latency
//! histograms, rendered in Prometheus text exposition format for
//! `GET /metrics`. The paper's Fig 10 argument — the tuner itself must be
//! lightweight — carries over to the service: observing a latency is two
//! relaxed atomic adds, nothing allocates on the hot path.

use super::transport::TransportStats;
use crate::telemetry::ResourceReport;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Histogram bucket upper bounds in microseconds (plus a +Inf bucket).
pub const LATENCY_BOUNDS_US: [u64; 12] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000,
];

/// A fixed-bucket latency histogram with atomic counters.
pub struct Histogram {
    /// One counter per bound, plus the +Inf bucket at the end.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..=LATENCY_BOUNDS_US.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one observation.
    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let idx = LATENCY_BOUNDS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(LATENCY_BOUNDS_US.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate `q`-quantile in microseconds (linear interpolation
    /// inside the winning bucket; the +Inf bucket reports its lower bound).
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, bucket) in self.buckets.iter().enumerate() {
            let n = bucket.load(Ordering::Relaxed);
            if cum + n >= target && n > 0 {
                let lo = if i == 0 { 0 } else { LATENCY_BOUNDS_US[i - 1] };
                let hi = LATENCY_BOUNDS_US.get(i).copied().unwrap_or(lo);
                if hi <= lo {
                    return lo as f64;
                }
                let frac = (target - cum) as f64 / n as f64;
                return lo as f64 + frac * (hi - lo) as f64;
            }
            cum += n;
        }
        *LATENCY_BOUNDS_US.last().unwrap() as f64
    }

    /// Append Prometheus `_bucket`/`_sum`/`_count` lines.
    pub fn render(&self, name: &str, out: &mut String) {
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, bound) in LATENCY_BOUNDS_US.iter().enumerate() {
            cum += self.buckets[i].load(Ordering::Relaxed);
            let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
        }
        cum += self.buckets[LATENCY_BOUNDS_US.len()].load(Ordering::Relaxed);
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum_us {}", self.sum_us.load(Ordering::Relaxed));
        let _ = writeln!(out, "{name}_count {}", self.count());
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Batch-size histogram bucket upper bounds (entries per batch request,
/// plus a +Inf bucket). Powers of two up to the default `max_batch`-sized
/// request cap, so the operator can see at a glance whether clients batch
/// at all and how close they run to the cap.
pub const BATCH_BOUNDS: [u64; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

/// A fixed-bucket histogram over dimensionless integer observations
/// (entries per batch request). Distinct from [`Histogram`] because the
/// exposition differs: a plain `{name}_sum`, not `{name}_sum_us`.
pub struct ValueHistogram {
    /// One counter per bound, plus the +Inf bucket at the end.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl ValueHistogram {
    pub fn new() -> ValueHistogram {
        ValueHistogram {
            buckets: (0..=BATCH_BOUNDS.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one observation.
    pub fn observe(&self, v: u64) {
        let idx = BATCH_BOUNDS
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(BATCH_BOUNDS.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Observations recorded (= batch requests seen).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations (= batch entries seen).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Append Prometheus `_bucket`/`_sum`/`_count` lines.
    pub fn render(&self, name: &str, out: &mut String) {
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, bound) in BATCH_BOUNDS.iter().enumerate() {
            cum += self.buckets[i].load(Ordering::Relaxed);
            let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
        }
        cum += self.buckets[BATCH_BOUNDS.len()].load(Ordering::Relaxed);
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum {}", self.sum());
        let _ = writeln!(out, "{name}_count {}", self.count());
    }
}

impl Default for ValueHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time gauges of the fleet-sync plane, sampled at render time
/// (the counts live in [`super::store::ShardedStore`] and
/// [`super::fleet::FleetStore`], not behind atomics here).
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetGauges {
    /// Remote nodes with a stored push slot.
    pub nodes: usize,
    /// Scenarios with an installed fleet prior.
    pub prior_keys: usize,
    /// Sessions warm-started from a fleet prior since boot.
    pub warm_starts: u64,
}

/// Point-in-time gauges of the flight recorder ([`crate::obs::Recorder`]),
/// sampled at render time.
#[derive(Debug, Clone, Copy, Default)]
pub struct TraceGauges {
    /// Events ever recorded (the next sequence number).
    pub recorded: u64,
    /// Events lost to ring wrap-around.
    pub overwritten: u64,
}

/// Point-in-time gauges of the chaos layer ([`crate::chaos::ChaosLayer`]),
/// sampled at render time. Default = no layer configured.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChaosGauges {
    /// Whether a chaos layer is configured (`--chaos` / `[chaos]`).
    pub enabled: bool,
    /// Faults injected since boot, across every fault point.
    pub injections: u64,
}

/// Degraded-mode states of the fleet-sync client plane, exported as the
/// `lasp_serve_fleet_sync_state` gauge and named in `/v1/trace`.
pub const FLEET_STATE_STANDALONE: u64 = 0;
pub const FLEET_STATE_SYNCING: u64 = 1;
pub const FLEET_STATE_BACKOFF: u64 = 2;

/// Human name for a fleet-sync state gauge value.
pub fn fleet_state_name(state: u64) -> &'static str {
    match state {
        FLEET_STATE_STANDALONE => "standalone",
        FLEET_STATE_SYNCING => "syncing",
        FLEET_STATE_BACKOFF => "backoff",
        _ => "unknown",
    }
}

/// All counters the service exports.
pub struct Metrics {
    started: Instant,
    pub suggest_latency: Histogram,
    pub report_latency: Histogram,
    pub best_latency: Histogram,
    /// Entries per batch request across both `/v1/suggest/batch` and
    /// `/v1/report/batch` — `_count` is batch requests, `_sum` is entries.
    pub batch_size: ValueHistogram,
    /// Fleet-sync server plane and checkpoint-write latencies — without
    /// these, a stalled leader merge or a slow checkpoint disk is
    /// invisible next to the sub-millisecond suggest path.
    pub sync_push_latency: Histogram,
    pub sync_pull_latency: Histogram,
    pub checkpoint_latency: Histogram,
    pub http_requests: AtomicU64,
    pub http_errors: AtomicU64,
    pub suggests: AtomicU64,
    pub reports_enqueued: AtomicU64,
    pub reports_applied: AtomicU64,
    pub reports_rejected: AtomicU64,
    /// Reports shed because a shard queue was full (the client is told —
    /// 503 — and can resend; the count makes the shedding visible).
    pub reports_dropped: AtomicU64,
    /// Duplicate/stale-seq reports absorbed by the idempotency window.
    pub reports_deduped: AtomicU64,
    pub update_batches: AtomicU64,
    pub queue_backpressure: AtomicU64,
    pub sessions_created: AtomicU64,
    pub checkpoints: AtomicU64,
    pub checkpoint_sessions: AtomicU64,
    /// Failed checkpoint file-write *attempts* (retries count each time).
    pub checkpoint_failures: AtomicU64,
    pub sessions_restored: AtomicU64,
    /// Fleet-sync degraded-mode state ([`FLEET_STATE_STANDALONE`] /
    /// [`FLEET_STATE_SYNCING`] / [`FLEET_STATE_BACKOFF`]), written by the
    /// sync thread, exported as a gauge and named in `/v1/trace`.
    pub fleet_state: AtomicU64,
    /// Fleet-sync client plane: completed pushes/pulls and failed cycles
    /// (the [`super::fleet::FleetSync`] thread).
    pub fleet_pushes: AtomicU64,
    pub fleet_pulls: AtomicU64,
    pub fleet_sync_errors: AtomicU64,
    /// Fleet-sync server plane: snapshots absorbed via `/v1/sync/push`
    /// and pulls served via `/v1/sync/pull`.
    pub fleet_push_snapshots: AtomicU64,
    pub fleet_pulls_served: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            suggest_latency: Histogram::new(),
            report_latency: Histogram::new(),
            best_latency: Histogram::new(),
            batch_size: ValueHistogram::new(),
            sync_push_latency: Histogram::new(),
            sync_pull_latency: Histogram::new(),
            checkpoint_latency: Histogram::new(),
            http_requests: AtomicU64::new(0),
            http_errors: AtomicU64::new(0),
            suggests: AtomicU64::new(0),
            reports_enqueued: AtomicU64::new(0),
            reports_applied: AtomicU64::new(0),
            reports_rejected: AtomicU64::new(0),
            reports_dropped: AtomicU64::new(0),
            reports_deduped: AtomicU64::new(0),
            update_batches: AtomicU64::new(0),
            queue_backpressure: AtomicU64::new(0),
            sessions_created: AtomicU64::new(0),
            checkpoints: AtomicU64::new(0),
            checkpoint_sessions: AtomicU64::new(0),
            checkpoint_failures: AtomicU64::new(0),
            sessions_restored: AtomicU64::new(0),
            fleet_state: AtomicU64::new(FLEET_STATE_STANDALONE),
            fleet_pushes: AtomicU64::new(0),
            fleet_pulls: AtomicU64::new(0),
            fleet_sync_errors: AtomicU64::new(0),
            fleet_push_snapshots: AtomicU64::new(0),
            fleet_pulls_served: AtomicU64::new(0),
        }
    }

    /// Seconds since service start.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Render the full `/metrics` page.
    pub fn render(
        &self,
        sessions: usize,
        shards: usize,
        transport: &TransportStats,
        resources: &ResourceReport,
        fleet: FleetGauges,
        trace: TraceGauges,
        chaos: ChaosGauges,
        loop_sessions: &[u64],
    ) -> String {
        let mut out = String::with_capacity(2048);
        let gauge = |out: &mut String, name: &str, v: f64| {
            let _ = writeln!(out, "# TYPE {name} gauge\n{name} {v}");
        };
        // Counters take the value, not the atomic, so monotone counts
        // sampled from non-atomic sources (the fleet gauges, the flight
        // recorder) go through the same exposition path.
        let counter = |out: &mut String, name: &str, v: u64| {
            let _ = writeln!(out, "# TYPE {name} counter\n{name} {v}");
        };
        let load = |v: &AtomicU64| v.load(Ordering::Relaxed);
        gauge(&mut out, "lasp_serve_uptime_seconds", self.uptime_s());
        gauge(&mut out, "lasp_serve_sessions", sessions as f64);
        gauge(&mut out, "lasp_serve_shards", shards as f64);
        counter(&mut out, "lasp_serve_http_requests_total", load(&self.http_requests));
        counter(&mut out, "lasp_serve_http_errors_total", load(&self.http_errors));
        counter(&mut out, "lasp_serve_suggests_total", load(&self.suggests));
        counter(&mut out, "lasp_serve_reports_enqueued_total", load(&self.reports_enqueued));
        counter(&mut out, "lasp_serve_reports_applied_total", load(&self.reports_applied));
        counter(&mut out, "lasp_serve_reports_rejected_total", load(&self.reports_rejected));
        counter(&mut out, "lasp_serve_reports_dropped_total", load(&self.reports_dropped));
        counter(&mut out, "lasp_serve_reports_deduped_total", load(&self.reports_deduped));
        counter(&mut out, "lasp_serve_update_batches_total", load(&self.update_batches));
        counter(&mut out, "lasp_serve_queue_backpressure_total", load(&self.queue_backpressure));
        counter(&mut out, "lasp_serve_sessions_created_total", load(&self.sessions_created));
        counter(&mut out, "lasp_serve_checkpoints_total", load(&self.checkpoints));
        counter(&mut out, "lasp_serve_checkpoint_sessions_total", load(&self.checkpoint_sessions));
        counter(&mut out, "lasp_serve_checkpoint_failures_total", load(&self.checkpoint_failures));
        counter(&mut out, "lasp_serve_sessions_restored_total", load(&self.sessions_restored));
        // Fleet-sync plane: client-side cycles, server-side absorption,
        // and the warm-start payoff (sessions that skipped cold start).
        counter(&mut out, "lasp_serve_fleet_pushes_total", load(&self.fleet_pushes));
        counter(&mut out, "lasp_serve_fleet_pulls_total", load(&self.fleet_pulls));
        counter(&mut out, "lasp_serve_fleet_sync_errors_total", load(&self.fleet_sync_errors));
        counter(&mut out, "lasp_serve_fleet_push_snapshots_total", load(&self.fleet_push_snapshots));
        counter(&mut out, "lasp_serve_fleet_pulls_served_total", load(&self.fleet_pulls_served));
        gauge(&mut out, "lasp_serve_fleet_nodes", fleet.nodes as f64);
        gauge(&mut out, "lasp_serve_fleet_prior_keys", fleet.prior_keys as f64);
        counter(&mut out, "lasp_serve_fleet_warm_starts_total", fleet.warm_starts);
        // Degraded-mode state machine (0 standalone / 1 syncing /
        // 2 backoff): an operator can alert on `== 2` without scraping
        // error-rate deltas.
        gauge(&mut out, "lasp_serve_fleet_sync_state", load(&self.fleet_state) as f64);
        // Chaos plane: whether a fault layer is armed and how much it has
        // actually broken so far.
        gauge(&mut out, "lasp_serve_chaos_enabled", if chaos.enabled { 1.0 } else { 0.0 });
        counter(&mut out, "lasp_serve_chaos_injections_total", chaos.injections);
        // Flight-recorder plane: total events and ring overwrites (loss
        // under overload is visible, never silent).
        counter(&mut out, "lasp_serve_trace_events_total", trace.recorded);
        counter(&mut out, "lasp_serve_trace_overwritten_total", trace.overwritten);
        // Transport plane: the zero-allocation contract is observable —
        // `alloc_events_total` flat under load means the HTTP+JSON layers
        // are not heap-allocating per request.
        counter(&mut out, "lasp_serve_transport_connections_total", load(&transport.connections));
        counter(&mut out, "lasp_serve_transport_requests_total", load(&transport.requests));
        counter(&mut out, "lasp_serve_transport_alloc_events_total", load(&transport.alloc_events));
        counter(&mut out, "lasp_serve_transport_rejected_431_total", load(&transport.rejected_431));
        // Reactor plane: event-loop sizing, wakeup volume (epoll_wait
        // returns), open-connection gauge, and how often a response had to
        // park on writability because the client's socket buffer was full.
        gauge(&mut out, "lasp_serve_event_loops", load(&transport.event_loops) as f64);
        counter(&mut out, "lasp_serve_epoll_wakeups_total", load(&transport.wakeups));
        gauge(&mut out, "lasp_serve_conns_open", load(&transport.conns_open) as f64);
        counter(&mut out, "lasp_serve_write_backpressure_total", load(&transport.write_backpressure));
        // Routed (shared-nothing) plane: keyed requests re-homed to their
        // owning event loop, and per-connection key-cache hits that
        // skipped the hash+intern on the hot path.
        counter(&mut out, "lasp_serve_forwarded_requests_total", load(&transport.forwarded));
        counter(&mut out, "lasp_serve_key_cache_hits_total", load(&transport.key_cache_hits));
        // Per-loop session ownership (routed plane only — empty slice on
        // the shared plane). One TYPE line, one labeled sample per loop:
        // a skewed distribution here explains a skewed per-loop load.
        if !loop_sessions.is_empty() {
            let _ = writeln!(out, "# TYPE lasp_serve_loop_owned_sessions gauge");
            for (l, n) in loop_sessions.iter().enumerate() {
                let _ = writeln!(out, "lasp_serve_loop_owned_sessions{{loop=\"{l}\"}} {n}");
            }
        }
        self.batch_size.render("lasp_serve_batch_size", &mut out);
        self.suggest_latency.render("lasp_serve_suggest_latency_us", &mut out);
        self.report_latency.render("lasp_serve_report_latency_us", &mut out);
        self.best_latency.render("lasp_serve_best_latency_us", &mut out);
        self.sync_push_latency.render("lasp_serve_sync_push_latency_us", &mut out);
        self.sync_pull_latency.render("lasp_serve_sync_pull_latency_us", &mut out);
        self.checkpoint_latency.render("lasp_serve_checkpoint_latency_us", &mut out);
        resources.render_prometheus("lasp_serve_process", &mut out);
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_quantiles() {
        let h = Histogram::new();
        for us in [40u64, 80, 80, 200, 600, 2_000, 400_000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        assert!(h.mean_us() > 0.0);
        let p50 = h.quantile_us(0.5);
        assert!(p50 >= 50.0 && p50 <= 250.0, "p50 {p50}");
        let p99 = h.quantile_us(0.99);
        assert!(p99 >= 250_000.0, "p99 {p99}");
        assert!(h.quantile_us(0.0) >= 0.0);
    }

    #[test]
    fn value_histogram_buckets_and_overflow() {
        let h = ValueHistogram::new();
        for v in [1u64, 8, 64, 300] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 373);
        let mut out = String::new();
        h.render("x", &mut out);
        assert!(out.contains("x_bucket{le=\"1\"} 1"), "{out}");
        assert!(out.contains("x_bucket{le=\"8\"} 2"), "{out}");
        assert!(out.contains("x_bucket{le=\"256\"} 3"), "{out}");
        assert!(out.contains("x_bucket{le=\"+Inf\"} 4"), "{out}");
        assert!(out.contains("x_sum 373"), "{out}");
        assert!(out.contains("x_count 4"), "{out}");
    }

    #[test]
    fn empty_histogram_is_quiet() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0.0);
    }

    #[test]
    fn render_is_prometheus_shaped() {
        let m = Metrics::new();
        m.http_requests.fetch_add(3, Ordering::Relaxed);
        m.suggest_latency.observe(Duration::from_micros(120));
        m.sync_push_latency.observe(Duration::from_micros(900));
        m.checkpoint_latency.observe(Duration::from_millis(3));
        let t = TransportStats::default();
        t.requests.fetch_add(7, Ordering::Relaxed);
        t.event_loops.store(4, Ordering::Relaxed);
        t.wakeups.fetch_add(21, Ordering::Relaxed);
        t.conns_open.fetch_add(12, Ordering::Relaxed);
        t.write_backpressure.fetch_add(2, Ordering::Relaxed);
        t.forwarded.fetch_add(13, Ordering::Relaxed);
        t.key_cache_hits.fetch_add(17, Ordering::Relaxed);
        m.fleet_sync_errors.fetch_add(2, Ordering::Relaxed);
        m.fleet_state.store(FLEET_STATE_BACKOFF, Ordering::Relaxed);
        m.reports_dropped.fetch_add(5, Ordering::Relaxed);
        m.reports_deduped.fetch_add(6, Ordering::Relaxed);
        m.checkpoint_failures.fetch_add(2, Ordering::Relaxed);
        m.batch_size.observe(16);
        m.batch_size.observe(3);
        let fleet = FleetGauges { nodes: 3, prior_keys: 2, warm_starts: 4 };
        let trace = TraceGauges { recorded: 11, overwritten: 1 };
        let chaos = ChaosGauges { enabled: true, injections: 9 };
        let page = m.render(5, 8, &t, &ResourceReport::default(), fleet, trace, chaos, &[3, 2]);
        assert!(page.contains("lasp_serve_http_requests_total 3"), "{page}");
        assert!(page.contains("lasp_serve_forwarded_requests_total 13"), "{page}");
        assert!(page.contains("lasp_serve_key_cache_hits_total 17"), "{page}");
        assert!(page.contains("lasp_serve_loop_owned_sessions{loop=\"0\"} 3"), "{page}");
        assert!(page.contains("lasp_serve_loop_owned_sessions{loop=\"1\"} 2"), "{page}");
        assert!(page.contains("lasp_serve_reports_dropped_total 5"), "{page}");
        assert!(page.contains("lasp_serve_reports_deduped_total 6"), "{page}");
        assert!(page.contains("lasp_serve_checkpoint_failures_total 2"), "{page}");
        assert!(page.contains("lasp_serve_fleet_sync_state 2"), "{page}");
        assert!(page.contains("lasp_serve_chaos_enabled 1"), "{page}");
        assert!(page.contains("lasp_serve_chaos_injections_total 9"), "{page}");
        assert!(page.contains("lasp_serve_sessions 5"), "{page}");
        assert!(page.contains("lasp_serve_fleet_nodes 3"), "{page}");
        assert!(page.contains("lasp_serve_fleet_prior_keys 2"), "{page}");
        assert!(page.contains("lasp_serve_fleet_warm_starts_total 4"), "{page}");
        assert!(page.contains("lasp_serve_fleet_sync_errors_total 2"), "{page}");
        assert!(page.contains("lasp_serve_trace_events_total 11"), "{page}");
        assert!(page.contains("lasp_serve_trace_overwritten_total 1"), "{page}");
        assert!(page.contains("lasp_serve_transport_requests_total 7"), "{page}");
        assert!(page.contains("lasp_serve_transport_alloc_events_total 0"), "{page}");
        assert!(page.contains("lasp_serve_event_loops 4"), "{page}");
        assert!(page.contains("lasp_serve_epoll_wakeups_total 21"), "{page}");
        assert!(page.contains("lasp_serve_conns_open 12"), "{page}");
        assert!(page.contains("lasp_serve_write_backpressure_total 2"), "{page}");
        assert!(page.contains("lasp_serve_suggest_latency_us_bucket{le=\"250\"} 1"));
        assert!(page.contains("lasp_serve_batch_size_bucket{le=\"16\"} 2"), "{page}");
        assert!(page.contains("lasp_serve_batch_size_sum 19"), "{page}");
        assert!(page.contains("lasp_serve_batch_size_count 2"), "{page}");
        assert!(page.contains("lasp_serve_sync_push_latency_us_count 1"), "{page}");
        assert!(page.contains("lasp_serve_sync_pull_latency_us_count 0"), "{page}");
        assert!(page.contains("lasp_serve_checkpoint_latency_us_count 1"), "{page}");
        assert!(page.contains("lasp_serve_process_peak_rss_mib"));
    }

    #[test]
    fn fleet_states_have_names() {
        assert_eq!(fleet_state_name(FLEET_STATE_STANDALONE), "standalone");
        assert_eq!(fleet_state_name(FLEET_STATE_SYNCING), "syncing");
        assert_eq!(fleet_state_name(FLEET_STATE_BACKOFF), "backoff");
        assert_eq!(fleet_state_name(77), "unknown");
    }

    /// Prometheus text-exposition lint over the full page: every sample
    /// name is declared by exactly one preceding `# TYPE` line, no metric
    /// family is declared twice, and nothing trails the final newline.
    #[test]
    fn render_passes_exposition_format_lint() {
        let m = Metrics::new();
        m.suggest_latency.observe(Duration::from_micros(75));
        m.sync_pull_latency.observe(Duration::from_micros(75));
        let page = m.render(
            1,
            2,
            &TransportStats::default(),
            &ResourceReport::default(),
            FleetGauges { nodes: 1, prior_keys: 1, warm_starts: 9 },
            TraceGauges { recorded: 5, overwritten: 0 },
            ChaosGauges::default(),
            &[4, 0, 1],
        );
        assert!(page.ends_with('\n'), "page must end with a newline, no trailing garbage");
        let mut declared: std::collections::BTreeSet<String> = Default::default();
        for line in page.lines() {
            assert!(!line.trim().is_empty(), "blank line in exposition output");
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut parts = rest.split_whitespace();
                let (name, kind) = (parts.next().unwrap(), parts.next().unwrap_or(""));
                assert!(
                    ["counter", "gauge", "histogram"].contains(&kind),
                    "bad TYPE kind in '{line}'"
                );
                assert!(declared.insert(name.to_string()), "metric family '{name}' declared twice");
                continue;
            }
            assert!(!line.starts_with('#'), "unexpected comment line '{line}'");
            // Sample name = text before the first '{' or ' '.
            let name = line.split(['{', ' ']).next().unwrap();
            let family = declared.iter().any(|d| {
                name == d
                    || (name.starts_with(d.as_str())
                        && ["_bucket", "_sum", "_sum_us", "_count"]
                            .contains(&&name[d.len()..]))
            });
            assert!(family, "sample '{name}' has no preceding # TYPE declaration");
            // The value parses as a number.
            let value = line.rsplit(' ').next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparsable value in '{line}'");
        }
    }
}
