//! LASP-as-a-service: a long-running online tuning daemon.
//!
//! The paper frames LASP as an *online* tuner — "online exploration and
//! exploitation" that "adapts seamlessly to changing environments" — but
//! the rest of this crate only exposes one-shot CLI campaigns and an
//! in-process fleet simulation. This module turns the bandit engine into
//! a service many edge clients can query concurrently, in the spirit of
//! on-line autotuning frameworks (mARGOt) and MAB-driven edge decision
//! services:
//!
//! * [`transport`] — dependency-free HTTP/1.1 serving over
//!   `std::net::TcpListener` with two interchangeable backends: the
//!   default **event-driven reactor** (N event loops, epoll/poll
//!   readiness, per-connection state machines, a timer wheel for the
//!   408 slow-loris deadline — 10k+ mostly-idle keep-alive clients per
//!   node) and the legacy **blocking worker pool** (bounded hand-off,
//!   the [`crate::coordinator`] backpressure idiom), both with an
//!   **allocation-free steady state**: reusable byte buffers,
//!   slice-based request parsing, keep-alive with pipelining, and
//!   counted buffer-growth events ([`transport::TransportStats`]) that
//!   certify the zero-allocation contract under load;
//! * [`store`] — the **sharded session store**: sessions keyed by
//!   `(client_id, app, device, policy)` hash onto N shards, each shard
//!   owning its bandit tuners behind a single lock, so the store scales
//!   across cores without a global bottleneck;
//! * [`batch`] — **batched reward ingestion**: `/v1/report` enqueues into
//!   per-shard bounded queues drained by background updaters, decoupling
//!   hot-path suggest latency from bandit updates;
//! * [`checkpoint`] — periodic snapshots of every shard via
//!   [`crate::bandit::persist`], with [`crate::bandit::persist::discounted`]
//!   staleness decay on boot, so a restarted service resumes learned state;
//! * [`fleet`] — **networked fleet sync**: nodes exchange compact sparse
//!   arm-statistic snapshots over `/v1/sync/push` and `/v1/sync/pull`,
//!   merge them with time-decayed counts, and warm-start new sessions
//!   from the fleet prior — knowledge learned on one edge node transfers
//!   to every other (the paper's Fig 1 leader/fleet story, made real);
//! * [`metrics`] — latency histograms and counters for `GET /metrics`;
//! * [`service`] — the endpoint router and server lifecycle
//!   (`/v1/suggest`, `/v1/report`, `/v1/suggest/batch`,
//!   `/v1/report/batch`, `/v1/best`, `/v1/checkpoint`,
//!   `/v1/sync/push`, `/v1/sync/pull`, `/v1/trace`,
//!   `/v1/debug/session`, `/healthz`, `/metrics` — see `docs/API.md`
//!   for the full HTTP reference), with every layer logging compact
//!   binary events into the [`crate::obs`] flight recorder; the batch
//!   endpoints carry many entries per request, grouped by shard so each
//!   shard lock is taken once per batch (`DESIGN.md` §Batched scoring);
//! * [`loadgen`] — a closed-loop load generator (`lasp loadgen`) that
//!   hammers one or more running servers through a pool of persistent
//!   keep-alive connections across all four apps and reports throughput,
//!   p50/p99 latency, and connection-reuse stats.

pub mod batch;
pub mod checkpoint;
pub mod fleet;
pub mod loadgen;
pub mod metrics;
pub(crate) mod plane;
pub mod service;
pub mod store;
pub mod transport;

pub use fleet::{FleetSnapshot, FleetStore, FleetSync, FleetSyncConfig};
pub use loadgen::{HttpClient, LoadgenConfig, LoadgenReport};
pub use service::{start, ServeConfig, ServerHandle, TuningService};
pub use store::{FleetKey, KeyRef, PolicyKind, SessionId, SessionKey};
pub use transport::{ResponseBuf, TransportKind, TransportStats};
