//! The routed (shared-nothing) data plane's cross-loop work mailboxes.
//!
//! In routed mode each reactor event loop exclusively owns the store
//! shards `{s : s % n_loops == loop_idx}`: single keyed requests reach
//! their owner by connection re-homing (the transport's
//! [`super::transport::LoopHooks::route`] seam), so the suggest/report
//! hot path touches only loop-owned state — zero locks, zero parks.
//! Everything that *cannot* ride a connection to its owner — foreign
//! batch-entry groups, checkpoint snapshot extraction, fleet-sync
//! aggregation — is expressed as a [`Job`]: a boxed closure posted into
//! the owning loop's mailbox here and executed on the owner's thread
//! during its [`super::transport::LoopHooks::on_tick`] slice.
//!
//! The mailbox mutex is deliberate, not a hot-path concession: only
//! batch requests and control-plane work post jobs, and the single
//! suggest/report path never touches a mailbox. Posting threads that
//! must wait for results spin-drain *their own* mailbox while waiting
//! (see the service), which makes loop-to-loop rendezvous deadlock-free:
//! jobs are depth-1 (they never post further jobs), so two loops posting
//! to each other both make progress by executing the other's work.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// One unit of owner-loop work: runs on the owning event loop's thread
/// with exclusive access to that loop's shards. The closure captures its
/// inputs and writes results through shared slots (`Arc<Mutex<..>>` +
/// an `Arc<AtomicBool>` done flag) owned by the poster.
pub(crate) type Job = Box<dyn FnOnce() + Send>;

/// Per-loop job mailboxes plus the wake handles to interrupt an idle
/// poller after a post.
pub(crate) struct RoutedPlane {
    n_loops: usize,
    n_shards: usize,
    mailboxes: Vec<Mutex<VecDeque<Job>>>,
    /// Wake closures registered by each loop at startup
    /// (`LoopHooks::on_loop_start`); `None` until the loop is up.
    wakes: Mutex<Vec<Option<Arc<dyn Fn() + Send + Sync>>>>,
    /// True while the event loops run. Cleared during shutdown (after
    /// the transport stops) so rendezvous waits bail out instead of
    /// waiting on ticks that will never come.
    live: AtomicBool,
}

impl RoutedPlane {
    pub(crate) fn new(n_loops: usize, n_shards: usize) -> RoutedPlane {
        assert!(n_loops > 0 && n_shards > 0 && n_shards % n_loops == 0);
        RoutedPlane {
            n_loops,
            n_shards,
            mailboxes: (0..n_loops).map(|_| Mutex::new(VecDeque::new())).collect(),
            wakes: Mutex::new(vec![None; n_loops]),
            live: AtomicBool::new(true),
        }
    }

    pub(crate) fn n_loops(&self) -> usize {
        self.n_loops
    }

    pub(crate) fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The ownership map: shard `s` belongs to loop `s % n_loops`. With
    /// `n_shards` a multiple of `n_loops` (enforced at config time),
    /// every loop owns exactly `n_shards / n_loops` shards.
    pub(crate) fn owner_of(&self, shard: usize) -> usize {
        shard % self.n_loops
    }

    /// Iterate the shards loop `loop_idx` owns.
    pub(crate) fn shards_of(&self, loop_idx: usize) -> impl Iterator<Item = usize> + '_ {
        (loop_idx..self.n_shards).step_by(self.n_loops)
    }

    /// Called from `LoopHooks::on_loop_start`: make this loop wakeable.
    pub(crate) fn register_wake(&self, loop_idx: usize, wake: Arc<dyn Fn() + Send + Sync>) {
        if let Ok(mut w) = self.wakes.lock() {
            w[loop_idx] = Some(wake);
        }
    }

    /// Post a job to `loop_idx`'s mailbox and wake its poller. Jobs
    /// posted after shutdown are dropped unexecuted (their done flags
    /// stay false; waiters time out via [`RoutedPlane::live`]).
    pub(crate) fn post(&self, loop_idx: usize, job: Job) {
        match self.mailboxes[loop_idx].lock() {
            Ok(mut q) => q.push_back(job),
            Err(_) => return,
        }
        let wake = match self.wakes.lock() {
            Ok(w) => w[loop_idx].clone(),
            Err(_) => None,
        };
        if let Some(w) = wake {
            w();
        }
    }

    /// Execute everything in `loop_idx`'s mailbox on the current thread.
    /// Called by the owning loop (its `on_tick`, or a handler spin-wait
    /// on the same loop). Jobs are popped one at a time so a job posted
    /// while another runs is seen in the same drain.
    pub(crate) fn drain(&self, loop_idx: usize) {
        loop {
            let job = match self.mailboxes[loop_idx].lock() {
                Ok(mut q) => q.pop_front(),
                Err(_) => return,
            };
            match job {
                Some(j) => j(),
                None => return,
            }
        }
    }

    /// Whether the event loops are still ticking (rendezvous waits check
    /// this to avoid blocking on a stopped transport).
    pub(crate) fn live(&self) -> bool {
        self.live.load(Ordering::Acquire)
    }

    /// Mark the loops stopped (called during shutdown, after the HTTP
    /// transport has been torn down).
    pub(crate) fn retire(&self) {
        self.live.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn ownership_map_partitions_shards_evenly() {
        let p = RoutedPlane::new(4, 8);
        for s in 0..8 {
            assert_eq!(p.owner_of(s), s % 4);
        }
        let mut seen = vec![false; 8];
        for l in 0..4 {
            let owned: Vec<usize> = p.shards_of(l).collect();
            assert_eq!(owned.len(), 2, "loop {l} owns {owned:?}");
            for s in owned {
                assert_eq!(p.owner_of(s), l);
                assert!(!seen[s], "shard {s} owned twice");
                seen[s] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every shard must have an owner");
    }

    #[test]
    fn posted_jobs_run_on_drain_and_wake_fires() {
        let p = RoutedPlane::new(2, 4);
        let woken = Arc::new(AtomicUsize::new(0));
        let w = woken.clone();
        p.register_wake(1, Arc::new(move || {
            w.fetch_add(1, Ordering::SeqCst);
        }));
        let ran = Arc::new(AtomicUsize::new(0));
        for _ in 0..3 {
            let ran = ran.clone();
            p.post(1, Box::new(move || {
                ran.fetch_add(1, Ordering::SeqCst);
            }));
        }
        assert_eq!(woken.load(Ordering::SeqCst), 3);
        assert_eq!(ran.load(Ordering::SeqCst), 0, "jobs must not run at post time");
        p.drain(1);
        assert_eq!(ran.load(Ordering::SeqCst), 3);
        p.drain(1); // empty drain is a no-op
        assert_eq!(ran.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn retire_flips_liveness() {
        let p = RoutedPlane::new(1, 1);
        assert!(p.live());
        p.retire();
        assert!(!p.live());
    }
}
