//! The tuning service proper: route handling over the [`super::http`]
//! transport, wired to the sharded store, the batched ingest plane and the
//! checkpointer.
//!
//! Endpoints:
//!
//! | method | path             | purpose                                      |
//! |--------|------------------|----------------------------------------------|
//! | POST   | `/v1/suggest`    | next configuration to evaluate (Eq. 2-3)     |
//! | POST   | `/v1/report`     | enqueue a measured evaluation (batched)      |
//! | GET    | `/v1/best`       | the session's tuned configuration (Eq. 4)    |
//! | POST   | `/v1/checkpoint` | force a snapshot of every session            |
//! | GET    | `/healthz`       | liveness + session count                     |
//! | GET    | `/metrics`       | Prometheus counters, latency histograms,     |
//! |        |                  | process [`ResourceReport`]                   |
//!
//! [`ResourceReport`]: crate::telemetry::ResourceReport

use super::batch::{BatchIngest, Report};
use super::checkpoint;
use super::http::{HttpHandler, HttpServer, Request, Response};
use super::metrics::Metrics;
use super::store::{AppsCache, PolicyKind, SessionKey, ShardedStore};
use crate::apps::AppKind;
use crate::device::PowerMode;
use crate::telemetry::ResourceTracker;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration (see `config/` for the `[serve]` TOML section).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8787` (`:0` for an ephemeral port).
    pub addr: String,
    /// HTTP worker threads.
    pub workers: usize,
    /// Session-store shards.
    pub shards: usize,
    /// Per-shard report queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Max reports applied per shard-lock acquisition.
    pub max_batch: usize,
    /// Directory for periodic session snapshots (None = stateless).
    pub checkpoint_dir: Option<PathBuf>,
    /// Period between automatic snapshots.
    pub checkpoint_every: Duration,
    /// Warm-start retention `∈ (0, 1]` applied to restored states.
    pub warm_retain: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8787".to_string(),
            workers: 8,
            shards: 8,
            queue_cap: 4096,
            max_batch: 128,
            checkpoint_dir: None,
            checkpoint_every: Duration::from_secs(30),
            warm_retain: 0.5,
        }
    }
}

impl ServeConfig {
    /// Sanity-check ranges (also delegated to by `LaspConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.shards == 0 || self.queue_cap == 0 || self.max_batch == 0 {
            return Err(anyhow!("serve: workers/shards/queue_cap/max_batch must be positive"));
        }
        if !(self.warm_retain > 0.0 && self.warm_retain <= 1.0) {
            return Err(anyhow!("serve: warm_retain must lie in (0, 1]"));
        }
        if self.checkpoint_every.is_zero() {
            return Err(anyhow!("serve: checkpoint_every must be positive"));
        }
        Ok(())
    }
}

/// Shared state behind every worker thread.
pub struct TuningService {
    cfg: ServeConfig,
    store: Arc<ShardedStore>,
    apps: Arc<AppsCache>,
    ingest: BatchIngest,
    metrics: Arc<Metrics>,
    tracker: Mutex<ResourceTracker>,
}

impl TuningService {
    /// Route one request.
    pub fn handle(&self, req: &Request) -> Response {
        self.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
        let resp = match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/suggest") => self.suggest(req),
            ("POST", "/v1/report") => self.report(req),
            ("GET", "/v1/best") => self.best(req),
            ("POST", "/v1/checkpoint") => self.checkpoint_now(),
            ("GET", "/healthz") => self.healthz(),
            ("GET", "/metrics") => self.metrics_page(),
            ("POST" | "GET", _) => Response::error(404, "no such endpoint"),
            _ => Response::error(405, "method not allowed"),
        };
        if resp.status >= 400 {
            self.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
        }
        resp
    }

    /// Read the session identity (+ weights) from a request body or query.
    fn parse_key(
        &self,
        get: impl Fn(&str) -> Option<String>,
    ) -> Result<(SessionKey, f64, f64), String> {
        let client_id = get("client_id").unwrap_or_default();
        if client_id.is_empty() {
            return Err("missing client_id".to_string());
        }
        let app: AppKind = get("app")
            .ok_or_else(|| "missing app".to_string())?
            .parse()
            .map_err(|e| format!("{e:#}"))?;
        let device: PowerMode = match get("device") {
            Some(d) => d.parse().map_err(|e| format!("{e:#}"))?,
            None => PowerMode::Maxn,
        };
        let k = self.apps.arms(app);
        let policy: PolicyKind = match get("policy") {
            Some(p) => p.parse().map_err(|e| format!("{e:#}"))?,
            None => PolicyKind::default_for(k),
        };
        let parse_weight = |name: &str, default: f64| -> Result<f64, String> {
            match get(name) {
                None => Ok(default),
                Some(s) => s.parse::<f64>().map_err(|_| format!("bad {name}")),
            }
        };
        let alpha = parse_weight("alpha", 0.8)?;
        let beta = parse_weight("beta", 0.2)?;
        if !(0.0..=1.0).contains(&alpha) || !(0.0..=1.0).contains(&beta) || alpha + beta == 0.0 {
            return Err("alpha/beta must lie in [0,1] with alpha+beta > 0".to_string());
        }
        Ok((SessionKey { client_id, app, device, policy }, alpha, beta))
    }

    fn body_getter(body: &Json) -> impl Fn(&str) -> Option<String> + '_ {
        move |name: &str| {
            body.get(name).and_then(|v| match v {
                Json::Str(s) => Some(s.clone()),
                Json::Num(n) => Some(format!("{n}")),
                _ => None,
            })
        }
    }

    fn suggest(&self, req: &Request) -> Response {
        let t0 = Instant::now();
        let body = match req.json() {
            Ok(b) => b,
            Err(e) => return Response::error(400, &format!("bad JSON: {e}")),
        };
        let (key, alpha, beta) = match self.parse_key(Self::body_getter(&body)) {
            Ok(x) => x,
            Err(e) => return Response::error(400, &e),
        };
        let k = self.apps.arms(key.app);
        let shard_i = self.store.shard_of(&key);
        let (arm, total_pulls, created) = {
            let mut shard = self.store.lock_shard(shard_i);
            let (session, created) = match shard.get_or_create(&key, alpha, beta, k) {
                Ok(x) => x,
                Err(e) => return Response::error(500, &e),
            };
            session.suggests += 1;
            let arm = session.tuner.select();
            (arm, session.tuner.total_pulls(), created)
        };
        if created {
            self.metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.suggests.fetch_add(1, Ordering::Relaxed);
        let mut obj = BTreeMap::new();
        obj.insert("arm".to_string(), Json::Num(arm as f64));
        obj.insert("config".to_string(), Json::Str(self.apps.describe(key.app, arm)));
        obj.insert("shard".to_string(), Json::Num(shard_i as f64));
        obj.insert("total_pulls".to_string(), Json::Num(total_pulls));
        let resp = Response::json(200, &Json::Obj(obj));
        self.metrics.suggest_latency.observe(t0.elapsed());
        resp
    }

    fn report(&self, req: &Request) -> Response {
        let t0 = Instant::now();
        let body = match req.json() {
            Ok(b) => b,
            Err(e) => return Response::error(400, &format!("bad JSON: {e}")),
        };
        let (key, alpha, beta) = match self.parse_key(Self::body_getter(&body)) {
            Ok(x) => x,
            Err(e) => return Response::error(400, &e),
        };
        let arm = match body.get("arm").and_then(Json::as_f64) {
            Some(a) if a >= 0.0 && a.fract() == 0.0 => a as usize,
            _ => return Response::error(400, "missing/invalid arm"),
        };
        let (time_s, power_w) = match (
            body.get("time_s").and_then(Json::as_f64),
            body.get("power_w").and_then(Json::as_f64),
        ) {
            (Some(t), Some(p)) if t.is_finite() && t > 0.0 && p.is_finite() && p >= 0.0 => (t, p),
            _ => return Response::error(400, "missing/invalid time_s or power_w"),
        };
        let shard_i = self.store.shard_of(&key);
        let report = Report { key, alpha, beta, arm, time_s, power_w };
        let resp = match self.ingest.enqueue(shard_i, report, &self.metrics) {
            Ok(()) => {
                self.metrics.reports_enqueued.fetch_add(1, Ordering::Relaxed);
                let mut obj = BTreeMap::new();
                obj.insert("queued".to_string(), Json::Bool(true));
                obj.insert("shard".to_string(), Json::Num(shard_i as f64));
                Response::json(202, &Json::Obj(obj))
            }
            Err(e) => Response::error(503, &e),
        };
        self.metrics.report_latency.observe(t0.elapsed());
        resp
    }

    fn best(&self, req: &Request) -> Response {
        let t0 = Instant::now();
        let query = &req.query;
        let (key, _, _) =
            match self.parse_key(|name: &str| query.get(name).cloned()) {
                Ok(x) => x,
                Err(e) => return Response::error(400, &e),
            };
        let shard_i = self.store.shard_of(&key);
        let shard = self.store.lock_shard(shard_i);
        let Some(session) = shard.sessions.get(&key) else {
            return Response::error(404, "unknown session");
        };
        let best = session.tuner.most_selected();
        let mut obj = BTreeMap::new();
        obj.insert("arm".to_string(), Json::Num(best as f64));
        obj.insert("config".to_string(), Json::Str(self.apps.describe(key.app, best)));
        obj.insert("pulls_of_best".to_string(), Json::Num(session.tuner.counts()[best]));
        obj.insert("total_pulls".to_string(), Json::Num(session.tuner.total_pulls()));
        obj.insert("suggests".to_string(), Json::Num(session.suggests as f64));
        obj.insert("reports".to_string(), Json::Num(session.reports as f64));
        obj.insert("policy".to_string(), Json::Str(session.tuner.name().to_string()));
        if let Some((mean_t, mean_p)) = session.tuner.mean_of(best) {
            obj.insert("mean_time_s".to_string(), Json::Num(mean_t));
            obj.insert("mean_power_w".to_string(), Json::Num(mean_p));
        }
        drop(shard);
        let resp = Response::json(200, &Json::Obj(obj));
        self.metrics.best_latency.observe(t0.elapsed());
        resp
    }

    fn checkpoint_now(&self) -> Response {
        let Some(dir) = &self.cfg.checkpoint_dir else {
            return Response::error(400, "no checkpoint_dir configured");
        };
        match checkpoint::snapshot(&self.store, dir) {
            Ok(n) => {
                self.metrics.checkpoints.fetch_add(1, Ordering::Relaxed);
                self.metrics.checkpoint_sessions.fetch_add(n as u64, Ordering::Relaxed);
                let mut obj = BTreeMap::new();
                obj.insert("sessions".to_string(), Json::Num(n as f64));
                Response::json(200, &Json::Obj(obj))
            }
            Err(e) => Response::error(500, &format!("{e:#}")),
        }
    }

    fn healthz(&self) -> Response {
        let mut obj = BTreeMap::new();
        obj.insert("ok".to_string(), Json::Bool(true));
        obj.insert("uptime_s".to_string(), Json::Num(self.metrics.uptime_s()));
        obj.insert("sessions".to_string(), Json::Num(self.store.session_count() as f64));
        obj.insert("shards".to_string(), Json::Num(self.store.num_shards() as f64));
        Response::json(200, &Json::Obj(obj))
    }

    fn metrics_page(&self) -> Response {
        let resources = {
            let mut tracker = match self.tracker.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            tracker.sample();
            tracker.report()
        };
        let body =
            self.metrics
                .render(self.store.session_count(), self.store.num_shards(), &resources);
        Response::text(200, body)
    }
}

/// A running server. Dropping the handle leaks the threads; call
/// [`ServerHandle::shutdown`] for an orderly stop (drains report queues,
/// writes a final checkpoint) or [`ServerHandle::wait`] to park forever.
pub struct ServerHandle {
    addr: SocketAddr,
    http: HttpServer,
    service: Arc<TuningService>,
    stop_checkpointer: Arc<AtomicBool>,
    checkpointer: Option<JoinHandle<()>>,
    restored: usize,
}

impl ServerHandle {
    /// The bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Sessions warm-started from the checkpoint directory at boot.
    pub fn restored_sessions(&self) -> usize {
        self.restored
    }

    /// Orderly shutdown: stop HTTP, drain report queues, final snapshot.
    pub fn shutdown(self) -> Result<()> {
        self.http.stop();
        self.service.ingest.stop();
        self.stop_checkpointer.store(true, Ordering::SeqCst);
        if let Some(h) = self.checkpointer {
            let _ = h.join();
        }
        if let Some(dir) = &self.service.cfg.checkpoint_dir {
            checkpoint::snapshot(&self.service.store, dir)
                .context("final shutdown checkpoint")?;
        }
        Ok(())
    }

    /// Block the calling thread for the life of the server (CLI mode).
    pub fn wait(self) {
        self.http.join();
    }
}

/// Boot the service: restore checkpoints, start ingest, bind, serve.
pub fn start(cfg: ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    let store = Arc::new(ShardedStore::new(cfg.shards));
    let apps = Arc::new(AppsCache::new());
    let metrics = Arc::new(Metrics::new());

    let mut restored = 0;
    if let Some(dir) = &cfg.checkpoint_dir {
        restored = checkpoint::restore(&store, &apps, dir, cfg.warm_retain)?;
        metrics.sessions_restored.fetch_add(restored as u64, Ordering::Relaxed);
    }

    let ingest = BatchIngest::start(
        store.clone(),
        apps.clone(),
        metrics.clone(),
        cfg.queue_cap,
        cfg.max_batch,
    );
    let service = Arc::new(TuningService {
        cfg: cfg.clone(),
        store: store.clone(),
        apps,
        ingest,
        metrics: metrics.clone(),
        tracker: Mutex::new(ResourceTracker::start()),
    });

    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let handler: HttpHandler = {
        let service = service.clone();
        Arc::new(move |req: &Request| service.handle(req))
    };
    let http = HttpServer::start(listener, cfg.workers, handler)?;
    let addr = http.addr();

    // Periodic checkpointer (only when a directory is configured).
    let stop_checkpointer = Arc::new(AtomicBool::new(false));
    let checkpointer = cfg.checkpoint_dir.clone().map(|dir| {
        let store = store.clone();
        let metrics = metrics.clone();
        let stop = stop_checkpointer.clone();
        let every = cfg.checkpoint_every;
        std::thread::spawn(move || {
            let mut last = Instant::now();
            loop {
                std::thread::sleep(Duration::from_millis(100));
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if last.elapsed() >= every {
                    if let Ok(n) = checkpoint::snapshot(&store, &dir) {
                        metrics.checkpoints.fetch_add(1, Ordering::Relaxed);
                        metrics.checkpoint_sessions.fetch_add(n as u64, Ordering::Relaxed);
                    }
                    last = Instant::now();
                }
            }
        })
    });

    Ok(ServerHandle {
        addr,
        http,
        service,
        stop_checkpointer,
        checkpointer,
        restored,
    })
}
