//! The tuning service proper: route handling over the [`super::http`]
//! transport, wired to the sharded store, the batched ingest plane and the
//! checkpointer.
//!
//! The `/v1/suggest` and `/v1/report` hot paths are allocation-free in
//! the HTTP+JSON layers: request bodies are read through the borrowed
//! [`JsonSlice`] (no tree, strings borrow from the connection buffer),
//! session identity is resolved to an interned [`SessionId`] (no key
//! clone), and responses serialize through [`JsonWriter`] into the
//! worker's reusable [`ResponseBuf`].
//!
//! Endpoints (full reference with examples: `docs/API.md`):
//!
//! | method | path                | purpose                                      |
//! |--------|---------------------|----------------------------------------------|
//! | POST   | `/v1/suggest`       | next configuration to evaluate (Eq. 2-3)     |
//! | POST   | `/v1/report`        | enqueue a measured evaluation (batched)      |
//! | POST   | `/v1/suggest/batch` | many suggests in one request, one shard lock |
//! |        |                     | per shard touched (see `DESIGN.md` §Batched) |
//! | POST   | `/v1/report/batch`  | many reports in one request, per-entry       |
//! |        |                     | queued/dropped status                        |
//! | GET    | `/v1/best`          | the session's tuned configuration (Eq. 4)    |
//! | POST   | `/v1/checkpoint`    | force a snapshot of every session            |
//! | POST   | `/v1/sync/push`     | deposit a peer node's arm statistics         |
//! | POST   | `/v1/sync/pull`     | fetch the discount-merged fleet prior        |
//! | GET    | `/v1/trace`         | drain flight-recorder events since a seq     |
//! | GET    | `/v1/debug/session` | full per-session arm statistics              |
//! | GET    | `/healthz`          | liveness + session count                     |
//! | GET    | `/metrics`          | Prometheus counters, latency histograms,     |
//! |        |                     | transport stats, process [`ResourceReport`]  |
//!
//! [`ResourceReport`]: crate::telemetry::ResourceReport

use super::batch::{BatchIngest, Enqueue, Report};
use super::checkpoint;
use super::fleet::{self, FleetSnapshot, FleetStore, FleetSync, FleetSyncConfig};
use super::transport::{
    self, HttpHandler, HttpServer, Request, ResponseBuf, TransportKind, TransportOptions,
    TransportStats,
};
use super::metrics::{fleet_state_name, ChaosGauges, FleetGauges, Metrics, TraceGauges};
use super::store::{AppsCache, KeyRef, PolicyKind, SessionId, ShardedStore, Tuner};
use crate::apps::AppKind;
use crate::chaos::{ChaosConfig, ChaosLayer, HandlerFault};
use crate::device::PowerMode;
use crate::obs::{self, EventKind, Recorder, TraceWriter};
use crate::telemetry::ResourceTracker;
use crate::util::json::{JsonSlice, JsonWriter};
use anyhow::{anyhow, Context, Result};
use std::borrow::Cow;
use std::cell::RefCell;
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration (see `config/` for the `[serve]` TOML section).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8787` (`:0` for an ephemeral port).
    pub addr: String,
    /// HTTP worker threads (blocking transport only).
    pub workers: usize,
    /// Reactor event loops; 0 = auto (one per core). Unlike `workers`,
    /// this does not cap concurrent connections — each loop multiplexes
    /// thousands — so the right value tracks cores, not expected load.
    pub event_loops: usize,
    /// Which transport backend serves the listener.
    pub transport: TransportKind,
    /// Session-store shards.
    pub shards: usize,
    /// Per-shard report queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Max reports applied per shard-lock acquisition.
    pub max_batch: usize,
    /// Directory for periodic session snapshots (None = stateless).
    pub checkpoint_dir: Option<PathBuf>,
    /// Period between automatic snapshots.
    pub checkpoint_every: Duration,
    /// Warm-start retention `∈ (0, 1]` applied to restored states.
    pub warm_retain: f64,
    /// Fleet leader to sync with (`host:port`; None = standalone node).
    pub leader: Option<String>,
    /// Stable node identity on the sync wire (None = derived from the
    /// bound address).
    pub node_id: Option<String>,
    /// Period between fleet push/pull cycles.
    pub sync_every: Duration,
    /// Retention `∈ (0, 1]` applied when warm-starting a session from a
    /// fleet prior (fleet knowledge biases, never dominates).
    pub fleet_retain: f64,
    /// Half-life for time-decaying fleet evidence (merge-side and on the
    /// installed prior).
    pub fleet_half_life: Duration,
    /// Stream the flight-recorder ring to this binary trace file
    /// (`LASPTRC1` format, decodable by `lasp trace dump`); `None` keeps
    /// tracing in-memory only (`GET /v1/trace`).
    pub trace_file: Option<PathBuf>,
    /// Fault-injection layer (`--chaos <file.toml>` / `[chaos]` section);
    /// `None` = no chaos code on any path (the zero-overhead default).
    pub chaos: Option<ChaosConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8787".to_string(),
            workers: 8,
            event_loops: 0,
            transport: transport::default_kind(),
            shards: 8,
            queue_cap: 4096,
            max_batch: 128,
            checkpoint_dir: None,
            checkpoint_every: Duration::from_secs(30),
            warm_retain: 0.5,
            leader: None,
            node_id: None,
            sync_every: Duration::from_secs(10),
            fleet_retain: 0.3,
            fleet_half_life: Duration::from_secs(600),
            trace_file: None,
            chaos: None,
        }
    }
}

impl ServeConfig {
    /// Sanity-check ranges (also delegated to by `LaspConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.shards == 0 || self.queue_cap == 0 || self.max_batch == 0 {
            return Err(anyhow!("serve: workers/shards/queue_cap/max_batch must be positive"));
        }
        if !(self.warm_retain > 0.0 && self.warm_retain <= 1.0) {
            return Err(anyhow!("serve: warm_retain must lie in (0, 1]"));
        }
        if self.checkpoint_every.is_zero() {
            return Err(anyhow!("serve: checkpoint_every must be positive"));
        }
        if !(self.fleet_retain > 0.0 && self.fleet_retain <= 1.0) {
            return Err(anyhow!("serve: fleet_retain must lie in (0, 1]"));
        }
        if self.sync_every.is_zero() {
            return Err(anyhow!("serve: sync_every must be positive"));
        }
        if self.fleet_half_life.is_zero() {
            return Err(anyhow!("serve: fleet_half_life must be positive"));
        }
        if matches!(&self.leader, Some(l) if l.is_empty()) {
            return Err(anyhow!("serve: leader address must not be empty"));
        }
        if let Some(chaos) = &self.chaos {
            chaos.validate()?;
        }
        Ok(())
    }

    /// How many transport threads this config actually starts: event
    /// loops for the reactor (0 = one per core), `workers` for the
    /// blocking pool.
    pub fn effective_threads(&self) -> usize {
        match self.transport {
            TransportKind::Reactor => {
                if self.event_loops > 0 {
                    self.event_loops
                } else {
                    transport::default_event_loops()
                }
            }
            TransportKind::Blocking => self.workers,
        }
    }
}

/// A request's parameter source: borrowed JSON body (POST) or raw query
/// string (GET). Both resolve values without allocating unless the wire
/// bytes contain escapes.
enum Params<'a> {
    Body(JsonSlice<'a>),
    Query(&'a str),
}

impl<'a> Params<'a> {
    /// `Ok(None)` = absent. A present-but-undecodable query value (e.g.
    /// percent-encoding that is not UTF-8) is an error, never a silent
    /// fall-back to the parameter's default.
    fn get_str(&self, name: &str) -> std::result::Result<Option<Cow<'a, str>>, String> {
        match self {
            Params::Body(b) => {
                let Some(v) = b.get(name) else {
                    return Ok(None);
                };
                if let Some(s) = v.as_str() {
                    return Ok(Some(s));
                }
                // Tolerate numeric values where strings are expected
                // (e.g. a numeric client_id); cold path, may allocate.
                match v.as_f64() {
                    Some(n) => Ok(Some(Cow::Owned(if n.fract() == 0.0 && n.abs() < 1e15 {
                        format!("{}", n as i64)
                    } else {
                        format!("{n}")
                    }))),
                    None => Err(format!("bad {name}")),
                }
            }
            Params::Query(q) => match transport::query_get_raw(q, name) {
                None => Ok(None),
                Some(raw) => match transport::percent_decode(raw) {
                    Some(v) => Ok(Some(v)),
                    None => Err(format!("bad percent-encoding in {name}")),
                },
            },
        }
    }

    /// `Ok(None)` = absent; present but unparsable is an error.
    fn get_f64(&self, name: &str) -> std::result::Result<Option<f64>, String> {
        match self {
            Params::Body(b) => match b.get(name) {
                None => Ok(None),
                Some(v) => v
                    .as_f64()
                    .or_else(|| v.as_str().and_then(|s| s.parse().ok()))
                    .map(Some)
                    .ok_or_else(|| format!("bad {name}")),
            },
            Params::Query(_) => match self.get_str(name)? {
                None => Ok(None),
                Some(s) => s.parse::<f64>().map(Some).map_err(|_| format!("bad {name}")),
            },
        }
    }
}

/// The session identity + objective weights parsed off a request.
struct ParsedKey<'a> {
    client_id: Cow<'a, str>,
    app: AppKind,
    device: PowerMode,
    policy: PolicyKind,
    alpha: f64,
    beta: f64,
}

impl ParsedKey<'_> {
    fn key_ref(&self) -> KeyRef<'_> {
        KeyRef {
            client_id: &*self.client_id,
            app: self.app,
            device: self.device,
            policy: self.policy,
        }
    }
}

/// Shared state behind every worker thread.
pub struct TuningService {
    cfg: ServeConfig,
    store: Arc<ShardedStore>,
    apps: Arc<AppsCache>,
    ingest: BatchIngest,
    metrics: Arc<Metrics>,
    transport: Arc<TransportStats>,
    tracker: Mutex<ResourceTracker>,
    /// Per-node snapshot registry for the sync plane (every node can
    /// serve as a leader; see [`super::fleet`]).
    fleet: Arc<FleetStore>,
    /// This node's identity on the sync wire.
    node_id: String,
    /// Last time `/v1/sync/push` refreshed the local warm-start priors —
    /// the fleet-wide merge is O(nodes × scenarios × arms), so it runs
    /// at most once per `PRIOR_REFRESH_MIN` rather than per push.
    prior_refresh: Mutex<Option<Instant>>,
    /// Cached local aggregate served to `/v1/sync/pull` (same TTL): the
    /// session-store scan takes every shard's read lock, so a large
    /// follower fleet pulling must not re-run it per request.
    local_agg: Mutex<Option<(Instant, Arc<Vec<FleetSnapshot>>)>>,
    /// The flight recorder every layer logs into (see [`crate::obs`]).
    recorder: Arc<Recorder>,
    /// Seeded fault-injection layer; `None` (the default) keeps every
    /// hot path chaos-free — call sites short-circuit on the `Option`.
    chaos: Option<Arc<ChaosLayer>>,
}

/// Hard cap on entries per batch request (`/v1/suggest/batch`,
/// `/v1/report/batch`). Oversized batches are rejected whole with 400 —
/// a cap keeps one request from monopolizing a shard lock, and rejecting
/// is cheaper than silently truncating a client's stream.
pub const MAX_BATCH_ENTRIES: usize = 256;

/// One validated batch entry, resolved to its interned session id. The
/// measurement fields are zeroed for suggest entries.
#[derive(Clone, Copy)]
struct EntryPlan {
    id: SessionId,
    shard: u32,
    app: AppKind,
    policy: PolicyKind,
    alpha: f64,
    beta: f64,
    arm: usize,
    time_s: f64,
    power_w: f64,
    seq: Option<u64>,
}

/// Per-entry suggest outcome, written back in entry order.
#[derive(Clone, Copy, Default)]
struct ChoiceSlot {
    arm: usize,
    total_pulls: f64,
}

/// Reusable per-worker-thread scratch for the batch endpoints. Every
/// buffer grows to its high-water mark once and is then only cleared and
/// refilled, so steady-state batch handling allocates nothing — the same
/// discipline as [`ResponseBuf`] on the single-request path.
struct BatchArena {
    /// Validated entries, in request order.
    entries: Vec<EntryPlan>,
    /// Entry indices sorted by (shard, arrival): the shard-grouped visit
    /// order. Stable within a shard, so a session's entries apply in the
    /// order the client sent them (sessions are pinned to one shard).
    order: Vec<u32>,
    /// One bandit scratch shared by every session scored in the batch
    /// (see [`crate::bandit::Scratch`] — `resize` keeps capacity, so
    /// mixed arm counts share one high-water allocation).
    scratch: crate::bandit::Scratch,
    /// Suggest outcomes, indexed by entry.
    choices: Vec<ChoiceSlot>,
    /// Staging for one shard's run of reports.
    reports: Vec<Report>,
    /// Enqueue outcomes in shard-grouped order...
    grouped: Vec<Enqueue>,
    /// ...scattered back to entry order for the response.
    statuses: Vec<Enqueue>,
}

impl BatchArena {
    fn new() -> BatchArena {
        BatchArena {
            entries: Vec::new(),
            order: Vec::new(),
            scratch: crate::bandit::Scratch::new(),
            choices: Vec::new(),
            reports: Vec::new(),
            grouped: Vec::new(),
            statuses: Vec::new(),
        }
    }
}

thread_local! {
    /// One arena per transport thread: reactor event loops and blocking
    /// pool workers are both OS threads that serve one request at a
    /// time, so this is per-event-loop (or per-worker) reuse without
    /// locking.
    static BATCH_ARENA: RefCell<BatchArena> = RefCell::new(BatchArena::new());
}

/// Flight-recorder route code for a request (see [`obs::route`]).
fn route_code(method: &str, path: &str) -> u64 {
    match (method, path) {
        ("POST", "/v1/suggest") => obs::route::SUGGEST,
        ("POST", "/v1/report") => obs::route::REPORT,
        ("POST", "/v1/suggest/batch") => obs::route::SUGGEST_BATCH,
        ("POST", "/v1/report/batch") => obs::route::REPORT_BATCH,
        ("GET", "/v1/best") => obs::route::BEST,
        ("POST", "/v1/checkpoint") => obs::route::CHECKPOINT,
        ("POST", "/v1/sync/push") => obs::route::SYNC_PUSH,
        ("POST", "/v1/sync/pull") => obs::route::SYNC_PULL,
        ("GET", "/v1/trace") => obs::route::TRACE,
        ("GET", "/v1/debug/session") => obs::route::DEBUG_SESSION,
        ("GET", "/healthz") => obs::route::HEALTHZ,
        ("GET", "/metrics") => obs::route::METRICS,
        _ => obs::route::OTHER,
    }
}

/// Minimum interval between full prior-refresh merges in the push
/// handler (a 256-follower leader sees ~50 pushes/s; consecutive merges
/// are near-identical).
const PRIOR_REFRESH_MIN: Duration = Duration::from_secs(1);

impl TuningService {
    /// Route one request, serializing into the worker's reusable buffer.
    pub fn handle(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        self.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let route = route_code(req.method, req.path);
        self.recorder.record(EventKind::ReqStart, route, 0, 0);
        // Chaos handler faults fire after ReqStart so the trace shows the
        // request that was hit; an injected error still flows through the
        // shared epilogue (error counter + ReqEnd) like a real failure.
        let mut faulted = false;
        if let Some(chaos) = &self.chaos {
            match chaos.handler_fault() {
                Some(HandlerFault::Error) => faulted = true,
                Some(HandlerFault::Delay(d)) => std::thread::sleep(d),
                None => {}
            }
        }
        if faulted {
            out.error(503, "chaos: injected handler fault");
        } else {
            self.route(req, out);
        }
        if out.status() >= 400 {
            self.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
        }
        self.recorder.record(
            EventKind::ReqEnd,
            route,
            out.status() as u64,
            t0.elapsed().as_micros() as u64,
        );
    }

    fn route(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        match (req.method, req.path) {
            ("POST", "/v1/suggest") => self.suggest(req, out),
            ("POST", "/v1/report") => self.report(req, out),
            ("POST", "/v1/suggest/batch") => self.suggest_batch(req, out),
            ("POST", "/v1/report/batch") => self.report_batch(req, out),
            ("GET", "/v1/best") => self.best(req, out),
            ("POST", "/v1/checkpoint") => self.checkpoint_now(out),
            ("POST", "/v1/sync/push") => self.sync_push(req, out),
            ("POST", "/v1/sync/pull") => self.sync_pull(req, out),
            ("GET", "/v1/trace") => self.trace(req, out),
            ("GET", "/v1/debug/session") => self.debug_session(req, out),
            ("GET", "/healthz") => self.healthz(out),
            ("GET", "/metrics") => self.metrics_page(out),
            ("POST" | "GET", _) => out.error(404, "no such endpoint"),
            _ => out.error(405, "method not allowed"),
        }
    }

    /// Read the session identity (+ weights) from a parameter source.
    fn parse_key<'a>(&self, p: &Params<'a>) -> std::result::Result<ParsedKey<'a>, String> {
        let client_id = p.get_str("client_id")?.unwrap_or(Cow::Borrowed(""));
        if client_id.is_empty() {
            return Err("missing client_id".to_string());
        }
        let app: AppKind = p
            .get_str("app")?
            .ok_or_else(|| "missing app".to_string())?
            .parse()
            .map_err(|e: anyhow::Error| format!("{e:#}"))?;
        let device: PowerMode = match p.get_str("device")? {
            Some(d) => d.parse().map_err(|e: anyhow::Error| format!("{e:#}"))?,
            None => PowerMode::Maxn,
        };
        let k = self.apps.arms(app);
        let policy: PolicyKind = match p.get_str("policy")? {
            Some(s) => s.parse().map_err(|e: anyhow::Error| format!("{e:#}"))?,
            None => PolicyKind::default_for(k),
        };
        let alpha = p.get_f64("alpha")?.unwrap_or(0.8);
        let beta = p.get_f64("beta")?.unwrap_or(0.2);
        if !(0.0..=1.0).contains(&alpha) || !(0.0..=1.0).contains(&beta) || alpha + beta == 0.0 {
            return Err("alpha/beta must lie in [0,1] with alpha+beta > 0".to_string());
        }
        Ok(ParsedKey { client_id, app, device, policy, alpha, beta })
    }

    fn suggest(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        let p = Params::Body(body);
        let pk = match self.parse_key(&p) {
            Ok(x) => x,
            Err(e) => return out.error(400, &e),
        };
        let kref = pk.key_ref();
        let hash = kref.hash64();
        let id = self.store.intern(&kref, hash);
        let k = self.apps.arms(pk.app);
        let shard_i = self.store.shard_of_hash(hash);
        let (choice, total_pulls, created, warm) = {
            let mut shard = self.store.write_shard(shard_i);
            let (session, created) =
                match self.store.get_or_create(&mut shard, id, pk.alpha, pk.beta, k) {
                    Ok(x) => x,
                    Err(e) => return out.error(500, &e),
                };
            session.suggests += 1;
            // Warm-started sessions are born with prior pulls.
            let warm = created && session.tuner.total_pulls() > 0.0;
            let choice = session.tuner.select_traced();
            (choice, session.tuner.total_pulls(), created, warm)
        };
        let arm = choice.arm;
        if created {
            self.metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
            self.recorder.record(
                EventKind::SessionCreate,
                id.0 as u64,
                k as u64,
                warm as u64 | (pk.policy.code() as u64) << 8,
            );
        }
        let (a, b, c) = obs::pack_suggest(
            id.0,
            arm as u32,
            choice.gap,
            choice.explore,
            pk.policy.code(),
            total_pulls as u64,
        );
        self.recorder.record(EventKind::Suggest, a, b, c);
        self.metrics.suggests.fetch_add(1, Ordering::Relaxed);
        self.apps.describe_into(pk.app, arm, &mut out.scratch);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("arm", arm as f64);
        w.field_str("config", &out.scratch);
        w.field_num("shard", shard_i as f64);
        w.field_num("total_pulls", total_pulls);
        w.end_obj();
        self.metrics.suggest_latency.observe(t0.elapsed());
    }

    fn report(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        let p = Params::Body(body);
        let pk = match self.parse_key(&p) {
            Ok(x) => x,
            Err(e) => return out.error(400, &e),
        };
        // Strict arm conversion: negative, fractional or oversized
        // numbers are rejected instead of silently truncated.
        let arm = match body.get("arm").and_then(|v| v.as_usize()) {
            Some(a) => a,
            None => return out.error(400, "missing/invalid arm"),
        };
        let (time_s, power_w) = match (
            body.get("time_s").and_then(|v| v.as_f64()),
            body.get("power_w").and_then(|v| v.as_f64()),
        ) {
            (Some(t), Some(p)) if t.is_finite() && t > 0.0 && p.is_finite() && p >= 0.0 => (t, p),
            _ => return out.error(400, "missing/invalid time_s or power_w"),
        };
        // Optional client sequence number: when present, duplicate and
        // reordered deliveries inside the per-session window are absorbed
        // by the shard updater instead of double-counting the reward.
        let seq = match body.get("seq") {
            None => None,
            Some(v) => match v.as_usize() {
                Some(s) => Some(s as u64),
                None => return out.error(400, "invalid seq (expect a non-negative integer)"),
            },
        };
        let kref = pk.key_ref();
        let hash = kref.hash64();
        let id = self.store.intern(&kref, hash);
        let shard_i = self.store.shard_of_hash(hash);
        let report = Report {
            id,
            app: pk.app,
            alpha: pk.alpha,
            beta: pk.beta,
            arm,
            time_s,
            power_w,
            seq,
        };
        match self.ingest.enqueue(shard_i, report, &self.metrics) {
            Ok(Enqueue::Queued) => {
                self.metrics.reports_enqueued.fetch_add(1, Ordering::Relaxed);
                out.set_status(202);
                let mut w = JsonWriter::new(&mut out.body);
                w.begin_obj();
                w.field_bool("queued", true);
                w.field_num("shard", shard_i as f64);
                w.end_obj();
            }
            Ok(Enqueue::Dropped) => out.error(503, "report queue full"),
            Err(e) => out.error(503, &e),
        }
        self.metrics.report_latency.observe(t0.elapsed());
    }

    /// Shared validation for both batch endpoints: parse the `entries`
    /// array, reject malformed or ambiguous input *atomically* (every
    /// entry is validated before any session state changes, so a 4xx
    /// means nothing was applied), and resolve each entry to its
    /// interned session id. `with_report` additionally requires the
    /// measurement fields. On success the arena holds the entry plans
    /// and the shard-grouped visit order; returns the entry count.
    fn parse_batch(
        &self,
        body: &JsonSlice<'_>,
        with_report: bool,
        arena: &mut BatchArena,
    ) -> std::result::Result<usize, (u16, String)> {
        // Duplicate keys are grammatical JSON but ambiguous (`get`
        // returns the first occurrence, tree parsers keep the last):
        // reject instead of guessing which value the client meant.
        if body.has_duplicate_keys() {
            return Err((400, "duplicate keys in request object".to_string()));
        }
        let entries_v = match body.get("entries") {
            Some(v) if v.is_arr() => v,
            Some(_) => return Err((400, "entries must be an array".to_string())),
            None => return Err((400, "missing entries array".to_string())),
        };
        arena.entries.clear();
        for (i, entry) in entries_v.items().enumerate() {
            if arena.entries.len() >= MAX_BATCH_ENTRIES {
                return Err((400, format!("too many entries (max {MAX_BATCH_ENTRIES})")));
            }
            if !entry.is_obj() {
                return Err((400, format!("entry {i}: not an object")));
            }
            if entry.has_duplicate_keys() {
                return Err((400, format!("entry {i}: duplicate keys")));
            }
            let p = Params::Body(entry);
            let pk = self.parse_key(&p).map_err(|e| (400, format!("entry {i}: {e}")))?;
            let mut plan = EntryPlan {
                id: SessionId(0),
                shard: 0,
                app: pk.app,
                policy: pk.policy,
                alpha: pk.alpha,
                beta: pk.beta,
                arm: 0,
                time_s: 0.0,
                power_w: 0.0,
                seq: None,
            };
            if with_report {
                // Same strictness as the single-report path: arm range is
                // checked at apply time (`Tuner::observe`), everything
                // else here.
                plan.arm = match entry.get("arm").and_then(|v| v.as_usize()) {
                    Some(a) => a,
                    None => return Err((400, format!("entry {i}: missing/invalid arm"))),
                };
                (plan.time_s, plan.power_w) = match (
                    entry.get("time_s").and_then(|v| v.as_f64()),
                    entry.get("power_w").and_then(|v| v.as_f64()),
                ) {
                    (Some(t), Some(pw))
                        if t.is_finite() && t > 0.0 && pw.is_finite() && pw >= 0.0 =>
                    {
                        (t, pw)
                    }
                    _ => {
                        return Err((
                            400,
                            format!("entry {i}: missing/invalid time_s or power_w"),
                        ))
                    }
                };
                plan.seq = match entry.get("seq") {
                    None => None,
                    Some(v) => match v.as_usize() {
                        Some(s) => Some(s as u64),
                        None => {
                            return Err((
                                400,
                                format!("entry {i}: invalid seq (expect a non-negative integer)"),
                            ))
                        }
                    },
                };
            }
            let kref = pk.key_ref();
            let hash = kref.hash64();
            plan.id = self.store.intern(&kref, hash);
            plan.shard = self.store.shard_of_hash(hash) as u32;
            arena.entries.push(plan);
        }
        if arena.entries.is_empty() {
            return Err((400, "empty batch".to_string()));
        }
        // Shard-grouped visit order: each shard lock is taken once per
        // batch. `sort_unstable` on a (shard, arrival) key keeps a
        // session's entries in client order within its shard.
        arena.order.clear();
        arena.order.extend(0..arena.entries.len() as u32);
        let entries = &arena.entries;
        arena
            .order
            .sort_unstable_by_key(|&i| ((entries[i as usize].shard as u64) << 32) | i as u64);
        Ok(arena.entries.len())
    }

    /// `POST /v1/suggest/batch`: many suggests in one request. Entries
    /// are validated as a unit (any bad entry rejects the whole batch
    /// with 400 and no state change), grouped by shard so each shard
    /// write lock is taken once, and scored through one shared bandit
    /// scratch. Results come back in entry order.
    fn suggest_batch(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        BATCH_ARENA.with(|cell| {
            let arena = &mut *cell.borrow_mut();
            let n = match self.parse_batch(&body, false, arena) {
                Ok(n) => n,
                Err((code, e)) => return out.error(code, &e),
            };
            arena.choices.clear();
            arena.choices.resize(n, ChoiceSlot::default());
            let BatchArena { entries, order, scratch, choices, .. } = arena;
            let mut pos = 0usize;
            while pos < order.len() {
                let shard_i = entries[order[pos] as usize].shard as usize;
                let mut shard = self.store.write_shard(shard_i);
                while pos < order.len()
                    && entries[order[pos] as usize].shard as usize == shard_i
                {
                    let idx = order[pos] as usize;
                    let e = &entries[idx];
                    let k = self.apps.arms(e.app);
                    let (session, created) =
                        match self.store.get_or_create(&mut shard, e.id, e.alpha, e.beta, k) {
                            Ok(x) => x,
                            Err(err) => return out.error(500, &err),
                        };
                    session.suggests += 1;
                    let warm = created && session.tuner.total_pulls() > 0.0;
                    let choice = session.tuner.select_traced_in(scratch);
                    let total_pulls = session.tuner.total_pulls();
                    if created {
                        self.metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
                        self.recorder.record(
                            EventKind::SessionCreate,
                            e.id.0 as u64,
                            k as u64,
                            warm as u64 | (e.policy.code() as u64) << 8,
                        );
                    }
                    let (a, b, c) = obs::pack_suggest(
                        e.id.0,
                        choice.arm as u32,
                        choice.gap,
                        choice.explore,
                        e.policy.code(),
                        total_pulls as u64,
                    );
                    self.recorder.record(EventKind::Suggest, a, b, c);
                    self.metrics.suggests.fetch_add(1, Ordering::Relaxed);
                    choices[idx] = ChoiceSlot { arm: choice.arm, total_pulls };
                    pos += 1;
                }
            }
            self.metrics.batch_size.observe(n as u64);
            let mut w = JsonWriter::new(&mut out.body);
            w.begin_obj();
            w.field_num("count", n as f64);
            w.key("results");
            w.begin_arr();
            for (i, e) in entries.iter().enumerate() {
                out.scratch.clear();
                self.apps.describe_into(e.app, choices[i].arm, &mut out.scratch);
                w.begin_obj();
                w.field_num("arm", choices[i].arm as f64);
                w.field_str("config", &out.scratch);
                w.field_num("shard", e.shard as f64);
                w.field_num("total_pulls", choices[i].total_pulls);
                w.end_obj();
            }
            w.end_arr();
            w.end_obj();
            self.metrics.suggest_latency.observe(t0.elapsed());
        })
    }

    /// `POST /v1/report/batch`: many reports in one request. Validation
    /// is all-or-nothing (400, nothing enqueued); *enqueueing* is
    /// per-entry — an entry hitting a full shard queue is dropped and
    /// counted individually (`lasp_serve_reports_dropped_total`, status
    /// `"dropped"` in the response) while its neighbors proceed, so one
    /// saturated shard degrades entries, never whole batches. Always 202
    /// once validation passes; per-entry outcomes ride in `results`.
    fn report_batch(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        BATCH_ARENA.with(|cell| {
            let arena = &mut *cell.borrow_mut();
            let n = match self.parse_batch(&body, true, arena) {
                Ok(n) => n,
                Err((code, e)) => return out.error(code, &e),
            };
            let BatchArena { entries, order, reports, grouped, statuses, .. } = arena;
            statuses.clear();
            statuses.resize(n, Enqueue::Dropped);
            grouped.clear();
            let mut pos = 0usize;
            while pos < order.len() {
                let shard_i = entries[order[pos] as usize].shard as usize;
                let run_start = pos;
                reports.clear();
                while pos < order.len()
                    && entries[order[pos] as usize].shard as usize == shard_i
                {
                    let e = &entries[order[pos] as usize];
                    reports.push(Report {
                        id: e.id,
                        app: e.app,
                        alpha: e.alpha,
                        beta: e.beta,
                        arm: e.arm,
                        time_s: e.time_s,
                        power_w: e.power_w,
                        seq: e.seq,
                    });
                    pos += 1;
                }
                let base = grouped.len();
                if let Err(e) = self.ingest.enqueue_group(shard_i, reports, &self.metrics, grouped)
                {
                    return out.error(503, &e);
                }
                for (j, &idx) in order[run_start..pos].iter().enumerate() {
                    statuses[idx as usize] = grouped[base + j];
                }
            }
            let queued = statuses.iter().filter(|&&s| s == Enqueue::Queued).count();
            self.metrics.reports_enqueued.fetch_add(queued as u64, Ordering::Relaxed);
            self.metrics.batch_size.observe(n as u64);
            out.set_status(202);
            let mut w = JsonWriter::new(&mut out.body);
            w.begin_obj();
            w.field_num("queued", queued as f64);
            w.field_num("dropped", (n - queued) as f64);
            w.key("results");
            w.begin_arr();
            for (i, e) in entries.iter().enumerate() {
                w.begin_obj();
                w.field_str(
                    "status",
                    match statuses[i] {
                        Enqueue::Queued => "queued",
                        Enqueue::Dropped => "dropped",
                    },
                );
                w.field_num("shard", e.shard as f64);
                w.end_obj();
            }
            w.end_arr();
            w.end_obj();
            self.metrics.report_latency.observe(t0.elapsed());
        })
    }

    fn best(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let p = Params::Query(req.query);
        let pk = match self.parse_key(&p) {
            Ok(x) => x,
            Err(e) => return out.error(400, &e),
        };
        let kref = pk.key_ref();
        let hash = kref.hash64();
        // Read-only surface: never interns, never takes a write lock.
        let Some(id) = self.store.lookup(&kref, hash) else {
            return out.error(404, "unknown session");
        };
        let shard_i = self.store.shard_of_hash(hash);
        let shard = self.store.read_shard(shard_i);
        let Some(session) = shard.sessions.get(&id.0) else {
            return out.error(404, "unknown session");
        };
        let best = session.tuner.most_selected();
        self.apps.describe_into(pk.app, best, &mut out.scratch);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("arm", best as f64);
        w.field_str("config", &out.scratch);
        w.field_num("pulls_of_best", session.tuner.counts()[best]);
        w.field_num("total_pulls", session.tuner.total_pulls());
        w.field_num("suggests", session.suggests as f64);
        w.field_num("reports", session.reports as f64);
        w.field_str("policy", session.tuner.name());
        if let Some((mean_t, mean_p)) = session.tuner.mean_of(best) {
            w.field_num("mean_time_s", mean_t);
            w.field_num("mean_power_w", mean_p);
        }
        w.end_obj();
        drop(shard);
        self.metrics.best_latency.observe(t0.elapsed());
    }

    fn checkpoint_now(&self, out: &mut ResponseBuf) {
        let Some(dir) = &self.cfg.checkpoint_dir else {
            return out.error(400, "no checkpoint_dir configured");
        };
        let t0 = Instant::now();
        match checkpoint::snapshot_with(
            &self.store,
            dir,
            self.chaos.as_deref(),
            Some(&self.metrics.checkpoint_failures),
        ) {
            Ok(n) => {
                let took = t0.elapsed();
                self.metrics.checkpoints.fetch_add(1, Ordering::Relaxed);
                self.metrics.checkpoint_sessions.fetch_add(n as u64, Ordering::Relaxed);
                self.metrics.checkpoint_latency.observe(took);
                self.recorder.record(
                    EventKind::Checkpoint,
                    n as u64,
                    took.as_micros() as u64,
                    0,
                );
                let mut w = JsonWriter::new(&mut out.body);
                w.begin_obj();
                w.field_num("sessions", n as f64);
                w.end_obj();
            }
            Err(e) => out.error(500, &format!("{e:#}")),
        }
    }

    /// Read the mandatory `node_id` off a sync request body.
    fn sync_node_id<'a>(body: &JsonSlice<'a>) -> std::result::Result<Cow<'a, str>, String> {
        match body.get("node_id").and_then(|v| v.as_str()) {
            Some(id) if !id.is_empty() => Ok(id),
            _ => Err("missing node_id".to_string()),
        }
    }

    /// `POST /v1/sync/push`: store a peer's snapshots under its node id
    /// (replace semantics — repeated pushes are idempotent), then refresh
    /// this node's own warm-start priors from everything remote.
    fn sync_push(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        let node_id = match Self::sync_node_id(&body) {
            Ok(id) => id,
            Err(e) => return out.error(400, &e),
        };
        if node_id.as_ref() == self.node_id.as_str() {
            // A leader flag pointing a node at itself would echo its own
            // statistics back as "remote" evidence; refuse loudly.
            return out.error(400, "node cannot sync with itself (check --leader)");
        }
        let snaps_v = match body.get("snapshots") {
            Some(v) if v.is_arr() => v,
            _ => return out.error(400, "missing snapshots array"),
        };
        let mut snapshots = Vec::new();
        for item in snaps_v.items() {
            match FleetSnapshot::from_slice(&item) {
                Ok(s) => snapshots.push(s),
                Err(e) => return out.error(400, &format!("bad snapshot: {e}")),
            }
        }
        let accepted = self.fleet.absorb(node_id.as_ref(), snapshots);
        self.metrics
            .fleet_push_snapshots
            .fetch_add(accepted as u64, Ordering::Relaxed);
        // Pushes teach this node something: refresh the local warm-start
        // priors from the full remote merge — throttled, since the merge
        // scans every node slot and back-to-back pushes barely change
        // it. (Local sessions are not folded in — they already hold
        // their own evidence.)
        let refresh_due = {
            let mut last = match self.prior_refresh.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            match *last {
                Some(t) if t.elapsed() < PRIOR_REFRESH_MIN => false,
                _ => {
                    *last = Some(Instant::now());
                    true
                }
            }
        };
        if refresh_due {
            let merged = self.fleet.merged(None, None);
            fleet::install_priors(&merged, &self.store, &self.apps);
        }
        let nodes = self.fleet.node_count();
        self.recorder
            .record(EventKind::FleetMerge, accepted as u64, nodes as u64, 0);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("accepted", accepted as f64);
        w.field_num("nodes", nodes as f64);
        w.end_obj();
        self.metrics.sync_push_latency.observe(t0.elapsed());
    }

    /// The node's local aggregate, recomputed at most once per
    /// `PRIOR_REFRESH_MIN` (concurrent pulls share one scan; holding the
    /// cache lock across the scan prevents a stampede).
    fn cached_local_aggregate(&self) -> Arc<Vec<FleetSnapshot>> {
        let mut guard = match self.local_agg.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if let Some((at, snaps)) = guard.as_ref() {
            if at.elapsed() < PRIOR_REFRESH_MIN {
                return snaps.clone();
            }
        }
        let fresh = Arc::new(fleet::aggregate_local(&self.store));
        *guard = Some((Instant::now(), fresh.clone()));
        fresh
    }

    /// `POST /v1/sync/pull`: serve the discount-merged knowledge of every
    /// other node plus this node's (lightly cached) local aggregate.
    fn sync_pull(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        let node_id = match Self::sync_node_id(&body) {
            Ok(id) => id,
            Err(e) => return out.error(400, &e),
        };
        let local = self.cached_local_aggregate();
        let merged = self
            .fleet
            .merged(Some(node_id.as_ref()), Some((self.node_id.as_str(), local.as_slice())));
        self.metrics.fleet_pulls_served.fetch_add(1, Ordering::Relaxed);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_str("node_id", &self.node_id);
        w.key("snapshots");
        w.begin_arr();
        for s in &merged {
            s.write_json(&mut w);
        }
        w.end_arr();
        w.end_obj();
        self.metrics.sync_pull_latency.observe(t0.elapsed());
    }

    /// `GET /v1/trace?since=<seq>&limit=<n>`: drain flight-recorder
    /// events with `seq >= since` as decoded JSON. Cold path — may
    /// allocate. `next_since` is the cursor to resume from; a jump in
    /// `seq` between drains marks ring overwrites (`overwritten` counts
    /// them globally).
    fn trace(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let p = Params::Query(req.query);
        let since = match p.get_f64("since") {
            Ok(v) => v.unwrap_or(0.0) as u64,
            Err(e) => return out.error(400, &e),
        };
        let limit = match p.get_f64("limit") {
            Ok(Some(v)) if v >= 1.0 => (v as usize).min(65_536),
            Ok(Some(_)) => return out.error(400, "limit must be >= 1"),
            Ok(None) => 4096,
            Err(e) => return out.error(400, &e),
        };
        let mut events = Vec::new();
        self.recorder.drain_since(since, &mut events);
        let truncated = events.len() > limit;
        events.truncate(limit);
        let next_since = events.last().map_or(since, |e| e.seq + 1);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("next_since", next_since as f64);
        w.field_num("recorded", self.recorder.recorded() as f64);
        w.field_num("overwritten", self.recorder.overwritten() as f64);
        w.field_str(
            "fleet_state",
            fleet_state_name(self.metrics.fleet_state.load(Ordering::Relaxed)),
        );
        w.field_bool("truncated", truncated);
        w.key("events");
        w.begin_arr();
        for e in &events {
            obs::write_event_json(e, &mut w);
        }
        w.end_arr();
        w.end_obj();
    }

    /// `GET /v1/debug/session?...`: full per-session arm statistics for
    /// one session (same query key as `/v1/best`). Read-only; emits
    /// every pulled arm (capped by `limit`, default 512, index order)
    /// with pull counts and mean measurements, plus a regret-vs-best
    /// proxy: Σ pulls·(weighted cost − best weighted cost) over pulled
    /// arms, using the session's α/β objective weights.
    fn debug_session(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let p = Params::Query(req.query);
        let pk = match self.parse_key(&p) {
            Ok(x) => x,
            Err(e) => return out.error(400, &e),
        };
        let limit = match p.get_f64("limit") {
            Ok(v) => v.map_or(512, |x| x as usize).max(1),
            Err(e) => return out.error(400, &e),
        };
        let kref = pk.key_ref();
        let hash = kref.hash64();
        let Some(id) = self.store.lookup(&kref, hash) else {
            return out.error(404, "unknown session");
        };
        let shard_i = self.store.shard_of_hash(hash);
        let shard = self.store.read_shard(shard_i);
        let Some(session) = shard.sessions.get(&id.0) else {
            return out.error(404, "unknown session");
        };
        let tuner = &session.tuner;
        let counts = tuner.counts();
        let cost = |t: f64, p: f64| session.alpha * t + session.beta * p;
        // Current-best weighted cost among pulled arms — the proxy's
        // reference point (the tuner's live belief, not ground truth).
        let mut best_cost = f64::INFINITY;
        for (arm, &n) in counts.iter().enumerate() {
            if n > 0.0 {
                if let Some((mt, mp)) = tuner.mean_of(arm) {
                    best_cost = best_cost.min(cost(mt, mp));
                }
            }
        }
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("session", id.0 as f64);
        w.field_str("policy", tuner.name());
        w.field_num("policy_code", pk.policy.code() as f64);
        w.field_num("k", tuner.k() as f64);
        w.field_num("total_pulls", tuner.total_pulls());
        w.field_num("suggests", session.suggests as f64);
        w.field_num("reports", session.reports as f64);
        w.field_num("alpha", session.alpha);
        w.field_num("beta", session.beta);
        let best = tuner.most_selected();
        w.field_num("best_arm", best as f64);
        if let Some((mt, mp)) = tuner.mean_of(best) {
            w.field_num("best_mean_time_s", mt);
            w.field_num("best_mean_power_w", mp);
        }
        // Policy internals worth surfacing beyond the shared core.
        if let Tuner::Subset(t) = tuner {
            w.field_num("candidates", t.candidates().len() as f64);
        }
        let mut regret = 0.0;
        let mut emitted = 0usize;
        let mut pulled = 0usize;
        w.key("arms");
        w.begin_arr();
        for (arm, &n) in counts.iter().enumerate() {
            if n <= 0.0 {
                continue;
            }
            pulled += 1;
            let Some((mt, mp)) = tuner.mean_of(arm) else {
                continue;
            };
            if best_cost.is_finite() {
                regret += n * (cost(mt, mp) - best_cost);
            }
            if emitted < limit {
                emitted += 1;
                w.begin_obj();
                w.field_num("arm", arm as f64);
                w.field_num("pulls", n);
                w.field_num("mean_time_s", mt);
                w.field_num("mean_power_w", mp);
                w.end_obj();
            }
        }
        w.end_arr();
        w.field_num("arms_pulled", pulled as f64);
        w.field_bool("arms_truncated", pulled > emitted);
        w.field_num("regret_vs_best_proxy", regret);
        w.end_obj();
        drop(shard);
    }

    fn healthz(&self, out: &mut ResponseBuf) {
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_bool("ok", true);
        w.field_num("uptime_s", self.metrics.uptime_s());
        w.field_num("sessions", self.store.session_count() as f64);
        w.field_num("shards", self.store.num_shards() as f64);
        w.end_obj();
    }

    fn metrics_page(&self, out: &mut ResponseBuf) {
        let resources = {
            let mut tracker = match self.tracker.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            tracker.sample();
            tracker.report()
        };
        let fleet = FleetGauges {
            nodes: self.fleet.node_count(),
            prior_keys: self.store.fleet_prior_keys(),
            warm_starts: self.store.fleet_warm_starts(),
        };
        let trace = TraceGauges {
            recorded: self.recorder.recorded(),
            overwritten: self.recorder.overwritten(),
        };
        let chaos = ChaosGauges {
            enabled: self.chaos.is_some(),
            injections: self.chaos.as_ref().map_or(0, |c| c.injections()),
        };
        let body = self.metrics.render(
            self.store.session_count(),
            self.store.num_shards(),
            &self.transport,
            &resources,
            fleet,
            trace,
            chaos,
        );
        out.text(200, &body);
    }
}

/// A running server. Dropping the handle leaks the threads; call
/// [`ServerHandle::shutdown`] for an orderly stop (drains report queues,
/// writes a final checkpoint) or [`ServerHandle::wait`] to park forever.
pub struct ServerHandle {
    addr: SocketAddr,
    http: HttpServer,
    service: Arc<TuningService>,
    stop_checkpointer: Arc<AtomicBool>,
    checkpointer: Option<JoinHandle<()>>,
    fleet_sync: Option<FleetSync>,
    trace_writer: Option<TraceWriter>,
    restored: usize,
}

impl ServerHandle {
    /// The bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// This node's identity on the fleet-sync wire.
    pub fn node_id(&self) -> &str {
        &self.service.node_id
    }

    /// Sessions warm-started from the checkpoint directory at boot.
    pub fn restored_sessions(&self) -> usize {
        self.restored
    }

    /// Transport counters (connections, requests, alloc events) — the
    /// perf baseline reads these to certify the zero-allocation path.
    pub fn transport_stats(&self) -> Arc<TransportStats> {
        self.service.transport.clone()
    }

    /// Scratch-buffer growth events across every live session's bandit
    /// core — the bandit-layer counterpart of
    /// [`TransportStats::alloc_events`]: flat in steady state, so the
    /// end-to-end zero-allocation assertion covers the policy layer too.
    pub fn bandit_scratch_growths(&self) -> u64 {
        self.service.store.scratch_growth_total()
    }

    /// The server's flight recorder (tests and embedding tools drain it
    /// directly; HTTP clients use `GET /v1/trace`).
    pub fn recorder(&self) -> Arc<Recorder> {
        self.service.recorder.clone()
    }

    /// Orderly shutdown: stop fleet sync and HTTP, drain report queues,
    /// final snapshot.
    pub fn shutdown(mut self) -> Result<()> {
        if let Some(mut sync) = self.fleet_sync.take() {
            sync.stop();
        }
        self.http.stop();
        self.service.ingest.stop();
        self.stop_checkpointer.store(true, Ordering::SeqCst);
        if let Some(h) = self.checkpointer {
            let _ = h.join();
        }
        // Final ring drain + flush to the binary trace file.
        if let Some(mut tw) = self.trace_writer.take() {
            tw.stop();
        }
        if let Some(dir) = &self.service.cfg.checkpoint_dir {
            checkpoint::snapshot(&self.service.store, dir)
                .context("final shutdown checkpoint")?;
        }
        Ok(())
    }

    /// Block the calling thread for the life of the server (CLI mode).
    pub fn wait(self) {
        self.http.join();
    }
}

/// Boot the service: restore checkpoints, start ingest, bind, serve,
/// and (when a leader is configured) start the fleet-sync thread.
pub fn start(cfg: ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    let store = Arc::new(
        ShardedStore::new(cfg.shards).with_fleet_tuning(cfg.fleet_retain, cfg.fleet_half_life),
    );
    let apps = Arc::new(AppsCache::new());
    let metrics = Arc::new(Metrics::new());
    let transport = Arc::new(TransportStats::default());
    let fleet = Arc::new(FleetStore::new(cfg.fleet_half_life));

    let mut restored = 0;
    if let Some(dir) = &cfg.checkpoint_dir {
        restored = checkpoint::restore(&store, &apps, dir, cfg.warm_retain)?;
        metrics.sessions_restored.fetch_add(restored as u64, Ordering::Relaxed);
    }

    // Bind before constructing the service: the node's default sync
    // identity is derived from the resolved (ephemeral ports included)
    // bound address.
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let bound = listener.local_addr().context("resolving bound address")?;
    let node_id = cfg
        .node_id
        .clone()
        .unwrap_or_else(|| format!("node-{bound}"));

    let recorder = Arc::new(Recorder::for_workers(cfg.effective_threads()));
    let trace_writer = match &cfg.trace_file {
        Some(path) => Some(TraceWriter::start(recorder.clone(), path.clone())?),
        None => None,
    };
    // The chaos layer is built once and shared by every injection
    // surface; `None` keeps each surface's hot path a plain branch.
    let chaos = cfg
        .chaos
        .clone()
        .map(|c| Arc::new(ChaosLayer::new(c, recorder.clone())));
    let ingest = BatchIngest::start(
        store.clone(),
        apps.clone(),
        metrics.clone(),
        recorder.clone(),
        cfg.queue_cap,
        cfg.max_batch,
        chaos.clone(),
    );
    let service = Arc::new(TuningService {
        cfg: cfg.clone(),
        store: store.clone(),
        apps: apps.clone(),
        ingest,
        metrics: metrics.clone(),
        transport: transport.clone(),
        tracker: Mutex::new(ResourceTracker::start()),
        fleet,
        node_id: node_id.clone(),
        prior_refresh: Mutex::new(None),
        local_agg: Mutex::new(None),
        recorder: recorder.clone(),
        chaos: chaos.clone(),
    });

    let handler: HttpHandler = {
        let service = service.clone();
        Arc::new(move |req: &Request<'_>, out: &mut ResponseBuf| service.handle(req, out))
    };
    let http = HttpServer::start_with_opts(
        listener,
        handler,
        TransportOptions {
            kind: cfg.transport,
            threads: cfg.effective_threads(),
            stats: transport,
            chaos: chaos.clone(),
            recorder: Some(recorder.clone()),
        },
    )?;
    let addr = http.addr();

    // Follower plane: periodic push/pull against the configured leader.
    // Best-effort by design — an unreachable leader leaves the node
    // serving standalone and only bumps `fleet_sync_errors_total`.
    let fleet_sync = cfg.leader.clone().map(|leader| {
        FleetSync::start(
            FleetSyncConfig {
                leader,
                node_id,
                every: cfg.sync_every,
            },
            store.clone(),
            apps.clone(),
            metrics.clone(),
            recorder.clone(),
            chaos.clone(),
        )
    });

    // Periodic checkpointer (only when a directory is configured).
    let stop_checkpointer = Arc::new(AtomicBool::new(false));
    let checkpointer = cfg.checkpoint_dir.clone().map(|dir| {
        let store = store.clone();
        let metrics = metrics.clone();
        let recorder = recorder.clone();
        let stop = stop_checkpointer.clone();
        let every = cfg.checkpoint_every;
        let chaos = chaos.clone();
        std::thread::spawn(move || {
            let mut last = Instant::now();
            loop {
                std::thread::sleep(Duration::from_millis(100));
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if last.elapsed() >= every {
                    let t0 = Instant::now();
                    if let Ok(n) = checkpoint::snapshot_with(
                        &store,
                        &dir,
                        chaos.as_deref(),
                        Some(&metrics.checkpoint_failures),
                    ) {
                        let took = t0.elapsed();
                        metrics.checkpoints.fetch_add(1, Ordering::Relaxed);
                        metrics.checkpoint_sessions.fetch_add(n as u64, Ordering::Relaxed);
                        metrics.checkpoint_latency.observe(took);
                        recorder.record(
                            EventKind::Checkpoint,
                            n as u64,
                            took.as_micros() as u64,
                            0,
                        );
                    }
                    last = Instant::now();
                }
            }
        })
    });

    Ok(ServerHandle {
        addr,
        http,
        service,
        stop_checkpointer,
        checkpointer,
        fleet_sync,
        trace_writer,
        restored,
    })
}
